"""Defragmentation subsystem: fragmentation-aware placement + consolidation.

Replays seeded Poisson traces with a *bimodal* request mix — small
interactive jobs (k in 2..6) that fragment the hosts, and large training
jobs (k in 8..16) that pay for it with rail-contended cross-host
placements — through the Ideal-BP dispatcher (ground-truth predictor: no
surrogate training, so this doubles as the CI smoke for the defrag
plumbing), with the subsystem off vs on:

  * ``off`` — ``SchedulerConfig(policy="fifo")``, ``frag_weight=0``:
    bit-identical to the PR 3 scheduler (golden-pinned in
    ``tests/test_defrag.py``);
  * ``on``  — ``SchedulerConfig(defrag=True)`` (background consolidation
    pass + on-demand make-room pass, migration budget
    ``DEFRAG_BUDGET``) and the fragmentation-aware placement tie-break
    (``frag_weight=0.02``).

Reported per cluster, averaged over ``BENCH_DEFRAG_SEEDS`` seeded traces:
mean contention-degraded GBE (all arrivals and the k>=8 slice), mean
contended bandwidth of k>=8 arrivals, mean stranding at admit time, and
committed moves vs the budget.  Headline (the ISSUE 4 acceptance bar): on
H100 the large arrivals' mean contended bandwidth improves double-digit
GB/s at flat (ceiling) GBE; on Het-4Mix mean contention-degraded GBE
improves by points overall AND on the k>=8 slice; migrations never
exceed the budget and defrag=off stays bit-identical to PR 3.

Knobs: BENCH_TRACE_JOBS (default 60), BENCH_DEFRAG_SEEDS (default 4),
BENCH_DEFRAG_BUDGET (default 16).
"""

from __future__ import annotations

import os

import numpy as np

import repro.core as core
from benchmarks.common import csv_row

CLUSTERS = ("H100", "Het-4Mix")
N_JOBS = int(os.environ.get("BENCH_TRACE_JOBS", "60"))
N_SEEDS = int(os.environ.get("BENCH_DEFRAG_SEEDS", "4"))
DEFRAG_BUDGET = int(os.environ.get("BENCH_DEFRAG_BUDGET", "16"))
MEAN_INTERARRIVAL = 1.0
MEAN_DURATION = 8.0
K_MIX = (2, 2, 3, 4, 4, 6, 8, 12, 16)  # bimodal: fragmenters + sufferers
FRAG_WEIGHT = 0.02


def _metrics(records):
    big = [r for r in records if r.k >= 8]
    # the regime defrag exists for: large arrivals whose rails are shared
    big_cont = [r for r in big if r.n_contended_hosts > 0]

    def mean(vals):  # short traces may draw no k>=8 (or no contended) jobs
        return float(np.mean(vals)) if vals else float("nan")

    s = next(iter(core.summarize_trace(records).values()))
    return {
        "gbe": 100.0 * s["mean_gbe"],
        "gbe_k8": 100.0 * mean([r.gbe for r in big]),
        "gbe_k8_cont": 100.0 * mean([r.gbe for r in big_cont]),
        "bw_k8": mean([r.bw for r in big]),
        "stranding": s["mean_stranding"],
        "clean_hosts": s["mean_clean_hosts"],
        "wait": s["mean_wait"],
    }


def _replay(cluster, sim, tables, trace, config, frag_weight):
    disp = core.BandPilotDispatcher(
        cluster, tables, core.GroundTruthPredictor(sim),
        name="Ideal-BP", frag_weight=frag_weight,
    )
    sched = core.AdmissionScheduler(cluster, sim, tables, disp, config)
    records = sched.run(trace)
    return _metrics(records), sched


def run() -> list:
    rows = []
    for name in CLUSTERS:
        cluster = core.PAPER_CLUSTERS[name]()
        sim = core.BandwidthSimulator(cluster)
        tables = core.IntraHostTables(cluster, sim)
        offs, ons, moves = [], [], []
        for seed in range(N_SEEDS):
            trace = core.poisson_trace(
                cluster, N_JOBS, np.random.default_rng(seed),
                mean_interarrival=MEAN_INTERARRIVAL,
                mean_duration=MEAN_DURATION,
                k_choices=K_MIX,
            )
            off, _ = _replay(
                cluster, sim, tables, trace,
                core.SchedulerConfig(policy="fifo"), 0.0,
            )
            dcfg = core.DefragConfig(
                max_total_moves=DEFRAG_BUDGET, max_moves_per_pass=3,
                interval=2.0,
            )
            on, sched = _replay(
                cluster, sim, tables, trace,
                core.SchedulerConfig(
                    policy="fifo", defrag=True, defrag_config=dcfg
                ),
                FRAG_WEIGHT,
            )
            n_moves = len(sched.migrations)
            if n_moves > DEFRAG_BUDGET:
                raise AssertionError(
                    f"defrag exceeded its migration budget: "
                    f"{n_moves} > {DEFRAG_BUDGET}"
                )
            offs.append(off)
            ons.append(on)
            moves.append(n_moves)
        # nanmean: one seed with an empty slice must not erase the others
        # (all-nan — e.g. a tiny smoke trace with no contended k>=8 jobs —
        # stays nan and renders as n/a below)
        def agg(rows, key):
            vals = [r[key] for r in rows if not np.isnan(r[key])]
            return float(np.mean(vals)) if vals else float("nan")

        mo = {k: agg(offs, k) for k in offs[0]}
        mn = {k: agg(ons, k) for k in ons[0]}

        def pct(v):
            return "n/a" if np.isnan(v) else f"{v:.2f}%"

        def dpts(v):
            return "n/a" if np.isnan(v) else f"{v:+.2f}pts"

        def gbs(v, sign=""):
            return "n/a" if np.isnan(v) else f"{v:{sign}.1f}GB/s"
        for tag, s in (("off", mo), ("on", mn)):
            rows.append(csv_row(
                f"defrag_{name}_{tag}", 0.0,
                f"gbe={pct(s['gbe'])};gbe_k8={pct(s['gbe_k8'])};"
                f"gbe_k8_contended={pct(s['gbe_k8_cont'])};"
                f"bw_k8={gbs(s['bw_k8'])};stranding={s['stranding']:.3f};"
                f"clean_hosts={s['clean_hosts']:.2f}",
            ))
        rows.append(csv_row(
            f"defrag_{name}_on_vs_off", 0.0,
            f"gbe_delta={dpts(mn['gbe'] - mo['gbe'])};"
            f"gbe_k8_delta={dpts(mn['gbe_k8'] - mo['gbe_k8'])};"
            f"gbe_k8_contended_delta="
            f"{dpts(mn['gbe_k8_cont'] - mo['gbe_k8_cont'])};"
            f"bw_k8_delta={gbs(mn['bw_k8'] - mo['bw_k8'], '+')};"
            f"moves={int(np.sum(moves))}<=budget={DEFRAG_BUDGET * N_SEEDS};"
            f"seeds={N_SEEDS}",
        ))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run():
        print(row, flush=True)
