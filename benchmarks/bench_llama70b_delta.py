"""Appendix A: Llama-2 70B training-time impact of one dispatch decision.

Paper: 140 GB all-reduce per step; 412.49 vs 157.30 GB/s effective bandwidth
=> +0.55 s/step => ~3.2 days over 500k steps.  We recompute from *our*
simulator's Fig.-1 scenario bandwidths.
"""

from __future__ import annotations

import time

import repro.core as core
from benchmarks.common import csv_row

GRAD_GB = 140.0
STEPS = 500_000


def run() -> list:
    cluster = core.h100_cluster()
    sim = core.BandwidthSimulator(cluster)
    t0 = time.time()
    optimal = sim.true_bandwidth(list(range(0, 5)) + list(range(8, 13)))   # 5+5
    compact = sim.true_bandwidth(list(range(0, 8)) + list(range(8, 10)))   # 8+2
    per_step = GRAD_GB / compact - GRAD_GB / optimal
    days = per_step * STEPS / 86400.0
    us = (time.time() - t0) * 1e6
    return [csv_row(
        "appendixA_llama70b", us,
        f"bw_opt={optimal:.1f};bw_compact={compact:.1f};"
        f"delta_s_per_step={per_step:.3f};delta_days={days:.2f};paper=3.2days",
    )]
