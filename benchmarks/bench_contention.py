"""Sec. 4.4: multi-tenant contention — trace replay, aware vs oblivious.

Streams a seeded Poisson job trace (arrivals + exponential durations)
through the stateful dispatcher services on the H100 and Het-4Mix clusters
and reports mean *contention-degraded* GBE: every admission is graded with
``B(S | ledger) / B(S* | ledger)`` against the ledger-aware exact Oracle.

Headline: contention-aware BandPilot (virtual-merge fair-share rail
estimator) strictly beats the contention-oblivious variant on the same
trace, with the Ideal pair (ground-truth predictor) isolating the value of
the contention model from surrogate error.

Knobs: BENCH_TRACE_JOBS (default 40), BENCH_TRACE_SEED (default 0).
"""

from __future__ import annotations

import os

import numpy as np

import repro.core as core
from benchmarks.common import csv_row, get_context

CLUSTERS = ("H100", "Het-4Mix")
N_JOBS = int(os.environ.get("BENCH_TRACE_JOBS", "40"))
SEED = int(os.environ.get("BENCH_TRACE_SEED", "0"))
MEAN_INTERARRIVAL = 1.0
MEAN_DURATION = 8.0   # ~8 jobs in flight: cross-host placements contend


def _k_choices(cluster) -> range:
    # up to half the cluster: big enough to span hosts, small enough that
    # several jobs run concurrently
    return range(4, max(cluster.n_gpus // 2, 5) + 1)


def run() -> list:
    rows = []
    for name in CLUSTERS:
        ctx = get_context(name)
        cluster, sim, tables = ctx.cluster, ctx.sim, ctx.tables
        trace = core.poisson_trace(
            cluster, N_JOBS, np.random.default_rng(SEED),
            mean_interarrival=MEAN_INTERARRIVAL,
            mean_duration=MEAN_DURATION,
            k_choices=_k_choices(cluster),
        )
        results = core.compare_contention_awareness(
            cluster, sim, tables, lambda: ctx.predictor, trace, seed=SEED,
        )
        results.update(core.compare_contention_awareness(
            cluster, sim, tables,
            lambda: core.GroundTruthPredictor(sim), trace, seed=SEED,
            name="Ideal-BP", include_baselines=False,
        ))
        summaries = {
            disp: core.summarize_trace(recs)[disp]
            for disp, recs in results.items()
        }
        for disp, s in sorted(
            summaries.items(), key=lambda kv: -kv[1]["mean_gbe"]
        ):
            rows.append(csv_row(
                f"sec44_{name}_{disp}", 0.0,
                f"gbe={100 * s['mean_gbe']:.2f}%;"
                f"degr={100 * s['mean_degradation']:.1f}%;"
                f"contended={100 * s['frac_contended']:.0f}%;"
                f"wait={s['mean_wait']:.2f}",
            ))
        for pair in ("BandPilot", "Ideal-BP"):
            delta = 100 * (
                summaries[pair]["mean_gbe"]
                - summaries[f"{pair}-oblivious"]["mean_gbe"]
            )
            rows.append(csv_row(
                f"sec44_{name}_{pair}_aware_delta", 0.0, f"{delta:+.2f}pts"
            ))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run():
        print(row, flush=True)
