"""Fig. 9 (ablation 5.5.1): hierarchical vs naive monolithic surrogate.

Paper claim: at 250 samples the hierarchical model reaches R^2 > 0.95 while
the naive raw-identifier Transformer lags badly.
"""

from __future__ import annotations

import time

import repro.core as core
from benchmarks.common import SURROGATE_STEPS, csv_row


def run() -> list:
    rows = []
    cluster = core.PAPER_CLUSTERS["H100"]()
    sim = core.BandwidthSimulator(cluster)
    tables = core.IntraHostTables(cluster, sim)
    for n in (100, 250):
        train, test = core.make_train_test_split(sim, n, seed=0)
        results = {}
        for naive in (False, True):
            t0 = time.time()
            params, _ = core.train_surrogate(
                cluster, tables, train,
                core.TrainConfig(steps=SURROGATE_STEPS), naive=naive,
            )
            pred = core.SurrogatePredictor(cluster, tables, params, naive=naive)
            m = core.evaluate_surrogate(pred, test)
            results["naive" if naive else "hier"] = (m, time.time() - t0)
        (mh, th), (mn, tn) = results["hier"], results["naive"]
        rows.append(csv_row(
            f"fig9_n{n}", 1e6 * (th + tn),
            f"hier_r2={mh['r2']:.4f};naive_r2={mn['r2']:.4f};"
            f"hier_mape={mh['mape']:.1f}%;naive_mape={mn['mape']:.1f}%",
        ))
    return rows
