"""Fig. 8: search-time overhead breakdown (EHA vs PTS vs model inference).

Paper claim: total hybrid search stays well under 250 ms on the 32-GPU
cluster, dominated by cumulative surrogate inference in the PTS phase.

Extended (ISSUE 5) with the configurations the admission scheduler actually
runs: Het-4Mix rows and ``mode="learned"`` rows, each with the fast path's
per-phase breakdown — featurize / infer / contention-wrap / other — taken
from the unified :class:`repro.core.PredictorStats`.  The contended rows
search against a tenanted ledger (two live cross-host jobs), so the
contention wrapper and (in learned mode) the ContendedSurrogate are genuinely
on the hot path.  The fast path's job is to move the featurize share from
dominant to minor; these rows are where that is visible.
"""

from __future__ import annotations

import time

import numpy as np

import repro.core as core
from repro.core import search
from repro.core import surrogate as surr
from repro.core.predict_cache import collect_stats
from benchmarks.common import csv_row, get_context


def run() -> list:
    ctx = get_context("H100")
    rows = []
    worst_total = 0.0
    for k in (4, 8, 16, 24):
        avail = ctx.cluster.all_gpus()
        pred = ctx.predictor
        pred.predict_seconds = 0.0
        t0 = time.time()
        eha = search.eha_search(ctx.cluster, ctx.tables, pred, avail, k)
        pts = search.pts_search(ctx.cluster, ctx.tables, pred, avail, k)
        total = time.time() - t0
        worst_total = max(worst_total, total)
        rows.append(csv_row(
            f"fig8_search_k{k}", 1e6 * total,
            f"eha_ms={1e3 * eha.seconds:.1f};pts_ms={1e3 * pts.seconds:.1f};"
            f"predict_ms={1e3 * pred.predict_seconds:.1f};"
            f"n_eval={eha.n_candidates + pts.n_candidates}",
        ))
    rows.append(csv_row(
        "fig8_under_250ms", 1e6 * worst_total,
        f"worst_total_ms={1e3 * worst_total:.0f};claim=<250ms",
    ))

    # -- scheduler configurations: per-phase breakdown under tenancy --------
    for name in ("H100", "Het-4Mix"):
        cctx = get_context(name)
        cl, tables = cctx.cluster, cctx.tables
        cparams = surr.init_contended_params(cctx.params)
        for mode in ("analytic", "learned"):
            for k in (8, 16):
                ledger = core.JobLedger(cl)
                # two cross-host tenants: candidate rails genuinely shared
                ledger.admit("t0", [cl.hosts[0].gpu_ids[0],
                                    cl.hosts[1].gpu_ids[0]])
                ledger.admit("t1", [cl.hosts[-2].gpu_ids[1],
                                    cl.hosts[-1].gpu_ids[0]])
                iso = core.SurrogatePredictor(cl, tables, cctx.params)
                contended = (
                    core.ContendedSurrogatePredictor(cl, tables, cparams)
                    if mode == "learned" else None
                )
                avail = ledger.available()
                # unmeasured warm-up: JIT compilation of this config's
                # shape buckets is a once-per-process cost, not search
                # time.  The measured pass gets a FRESH prediction cache
                # (cold misses), only the compiled executables are reused.
                warm = core.cached_contention_predictor(
                    cl, iso, ledger, mode=mode, contended=contended,
                )
                search.eha_search(cl, tables, warm, avail, k)
                search.pts_search(cl, tables, warm, avail, k)
                iso.stats.reset()
                if contended is not None:
                    contended.stats.reset()
                pred = core.cached_contention_predictor(
                    cl, iso, ledger, mode=mode, contended=contended,
                )
                t0 = time.time()
                eha = search.eha_search(cl, tables, pred, avail, k)
                pts = search.pts_search(cl, tables, pred, avail, k)
                total = time.time() - t0
                st = collect_stats(pred, contended)
                other = max(
                    total - st.featurize_seconds - st.infer_seconds
                    - st.wrapper_seconds, 0.0,
                )
                rows.append(csv_row(
                    f"fig8_{name}_{mode}_k{k}", 1e6 * total,
                    f"eha_ms={1e3 * eha.seconds:.1f};"
                    f"pts_ms={1e3 * pts.seconds:.1f};"
                    f"feat_ms={1e3 * st.featurize_seconds:.1f};"
                    f"infer_ms={1e3 * st.infer_seconds:.1f};"
                    f"wrap_ms={1e3 * st.wrapper_seconds:.1f};"
                    f"other_ms={1e3 * other:.1f};"
                    f"feat_share={st.featurize_seconds / max(total, 1e-9):.2f};"
                    f"n_eval={eha.n_candidates + pts.n_candidates};"
                    f"hits={st.cache_hits}",
                ))
    return rows
