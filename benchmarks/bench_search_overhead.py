"""Fig. 8: search-time overhead breakdown (EHA vs PTS vs model inference).

Paper claim: total hybrid search stays well under 250 ms on the 32-GPU
cluster, dominated by cumulative surrogate inference in the PTS phase.
"""

from __future__ import annotations

import time

import numpy as np

import repro.core as core
from repro.core import search
from benchmarks.common import csv_row, get_context


def run() -> list:
    ctx = get_context("H100")
    rows = []
    worst_total = 0.0
    for k in (4, 8, 16, 24):
        avail = ctx.cluster.all_gpus()
        pred = ctx.predictor
        pred.predict_seconds = 0.0
        t0 = time.time()
        eha = search.eha_search(ctx.cluster, ctx.tables, pred, avail, k)
        pts = search.pts_search(ctx.cluster, ctx.tables, pred, avail, k)
        total = time.time() - t0
        worst_total = max(worst_total, total)
        rows.append(csv_row(
            f"fig8_search_k{k}", 1e6 * total,
            f"eha_ms={1e3 * eha.seconds:.1f};pts_ms={1e3 * pts.seconds:.1f};"
            f"predict_ms={1e3 * pred.predict_seconds:.1f};"
            f"n_eval={eha.n_candidates + pts.n_candidates}",
        ))
    rows.append(csv_row(
        "fig8_under_250ms", 1e6 * worst_total,
        f"worst_total_ms={1e3 * worst_total:.0f};claim=<250ms",
    ))
    return rows
