"""Table 3: one-time offline intra-host measurement cost (simulated clock).

The paper reports 503–1512 s per host type for the 255-combination sweep
(+1 warmup).  Real nccl-tests invocations cost ~2–6 s each depending on the
host's link speeds; our simulator charges each combination the same
size-dependent cost model and reports the resulting wall clock, alongside
the *actual* CPU time to build the tables (the simulator's cost).
"""

from __future__ import annotations

import time

import repro.core as core
from repro.core.cluster import HOST_TYPES
from benchmarks.common import csv_row

# seconds per nccl-tests all-gather @16MB, by host class (fit to Table 3)
_PER_MEASUREMENT_S = {
    "RTX4090": 2.0, "V100": 2.1, "A6000": 3.4, "A800": 5.9, "H100": 5.0,
}
PAPER_TABLE3 = {"RTX4090": 503, "V100": 534, "A6000": 866, "A800": 1512,
                "H100": 1288}


def run() -> list:
    rows = []
    for ht, per_s in _PER_MEASUREMENT_S.items():
        cluster = core.Cluster([(ht, 1)], name=f"bench-{ht}")
        sim = core.BandwidthSimulator(cluster)
        t0 = time.time()
        tables = core.IntraHostTables(cluster, sim)
        build_s = time.time() - t0
        simulated = tables.n_measurements * per_s
        rows.append(csv_row(
            f"table3_{ht}", 1e6 * build_s,
            f"simulated_s={simulated:.0f};paper_s={PAPER_TABLE3[ht]};"
            f"points={tables.n_measurements};"
            f"storage_kb={tables.storage_bytes() / 1024:.1f}",
        ))
    return rows
