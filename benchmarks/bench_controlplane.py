"""Concurrent-admission control plane (ISSUE 7): CAS throughput + recovery.

Drives wave-shaped admission storms through
:class:`repro.core.controlplane.AdmissionControlPlane` on H100 and
compares against the plain serialized ``dispatcher.admit`` loop: each
wave submits ~30 GPUs worth of k in {2..6} jobs through ``admit_many``
(every member searches against the same pinned ledger snapshot, so
waves maximize CAS contention), asserts the committed placements are
pairwise disjoint (the zero-double-allocation invariant), then releases
everything and starts the next wave.  Worker counts 1/4/8 are timed as
the best of ``BENCH_CPLANE_REPS`` repetitions after one untimed
warm-up pass per side (JIT shape compiles are process-wide and must not
land in a timed window; min-of-reps filters scheduler-quantum stalls a
shared 1-core runner inflicts on any single rep).

Scaling honesty: admission staging is GIL-bound Python around
GIL-releasing XLA applies.  On a multi-core host the w4/w1 ratio
reflects genuine overlap; on a 1-vCPU host there is no second core to
overlap onto and the ratio hovers at ~1x (conflict retries are the only
added work).  When the measured scaling misses the >1x target the
``cplane_scaling`` row documents that ceiling rather than hiding it,
mirroring ``dispatch_tput_target``.

Recovery: synthetic admit/release/migrate streams of increasing length
are journaled and replayed through ``replay_journal``; every replay is
asserted bit-identical (allocations + version counter) to the live
ledger that wrote the journal before its timing is reported.

Rows:
  cplane_tput_serial      — us per admission, plain dispatcher.admit loop
  cplane_tput_w{N}        — us per admission at N workers, notes = adm/s
                            + conflict/validated/serialized/parked counts
  cplane_scaling          — w4/w1 and w4/serial ratios, target >1x w4/w1,
                            zero-double-alloc flag, ceiling note when the
                            1-core GIL bound keeps the ratio at ~1x
  cplane_journal          — w4 with write-ahead journal attached: percent
                            overhead vs journal-off, replay checked
                            version-identical
  cplane_recovery         — replay_journal events/sec at each stream
                            length in BENCH_CPLANE_JOURNAL_EVENTS
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

import repro.core as core
from repro.core.controlplane import (
    AdmissionControlPlane,
    LedgerJournal,
    replay_journal,
)
from repro.core.tenancy import JobLedger
from benchmarks.common import csv_row, get_context

N_WAVES = int(os.environ.get("BENCH_CPLANE_WAVES", "6"))
N_REPS = int(os.environ.get("BENCH_CPLANE_REPS", "3"))
JOURNAL_EVENTS = tuple(
    int(s) for s in
    os.environ.get("BENCH_CPLANE_JOURNAL_EVENTS", "200,800,3200").split(",")
)
WORKERS = (1, 4, 8)
WAVE_GPU_CAP = 30  # of H100's 32: near-full waves force real contention


def _waves(rng):
    waves = []
    for _ in range(N_WAVES):
        wave, total = [], 0
        while True:
            k = int(rng.integers(2, 7))
            if total + k > WAVE_GPU_CAP:
                break
            wave.append(k)
            total += k
        waves.append(wave)
    return waves


def _dispatcher(ctx):
    pred = core.SurrogatePredictor(ctx.cluster, ctx.tables, ctx.params)
    return core.BandPilotDispatcher(
        ctx.cluster, ctx.tables, pred, aot_warm=False
    )


def _assert_disjoint(outcomes):
    taken = set()
    for out in outcomes:
        gpus = set(out.alloc.gpus)
        assert not (gpus & taken), (
            f"double allocation: {out.job_id} overlaps {gpus & taken}"
        )
        taken |= gpus


def _run_serial(ctx, waves):
    disp = _dispatcher(ctx)
    t0 = time.time()
    for wi, wave in enumerate(waves):
        ids = [f"s{wi}-{i}" for i in range(len(wave))]
        for jid, k in zip(ids, wave):
            disp.admit(jid, k)
        for jid in ids:
            disp.release(jid)
    return time.time() - t0, None


def _run_cplane(ctx, waves, n_workers, journal=None):
    disp = _dispatcher(ctx)
    cp = AdmissionControlPlane(disp, n_workers=n_workers, journal=journal)
    t0 = time.time()
    for wi, wave in enumerate(waves):
        outs = cp.admit_many(
            [(f"c{wi}-{i}", k, "") for i, k in enumerate(wave)],
            timeout=300,
        )
        assert all(o is not None and o.admitted for o in outs)
        _assert_disjoint(outs)
        for out in outs:
            cp.release(out.job_id)
    dt = time.time() - t0
    assert len(cp.ledger) == 0, "ledger failed to drain"
    stats = cp.stats.as_dict()
    version = cp.ledger.version
    cp.shutdown()
    return dt, (stats, version)


def _best_run(fn, *args, **kw):
    """Best-of-reps: a shared 1-core box can stall any single rep for
    whole scheduler quanta, and min() is the standard de-noiser for
    throughput microbenches (median still admits one stall at reps=2)."""
    times, last = [], None
    for _ in range(N_REPS):
        dt, extra = fn(*args, **kw)
        times.append(dt)
        last = extra
    return min(times), last


def _synthetic_journal(cluster, path, n_events, rng):
    """Journal ``n_events`` random admit/release/migrate ops; return the
    live ledger they produced (the replay oracle)."""
    ledger = JobLedger(cluster)
    ledger.attach_journal(LedgerJournal(path))
    live, uid = [], 0
    while ledger.version < n_events:
        free = sorted(ledger.available())
        op = int(rng.integers(3))
        if live and (op == 0 or not free):
            ledger.release(live.pop(int(rng.integers(len(live)))))
        elif live and op == 1 and len(free) >= 2:
            jid = live[int(rng.integers(len(live)))]
            k = len(ledger.allocation(jid).gpus)
            if len(free) >= k:
                pick = rng.choice(len(free), size=k, replace=False)
                ledger.migrate(jid, [free[i] for i in pick])
        elif free:
            k = min(int(rng.integers(1, 5)), len(free))
            pick = rng.choice(len(free), size=k, replace=False)
            jid = f"j{uid}"
            uid += 1
            ledger.admit(jid, [free[i] for i in pick])
            live.append(jid)
    ledger.journal.close()
    return ledger


def _ledger_state(ledger):
    return (
        sorted((a.job_id, tuple(a.gpus)) for a in ledger.jobs()),
        ledger.version,
    )


def run() -> list:
    rows = []
    ctx = get_context("H100")
    waves = _waves(np.random.default_rng(5))
    n_jobs = sum(len(w) for w in waves)

    # untimed warm-up of every side: JIT shape buckets are compiled
    # process-wide, and racing searches reach shapes serial replay never
    # touches — both must land before any timed window
    _run_serial(ctx, waves)
    for w in WORKERS:
        _run_cplane(ctx, waves, w)

    dt_serial, _ = _best_run(_run_serial, ctx, waves)
    rows.append(csv_row(
        "cplane_tput_serial", 1e6 * dt_serial / n_jobs,
        f"adm_per_s={n_jobs / dt_serial:.1f};jobs={n_jobs};waves={N_WAVES}",
    ))

    tput = {}
    for w in WORKERS:
        dt, (stats, _) = _best_run(_run_cplane, ctx, waves, w)
        tput[w] = n_jobs / dt
        rows.append(csv_row(
            f"cplane_tput_w{w}", 1e6 * dt / n_jobs,
            f"adm_per_s={tput[w]:.1f};"
            f"cas_commits={stats['n_cas_commits']};"
            f"conflicts={stats['n_conflicts']};"
            f"validated={stats['n_validated']};"
            f"serialized={stats['n_serialized']};"
            f"parked={stats['n_parked']}",
        ))

    sc_14 = tput[4] / tput[1]
    sc_vs_serial = tput[4] / (n_jobs / dt_serial)
    met = sc_14 > 1.0
    note = (
        f"scaling_w1_to_w4={sc_14:.2f}x;vs_serial={sc_vs_serial:.2f}x;"
        f"target=>1x;met={met};zero_double_alloc=True"
    )
    if not met:
        # acceptance escape hatch: staging is GIL-bound Python — without a
        # second core to overlap the GIL-releasing XLA applies onto, w4 adds
        # only conflict-retry work over w1; document the ceiling instead
        note += f";ceiling_documented=True;cores={os.cpu_count()}"
    rows.append(csv_row("cplane_scaling", 0.0, note))

    with tempfile.TemporaryDirectory() as tmp:
        # warm the journaled config too — racing commit orders reach JIT
        # shapes the journal-off warm-up may never have compiled
        _run_cplane(ctx, waves, 4, journal=os.path.join(tmp, "warm.journal"))
        # single rep: the journal is append-only, so a second rep on the
        # same path would replay to the concatenation of both runs
        jpath = os.path.join(tmp, "admissions.journal")
        dt_j, (_, version) = _run_cplane(ctx, waves, 4, journal=jpath)
        replayed = replay_journal(jpath, ctx.cluster)
        assert len(replayed) == 0 and replayed.version == version, (
            "journal replay diverged from the live ledger"
        )
        overhead = 100.0 * (dt_j - (n_jobs / tput[4])) / (n_jobs / tput[4])
        rows.append(csv_row(
            "cplane_journal", 1e6 * dt_j / n_jobs,
            f"adm_per_s={n_jobs / dt_j:.1f};"
            f"overhead_vs_nojournal={overhead:.1f}%;"
            f"replay_version_identical=True",
        ))

        notes = []
        us_per_event = float("nan")
        for n_events in JOURNAL_EVENTS:
            path = os.path.join(tmp, f"recovery_{n_events}.journal")
            oracle = _synthetic_journal(
                ctx.cluster, path, n_events, np.random.default_rng(n_events)
            )
            t0 = time.time()
            rebuilt = replay_journal(path, ctx.cluster)
            dt = time.time() - t0
            assert _ledger_state(rebuilt) == _ledger_state(oracle), (
                f"recovery replay diverged at {n_events} events"
            )
            n = rebuilt.version  # events actually journaled
            notes.append(f"{n}ev={n / dt:.0f}ev/s")
            us_per_event = 1e6 * dt / n
        rows.append(csv_row(
            "cplane_recovery", us_per_event,
            ";".join(notes) + ";bit_identical=True",
        ))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
