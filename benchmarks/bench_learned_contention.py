"""ISSUE 3: the learned-contention subsystem — learned vs analytic cap.

Protocol: the **saturating** contention ground truth (demand-weighted rail
shares + non-linear NIC multiplexing loss, ``BandwidthSimulator(contention=
"saturating")``) stands in for the system-level bottlenecks a production
fabric shows and the analytic even-split cap cannot see.  Per cluster:

1. **Accuracy** — train the ContendedSurrogate on a (subset, ledger,
   contended-bw) curriculum (`make_contended_split`), then report held-out
   contended MAPE for the learned predictor vs the analytic baseline
   ``min(isolated surrogate, even-split cap)``, overall and on the
   contended-only slice.
2. **End-to-end** — the full deployment loop: replay a *fitting* Poisson
   trace through analytic-mode BandPilot with a TelemetryHarvester
   attached, fine-tune the ContendedSurrogate online on the harvested
   admissions (the live-trace ledger depth is outside the synthetic
   curriculum — this is exactly what the Sec. 4.1.2 adaptation loop is
   for), then replay a **held-out** trace (different seed) in both modes
   and compare mean contention-degraded GBE.

Acceptance (ISSUE 3): learned MAPE < analytic MAPE on H100 and Het-4Mix,
and learned trace GBE within 1 point of (or better than) analytic.

Knobs: BENCH_CONTENDED_SAMPLES (default 600), BENCH_SURROGATE_STEPS
(default 2000), BENCH_FINETUNE_STEPS (default 300), BENCH_TRACE_JOBS
(default 40), BENCH_TRACE_SEED (default 0).
"""

from __future__ import annotations

import os

import numpy as np

import repro.core as core
from repro.core.training import _accuracy
from benchmarks.common import SURROGATE_STEPS, csv_row, get_context

CLUSTERS = ("H100", "Het-4Mix")
N_SAMPLES = int(os.environ.get("BENCH_CONTENDED_SAMPLES", "600"))
FINETUNE_STEPS = int(os.environ.get("BENCH_FINETUNE_STEPS", "300"))
N_JOBS = int(os.environ.get("BENCH_TRACE_JOBS", "40"))
SEED = int(os.environ.get("BENCH_TRACE_SEED", "0"))
MEAN_INTERARRIVAL = 1.0
MEAN_DURATION = 8.0
MAX_COTENANTS = 6  # curriculum ledger depth (live traces run ~8 jobs deep)


def _k_choices(cluster) -> range:
    return range(4, max(cluster.n_gpus // 2, 5) + 1)


def _mape(y: np.ndarray, p: np.ndarray) -> float:
    return _accuracy(y, p)["mape"]  # the training module's definition


def run() -> list:
    rows = []
    for name in CLUSTERS:
        ctx = get_context(name)
        cluster, tables = ctx.cluster, ctx.tables
        sat = core.BandwidthSimulator(cluster, contention="saturating")

        # 1. held-out contended accuracy -------------------------------------
        train, test = core.make_contended_split(
            sat, N_SAMPLES, test_mult=1, seed=SEED + 3,
            max_cotenants=MAX_COTENANTS,
        )
        trip_train = core.to_triples(cluster, train)
        trip_test = core.to_triples(cluster, test)
        cparams, info = core.train_contended_surrogate(
            cluster, tables, trip_train,
            core.TrainConfig(steps=SURROGATE_STEPS, seed=SEED),
            base_params=ctx.params,
        )
        cpred = core.ContendedSurrogatePredictor(cluster, tables, cparams)
        # one inference pass per predictor; the contended-only slice reuses it
        y = np.asarray([bw for _, _, bw in trip_test])
        p_learned = np.asarray(cpred.predict_pairs(
            [(s, led) for s, led, _ in trip_test]
        ))
        p_analytic, _ = core.evaluate_analytic_cap(
            cluster, ctx.predictor, trip_test
        )
        cont = np.asarray([led is not None for _, led, _ in trip_test])
        rows.append(csv_row(
            f"learned_{name}_contended_mape", 0.0,
            f"learned={_mape(y, p_learned):.2f}%;"
            f"analytic={_mape(y, p_analytic):.2f}%;"
            f"learned_contended_only={_mape(y[cont], p_learned[cont]):.2f}%;"
            f"analytic_contended_only={_mape(y[cont], p_analytic[cont]):.2f}%;"
            f"n_test={len(y)};train_s={info['train_seconds']:.0f}",
        ))

        # 2. end-to-end: an analytic replay of the *fitting* trace harvests
        #    telemetry, the online fine-tune absorbs it, and both modes are
        #    then graded on a held-out trace (different seed) ---------------
        def _trace(seed):
            return core.poisson_trace(
                cluster, N_JOBS, np.random.default_rng(seed),
                mean_interarrival=MEAN_INTERARRIVAL,
                mean_duration=MEAN_DURATION,
                k_choices=_k_choices(cluster),
            )

        _, harvester = core.harvest_trace(
            cluster, sat, tables,
            core.BandPilotDispatcher(cluster, tables, ctx.predictor),
            _trace(SEED), rng=np.random.default_rng(SEED),
        )
        ft_params = core.online_finetune_contended(
            cluster, tables, cparams, harvester.triples(),
            steps=FINETUNE_STEPS,
        )
        trace_eval = _trace(SEED + 1)
        gbe = {}
        for mode in ("analytic", "learned"):
            disp = core.BandPilotDispatcher(
                cluster, tables, ctx.predictor, name=f"BandPilot-{mode}",
                contention_mode=mode,
                contended_predictor=core.ContendedSurrogatePredictor(
                    cluster, tables, ft_params
                ) if mode == "learned" else None,
            )
            recs = core.replay_trace(
                cluster, sat, tables, disp, trace_eval,
                rng=np.random.default_rng(SEED + 1),
            )
            s = core.summarize_trace(recs)[disp.name]
            gbe[mode] = s["mean_gbe"]
            rows.append(csv_row(
                f"learned_{name}_trace_{mode}", 0.0,
                f"gbe={100 * s['mean_gbe']:.2f}%;"
                f"degr={100 * s['mean_degradation']:.1f}%;"
                f"contended={100 * s['frac_contended']:.0f}%;"
                f"wait={s['mean_wait']:.2f}"
                + (f";finetuned_on={len(harvester)}" if mode == "learned"
                   else ""),
            ))
        rows.append(csv_row(
            f"learned_{name}_trace_delta", 0.0,
            f"{100 * (gbe['learned'] - gbe['analytic']):+.2f}pts",
        ))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run():
        print(row, flush=True)
