"""Fig. 7: absolute bandwidth loss vs the Oracle, by request size.

Paper claim: Topo's loss peaks near 50 GB/s (H100) / 16 GB/s (Het-4Mix) on
requests of 8..20 GPUs; BandPilot stays near zero.
"""

from __future__ import annotations

import numpy as np

import repro.core as core
from benchmarks.common import csv_row, get_eval_records


def run() -> list:
    rows = []
    for name in ("H100", "Het-4Mix"):
        recs = get_eval_records(name)
        loss = core.bw_loss_by_k(recs)
        for disp in ("BandPilot", "Topo"):
            per_k = loss[disp]
            mid = {k: v for k, v in per_k.items() if 8 <= k <= 20}
            peak_k = max(mid, key=mid.get) if mid else max(per_k, key=per_k.get)
            rows.append(csv_row(
                f"fig7_{name}_{disp}", 0.0,
                f"peak_loss={per_k[peak_k]:.1f}GBps@k={peak_k};"
                f"mean_loss={np.mean(list(per_k.values())):.1f}GBps",
            ))
    return rows
