"""Fig. 5: surrogate data efficiency — R^2 / MAPE vs training-set size.

Paper claim: R^2 > 0.95 and MAPE < 5% with only 250 samples across the
cluster zoo.
"""

from __future__ import annotations

import time

import numpy as np

import repro.core as core
from benchmarks.common import SURROGATE_STEPS, csv_row

SAMPLE_COUNTS = (50, 100, 250, 500)
CLUSTERS = ("H100", "Het-RA", "Het-VA", "Het-4Mix")


def run() -> list:
    rows = []
    for name in CLUSTERS:
        cluster = core.PAPER_CLUSTERS[name]()
        sim = core.BandwidthSimulator(cluster)
        tables = core.IntraHostTables(cluster, sim)
        for n in SAMPLE_COUNTS:
            train, test = core.make_train_test_split(sim, n, seed=0)
            t0 = time.time()
            params, _ = core.train_surrogate(
                cluster, tables, train, core.TrainConfig(steps=SURROGATE_STEPS)
            )
            train_s = time.time() - t0
            pred = core.SurrogatePredictor(cluster, tables, params)
            t0 = time.time()
            m = core.evaluate_surrogate(pred, test)
            n_eval = m["n"]
            us = (time.time() - t0) / max(n_eval, 1) * 1e6
            rows.append(csv_row(
                f"fig5_{name}_n{n}", us,
                f"r2={m['r2']:.4f};mape={m['mape']:.2f}%;train_s={train_s:.0f}",
            ))
    return rows
