"""Fig. 5: surrogate data efficiency — R^2 / MAPE vs training-set size.

Paper claim: R^2 > 0.95 and MAPE < 5% with only 250 samples across the
cluster zoo.

The heterogeneous clusters additionally report a ``legacyfeat`` ablation
row at the paper's headline n=250: the same protocol with the per-host-type
normalized intra-bandwidth channel zeroed (``host_norm=False``) — the MAPE
delta of the ROADMAP's Het-VA feature-normalization item.

Het-VA further reports a ``smallk`` row at n=250: the ROADMAP follow-up on
the residual small-k / near-crossover error mode.  A second surrogate is
trained on a fresh-seed dataset drawn with ``sample_allocations(
small_k_weight=SMALL_K_WEIGHT)`` at the same n=250 budget, filtered to be
disjoint from the baseline test split (small-k subsets are few enough
that independent draws would otherwise leak), and both models are scored
on the *baseline* test split's small-k slice (k <= 5, where the Het-VA
intra and inter constraints nearly cross), so the row isolates the
sampling-curriculum effect.
"""

from __future__ import annotations

import time

import numpy as np

import repro.core as core
from benchmarks.common import SURROGATE_STEPS, csv_row

SAMPLE_COUNTS = (50, 100, 250, 500)
CLUSTERS = ("H100", "Het-RA", "Het-VA", "Het-4Mix")
ABLATE_HOST_NORM = ("Het-VA", "Het-4Mix")  # legacyfeat rows at n=250
OVERSAMPLE_SMALL_K = ("Het-VA",)           # smallk rows at n=250
SMALL_K_MAX = 5                            # near-crossover slice bound
SMALL_K_WEIGHT = 0.5


def _fit_eval(cluster, tables, train, test, host_norm=True):
    t0 = time.time()
    params, _ = core.train_surrogate(
        cluster, tables, train, core.TrainConfig(steps=SURROGATE_STEPS),
        host_norm=host_norm,
    )
    train_s = time.time() - t0
    pred = core.SurrogatePredictor(cluster, tables, params, host_norm=host_norm)
    t0 = time.time()
    m = core.evaluate_surrogate(pred, test)
    us = (time.time() - t0) / max(m["n"], 1) * 1e6
    return m, us, train_s, pred


def run() -> list:
    rows = []
    for name in CLUSTERS:
        cluster = core.PAPER_CLUSTERS[name]()
        sim = core.BandwidthSimulator(cluster)
        tables = core.IntraHostTables(cluster, sim)
        for n in SAMPLE_COUNTS:
            train, test = core.make_train_test_split(sim, n, seed=0)
            m, us, train_s, pred = _fit_eval(cluster, tables, train, test)
            rows.append(csv_row(
                f"fig5_{name}_n{n}", us,
                f"r2={m['r2']:.4f};mape={m['mape']:.2f}%;train_s={train_s:.0f}",
            ))
            if n == 250 and name in ABLATE_HOST_NORM:
                leg, us_l, _, _ = _fit_eval(
                    cluster, tables, train, test, host_norm=False
                )
                rows.append(csv_row(
                    f"fig5_{name}_n{n}_legacyfeat", us_l,
                    f"r2={leg['r2']:.4f};mape={leg['mape']:.2f}%;"
                    f"norm_delta={m['mape'] - leg['mape']:+.2f}pts",
                ))
            if n == 250 and name in OVERSAMPLE_SMALL_K:
                small_test = [
                    (s, bw) for s, bw in test if len(s) <= SMALL_K_MAX
                ]
                base_small = core.evaluate_surrogate(pred, small_test)
                # draw extra, then drop any allocation that appears in the
                # baseline test split: small-k subsets are few on a 32-GPU
                # cluster, so independent draws WOULD collide and leak
                test_keys = {tuple(s) for s, _ in test}
                over_pool = sim.build_dataset(
                    2 * n, np.random.default_rng(1),
                    small_k_weight=SMALL_K_WEIGHT,
                )
                over_train = [
                    d for d in over_pool if tuple(d[0]) not in test_keys
                ][:n]
                over, _, _, over_pred = _fit_eval(
                    cluster, tables, over_train, test
                )
                over_small = core.evaluate_surrogate(over_pred, small_test)
                rows.append(csv_row(
                    f"fig5_{name}_n{n}_smallk", 0.0,
                    f"base_mape={base_small['mape']:.2f}%;"
                    f"oversampled_mape={over_small['mape']:.2f}%;"
                    f"smallk_delta={base_small['mape'] - over_small['mape']:+.2f}pts;"
                    f"full_mape={over['mape']:.2f}%;n_small={base_small['n']};"
                    # visible when collisions shrink the curriculum budget
                    f"n_train={len(over_train)}",
                ))
    return rows
