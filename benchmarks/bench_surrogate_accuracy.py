"""Fig. 5: surrogate data efficiency — R^2 / MAPE vs training-set size.

Paper claim: R^2 > 0.95 and MAPE < 5% with only 250 samples across the
cluster zoo.

The heterogeneous clusters additionally report a ``legacyfeat`` ablation
row at the paper's headline n=250: the same protocol with the per-host-type
normalized intra-bandwidth channel zeroed (``host_norm=False``) — the MAPE
delta of the ROADMAP's Het-VA feature-normalization item.
"""

from __future__ import annotations

import time

import numpy as np

import repro.core as core
from benchmarks.common import SURROGATE_STEPS, csv_row

SAMPLE_COUNTS = (50, 100, 250, 500)
CLUSTERS = ("H100", "Het-RA", "Het-VA", "Het-4Mix")
ABLATE_HOST_NORM = ("Het-VA", "Het-4Mix")  # legacyfeat rows at n=250


def _fit_eval(cluster, tables, train, test, host_norm=True):
    t0 = time.time()
    params, _ = core.train_surrogate(
        cluster, tables, train, core.TrainConfig(steps=SURROGATE_STEPS),
        host_norm=host_norm,
    )
    train_s = time.time() - t0
    pred = core.SurrogatePredictor(cluster, tables, params, host_norm=host_norm)
    t0 = time.time()
    m = core.evaluate_surrogate(pred, test)
    us = (time.time() - t0) / max(m["n"], 1) * 1e6
    return m, us, train_s


def run() -> list:
    rows = []
    for name in CLUSTERS:
        cluster = core.PAPER_CLUSTERS[name]()
        sim = core.BandwidthSimulator(cluster)
        tables = core.IntraHostTables(cluster, sim)
        for n in SAMPLE_COUNTS:
            train, test = core.make_train_test_split(sim, n, seed=0)
            m, us, train_s = _fit_eval(cluster, tables, train, test)
            rows.append(csv_row(
                f"fig5_{name}_n{n}", us,
                f"r2={m['r2']:.4f};mape={m['mape']:.2f}%;train_s={train_s:.0f}",
            ))
            if n == 250 and name in ABLATE_HOST_NORM:
                leg, us_l, _ = _fit_eval(
                    cluster, tables, train, test, host_norm=False
                )
                rows.append(csv_row(
                    f"fig5_{name}_n{n}_legacyfeat", us_l,
                    f"r2={leg['r2']:.4f};mape={leg['mape']:.2f}%;"
                    f"norm_delta={m['mape'] - leg['mape']:+.2f}pts",
                ))
    return rows
