"""Benchmark harness: one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (stdout) per the repo contract.
Scenario counts honour BENCH_SCENARIOS (default 20; paper protocol = 50).

  PYTHONPATH=src python -m benchmarks.run            # all benches
  PYTHONPATH=src python -m benchmarks.run fig5 fig9  # subset by prefix
"""

from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (
    bench_surrogate_accuracy,
    bench_dispatch_gbe,
    bench_bandwidth_loss,
    bench_search_overhead,
    bench_hier_vs_naive,
    bench_search_ablation,
    bench_offline_cost,
    bench_llama70b_delta,
    bench_contention,
    bench_scheduler,
    bench_learned_contention,
    bench_defrag,
    bench_dispatch_throughput,
    bench_controlplane,
)

BENCHES = [
    ("fig5_surrogate_accuracy", bench_surrogate_accuracy.run),
    ("table2_fig6_dispatch_gbe", bench_dispatch_gbe.run),
    ("fig7_bandwidth_loss", bench_bandwidth_loss.run),
    ("fig8_search_overhead", bench_search_overhead.run),
    ("fig9_hier_vs_naive", bench_hier_vs_naive.run),
    ("fig10_search_ablation", bench_search_ablation.run),
    ("table3_offline_cost", bench_offline_cost.run),
    ("appendixA_llama70b_delta", bench_llama70b_delta.run),
    ("sec44_contention", bench_contention.run),
    ("issue2_scheduler_policies", bench_scheduler.run),
    ("issue3_learned_contention", bench_learned_contention.run),
    ("issue4_defrag", bench_defrag.run),
    ("issue6_dispatch_throughput", bench_dispatch_throughput.run),
    ("issue7_controlplane", bench_controlplane.run),
]


def main() -> None:
    prefixes = [a for a in sys.argv[1:] if not a.startswith("-")]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in BENCHES:
        if prefixes and not any(name.startswith(p) or p in name
                                for p in prefixes):
            continue
        t0 = time.time()
        try:
            for row in fn():
                print(row, flush=True)
            print(f"# {name} done in {time.time() - t0:.0f}s", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},0,FAILED", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
