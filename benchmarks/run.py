"""Benchmark harness: one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (stdout) per the repo contract.
Scenario counts honour BENCH_SCENARIOS (default 20; paper protocol = 50).

Alongside the CSV stream, every completed run writes a machine-readable
``BENCH_RESULTS.json`` (path override: ``BENCH_RESULTS_PATH``) so the perf
trajectory is trackable across commits — one entry per row with the bench
name, config row, metric value/units, the parsed derived fields, and the
git commit.  CI archives it as an artifact (see .github/workflows/ci.yml).

  PYTHONPATH=src python -m benchmarks.run            # all benches
  PYTHONPATH=src python -m benchmarks.run fig5 fig9  # subset by prefix
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time
import traceback

from benchmarks import (
    bench_surrogate_accuracy,
    bench_dispatch_gbe,
    bench_bandwidth_loss,
    bench_search_overhead,
    bench_hier_vs_naive,
    bench_search_ablation,
    bench_offline_cost,
    bench_llama70b_delta,
    bench_contention,
    bench_scheduler,
    bench_learned_contention,
    bench_defrag,
    bench_dispatch_throughput,
    bench_controlplane,
    bench_failure_recovery,
)

BENCHES = [
    ("fig5_surrogate_accuracy", bench_surrogate_accuracy.run),
    ("table2_fig6_dispatch_gbe", bench_dispatch_gbe.run),
    ("fig7_bandwidth_loss", bench_bandwidth_loss.run),
    ("fig8_search_overhead", bench_search_overhead.run),
    ("fig9_hier_vs_naive", bench_hier_vs_naive.run),
    ("fig10_search_ablation", bench_search_ablation.run),
    ("table3_offline_cost", bench_offline_cost.run),
    ("appendixA_llama70b_delta", bench_llama70b_delta.run),
    ("sec44_contention", bench_contention.run),
    ("issue2_scheduler_policies", bench_scheduler.run),
    ("issue3_learned_contention", bench_learned_contention.run),
    ("issue4_defrag", bench_defrag.run),
    ("issue6_dispatch_throughput", bench_dispatch_throughput.run),
    ("issue7_controlplane", bench_controlplane.run),
    ("issue10_failure_recovery", bench_failure_recovery.run),
]

RESULTS_SCHEMA = 1


def _git_commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def parse_row(bench: str, row: str) -> dict:
    """One CSV row -> one BENCH_RESULTS.json entry.

    Rows follow the repo contract ``name,us_per_call,derived`` where
    ``derived`` is ";"-separated ``k=v`` pairs (kept verbatim *and* parsed
    into ``derived_fields``, with numeric strings coerced).
    """
    name, _, rest = row.partition(",")
    value_str, _, derived = rest.partition(",")
    try:
        value = float(value_str)
    except ValueError:
        value = float("nan")
    fields = {}
    for pair in derived.split(";"):
        k, sep, v = pair.partition("=")
        if not sep:
            continue
        try:
            fields[k.strip()] = float(v)
        except ValueError:
            fields[k.strip()] = v.strip()
    return {
        "bench": bench,
        "row": name,
        "metric": "us_per_call",
        "value": value,
        "units": "us",
        "derived": derived,
        "derived_fields": fields,
    }


def write_results(entries, path=None, commit=None) -> str:
    """Dump entries (plus schema/commit header) to BENCH_RESULTS.json."""
    path = path or os.environ.get("BENCH_RESULTS_PATH", "BENCH_RESULTS.json")
    doc = {
        "schema": RESULTS_SCHEMA,
        "commit": commit if commit is not None else _git_commit(),
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "results": entries,
    }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return path


def main() -> None:
    prefixes = [a for a in sys.argv[1:] if not a.startswith("-")]
    print("name,us_per_call,derived")
    failures = 0
    entries = []
    for name, fn in BENCHES:
        if prefixes and not any(name.startswith(p) or p in name
                                for p in prefixes):
            continue
        t0 = time.time()
        try:
            for row in fn():
                print(row, flush=True)
                entries.append(parse_row(name, row))
            print(f"# {name} done in {time.time() - t0:.0f}s", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},0,FAILED", flush=True)
            entries.append({
                "bench": name, "row": name, "metric": "failed",
                "value": float("nan"), "units": "", "derived": "FAILED",
                "derived_fields": {},
            })
    path = write_results(entries)
    print(f"# wrote {len(entries)} entries to {path}", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
