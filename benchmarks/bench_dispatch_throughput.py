"""Dispatch fast path (ISSUE 5): end-to-end admissions/sec, before vs after.

Replays pinned scheduler traces (H100 + Het-4Mix; fifo/batched x
analytic/learned x defrag on/off) through BandPilot twice per
configuration:

* **before** — the pre-PR dispatch path: per-candidate loop featurizers,
  per-candidate analytic caps, sequential PTS rounds, no prediction cache,
  JIT shapes always padded to ``cluster.n_hosts`` tokens;
* **after** — the fast path defaults: vectorized featurization, fused PTS
  rounds, batched caps, ledger-versioned prediction cache, bucketed JIT
  shapes.

Both sides replay with oracle grading off (``AdmissionScheduler(grade=
False)``): the exact-Oracle baseline is evaluation apparatus, identical on
both sides, and a production dispatcher never runs it — admissions/sec
must measure the dispatch path.  The chosen subsets are asserted identical
between the two sides on every configuration (the bit-identity contract),
and the per-phase breakdown (featurize / infer / contention-wrap / other)
is reported for each.

Rows:
  dispatch_tput_{cluster}_{policy}_{mode}[_defrag] — us per admission
    (after side), derived = before/after admissions/sec + speedup +
    both breakdowns + identical-subsets flag
  dispatch_tput_target — the pinned headline config (H100 fifo analytic)
    speedup vs the >=5x target
  dispatch_latency_guard — worst-case hybrid-search latency (after side)
    vs the Fig. 8 250 ms envelope (threshold via BENCH_SEARCH_LATENCY_MS)
"""

from __future__ import annotations

import os
import time

import numpy as np

import repro.core as core
from repro.core import surrogate as surr
from benchmarks.common import csv_row, get_context

CLUSTERS = ("H100", "Het-4Mix")
N_JOBS = int(os.environ.get("BENCH_TRACE_JOBS", "50"))
LATENCY_MS = float(os.environ.get("BENCH_SEARCH_LATENCY_MS", "250"))
TARGET_SPEEDUP = 5.0
PINNED = ("H100", "fifo", "analytic", False)  # the headline config

CONFIGS = (
    # (policy, batch_window, mode, defrag)
    ("fifo", 0.0, "analytic", False),
    ("batched", 2.0, "analytic", False),
    ("fifo", 0.0, "learned", False),
    ("fifo", 0.0, "analytic", True),
)


def _trace(cluster):
    return core.poisson_trace(
        cluster, N_JOBS, np.random.default_rng(11),
        mean_interarrival=1.0, mean_duration=8.0,
        k_choices=range(4, cluster.n_gpus // 2 + 1),
    )


def _dispatcher(ctx, mode, fast):
    pred = core.SurrogatePredictor(
        ctx.cluster, ctx.tables, ctx.params,
        vectorized=fast, bucket_shapes=fast,
    )
    kw = {}
    if mode == "learned":
        # untrained warm-start head: the bench measures the dispatch path,
        # not model accuracy, and an untrained ContendedSurrogate exercises
        # exactly the same featurize+infer work as a trained one
        kw = dict(
            contention_mode="learned",
            contended_predictor=core.ContendedSurrogatePredictor(
                ctx.cluster, ctx.tables,
                surr.init_contended_params(ctx.params),
                vectorized=fast, bucket_shapes=fast,
            ),
        )
    disp = core.BandPilotDispatcher(
        ctx.cluster, ctx.tables, pred, cache=fast, **kw
    )
    if not fast:
        disp.contention_predictor.vectorized = False
    return disp


def _replay(ctx, trace, policy, window, mode, defrag, fast):
    """-> (seconds, chosen subsets, stats, worst hybrid-search seconds)."""
    disp = _dispatcher(ctx, mode, fast)
    chosen = []
    worst = [0.0]
    orig = core.BandPilotDispatcher.dispatch

    def wrapped(self, avail, k, rng=None):
        s = orig(self, avail, k, rng=rng)
        chosen.append(tuple(s))
        if self.last_result is not None:
            worst[0] = max(worst[0], self.last_result.total_seconds)
        return s

    disp.dispatch = wrapped.__get__(disp)
    cfg = core.SchedulerConfig(
        policy=policy, batch_window=window, defrag=defrag,
    )
    sched = core.AdmissionScheduler(
        ctx.cluster, ctx.sim, ctx.tables, disp, cfg, grade=False
    )
    t0 = time.time()
    recs = sched.run(trace)
    # joint batched placements commit without dispatch(): fold the graded
    # records in so the identity check covers every admission path
    chosen += [(r.job_id, r.bw) for r in recs]
    return time.time() - t0, chosen, disp.predictor_stats(), worst[0]


def _breakdown(dt, st):
    other = max(dt - st.featurize_seconds - st.infer_seconds
                - st.wrapper_seconds, 0.0)
    return (
        f"feat={st.featurize_seconds:.2f}s;infer={st.infer_seconds:.2f}s;"
        f"wrap={st.wrapper_seconds:.2f}s;other={other:.2f}s;"
        f"hits={st.cache_hits};misses={st.cache_misses}"
    )


def run() -> list:
    rows = []
    pinned_speedup = None
    first_speedup = None
    worst_latency = 0.0
    for name in CLUSTERS:
        ctx = get_context(name)
        trace = _trace(ctx.cluster)
        for policy, window, mode, defrag in CONFIGS:
            # full unmeasured replay per side first: JIT compilation of
            # every (B, H) shape bucket the trace exercises must land
            # outside the timed window (it is a once-per-process cost, not
            # a per-admission one)
            _replay(ctx, trace, policy, window, mode, defrag, fast=True)
            _replay(ctx, trace, policy, window, mode, defrag, fast=False)
            dt_a, sub_a, st_a, worst_a = _replay(
                ctx, trace, policy, window, mode, defrag, fast=True
            )
            dt_b, sub_b, st_b, _ = _replay(
                ctx, trace, policy, window, mode, defrag, fast=False
            )
            identical = sub_a == sub_b
            assert identical, (
                f"fast path changed subset selection: {name} {policy} {mode}"
            )
            worst_latency = max(worst_latency, worst_a)
            speedup = dt_b / dt_a if dt_a > 0 else float("inf")
            tag = f"{policy}_{mode}" + ("_defrag" if defrag else "")
            if (name, policy, mode, defrag) == PINNED:
                pinned_speedup = speedup
            if first_speedup is None:
                first_speedup = speedup
            rows.append(csv_row(
                f"dispatch_tput_{name}_{tag}",
                1e6 * dt_a / len(trace),
                f"after={len(trace) / dt_a:.1f}adm/s;"
                f"before={len(trace) / dt_b:.1f}adm/s;"
                f"speedup={speedup:.2f}x;identical={identical};"
                f"after[{_breakdown(dt_a, st_a)}];"
                f"before[{_breakdown(dt_b, st_b)}]",
            ))
    # a CI smoke override may run a config subset without the pinned one:
    # fall back to the first measured config rather than crash
    headline = pinned_speedup if pinned_speedup is not None else first_speedup
    rows.append(csv_row(
        "dispatch_tput_target", 0.0,
        f"pinned=H100/fifo/analytic;speedup={headline:.2f}x;"
        f"target={TARGET_SPEEDUP:.0f}x;"
        f"met={headline >= TARGET_SPEEDUP}",
    ))
    rows.append(csv_row(
        "dispatch_latency_guard", 1e6 * worst_latency,
        f"worst_search_ms={1e3 * worst_latency:.1f};"
        f"threshold_ms={LATENCY_MS:.0f};"
        f"ok={1e3 * worst_latency < LATENCY_MS}",
    ))
    return rows
