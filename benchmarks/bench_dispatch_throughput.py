"""Dispatch throughput (ISSUE 5 + 6): admissions/sec, three-way.

Replays pinned scheduler traces (H100 + Het-4Mix; fifo/batched x
analytic/learned x defrag on/off) through BandPilot three times per
configuration:

* **before** — the pre-PR-5 dispatch path: per-candidate loop
  featurizers, per-candidate analytic caps, sequential PTS rounds, no
  prediction cache, JIT shapes always padded to ``cluster.n_hosts``
  tokens;
* **scanoff** — the ISSUE-5 fast path: vectorized featurization, fused
  host PTS rounds, batched caps, ledger-versioned prediction cache,
  bucketed JIT shapes — but the on-device descent disabled
  (``use_scan=False``);
* **scanon** — the full ISSUE-6 path: whole PTS descents run as one
  fused on-device ``lax.scan`` through AOT-compiled executables.

All sides replay with oracle grading off (``AdmissionScheduler(grade=
False)``): the exact-Oracle baseline is evaluation apparatus, identical
on every side, and a production dispatcher never runs it.  The chosen
subsets are asserted identical across all three sides on every
configuration (the bit-identity contract), and the per-phase breakdown
(featurize / infer / scan / contention-wrap / other) is reported.

Cold start vs warm latency: the scan executables are AOT-compiled at
dispatcher construction (``aot_warm``), so the compile spike lands
before the first admission.  ``dispatch_aot_warm_{cluster}`` reports
that one-time cost next to the warm per-round descent latency; the
executables are process-wide and shared across same-shaped clusters, so
the second cluster's row shows the (near-zero) shared-cache cost.

Rows:
  dispatch_tput_{cluster}_{policy}_{mode}[_defrag] — us per admission
    (scanon side), notes = all three admissions/sec + scan and total
    speedups + identical-subsets flag + per-phase breakdowns
  dispatch_aot_warm_{cluster} — one-time AOT compile seconds vs warm
    per-round scan latency
  dispatch_tput_target — the pinned headline config (H100 fifo
    analytic) total speedup vs the >=5x target; when the XLA-CPU
    compute bound keeps the headline below target, the row documents
    the measured ceiling with the per-phase breakdown instead
  dispatch_latency_guard — worst-case hybrid-search latency (scanon
    side) vs the Fig. 8 envelope (threshold via BENCH_SEARCH_LATENCY_MS)
  dispatch_trace_overhead — best-of-N replay of the pinned config with
    the admission tracer installed vs disabled: asserts byte-identical
    placements and reports the overhead percentage against the
    BENCH_TRACE_OVERHEAD_PCT guard (default 5; CI asserts ok=True)
  dispatch_forensics_overhead — the same interleaved best-of-N protocol
    with dossier capture (forensics.DossierRecorder) installed vs
    disabled: byte-identical placements, overhead vs the
    BENCH_FORENSICS_OVERHEAD_PCT guard (default 5; CI 25)
  dispatch_regret_summary — per-tenant regret ledger from a graded
    capture-on replay (round-robin tenants): admissions and mean oracle
    regret (GB/s) per tenant
"""

from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

import repro.core as core
from repro.core import forensics
from repro.core import surrogate as surr
from repro.core import telemetry
from benchmarks.common import csv_row, get_context

CLUSTERS = ("H100", "Het-4Mix")
N_JOBS = int(os.environ.get("BENCH_TRACE_JOBS", "50"))
REGRET_JOBS = int(os.environ.get("BENCH_REGRET_JOBS", "15"))
LATENCY_MS = float(os.environ.get("BENCH_SEARCH_LATENCY_MS", "150"))
OVERHEAD_PCT = float(os.environ.get("BENCH_TRACE_OVERHEAD_PCT", "5"))
FORENSICS_PCT = float(os.environ.get("BENCH_FORENSICS_OVERHEAD_PCT", "5"))
OVERHEAD_REPS = int(os.environ.get("BENCH_TRACE_OVERHEAD_REPS", "3"))
TARGET_SPEEDUP = 5.0
PINNED = ("H100", "fifo", "analytic", False)  # the headline config

CONFIGS = (
    # (policy, batch_window, mode, defrag)
    ("fifo", 0.0, "analytic", False),
    ("batched", 2.0, "analytic", False),
    ("fifo", 0.0, "learned", False),
    ("fifo", 0.0, "analytic", True),
)

SIDES = ("scanon", "scanoff", "before")


def _trace(cluster):
    return core.poisson_trace(
        cluster, N_JOBS, np.random.default_rng(11),
        mean_interarrival=1.0, mean_duration=8.0,
        k_choices=range(4, cluster.n_gpus // 2 + 1),
    )


def _dispatcher(ctx, mode, side):
    fast = side != "before"
    use_scan = side == "scanon"
    pred = core.SurrogatePredictor(
        ctx.cluster, ctx.tables, ctx.params,
        vectorized=fast, bucket_shapes=fast, use_scan=use_scan,
    )
    kw = {}
    if mode == "learned":
        # untrained warm-start head: the bench measures the dispatch path,
        # not model accuracy, and an untrained ContendedSurrogate exercises
        # exactly the same featurize+infer work as a trained one
        kw = dict(
            contention_mode="learned",
            contended_predictor=core.ContendedSurrogatePredictor(
                ctx.cluster, ctx.tables,
                surr.init_contended_params(ctx.params),
                vectorized=fast, bucket_shapes=fast,
            ),
        )
    disp = core.BandPilotDispatcher(
        ctx.cluster, ctx.tables, pred, cache=fast, aot_warm=use_scan, **kw
    )
    if not fast:
        disp.contention_predictor.vectorized = False
    return disp


def _replay(ctx, trace, policy, window, mode, defrag, side):
    """-> (seconds, chosen subsets, stats, worst hybrid-search seconds)."""
    disp = _dispatcher(ctx, mode, side)
    chosen = []
    worst = [0.0]
    orig = core.BandPilotDispatcher.dispatch

    def wrapped(self, avail, k, rng=None):
        s = orig(self, avail, k, rng=rng)
        chosen.append(tuple(s))
        if self.last_result is not None:
            worst[0] = max(worst[0], self.last_result.total_seconds)
        return s

    disp.dispatch = wrapped.__get__(disp)
    cfg = core.SchedulerConfig(
        policy=policy, batch_window=window, defrag=defrag,
    )
    sched = core.AdmissionScheduler(
        ctx.cluster, ctx.sim, ctx.tables, disp, cfg, grade=False
    )
    t0 = time.time()
    recs = sched.run(trace)
    # joint batched placements commit without dispatch(): fold the graded
    # records in so the identity check covers every admission path
    chosen += [(r.job_id, r.bw) for r in recs]
    return time.time() - t0, chosen, disp.predictor_stats(), worst[0]


def _breakdown(dt, st):
    other = max(dt - st.featurize_seconds - st.infer_seconds
                - st.scan_seconds - st.wrapper_seconds, 0.0)
    return (
        f"feat={st.featurize_seconds:.2f}s;infer={st.infer_seconds:.2f}s;"
        f"scan={st.scan_seconds:.2f}s/{st.n_scan_steps}r;"
        f"wrap={st.wrapper_seconds:.2f}s;other={other:.2f}s;"
        f"hits={st.cache_hits};misses={st.cache_misses}"
    )


def _trace_overhead_row():
    """Tracing-overhead guard on the pinned headline config.

    Best-of-N replays interleave traced and untraced runs (same trace,
    fresh dispatcher each side) so machine noise hits both sides alike.
    The placements must be byte-identical — the tracer only records.
    """
    name, policy, mode, defrag = PINNED
    ctx = get_context(name)
    trace = _trace(ctx.cluster)
    _replay(ctx, trace, policy, 0.0, mode, defrag, "scanon")  # JIT warm-up
    best = {"off": float("inf"), "on": float("inf")}
    subs = {}
    n_spans = 0
    for _ in range(max(OVERHEAD_REPS, 1)):
        dt, sub, _, _ = _replay(ctx, trace, policy, 0.0, mode, defrag,
                                "scanon")
        best["off"] = min(best["off"], dt)
        subs["off"] = sub
        tracer = telemetry.AdmissionTracer()
        with telemetry.trace(tracer):
            dt, sub, _, _ = _replay(ctx, trace, policy, 0.0, mode, defrag,
                                    "scanon")
        best["on"] = min(best["on"], dt)
        subs["on"] = sub
        n_spans = tracer.n_spans
    assert subs["on"] == subs["off"], "tracing changed subset selection"
    pct = 100.0 * (best["on"] - best["off"]) / best["off"]
    return csv_row(
        "dispatch_trace_overhead",
        1e6 * max(best["on"] - best["off"], 0.0) / len(trace),
        f"traced={best['on'] * 1e3:.1f}ms;untraced={best['off'] * 1e3:.1f}ms;"
        f"overhead_pct={pct:.2f};threshold_pct={OVERHEAD_PCT:.1f};"
        f"spans_per_replay={n_spans};identical=True;"
        f"ok={pct <= OVERHEAD_PCT}",
    )


def _forensics_overhead_row():
    """Dossier-capture overhead guard, same protocol as the tracer's:
    interleaved best-of-N replays of the pinned config with a
    DossierRecorder installed vs disabled, byte-identical placements
    asserted (capture only records — it never steers the search)."""
    name, policy, mode, defrag = PINNED
    ctx = get_context(name)
    trace = _trace(ctx.cluster)
    _replay(ctx, trace, policy, 0.0, mode, defrag, "scanon")  # JIT warm-up
    best = {"off": float("inf"), "on": float("inf")}
    subs = {}
    n_dossiers = 0
    for _ in range(max(OVERHEAD_REPS, 1)):
        dt, sub, _, _ = _replay(ctx, trace, policy, 0.0, mode, defrag,
                                "scanon")
        best["off"] = min(best["off"], dt)
        subs["off"] = sub
        rec = forensics.DossierRecorder()
        with forensics.capture(rec):
            dt, sub, _, _ = _replay(ctx, trace, policy, 0.0, mode, defrag,
                                    "scanon")
        best["on"] = min(best["on"], dt)
        subs["on"] = sub
        n_dossiers = len(rec)
    assert subs["on"] == subs["off"], "dossier capture changed placements"
    pct = 100.0 * (best["on"] - best["off"]) / best["off"]
    return csv_row(
        "dispatch_forensics_overhead",
        1e6 * max(best["on"] - best["off"], 0.0) / len(trace),
        f"captured={best['on'] * 1e3:.1f}ms;plain={best['off'] * 1e3:.1f}ms;"
        f"overhead_pct={pct:.2f};threshold_pct={FORENSICS_PCT:.1f};"
        f"dossiers_per_replay={n_dossiers};identical=True;"
        f"ok={pct <= FORENSICS_PCT}",
    )


def _regret_summary_row():
    """Per-tenant regret from a graded capture-on replay of the pinned
    config: a short trace (grading runs the exact Oracle per admission)
    with round-robin tenants, the scheduler's note_grade feeding the
    recorder's RegretLedger."""
    name, policy, mode, defrag = PINNED
    ctx = get_context(name)
    tenants = ("tenant-a", "tenant-b")
    trace = [
        dataclasses.replace(j, tenant=tenants[i % len(tenants)])
        for i, j in enumerate(_trace(ctx.cluster)[:REGRET_JOBS])
    ]
    disp = _dispatcher(ctx, mode, "scanon")
    sched = core.AdmissionScheduler(
        ctx.cluster, ctx.sim, ctx.tables, disp,
        core.SchedulerConfig(policy=policy, defrag=defrag),
    )
    rec = forensics.DossierRecorder()
    t0 = time.time()
    with forensics.capture(rec):
        sched.run(trace)
    dt = time.time() - t0
    summ = rec.regret.summary()
    parts = []
    for tenant in tenants:
        row = summ.get(tenant)
        if row is None:
            continue
        parts.append(
            f"{tenant}.n={int(row['n'])};"
            f"{tenant}.mean_realized={row['mean_realized']:.1f};"
            f"{tenant}.mean_oracle_regret={row['mean_oracle_regret']:.2f}"
        )
    return csv_row(
        "dispatch_regret_summary", 1e6 * dt / max(len(trace), 1),
        ";".join(parts) + f";dossiers={len(rec)}",
    )


def run() -> list:
    rows = []
    pinned = None
    first = None
    worst_latency = 0.0
    for name in CLUSTERS:
        ctx = get_context(name)
        # one-time AOT warm-up cost, paid at dispatcher construction (the
        # compiled executables are process-wide: the second same-shaped
        # cluster finds them in the cache)
        aot = _dispatcher(ctx, "analytic", "scanon").aot_warm_seconds
        trace = _trace(ctx.cluster)
        warm_scan_stats = None
        for policy, window, mode, defrag in CONFIGS:
            timed = {}
            for side in SIDES:
                # full unmeasured replay first: JIT compilation of every
                # (B, H) shape bucket the trace exercises must land outside
                # the timed window (once-per-process, not per-admission)
                _replay(ctx, trace, policy, window, mode, defrag, side)
                timed[side] = _replay(
                    ctx, trace, policy, window, mode, defrag, side
                )
            dt_on, sub_on, st_on, worst_on = timed["scanon"]
            dt_off, sub_off, st_off, _ = timed["scanoff"]
            dt_b, sub_b, st_b, _ = timed["before"]
            identical = sub_on == sub_off == sub_b
            assert identical, (
                f"scan/fast path changed subset selection: "
                f"{name} {policy} {mode}"
            )
            if warm_scan_stats is None and st_on.n_scan_steps:
                warm_scan_stats = st_on
            worst_latency = max(worst_latency, worst_on)
            sp_scan = dt_off / dt_on if dt_on > 0 else float("inf")
            sp_total = dt_b / dt_on if dt_on > 0 else float("inf")
            tag = f"{policy}_{mode}" + ("_defrag" if defrag else "")
            if (name, policy, mode, defrag) == PINNED:
                pinned = (sp_total, dt_on, st_on)
            if first is None:
                first = (sp_total, dt_on, st_on)
            rows.append(csv_row(
                f"dispatch_tput_{name}_{tag}",
                1e6 * dt_on / len(trace),
                f"scanon={len(trace) / dt_on:.1f}adm/s;"
                f"scanoff={len(trace) / dt_off:.1f}adm/s;"
                f"before={len(trace) / dt_b:.1f}adm/s;"
                f"speedup_scan={sp_scan:.2f}x;"
                f"speedup_total={sp_total:.2f}x;identical={identical};"
                f"scanon[{_breakdown(dt_on, st_on)}];"
                f"before[{_breakdown(dt_b, st_b)}]",
            ))
        wst = warm_scan_stats
        warm_ms = (
            1e3 * wst.scan_seconds / max(wst.n_scan_steps, 1)
            if wst is not None else float("nan")
        )
        rows.append(csv_row(
            f"dispatch_aot_warm_{name}", 1e6 * aot,
            f"compile={aot:.2f}s;warm_ms_per_round={warm_ms:.2f};"
            f"shared_cache={aot < 0.1}",
        ))
    # a CI smoke override may run a config subset without the pinned one:
    # fall back to the first measured config rather than crash
    headline, dt_on, st_on = pinned if pinned is not None else first
    met = headline >= TARGET_SPEEDUP
    note = (
        f"pinned=H100/fifo/analytic;speedup={headline:.2f}x;"
        f"target={TARGET_SPEEDUP:.0f}x;met={met}"
    )
    if not met:
        # acceptance escape hatch: on a 1-vCPU XLA-CPU host the descent is
        # compute-bound (the Transformer flops dominate, not dispatch
        # overhead) — document the measured ceiling with the breakdown
        note += (
            f";ceiling_documented=True;"
            f"scanon_breakdown[{_breakdown(dt_on, st_on)}]"
        )
    rows.append(csv_row("dispatch_tput_target", 0.0, note))
    rows.append(csv_row(
        "dispatch_latency_guard", 1e6 * worst_latency,
        f"worst_search_ms={1e3 * worst_latency:.1f};"
        f"threshold_ms={LATENCY_MS:.0f};"
        f"ok={1e3 * worst_latency < LATENCY_MS}",
    ))
    rows.append(_trace_overhead_row())
    rows.append(_forensics_overhead_row())
    rows.append(_regret_summary_row())
    return rows
