"""Fig. 6 + Table 2: end-to-end dispatching GBE across clusters.

Paper claims: mean GBE ~96.99% (H100) / ~89.9% (Het-4Mix); +12~31 points
over the Topo compactness heuristic; U-shaped GBE-vs-k curves.
"""

from __future__ import annotations

import time

import numpy as np

import repro.core as core
from benchmarks.common import csv_row, get_context, get_eval_records

CLUSTERS = ("H100", "Het-RA", "Het-VA", "Het-4Mix")


def run() -> list:
    rows = []
    for name in CLUSTERS:
        t0 = time.time()
        recs = get_eval_records(name)
        wall = time.time() - t0
        summ = core.summarize(recs)
        n_dispatch = sum(s["n"] for s in summ.values())
        us = wall / max(n_dispatch, 1) * 1e6
        for disp, s in sorted(summ.items(), key=lambda kv: -kv[1]["mean_gbe"]):
            rows.append(csv_row(
                f"table2_{name}_{disp}", 1e6 * s["mean_seconds"],
                f"gbe={100 * s['mean_gbe']:.2f}%;bw_loss={s['mean_bw_loss']:.2f}GBps",
            ))
        # headline vs Topo delta (paper: +12 / +31 points)
        delta = 100 * (summ["BandPilot"]["mean_gbe"] - summ["Topo"]["mean_gbe"])
        rows.append(csv_row(f"table2_{name}_delta_vs_topo", us,
                            f"+{delta:.1f}pts"))
        # U-shape check: GBE at the extremes vs the middle (Fig. 6)
        by_k = core.gbe_by_k(recs)["BandPilot"]
        ks = sorted(by_k)
        mid = ks[len(ks) // 2]
        rows.append(csv_row(
            f"fig6_{name}_BandPilot_kcurve", us,
            f"k{ks[0]}={100 * by_k[ks[0]]:.1f}%;"
            f"k{mid}={100 * by_k[mid]:.1f}%;"
            f"k{ks[-1]}={100 * by_k[ks[-1]]:.1f}%",
        ))
    return rows
