"""Failure-domain bench (ISSUE 10): fault storms, recovery, retention.

Drives a deterministic 3-event fault storm (gpu_down + nic_flap +
host_down) through the admission scheduler on H100 and Het-4Mix with a
trace of long-running jobs, and measures how much of the pre-fault
aggregate contended bandwidth each policy retains once the storm has been
absorbed (the ``agg_bw_after`` of the last fault's post-event drain over
the ``agg_bw_before`` of the first fault):

  * **recovery** — the full pipeline: victims are checkpoint-released,
    requeued with priority, re-admitted through BandPilot's search;
    nic_flaps run the wait-vs-migrate pricing.
  * **no-recovery** — the counterfactual: victims stay placed on dead
    GPUs (their contended bandwidth grades 0.0) and nothing re-places.
  * **oracle** — the upper bound: every pre-fault job re-placed from
    scratch by the exact ledger-aware Oracle against the post-storm
    health state (what a clairvoyant re-placement could retain).

The ISSUE 10 acceptance bar is asserted on H100: recovery retains >= 80%
while no-recovery retains <= 60%.  Each recovery run writes a write-ahead
journal; the bench replays it and asserts the rebuilt ledger is
bit-identical (allocations + health state + version counter) before
reporting, and every admission along the way is pairwise disjoint by
ledger construction (double-allocation raises, never silently shares).

Rows:
  recovery_storm_{cluster}    — wall us per fault event for the recovery
                                run; retention %% for all three arms,
                                mean/max MTTR, re-admission attempts
  recovery_journal_{cluster}  — journal events written + replay identity
  recovery_seeded_{cluster}   — FaultSchedule.generate storm (seeded)
                                through the same pipeline: retention +
                                recovered/gave-up counts

Knobs: BENCH_STORM_SEED (default 0), BENCH_STORM_EVENTS (default 4).
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

import repro.core as core
from repro.core import faults
from repro.core.baselines import oracle_dispatch
from repro.core.controlplane import replay_journal
from repro.core.scheduler import AdmissionScheduler, SchedulerConfig, TraceJob
from repro.core.tenancy import JobLedger
from benchmarks.common import csv_row

CLUSTERS = ("H100", "Het-4Mix")
STORM_SEED = int(os.environ.get("BENCH_STORM_SEED", "0"))
STORM_EVENTS = int(os.environ.get("BENCH_STORM_EVENTS", "4"))

# acceptance bar (ISSUE 10), asserted on the H100 handcrafted storm
RETENTION_FLOOR_PCT = 80.0
NO_RECOVERY_CEIL_PCT = 60.0


def _storm(cluster, sim, tables, trace):
    """The deterministic 3-event storm: partial gpu_down, a mid-grade
    nic_flap, and a whole-host blackout — each recovering later, so the
    run drains.  Targets are chosen by a dry placement of the trace (same
    dispatcher, same rng) so the storm hits hosts that actually carry
    jobs on every cluster shape, not just H100's packing."""
    disp = core.BandPilotDispatcher(
        cluster, tables, core.GroundTruthPredictor(sim), name="dry",
    )
    for j in sorted(trace, key=lambda j: j.arrival):
        disp.admit(j.job_id, j.k)
    occ = sorted(
        cluster.hosts,
        key=lambda h: (-disp.ledger.occupancy(h.host_id), h.host_id),
    )
    h_gpu, h_flap, h_down = (occ + occ)[:3]  # wrap on tiny clusters
    return [
        faults.FaultEvent(
            t=10.0, kind="gpu_down", host_id=h_gpu.host_id,
            gpus=tuple(h_gpu.gpu_ids[:2]), t_recover=60.0,
        ),
        faults.FaultEvent(
            t=12.0, kind="nic_flap", host_id=h_flap.host_id,
            factor=0.75, t_recover=30.0,
        ),
        faults.FaultEvent(
            t=15.0, kind="host_down", host_id=h_down.host_id,
            gpus=tuple(h_down.gpu_ids), t_recover=50.0,
        ),
    ]


def _trace(cluster):
    """Long-duration jobs admitted before the storm at ~60% occupancy, so
    victims have somewhere to go and the retention measurement isolates
    re-placement quality rather than raw capacity."""
    n = max(3, int(cluster.n_gpus * 0.6) // 4)
    return [TraceJob(f"j{i}", 0.5 + 0.1 * i, 80.0, 4) for i in range(n)]


def _scheduler(cluster, sim, tables, storm, **kw):
    disp = core.BandPilotDispatcher(
        cluster, tables, core.GroundTruthPredictor(sim), name="Ideal-BP",
    )
    return AdmissionScheduler(
        cluster, sim, tables, disp,
        SchedulerConfig(fault_schedule=storm, **kw),
        rng=np.random.default_rng(STORM_SEED),
    )


def _retention(sched) -> float:
    rows = [r for r in sched.fault_log if r["op"] == "fault"]
    pre, post = rows[0]["agg_bw_before"], rows[-1]["agg_bw_after"]
    return 100.0 * post / pre if pre > 0 else float("nan")


def _oracle_retention(cluster, sim, tables, storm, trace) -> float:
    """Clairvoyant upper bound: pre-fault jobs re-placed from scratch by
    the exact Oracle against the health state right after the last fault
    lands (recoveries that fire later do not help it)."""
    t_probe = max(ev.t for ev in storm)
    led = JobLedger(cluster)
    for ev in storm:
        if ev.t <= t_probe:
            led.apply_fault(
                ev.kind, gpus=ev.gpus, host_id=ev.host_id, factor=ev.factor
            )
        if ev.t_recover is not None and ev.t_recover <= t_probe:
            led.apply_recover(ev.kind, gpus=ev.gpus, host_id=ev.host_id)
    # pre-fault aggregate: the same jobs on a healthy ledger, placed the
    # same oracle way (so the ratio compares placements, not predictors)
    healthy = JobLedger(cluster)
    for jobs, ledger in ((trace, healthy), (trace, led)):
        for j in sorted(jobs, key=lambda j: (-j.k, j.job_id)):
            avail = ledger.available()
            if j.k > len(avail):
                continue  # the oracle sheds what cannot fit post-storm
            sub, _ = oracle_dispatch(
                cluster, sim, tables, avail, j.k, ledger=ledger
            )
            ledger.admit(j.job_id, sub)
    pre = sum(
        sim.true_bandwidth(a.gpus, ledger=healthy) for a in healthy.jobs()
    )
    post = sum(sim.true_bandwidth(a.gpus, ledger=led) for a in led.jobs())
    return 100.0 * post / pre if pre > 0 else float("nan")


def _assert_replay_identity(journal_path, ledger, cluster):
    rebuilt = replay_journal(journal_path, cluster)
    live = sorted((a.job_id, a.gpus) for a in ledger.jobs())
    got = sorted((a.job_id, a.gpus) for a in rebuilt.jobs())
    assert live == got, "journal replay diverged on allocations"
    assert ledger.health_state() == rebuilt.health_state(), (
        "journal replay diverged on health state"
    )
    assert ledger.version == rebuilt.version, (
        f"journal replay diverged on version: "
        f"{ledger.version} != {rebuilt.version}"
    )


def run() -> list:
    rows = []
    for name in CLUSTERS:
        cluster = core.PAPER_CLUSTERS[name]()
        sim = core.BandwidthSimulator(cluster)
        tables = core.IntraHostTables(cluster, sim)
        trace = _trace(cluster)
        storm = _storm(cluster, sim, tables, trace)

        with tempfile.TemporaryDirectory() as td:
            jp = os.path.join(td, "recovery.journal")
            sched = _scheduler(
                cluster, sim, tables, storm, journal_path=jp,
            )
            t0 = time.time()
            sched.run(trace)
            wall = time.time() - t0
            _assert_replay_identity(jp, sched.dispatcher.ledger, cluster)
            n_events = sum(
                1 for _ in open(jp)
            )
        no_rec = _scheduler(
            cluster, sim, tables, storm, recovery=False, flap_migrate=False,
        )
        no_rec.run(trace)

        ret = _retention(sched)
        ret_none = _retention(no_rec)
        ret_oracle = _oracle_retention(cluster, sim, tables, storm, trace)
        done = [r for r in sched.recoveries if not r.gave_up]
        mttr = [r.mttr for r in done]
        if name == "H100":
            assert ret >= RETENTION_FLOOR_PCT, (
                f"recovery retained only {ret:.1f}% (< {RETENTION_FLOOR_PCT}%)"
            )
            assert ret_none <= NO_RECOVERY_CEIL_PCT, (
                f"no-recovery retained {ret_none:.1f}% "
                f"(> {NO_RECOVERY_CEIL_PCT}%): the storm is not binding"
            )
        rows.append(csv_row(
            f"recovery_storm_{name}",
            1e6 * wall / max(len(storm), 1),
            f"retention={ret:.1f}%;no_recovery={ret_none:.1f}%;"
            f"oracle={ret_oracle:.1f}%;"
            f"mttr_mean={np.mean(mttr) if mttr else 0.0:.2f};"
            f"mttr_max={max(mttr) if mttr else 0.0:.2f};"
            f"recovered={len(done)};gave_up="
            f"{len(sched.recoveries) - len(done)}",
        ))
        rows.append(csv_row(
            f"recovery_journal_{name}", 0.0,
            f"events={n_events};replay=bit-identical;"
            f"double_alloc=0",
        ))

        seeded = faults.FaultSchedule.generate(
            cluster, seed=STORM_SEED, n_events=STORM_EVENTS,
            t_start=5.0, t_end=60.0, mean_downtime=15.0,
        )
        s2 = _scheduler(cluster, sim, tables, seeded)
        s2.run(trace)
        done2 = [r for r in s2.recoveries if not r.gave_up]
        rows.append(csv_row(
            f"recovery_seeded_{name}", 0.0,
            f"events={len(seeded)};retention={_retention(s2):.1f}%;"
            f"recovered={len(done2)};"
            f"gave_up={len(s2.recoveries) - len(done2)};"
            f"migrations={len(s2.migrations)}",
        ))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run():
        print(row, flush=True)
