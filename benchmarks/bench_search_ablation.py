"""Fig. 10 (ablation 5.5.2): EHA-only vs PTS-only vs full hybrid.

Paper claim: EHA excels on the homogeneous H100 cluster; PTS is what keeps
GBE high on heterogeneous clusters; the hybrid dominates both everywhere.
"""

from __future__ import annotations

import time

import numpy as np

import repro.core as core
from repro.core import baselines, search
from repro.core.cluster import availability_scenario
from benchmarks.common import N_SCENARIOS, csv_row, get_context


class _SingleSearchDispatcher:
    def __init__(self, ctx, which: str):
        self.ctx = ctx
        self.name = which
        self.fn = {"EHA": search.eha_search, "PTS": search.pts_search}[which]

    def dispatch(self, avail, k, rng=None):
        return self.fn(
            self.ctx.cluster, self.ctx.tables, self.ctx.predictor, avail, k
        ).subset


def run() -> list:
    rows = []
    for name in ("H100", "Het-4Mix"):
        ctx = get_context(name)
        ds = [
            core.BandPilotDispatcher(ctx.cluster, ctx.tables, ctx.predictor,
                                     name="Hybrid"),
            _SingleSearchDispatcher(ctx, "EHA"),
            _SingleSearchDispatcher(ctx, "PTS"),
        ]
        t0 = time.time()
        recs = core.evaluate_dispatchers(
            ctx.cluster, ctx.sim, ctx.tables, ds,
            request_sizes=range(4, ctx.cluster.n_gpus, 4),
            n_scenarios=max(N_SCENARIOS // 2, 5), seed=11,
        )
        wall = time.time() - t0
        summ = core.summarize(recs)
        rows.append(csv_row(
            f"fig10_{name}", 1e6 * wall / max(sum(s['n'] for s in summ.values()), 1),
            ";".join(
                f"{d}={100 * summ[d]['mean_gbe']:.1f}%"
                for d in ("Hybrid", "EHA", "PTS")
            ),
        ))
    return rows
