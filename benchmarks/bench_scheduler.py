"""Admission-scheduler policies: fifo vs backfill vs batched (+ re-dispatch).

Replays one seeded Poisson trace per cluster through the Ideal-BP dispatcher
(ground-truth predictor — no surrogate training, so this doubles as the CI
smoke for the scheduler plumbing) under each queue policy, plus a fifo
variant with the release-time elastic re-dispatch hook, and reports mean
queueing wait, mean contention-degraded GBE, and the policy counters
(overtakes / joint batch size / migrations).

Headline (the ISSUE 2 acceptance bar): ``backfill`` and ``batched`` both
cut mean wait versus ``fifo`` while holding mean contention-degraded GBE
within 1 point.

Knobs: BENCH_TRACE_JOBS (default 60), BENCH_TRACE_SEED (default 0),
BENCH_BATCH_WINDOW (default 2.0).
"""

from __future__ import annotations

import os

import numpy as np

import repro.core as core
from benchmarks.common import csv_row

CLUSTERS = ("H100", "Het-4Mix")
N_JOBS = int(os.environ.get("BENCH_TRACE_JOBS", "60"))
SEED = int(os.environ.get("BENCH_TRACE_SEED", "0"))
BATCH_WINDOW = float(os.environ.get("BENCH_BATCH_WINDOW", "2.0"))
MEAN_INTERARRIVAL = 1.0
MEAN_DURATION = 8.0   # ~8 jobs in flight: queueing + contention both bind


def _k_choices(cluster) -> range:
    return range(4, max(cluster.n_gpus // 2, 5) + 1)


def run() -> list:
    rows = []
    for name in CLUSTERS:
        cluster = core.PAPER_CLUSTERS[name]()
        sim = core.BandwidthSimulator(cluster)
        tables = core.IntraHostTables(cluster, sim)
        trace = core.poisson_trace(
            cluster, N_JOBS, np.random.default_rng(SEED),
            mean_interarrival=MEAN_INTERARRIVAL,
            mean_duration=MEAN_DURATION,
            k_choices=_k_choices(cluster),
        )
        configs = {
            "fifo": core.SchedulerConfig(policy="fifo"),
            "backfill": core.SchedulerConfig(policy="backfill"),
            "batched": core.SchedulerConfig(
                policy="batched", batch_window=BATCH_WINDOW
            ),
            "fifo+redispatch": core.SchedulerConfig(
                policy="fifo", redispatch=True
            ),
        }
        schedulers = core.compare_policies(
            cluster, sim, tables,
            lambda: core.BandPilotDispatcher(
                cluster, tables, core.GroundTruthPredictor(sim),
                name="Ideal-BP",
            ),
            trace, configs=configs, seed=SEED,
        )
        summaries = {}
        for pol, sched in schedulers.items():
            s = next(iter(core.summarize_trace(sched.records).values()))
            summaries[pol] = s
            rows.append(csv_row(
                f"sched_{name}_{pol}", 0.0,
                f"wait={s['mean_wait']:.2f};"
                f"gbe={100 * s['mean_gbe']:.2f}%;"
                f"batch={s['mean_batch_size']:.2f};"
                f"overtakes={s['total_overtakes']};"
                f"migrations={len(sched.migrations)}",
            ))
        for pol in ("backfill", "batched"):
            dw = summaries["fifo"]["mean_wait"] - summaries[pol]["mean_wait"]
            dg = 100 * (
                summaries[pol]["mean_gbe"] - summaries["fifo"]["mean_gbe"]
            )
            rows.append(csv_row(
                f"sched_{name}_{pol}_vs_fifo", 0.0,
                f"wait_saved={dw:+.2f};gbe_delta={dg:+.2f}pts",
            ))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run():
        print(row, flush=True)
