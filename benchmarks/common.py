"""Shared benchmark context: clusters, simulators, trained surrogates.

Built once per process and reused across the per-figure benchmarks so
``python -m benchmarks.run`` doesn't retrain the same model five times.
Scenario counts honour BENCH_SCENARIOS (default 20; the paper uses 50 —
EXPERIMENTS.md numbers were produced with BENCH_SCENARIOS=50).
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

import repro.core as core

N_SCENARIOS = int(os.environ.get("BENCH_SCENARIOS", "20"))
N_TRAIN_SAMPLES = 250
SURROGATE_STEPS = int(os.environ.get("BENCH_SURROGATE_STEPS", "2000"))

_CTX: Dict[str, "ClusterContext"] = {}


class ClusterContext:
    def __init__(self, name: str, n_train: int = N_TRAIN_SAMPLES, seed: int = 0):
        self.name = name
        self.cluster = core.PAPER_CLUSTERS[name]()
        self.sim = core.BandwidthSimulator(self.cluster)
        self.tables = core.IntraHostTables(self.cluster, self.sim)
        self.train_set, self.test_set = core.make_train_test_split(
            self.sim, n_train, seed=seed
        )
        t0 = time.time()
        self.params, self.train_info = core.train_surrogate(
            self.cluster, self.tables, self.train_set,
            core.TrainConfig(steps=SURROGATE_STEPS, seed=seed),
        )
        self.train_seconds = time.time() - t0
        self.predictor = core.SurrogatePredictor(
            self.cluster, self.tables, self.params
        )

    def dispatchers(self, include_ideal: bool = True) -> List:
        ds = [
            core.BandPilotDispatcher(self.cluster, self.tables, self.predictor),
        ]
        if include_ideal:
            ds.append(
                core.BandPilotDispatcher(
                    self.cluster, self.tables,
                    core.GroundTruthPredictor(self.sim), name="Ideal-BP",
                )
            )
        ds += [
            core.BaselineDispatcher(self.cluster, k)
            for k in ("topo", "default", "random")
        ]
        return ds


def get_context(name: str) -> ClusterContext:
    if name not in _CTX:
        _CTX[name] = ClusterContext(name)
    return _CTX[name]


_RECORDS: Dict[str, list] = {}


def get_eval_records(name: str, request_sizes=None, n_scenarios=None):
    """Cached dispatcher-evaluation records per cluster (Figs. 6/7, Table 2)."""
    key = name
    if key not in _RECORDS:
        ctx = get_context(name)
        if request_sizes is None:
            request_sizes = range(2, ctx.cluster.n_gpus + 1, 2)
        recs = core.evaluate_dispatchers(
            ctx.cluster, ctx.sim, ctx.tables, ctx.dispatchers(),
            request_sizes=request_sizes,
            n_scenarios=n_scenarios or N_SCENARIOS,
            seed=7,
        )
        _RECORDS[key] = recs
    return _RECORDS[key]


def csv_row(name: str, us_per_call: float, derived) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
