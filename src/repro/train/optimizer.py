"""Optimizers in pure JAX (no optax dependency).

Provides AdamW (+ SGD-momentum) as ``(init_fn, update_fn)`` pairs operating
on arbitrary pytrees, global-norm gradient clipping, and LR schedules.
Used both by the BandPilot surrogate trainer (tiny model, CPU) and by the
large-model training loop (where the optimizer state is FSDP-sharded via the
same pytree structure as the parameters — see repro/parallel/sharding.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray   # scalar int32
    mu: PyTree          # first moment (same structure as params)
    nu: PyTree          # second moment


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: Optional[float] = 1.0
    # dtype for the moments; fp32 master-style by default.
    state_dtype: jnp.dtype = jnp.float32


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree_util.tree_map(lambda x: x * scale.astype(x.dtype), tree), norm


def adamw(
    config: AdamWConfig,
    schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
):
    """Returns (init_fn, update_fn).

    update_fn(grads, state, params) -> (new_params, new_state, metrics)
    """

    def init_fn(params: PyTree) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, dtype=config.state_dtype)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def update_fn(grads: PyTree, state: AdamWState, params: PyTree):
        step = state.step + 1
        lr = config.lr * (schedule(step) if schedule is not None else 1.0)
        metrics = {}
        if config.grad_clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, config.grad_clip_norm)
            metrics["grad_norm"] = gnorm
        b1, b2 = config.b1, config.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(config.state_dtype)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + config.eps)
            if config.weight_decay:
                delta = delta + config.weight_decay * p.astype(config.state_dtype)
            new_p = p.astype(config.state_dtype) - lr * delta
            return new_p.astype(p.dtype), m, v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_mu = treedef.unflatten([o[1] for o in out])
        new_nu = treedef.unflatten([o[2] for o in out])
        metrics["lr"] = lr
        return new_params, AdamWState(step, new_mu, new_nu), metrics

    return init_fn, update_fn


# -- LR schedules -------------------------------------------------------------

def cosine_schedule(total_steps: int, warmup_steps: int = 0, final_frac: float = 0.0):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.clip(step / max(warmup_steps, 1), 0.0, 1.0)
        prog = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return warm * (final_frac + (1.0 - final_frac) * cos)

    return fn


def constant_schedule():
    return lambda step: jnp.ones_like(step, dtype=jnp.float32)
