"""Training step factory + loop.

``make_train_step`` builds the jit-able (params, opt_state, batch) -> ...
function: mixed-precision forward (bf16 compute over fp32 master weights),
remat-able scan groups, AdamW with global-norm clipping.  Under pjit the
optimizer state inherits the parameters' FSDP sharding (ZeRO-style: moments
live sharded; XLA turns the gradient sync into reduce-scatter + all-gather
around the update).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model_zoo import Model
from repro.train.optimizer import AdamWConfig, adamw, cosine_schedule

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainRunConfig:
    optimizer: AdamWConfig = AdamWConfig(lr=3e-4, weight_decay=0.1)
    total_steps: int = 1000
    warmup_steps: int = 100
    remat_policy: str = "nothing"
    compute_dtype: Any = jnp.bfloat16
    grad_accum: int = 1
    kernel_backend: str = "auto"
    scan_unroll: int = 1  # >1: unroll scan-over-layers (exact HLO cost counts)


def make_train_step(
    model: Model, run: TrainRunConfig, grad_shardings: Optional[PyTree] = None
) -> Tuple[Callable, Callable]:
    """Returns (train_step, opt_init).

    ``grad_shardings``: optional NamedSharding pytree (mirroring params);
    when given, gradients are constrained to it before the optimizer update,
    which steers GSPMD toward reduce-scatter (grads arrive pre-sharded for
    the ZeRO update) instead of all-reduce + slice.
    """
    opt_init, opt_update = adamw(
        run.optimizer, cosine_schedule(run.total_steps, run.warmup_steps)
    )

    def loss_fn(params, batch):
        return model.loss(
            params, batch,
            remat_policy=run.remat_policy,
            compute_dtype=run.compute_dtype,
            backend=run.kernel_backend,
            scan_unroll=run.scan_unroll,
        )

    def train_step(params, opt_state, batch):
        if run.grad_accum > 1:
            # microbatch over the leading batch dim.  Statically unrolled:
            # the microbatch count is a config constant, unrolling lets XLA
            # overlap microbatches AND keeps HLO cost analysis exact (loop
            # bodies are tallied once by cost_analysis).
            n = run.grad_accum

            def micro(i):
                mb = jax.tree_util.tree_map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // n), x.shape[0] // n, 0,
                    ),
                    batch,
                )
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                return l, g

            loss, grads = micro(0)
            for i in range(1, n):
                l_i, g_i = micro(i)
                loss = loss + l_i
                grads = jax.tree_util.tree_map(jnp.add, grads, g_i)
            grads = jax.tree_util.tree_map(lambda g: g / n, grads)
            loss = loss / n
            metrics = {"xent": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, batch)
        import os

        if os.environ.get("REPRO_GRAD_SYNC_BF16", "0") == "1":
            # round-trip grads through bf16 so the cross-shard reduction
            # rides the wire at 2 bytes/element (standard large-scale
            # practice; fp32 master accumulation happens in the optimizer)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads
            )
        if grad_shardings is not None:
            grads = jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, grads, grad_shardings
            )
        params, opt_state, om = opt_update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step, opt_init


def train_loop(
    model: Model,
    params: PyTree,
    batches,                      # iterable of batches
    run: TrainRunConfig,
    *,
    log_every: int = 10,
    checkpointer=None,
    checkpoint_every: int = 0,
    start_step: int = 0,
    opt_state: Optional[PyTree] = None,
) -> Tuple[PyTree, PyTree, list]:
    """Single-process training loop (examples / integration tests)."""
    train_step, opt_init = make_train_step(model, run)
    step_fn = jax.jit(train_step, donate_argnums=(0, 1))
    if opt_state is None:
        opt_state = opt_init(params)
    history = []
    t0 = time.time()
    for step, batch in enumerate(batches, start=start_step):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if log_every and (step + 1) % log_every == 0:
            loss = float(metrics["loss"])
            dt = (time.time() - t0) / log_every
            history.append({"step": step + 1, "loss": loss, "s_per_step": dt})
            print(f"step {step + 1}: loss={loss:.4f} ({dt:.2f}s/step)")
            t0 = time.time()
        if checkpointer and checkpoint_every and (step + 1) % checkpoint_every == 0:
            checkpointer.save(step + 1, {"params": params, "opt": opt_state})
    return params, opt_state, history
