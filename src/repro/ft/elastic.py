"""Fault tolerance: failure handling, elastic re-dispatch, stragglers.

The contract at 1000+ node scale:

1. **Checkpoint/restart** — training state (params + optimizer + data step)
   is periodically checkpointed (repro/checkpoint); any crash restarts from
   the latest atomic checkpoint and the deterministic data pipeline replays
   the exact stream.
2. **Node failure -> elastic rescale** — when hosts drop out, the surviving
   pool is *re-dispatched through BandPilot* (the paper's search runs on the
   new availability set), a fresh mesh is built over the chosen devices, and
   parameters are restored into the new sharding.  This is the framework
   integration of the paper: dispatch quality directly sets the post-failure
   collective bandwidth.
3. **Straggler mitigation** — a step-time watchdog flags devices/hosts whose
   step times exceed a robust threshold; persistent stragglers are treated
   as soft failures and trigger the same re-dispatch path (their GPUs are
   marked unavailable), which BandPilot then routes around.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import Cluster
from repro.core.defrag import net_migration_gain
from repro.core.dispatcher import BandPilotDispatcher


@dataclasses.dataclass
class FailureEvent:
    step: int
    failed_gpus: List[int]
    kind: str = "host_failure"  # or "straggler"


class StragglerMonitor:
    """Flags ranks whose step times are persistently above the fleet median.

    Decision rule: a rank is a straggler if its step time exceeds
    ``threshold x median`` for ``patience`` consecutive observations.
    """

    def __init__(self, threshold: float = 1.8, patience: int = 3):
        self.threshold = threshold
        self.patience = patience
        self._strikes: Dict[int, int] = {}

    def observe(self, step_times: Dict[int, float]) -> List[int]:
        """step_times: rank -> seconds.  Returns ranks flagged this round."""
        # prune strikes for ranks no longer reporting (failed, descheduled,
        # or replaced): a stale strike count must not carry over to a rank
        # id that later rejoins with a fresh device
        for rank in list(self._strikes):
            if rank not in step_times:
                del self._strikes[rank]
        med = float(np.median(list(step_times.values())))
        flagged = []
        for rank, t in step_times.items():
            if t > self.threshold * med:
                self._strikes[rank] = self._strikes.get(rank, 0) + 1
                if self._strikes[rank] >= self.patience:
                    flagged.append(rank)
            else:
                self._strikes[rank] = 0
        return flagged


@dataclasses.dataclass
class ElasticDecision:
    new_allocation: List[int]
    predicted_bw: float
    reason: str


class ElasticCoordinator:
    """Owns the availability state and re-dispatches through BandPilot.

    ``migration_cost_per_gpu`` prices voluntary moves: failure handling is
    mandatory (the old placement is gone), but :meth:`consider_rebalance`
    only migrates when the predicted gain beats the migration-cost charge —
    the same :func:`repro.core.defrag.net_migration_gain` rule the admission
    scheduler's release hook and the defrag planner apply.
    """

    def __init__(
        self,
        cluster: Cluster,
        dispatcher: BandPilotDispatcher,
        request_size: int,
        migration_cost_per_gpu: float = 2.0,
    ):
        self.cluster = cluster
        self.dispatcher = dispatcher
        self.request_size = request_size
        self.migration_cost_per_gpu = migration_cost_per_gpu
        self.unavailable: set = set()
        self.current: List[int] = []

    def initial_dispatch(self) -> ElasticDecision:
        avail = [g for g in self.cluster.all_gpus() if g not in self.unavailable]
        sub = self.dispatcher.dispatch(avail, self.request_size)
        self.current = sub
        bw = self.dispatcher.last_result.predicted_bw
        return ElasticDecision(sub, bw, "initial")

    def handle_failure(self, event: FailureEvent) -> ElasticDecision:
        """Mark GPUs dead, shrink the request if needed, re-dispatch."""
        self.unavailable.update(event.failed_gpus)
        avail = [g for g in self.cluster.all_gpus() if g not in self.unavailable]
        # elastic scale-down: keep request a multiple of the host size when
        # possible so mesh factorizations stay clean
        k = min(self.request_size, len(avail))
        # round to the SURVIVING pool's dominant host size, not hosts[0]'s:
        # on a heterogeneous cluster (or when host 0 itself died) the old
        # ``hosts[0].n_gpus`` rounding produced request sizes no surviving
        # host shape can factorize cleanly
        by_size: Dict[int, int] = {}
        for g in avail:
            n = self.cluster.hosts[self.cluster.gpu_host[g]].n_gpus
            by_size[n] = by_size.get(n, 0) + 1
        host_n = max(by_size, key=lambda n: (by_size[n], n))
        if k > host_n:
            k -= k % host_n
        if k == 0:
            raise RuntimeError("no survivors to dispatch")
        sub = self.dispatcher.dispatch(avail, k)
        self.current = sub
        bw = self.dispatcher.last_result.predicted_bw
        return ElasticDecision(sub, bw, event.kind)

    def consider_rebalance(self) -> Optional[ElasticDecision]:
        """Opportunistic elastic re-dispatch (no failure forcing it).

        After recovery events — co-tenants departing, stragglers returning
        to the pool — the current placement may have become stale.  Re-run
        the search over the surviving pool and migrate only when the
        predicted bandwidth gain exceeds the migration-cost charge for the
        GPUs that would move.  Returns the decision, or None to stay put.
        """
        if not self.current:
            raise RuntimeError("no current allocation; dispatch first")
        avail = [g for g in self.cluster.all_gpus() if g not in self.unavailable]
        # grade the incumbent with the same lens the search scores the
        # challenger: the dispatcher's ledger-aware contended predictor when
        # one is attached (the old isolated-predictor baseline overstated
        # cur_bw under co-tenancy, vetoing moves whose real gain paid)
        wrapper = getattr(self.dispatcher, "contention_predictor", None)
        scorer = wrapper if wrapper is not None else self.dispatcher.predictor
        cur_bw = float(np.asarray(scorer.predict([self.current]))[0])
        sub = self.dispatcher.dispatch(avail, len(self.current))
        new_bw = self.dispatcher.last_result.predicted_bw
        gain = net_migration_gain(
            self.current, sub, cur_bw, new_bw, self.migration_cost_per_gpu
        )
        if sorted(sub) == sorted(self.current) or gain <= 0:
            return None
        self.current = sub
        return ElasticDecision(sub, new_bw, "rebalance")


def run_elastic_training(
    coordinator: ElasticCoordinator,
    build_and_train: Callable[[List[int], int], Tuple[int, float]],
    failures: Sequence[FailureEvent],
    total_steps: int,
) -> List[Dict]:
    """Drive train -> fail -> re-dispatch -> restore -> train to completion.

    ``build_and_train(allocation, start_step)`` trains until the next
    failure (or the end) and returns (reached_step, last_loss).  Checkpoint
    save/restore is the callee's job (see examples/elastic_recovery.py).
    """
    log: List[Dict] = []
    decision = coordinator.initial_dispatch()
    log.append({"event": "dispatch", "alloc": decision.new_allocation,
                "bw": decision.predicted_bw})
    step = 0
    pending = sorted(failures, key=lambda f: f.step)
    for event in pending + [None]:
        until = event.step if event else total_steps
        if until > step:
            step, loss = build_and_train(coordinator.current, step)
            log.append({"event": "train", "until": step, "loss": loss})
        if event is None or step >= total_steps:
            break
        decision = coordinator.handle_failure(event)
        log.append({
            "event": "redispatch", "kind": event.kind,
            "failed": event.failed_gpus,
            "alloc": decision.new_allocation, "bw": decision.predicted_bw,
        })
    return log
