"""Assigned input-shape cells and ShapeDtypeStruct input specs.

Four LM shapes x ten architectures = 40 cells.  ``train_*``/``prefill_*``
lower the training/prefill step; ``decode_*``/``long_*`` lower
``serve_step`` (one token against a seq_len cache).  ``long_500k`` requires
sub-quadratic sequence mixing and therefore only runs for the SSM/hybrid
archs (skips are explicit, with reasons, so the cell table accounts for all
40).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

# Sub-quadratic sequence mixing is required at 500k; these families qualify.
LONG_CONTEXT_ARCHS = ("rwkv6-7b", "recurrentgemma-9b")


def cell_skip_reason(arch: str, shape: str) -> Optional[str]:
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return (
            "pure full-attention backbone: 500k-token decode needs a "
            "sub-quadratic mixer (see DESIGN.md §Arch-applicability)"
        )
    return None


def all_cells() -> List[Tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in SHAPES]


def runnable_cells() -> List[Tuple[str, str]]:
    return [(a, s) for a, s in all_cells() if cell_skip_reason(a, s) is None]


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def train_input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict:
    B, S = cell.global_batch, cell.seq_len
    batch = {
        "tokens": _sds((B, S), jnp.int32),
        "labels": _sds((B, S), jnp.int32),
    }
    if cfg.is_encoder_decoder:
        # audio backbone: the "sequence" is the encoder frame axis (stub
        # frontend supplies embeddings); decoder sees the token stream.
        dec_len = min(S, cfg.max_seq_len)
        batch = {
            "frames": _sds((B, S, cfg.d_model), jnp.bfloat16),
            "tokens": _sds((B, dec_len), jnp.int32),
            "labels": _sds((B, dec_len), jnp.int32),
        }
    elif cfg.frontend:
        batch["prefix_embeds"] = _sds(
            (B, cfg.frontend_seq_len, cfg.d_model), jnp.bfloat16
        )
    return batch


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> Dict:
    """eval_shape over init_cache — exact pytree of ShapeDtypeStructs."""
    from repro.models.model_zoo import build_model

    model = build_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(batch, cache_len, dtype)
    )


def decode_input_specs(
    cfg: ModelConfig, cell: ShapeCell, cache_dtype=jnp.bfloat16
) -> Tuple[Dict, Dict]:
    """-> (cache_specs, token_specs) for serve_step."""
    B, S = cell.global_batch, cell.seq_len
    cache = cache_specs(cfg, B, S, cache_dtype)
    tokens = _sds((B, 1), jnp.int32)
    return cache, tokens


def memory_specs(cfg: ModelConfig, cell: ShapeCell) -> Optional[jax.ShapeDtypeStruct]:
    """Encoder memory for enc-dec decode cells."""
    if not cfg.is_encoder_decoder:
        return None
    return _sds((cell.global_batch, cfg.frontend_seq_len, cfg.d_model),
                jnp.bfloat16)


def param_specs_shapes(cfg: ModelConfig, dtype=jnp.float32):
    """eval_shape over init — parameter ShapeDtypeStructs (no allocation)."""
    from repro.models.model_zoo import build_model

    model = build_model(cfg)
    return jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), dtype=dtype)
    )
