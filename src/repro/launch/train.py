"""Training launcher with BandPilot dispatch as a first-class feature.

  PYTHONPATH=src python -m repro.launch.train \
      --arch gemma-7b --reduced --steps 100 --dispatcher bandpilot \
      --devices 8 --mesh 4x2

Flow: (1) model the device pool as a cluster (hosts of 8), (2) dispatch k
devices through the requested policy (BandPilot = surrogate + hybrid
search), (3) build the mesh over the *chosen, ordered* devices, (4) train
under pjit with the FSDP x TP sharding rules, with checkpointing and the
deterministic data pipeline.

On this CPU container the pool is simulated (``--devices N`` forces N XLA
host devices — set before jax import); on real TPU/GPU fleets the same code
paths consume the actual device list.
"""

import argparse
import os
import sys


def _parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--dispatcher", default="bandpilot",
                    choices=["bandpilot", "topo", "default", "random", "none"])
    ap.add_argument("--devices", type=int, default=0,
                    help="force N simulated devices (CPU container)")
    ap.add_argument("--request", type=int, default=0,
                    help="device count to dispatch (default: all)")
    ap.add_argument("--mesh", default="",
                    help="mesh shape for the dispatched devices, e.g. 4x2")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def main(argv=None):
    args = _parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.core as core
    from repro.checkpoint.ckpt import Checkpointer
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.launch.mesh import bandpilot_mesh
    from repro.models.model_zoo import build_model
    from repro.parallel import sharding as shd
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_loop import TrainRunConfig, make_train_step

    devices = jax.devices()
    n_dev = len(devices)
    k = args.request or n_dev
    print(f"pool: {n_dev} devices; request k={k}; dispatcher={args.dispatcher}")

    # -- dispatch ---------------------------------------------------------
    dispatcher = None
    if args.dispatcher != "none" and n_dev > 1:
        hosts = max(1, n_dev // 8)
        cluster = core.tpu_pod_cluster(hosts) if n_dev >= 8 else core.Cluster(
            [("TPU_V5E", 1)], name="local"
        )
        sim = core.BandwidthSimulator(cluster)
        tables = core.IntraHostTables(cluster, sim)
        if args.dispatcher == "bandpilot":
            dispatcher = core.BandPilotDispatcher(
                cluster, tables, core.GroundTruthPredictor(sim)
            )
        else:
            dispatcher = core.BaselineDispatcher(cluster, args.dispatcher)

    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
    else:
        shape = (k, 1)
    axes = ("data", "model")[: len(shape)]
    if len(shape) == 1:
        axes = ("data",)
    mesh, chosen = bandpilot_mesh(dispatcher, devices, k, shape, axes)
    print(f"dispatched devices: {chosen}; mesh {dict(zip(axes, shape))}")

    # -- model + data -------------------------------------------------------
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    data = SyntheticLM(DataConfig(
        cfg.vocab_size, args.seq_len, args.global_batch, seed=args.seed
    ))

    run = TrainRunConfig(
        optimizer=AdamWConfig(lr=args.lr, weight_decay=0.01),
        total_steps=args.steps, warmup_steps=min(20, args.steps // 5),
        compute_dtype=jnp.float32 if args.reduced else jnp.bfloat16,
    )
    train_step, opt_init = make_train_step(model, run)

    rules = shd.STRATEGIES["fsdp_tp"]()
    param_sh = shd.param_shardings(mesh, rules, params)
    params = jax.device_put(params, param_sh)
    opt_state = jax.jit(opt_init, out_shardings=None)(params)

    ck = None
    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir, keep=2, async_save=True)

    with mesh, shd.use_sharding(mesh, rules):
        step_fn = jax.jit(train_step, donate_argnums=(0, 1))
        import time
        t0 = time.time()
        for step in range(args.steps):
            batch = {k_: jnp.asarray(v) for k_, v in data.batch(step).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if (step + 1) % args.log_every == 0:
                dt = (time.time() - t0) / args.log_every
                print(f"step {step + 1}: loss={float(metrics['loss']):.4f} "
                      f"({dt:.2f}s/step)", flush=True)
                t0 = time.time()
            if ck and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                ck.save(step + 1, {"params": params, "opt": opt_state})
    if ck:
        ck.wait()
    print("training complete")
    return params


if __name__ == "__main__":
    main()
