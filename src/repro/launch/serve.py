"""Serving launcher: batched generation with BandPilot-dispatched devices.

  PYTHONPATH=src python -m repro.launch.serve \
      --arch gemma2-9b --reduced --batch 4 --max-new 16 --devices 8
"""

import argparse
import os


def _parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--dispatcher", default="bandpilot")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def main(argv=None):
    args = _parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import time

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models.model_zoo import build_model
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    prompts = [
        rng.integers(0, cfg.vocab_size, rng.integers(4, args.prompt_len + 1))
        .tolist()
        for _ in range(args.batch)
    ]
    eng = ServeEngine(model, params, ServeConfig(
        max_len=args.max_len, max_new_tokens=args.max_new
    ))
    t0 = time.time()
    outs = eng.generate(prompts, rng_seed=args.seed)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in outs)
    for i, o in enumerate(outs):
        print(f"req{i}: prompt={prompts[i][:6]}... -> {o}")
    print(f"generated {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s batched)")
    return outs


if __name__ == "__main__":
    main()
