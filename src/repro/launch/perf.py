import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Perf-iteration driver: hypothesis -> change -> re-lower -> validate.

Each named experiment is a set of knobs over the same cell; the driver
compiles baseline + variants, prints the three roofline terms side by side,
and appends a JSONL log consumed by EXPERIMENTS.md §Perf.

  PYTHONPATH=src python -m repro.launch.perf --cell gemma-7b:train_4k \
      --exp baseline bf16_wire rs_grads --out results/perf_gemma
"""

import argparse
import json
import time

from repro.configs import get_config
from repro.launch import shapes as shp, steps, roofline, hlo_analysis
from repro.launch.dryrun import _cell_costs
from repro.launch.mesh import make_production_mesh
from repro.parallel import sharding as shd

# ---------------------------------------------------------------------------
# Experiment registry: name -> dict of knobs.
#   env: environment variables set during lowering (trace-time knobs)
#   strategy / rules_patch / remat / constrain_grads: builder knobs
# ---------------------------------------------------------------------------

EXPERIMENTS = {
    "baseline": {},
    # H1: fp32 activations ride the ICI for SP all-gathers; bf16-on-wire
    # should ~halve attention-side collective bytes.
    "bf16_wire": {"env": {"REPRO_ATTN_BF16_WIRE": "1"}},
    # H2: constraining grads to the FSDP shards turns all-reduce(+slice)
    # into reduce-scatter (~2x less gradient wire traffic).
    "rs_grads": {"constrain_grads": True},
    "bf16_wire+rs_grads": {"env": {"REPRO_ATTN_BF16_WIRE": "1"},
                           "constrain_grads": True},
    # H3: drop residual-stream sequence sharding (ablation — more memory,
    # fewer gathers?)
    "no_seq_shard": {"strategy": "fsdp_tp_noseq"},
    # H4 (MoE): 2D expert sharding — experts on "model", expert-ff on
    # "data"; expert weights never gathered (replaces per-layer FSDP
    # gathers with token all-to-alls).
    "moe_ep2d": {"rules_patch": {"experts": "model", "ff": "data",
                                 "embed": None}},
    "moe_ep2d+bf16_wire": {
        "rules_patch": {"experts": "model", "ff": "data", "embed": None},
        "env": {"REPRO_ATTN_BF16_WIRE": "1"},
    },
    "moe_ep2d+bf16_wire+rs_grads": {
        "rules_patch": {"experts": "model", "ff": "data", "embed": None},
        "env": {"REPRO_ATTN_BF16_WIRE": "1"},
        "constrain_grads": True,
    },
    # H5: remat policy — save matmul outputs (less recompute, more memory)
    "remat_dots": {"remat": "dots_with_no_batch_dims"},
    # H6: chunk attention scores at train seq lens (peak-memory lever: the
    # unchunked jnp path materializes fp32 S^2 scores per layer)
    "chunked_attn": {"env": {"REPRO_ATTN_CHUNK_THRESHOLD": "2097152"}},
    "chunked+bf16_wire": {
        "env": {"REPRO_ATTN_CHUNK_THRESHOLD": "2097152",
                "REPRO_ATTN_BF16_WIRE": "1"},
    },
    "chunked+bf16_wire+rs_grads": {
        "env": {"REPRO_ATTN_CHUNK_THRESHOLD": "2097152",
                "REPRO_ATTN_BF16_WIRE": "1"},
        "constrain_grads": True,
    },
    # H7: pin the master-weight bf16 cast before the FSDP gather
    "cast_barrier": {"env": {"REPRO_CAST_BARRIER": "1"}},
    # H8: gradient sync in bf16 (2 bytes on the wire)
    "grad_bf16": {"env": {"REPRO_GRAD_SYNC_BF16": "1"}},
    "kitchen_sink": {
        "env": {"REPRO_ATTN_CHUNK_THRESHOLD": "2097152",
                "REPRO_ATTN_BF16_WIRE": "1",
                "REPRO_CAST_BARRIER": "1",
                "REPRO_GRAD_SYNC_BF16": "1"},
        "constrain_grads": True,
    },
    "moe_kitchen_sink": {
        "rules_patch": {"experts": "model", "ff": "data", "embed": None},
        "env": {"REPRO_ATTN_CHUNK_THRESHOLD": "2097152",
                "REPRO_ATTN_BF16_WIRE": "1",
                "REPRO_CAST_BARRIER": "1",
                "REPRO_GRAD_SYNC_BF16": "1"},
        "constrain_grads": True,
    },
    # H9: Megatron-SP transition — gather activations (not weights) at the
    # SP x TP conflict points.  sp_gather alone vs the no-gather ablation.
    "sp_gather_off": {"env": {"REPRO_SP_GATHER": "0"}},
    # H10: gradient accumulation — 8 microbatches shrink activation
    # transients ~8x (the fit-in-HBM lever for 110B-class trains)
    "accum8": {"grad_accum": 8},
    "chunked+accum8": {
        "env": {"REPRO_ATTN_CHUNK_THRESHOLD": "2097152"},
        "grad_accum": 8,
    },
    # Final "optimized" configurations (what the post-hillclimb sweep uses)
    "optimized": {
        "env": {"REPRO_ATTN_CHUNK_THRESHOLD": "2097152",
                "REPRO_ATTN_BF16_WIRE": "1"},
    },
    "optimized_moe": {
        "rules_patch": {"experts": "model", "ff": "data", "embed": None},
        "env": {"REPRO_ATTN_CHUNK_THRESHOLD": "2097152",
                "REPRO_ATTN_BF16_WIRE": "1"},
    },
}


def run_experiment(arch: str, shape: str, exp_name: str, multi_pod=False):
    knobs = EXPERIMENTS[exp_name]
    env = knobs.get("env", {})
    old_env = {}
    for k, v in env.items():
        old_env[k] = os.environ.get(k)
        os.environ[k] = v
    try:
        cfg = get_config(arch)
        cell = shp.SHAPES[shape]
        mesh = make_production_mesh(multi_pod=multi_pod)
        strategy = knobs.get(
            "strategy", "serve_2d" if cell.kind == "decode" else "fsdp_tp"
        )
        rules = shd.STRATEGIES[strategy]()
        rules.update(knobs.get("rules_patch", {}))
        remat = knobs.get("remat", "nothing")
        builder_kw = dict(
            strategy=strategy, remat_policy=remat, rules_override=rules,
        )
        t0 = time.time()
        step = steps.build_step(
            cfg, cell, mesh,
            constrain_grads=knobs.get("constrain_grads", False),
            grad_accum=knobs.get("grad_accum", 1),
            **builder_kw,
        )
        compiled = step.compile()
        costs = _cell_costs(cfg, cell, mesh, 256, strategy, remat, rules,
                            grad_accum=knobs.get("grad_accum", 1))
        rep = roofline.analyze_from_costs(
            arch, cfg, shape, cell.kind,
            "2x16x16" if multi_pod else "16x16",
            mesh.devices.size, costs, compiled,
            cell.global_batch, cell.seq_len,
        )
        mem = compiled.memory_analysis()
        return {
            "experiment": exp_name,
            "arch": arch, "shape": shape,
            "wall_s": round(time.time() - t0, 1),
            "compute_ms": 1e3 * rep.compute_s,
            "memory_ms": 1e3 * rep.memory_s,
            "collective_ms": 1e3 * rep.collective_s,
            "bottleneck": rep.bottleneck,
            "useful_ratio": rep.useful_ratio,
            "roofline_frac": rep.roofline_fraction,
            "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 2**30,
            "ici_gb": rep.ici_bytes / 2**30,
            "dcn_gb": rep.dcn_bytes / 2**30,
        }
    finally:
        for k, v in old_env.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--exp", nargs="+", default=["baseline"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    arch, shape = args.cell.split(":")

    results = []
    for exp in args.exp:
        try:
            r = run_experiment(arch, shape, exp, args.multi_pod)
        except Exception as e:
            import traceback
            traceback.print_exc()
            r = {"experiment": exp, "arch": arch, "shape": shape,
                 "error": f"{type(e).__name__}: {e}"}
        results.append(r)
        if "error" not in r:
            print(f"[{exp:<28}] compute={r['compute_ms']:8.1f}ms "
                  f"memory={r['memory_ms']:8.1f}ms "
                  f"collective={r['collective_ms']:8.1f}ms "
                  f"({r['bottleneck']}-bound) useful={r['useful_ratio']:.2f} "
                  f"roofline={100 * r['roofline_frac']:.1f}% "
                  f"temp={r['temp_gb']:.1f}G", flush=True)
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out + ".jsonl", "a") as f:
                f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
