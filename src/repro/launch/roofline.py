"""Roofline model for the dry-run: three terms per (arch x shape x mesh).

Hardware constants (TPU v5e target):
  peak compute   197 TFLOP/s bf16 per chip
  HBM bandwidth  819 GB/s per chip
  ICI links      ~50 GB/s per link (per chip, per direction)
  DCN (inter-pod) ~25 GB/s per chip effective

Terms (seconds, per step, per chip — SPMD modules are per-device):
  compute    = HLO_FLOPs / 197e12
  memory     = HLO_bytes  / 819e9
  collective = ICI_bytes / 50e9  +  DCN_bytes / 25e9

plus MODEL_FLOPS = 6 N D (dense) or 6 N_active D (MoE) per chip, and the
usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat/dispatch waste).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.configs.base import ModelConfig
from repro.launch import hlo_analysis

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link
DCN_BW = 25e9             # bytes/s per chip across pods (effective)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    kind: str
    n_chips: int
    hlo_flops: float
    hlo_bytes: float
    ici_bytes: float
    dcn_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    useful_ratio: float
    bottleneck: str
    peak_memory_bytes: Optional[float] = None
    by_kind: Optional[Dict[str, int]] = None

    @property
    def step_time_s(self) -> float:
        """Optimistic no-overlap-needed estimate: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute / step-time vs peak: how close to roofline."""
        if self.step_time_s <= 0:
            return 0.0
        return (self.model_flops / PEAK_FLOPS) / self.step_time_s

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "kind": self.kind, "chips": self.n_chips,
            "compute_ms": 1e3 * self.compute_s,
            "memory_ms": 1e3 * self.memory_s,
            "collective_ms": 1e3 * self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_frac": self.roofline_fraction,
            "peak_mem_gb": (self.peak_memory_bytes or 0) / 2**30,
        }


def model_flops_per_step(cfg: ModelConfig, batch: int, seq: int, kind: str,
                         n_chips: int) -> float:
    """6*N*D (train) or 2*N*D (forward-only) per chip; MoE uses active N.

    Encoder-decoder: the encoder processes the frame sequence while the
    decoder processes only its (much shorter) token stream, so N*D splits
    per stack — 6*(N_enc*D_frames + N_dec*D_dec) with D_dec bounded by the
    decoder's native context.
    """
    mult = 6.0 if kind == "train" else 2.0
    tokens = batch * (seq if kind in ("train", "prefill") else 1)
    if cfg.is_encoder_decoder:
        d, ff = cfg.d_model, cfg.d_ff
        attn = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
        gates = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
        mlp = gates * d * ff
        n_enc = cfg.n_encoder_layers * (attn + mlp)
        n_dec = cfg.n_layers * (2 * attn + mlp) + cfg.vocab_size * d
        if kind == "decode":
            return mult * n_dec * batch / n_chips
        dec_tokens = batch * min(seq, cfg.max_seq_len)
        return mult * (n_enc * tokens + n_dec * dec_tokens) / n_chips
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    return mult * n * tokens / n_chips


def analyze(
    arch: str,
    cfg: ModelConfig,
    shape_name: str,
    kind: str,
    mesh_name: str,
    n_chips: int,
    pod_size: int,
    compiled,
    hlo_text: str,
    batch_global: int,
    seq_len: int,
) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    coll = hlo_analysis.collective_summary(hlo_text, pod_size=pod_size)
    costs = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "ici": float(coll["ici_bytes"]),
        "dcn": float(coll["dcn_bytes"]),
        "by_kind": coll["by_kind"],
    }
    return analyze_from_costs(
        arch, cfg, shape_name, kind, mesh_name, n_chips, costs, compiled,
        batch_global, seq_len,
    )


def analyze_from_costs(
    arch: str,
    cfg: ModelConfig,
    shape_name: str,
    kind: str,
    mesh_name: str,
    n_chips: int,
    costs: Dict,
    compiled,
    batch_global: int,
    seq_len: int,
) -> RooflineReport:
    flops = costs["flops"]
    byts = costs["bytes"]
    coll = {"ici_bytes": costs["ici"], "dcn_bytes": costs["dcn"],
            "by_kind": costs.get("by_kind", {})}
    ici_s = coll["ici_bytes"] / ICI_BW
    dcn_s = coll["dcn_bytes"] / DCN_BW
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = ici_s + dcn_s
    mf = model_flops_per_step(cfg, batch_global, seq_len, kind, n_chips)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    peak_mem = None
    try:
        ma = compiled.memory_analysis()
        peak_mem = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)
        )
    except Exception:
        pass
    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, kind=kind,
        n_chips=n_chips, hlo_flops=flops, hlo_bytes=byts,
        ici_bytes=float(coll["ici_bytes"]), dcn_bytes=float(coll["dcn_bytes"]),
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=mf, useful_ratio=(mf / flops if flops else 0.0),
        bottleneck=bottleneck, peak_memory_bytes=peak_mem,
        by_kind=coll["by_kind"],
    )


def format_table(reports) -> str:
    header = (
        f"{'arch':<22} {'shape':<12} {'mesh':<10} {'chips':>5} "
        f"{'compute':>9} {'memory':>9} {'collect':>9} {'bound':>10} "
        f"{'useful':>7} {'roofl%':>7} {'mem/chip':>9}"
    )
    lines = [header, "-" * len(header)]
    for r in reports:
        row = r.row()
        lines.append(
            f"{row['arch']:<22} {row['shape']:<12} {row['mesh']:<10} "
            f"{row['chips']:>5} {row['compute_ms']:>8.1f}ms "
            f"{row['memory_ms']:>8.1f}ms {row['collective_ms']:>8.1f}ms "
            f"{row['bottleneck']:>10} {row['useful_ratio']:>7.2f} "
            f"{100 * row['roofline_frac']:>6.1f}% {row['peak_mem_gb']:>8.2f}G"
        )
    return "\n".join(lines)
