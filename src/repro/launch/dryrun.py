import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes, print memory/cost analysis, emit roofline rows.

The two lines above MUST precede any jax-importing module: jax pins the
device count at first backend init, and the dry-run needs 512 placeholder
CPU devices to build the (2, 16, 16) multi-pod mesh.  (Tests and benches
must NOT inherit this — it is set here only, never in conftest/pyproject.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, get_config
from repro.launch import hlo_analysis, roofline, shapes as shp, steps
from repro.launch.mesh import make_production_mesh


def _cell_costs(cfg, cell, mesh, pod_size, strategy, remat_policy,
                rules_override, grad_accum=1):
    """Exact per-device (flops, bytes, ici, dcn, by_kind) via two-point
    extrapolation over UNROLLED reduced-depth compiles.

    XLA's cost_analysis tallies while-loop bodies once, so the loop-form
    module undercounts by ~n_groups.  With unrolled scans the counts are
    exact and linear in depth: cost(k groups) = outside + k * body.  Two
    cheap compiles at 1 and 2 groups (tail layers included in both) give
    the exact body delta; the full total follows analytically.
    """
    import dataclasses as _dc

    p = len(cfg.mixer_pattern)
    n_groups, n_tail = cfg.n_groups_and_tail()

    def variant(groups):
        kw = {"n_layers": groups * p + n_tail}
        if cfg.is_encoder_decoder:
            kw["n_encoder_layers"] = groups
        return _dc.replace(cfg, **kw)

    def measure(vcfg, unroll):
        step = steps.build_step(vcfg, cell, mesh, strategy=strategy,
                                remat_policy=remat_policy,
                                rules_override=rules_override,
                                scan_unroll=unroll,
                                grad_accum=grad_accum)
        compiled = step.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        coll = hlo_analysis.collective_summary(compiled.as_text(),
                                               pod_size=pod_size)
        return {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "ici": float(coll["ici_bytes"]),
            "dcn": float(coll["dcn_bytes"]),
            "by_kind": coll["by_kind"],
        }

    c1 = measure(variant(1), unroll=1 + (1 if n_tail else 0))
    if n_groups <= 1:
        return c1
    c2 = measure(variant(2), unroll=2 + (1 if n_tail else 0))
    extra = n_groups - 1
    out = {}
    for key in ("flops", "bytes", "ici", "dcn"):
        body = c2[key] - c1[key]
        # GSPMD occasionally picks different collectives at tiny depths;
        # clamp so extrapolation never dips below the 1-group floor.
        out[key] = max(c1[key] + extra * body, c1[key] if body >= 0 else 0.0)
    by_kind = {}
    for k in set(c1["by_kind"]) | set(c2["by_kind"]):
        b1 = c1["by_kind"].get(k, 0)
        b2 = c2["by_kind"].get(k, 0)
        by_kind[k] = max(int(b1 + extra * (b2 - b1)), 0)
    out["by_kind"] = by_kind
    return out


def run_cell(
    arch: str,
    shape: str,
    multi_pod: bool,
    strategy: str = "fsdp_tp",
    remat_policy: str = "nothing",
    verbose: bool = True,
    rules_override=None,
):
    """Lower+compile one cell.  Returns (roofline_report, record_dict)."""
    cfg = get_config(arch)
    cell = shp.SHAPES[shape]
    skip = shp.cell_skip_reason(arch, shape)
    if skip:
        return None, {"arch": arch, "shape": shape, "status": "skipped",
                      "reason": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_chips = mesh.devices.size
    pod_size = 256
    if cell.kind == "decode" and strategy == "fsdp_tp":
        strategy = "serve_2d"  # weight-stationary decode default
    # (1) loop-form full-depth compile: proves the sharding config is
    # coherent at full scale and yields the realistic memory analysis.
    t0 = time.time()
    step = steps.build_step(cfg, cell, mesh, strategy=strategy,
                            remat_policy=remat_policy,
                            rules_override=rules_override)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = step.compile()
    t_compile = time.time() - t0
    # (2) exact cost terms via unrolled reduced-depth extrapolation.
    costs = _cell_costs(cfg, cell, mesh, pod_size, strategy, remat_policy,
                        rules_override)
    rep = roofline.analyze_from_costs(
        arch, cfg, shape, cell.kind, mesh_name, n_chips,
        costs, compiled, cell.global_batch, cell.seq_len,
    )
    mem = compiled.memory_analysis()
    record = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "strategy": strategy, "remat": remat_policy, "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": {
            "argument_gb": getattr(mem, "argument_size_in_bytes", 0) / 2**30,
            "output_gb": getattr(mem, "output_size_in_bytes", 0) / 2**30,
            "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 2**30,
            "alias_gb": getattr(mem, "alias_size_in_bytes", 0) / 2**30,
        },
        "cost_analysis": {
            "flops": rep.hlo_flops, "bytes": rep.hlo_bytes,
        },
        "collectives": {
            "ici_bytes": rep.ici_bytes, "dcn_bytes": rep.dcn_bytes,
            "by_kind": rep.by_kind,
        },
        "roofline": rep.row(),
    }
    if verbose:
        ma = record["memory_analysis"]
        print(f"[{arch} x {shape} x {mesh_name}] lower={t_lower:.1f}s "
              f"compile={t_compile:.1f}s")
        print(f"  memory/device: args={ma['argument_gb']:.2f}G "
              f"out={ma['output_gb']:.2f}G temp={ma['temp_gb']:.2f}G "
              f"(aliased {ma['alias_gb']:.2f}G)")
        print(f"  cost: {rep.hlo_flops/1e12:.2f} TFLOP, "
              f"{rep.hlo_bytes/2**30:.2f} GiB touched; collectives: "
              f"ICI {rep.ici_bytes/2**20:.1f} MiB, DCN {rep.dcn_bytes/2**20:.1f} MiB")
        print(f"  roofline: compute={1e3*rep.compute_s:.1f}ms "
              f"memory={1e3*rep.memory_s:.1f}ms "
              f"collective={1e3*rep.collective_s:.1f}ms "
              f"-> {rep.bottleneck}-bound, useful={rep.useful_ratio:.2f}, "
              f"roofline={100*rep.roofline_fraction:.1f}%")
    return rep, record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="off")
    ap.add_argument("--strategy", default="fsdp_tp")
    ap.add_argument("--remat", default="nothing")
    ap.add_argument("--out", default=None, help="JSONL output path prefix")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, (
        "dry-run requires 512 placeholder devices; do not import jax before "
        "this module sets XLA_FLAGS"
    )

    cells = (
        shp.all_cells() if args.all
        else [(args.arch or "gemma-7b", args.shape or "train_4k")]
    )
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]

    out_path = None
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        out_path = args.out + ".jsonl"
        open(out_path, "w").close()  # truncate

    reports, records = [], []
    failures = []
    for arch, shape in cells:
        for mp in pods:
            try:
                rep, rec = run_cell(arch, shape, mp, args.strategy, args.remat)
            except Exception as e:  # a failure here is a bug in the system
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "FAILED", "error": f"{type(e).__name__}: {e}"}
                rep = None
                failures.append(rec)
            records.append(rec)
            if rep:
                reports.append(rep)
            if out_path:  # incremental flush: sweep progress survives crashes
                with open(out_path, "a") as f:
                    f.write(json.dumps(rec) + "\n")
            if rec["status"] == "skipped":
                print(f"[{arch} x {shape}] SKIPPED: {rec['reason']}")
                break  # skip applies to both meshes

    if reports:
        print("\n" + roofline.format_table(reports))
    if out_path:
        print(f"\nwrote {len(records)} records to {out_path}")
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
