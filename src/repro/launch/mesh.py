"""Production mesh construction (+ BandPilot-ordered device selection).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod (16, 16) = 256 chips with ("data", "model")
axes, or multi-pod (2, 16, 16) = 512 chips with ("pod", "data", "model").
Axis placement follows TPU practice: the fast ICI fabric carries the
"model" (TP/EP) axis, "data" runs FSDP over ICI, and the slow DCN fabric
carries the "pod" axis (pure DP / optional pipeline).

``bandpilot_mesh`` is the framework integration of the paper: given a device
pool and a request size, BandPilot selects *which* devices form the mesh
(balanced across hosts to maximize collective bandwidth) and orders them
host-major so the mesh's fastest-changing axis stays intra-host.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_from_devices(
    devices: Sequence, shape: Tuple[int, ...], axes: Tuple[str, ...]
):
    """Build a Mesh over an explicit (BandPilot-ordered) device list."""
    arr = np.asarray(devices, dtype=object).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def bandpilot_device_order(
    dispatcher,
    avail_ids: Sequence[int],
    k: int,
) -> List[int]:
    """Dispatch k device ids via BandPilot and order them host-major.

    The returned order is used to lay out the mesh so that consecutive mesh
    columns (the highest-traffic axis) stay on the same host where possible.
    """
    subset = dispatcher.dispatch(list(avail_ids), k)
    cluster = dispatcher.cluster
    return sorted(subset, key=lambda g: (cluster.gpu_host[g], cluster.gpu_local[g]))


def bandpilot_mesh(
    dispatcher,
    devices: Sequence,
    k: int,
    shape: Tuple[int, ...],
    axes: Tuple[str, ...],
    avail_ids: Optional[Sequence[int]] = None,
):
    """Select + order k devices with BandPilot, then build the mesh.

    ``devices[i]`` is assumed to correspond to cluster GPU id ``i`` (the
    launcher keeps that mapping).  Falls back to the first k devices if the
    dispatcher is None.
    """
    if avail_ids is None:
        avail_ids = range(len(devices))
    if dispatcher is None:
        chosen = list(avail_ids)[:k]
    else:
        chosen = bandpilot_device_order(dispatcher, avail_ids, k)
    dev_list = [devices[i] for i in chosen]
    return make_mesh_from_devices(dev_list, shape, axes), chosen
