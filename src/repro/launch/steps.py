"""Step-function builders: jit + shardings for train / prefill / serve.

Everything here is shape-only-safe: callers pass ShapeDtypeStructs (dry-run)
or real arrays (actual runs); lowering happens inside a ``use_sharding``
context so the models' activation constraints bind against the mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch import shapes as shp
from repro.models.model_zoo import build_model
from repro.parallel import sharding as shd
from repro.train.optimizer import AdamWConfig, adamw, cosine_schedule
from repro.train.train_loop import TrainRunConfig, make_train_step

PyTree = Any


@dataclasses.dataclass
class LoweredStep:
    kind: str
    arch: str
    shape: str
    strategy: str
    lowered: Any
    in_specs: Tuple
    mesh: Mesh

    def compile(self):
        return self.lowered.compile()


def _batch_shardings(mesh, rules, batch_specs_tree):
    specs = shd.batch_specs(mesh, rules, batch_specs_tree)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_train_step(
    cfg: ModelConfig,
    cell: shp.ShapeCell,
    mesh: Mesh,
    strategy: str = "fsdp_tp",
    remat_policy: str = "nothing",
    rules_override: Optional[Dict] = None,
    scan_unroll: int = 1,
    constrain_grads: bool = False,
    grad_accum: int = 1,
) -> LoweredStep:
    rules = rules_override or shd.STRATEGIES[strategy]()
    model = build_model(cfg)
    run = TrainRunConfig(
        optimizer=AdamWConfig(lr=3e-4, weight_decay=0.1),
        remat_policy=remat_policy,
        compute_dtype=jnp.bfloat16,
        kernel_backend="reference",  # dry-run lowers the XLA path
        scan_unroll=scan_unroll,
        grad_accum=grad_accum,
    )
    param_shapes = shp.param_specs_shapes(cfg, dtype=jnp.float32)
    param_sh = shd.param_shardings(mesh, rules, param_shapes)
    train_step, opt_init = make_train_step(
        model, run, grad_shardings=param_sh if constrain_grads else None
    )

    opt_shapes = jax.eval_shape(opt_init, param_shapes)
    batch_shapes = shp.train_input_specs(cfg, cell)

    # ZeRO: moments mirror the parameter shardings; step counter replicated
    opt_sh = _opt_state_shardings(opt_shapes, param_sh, mesh)
    batch_sh = _batch_shardings(mesh, rules, batch_shapes)

    with mesh, shd.use_sharding(mesh, rules):
        jitted = jax.jit(
            train_step,
            in_shardings=(param_sh, opt_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, None),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(param_shapes, opt_shapes, batch_shapes)
    return LoweredStep("train", cfg.name, cell.name, strategy, lowered,
                       (param_shapes, opt_shapes, batch_shapes), mesh)


def _opt_state_shardings(opt_shapes, param_sh, mesh):
    """AdamWState(step, mu, nu): moments mirror the parameter shardings."""
    replicated = NamedSharding(mesh, P())
    return type(opt_shapes)(step=replicated, mu=param_sh, nu=param_sh)


def build_prefill_step(
    cfg: ModelConfig,
    cell: shp.ShapeCell,
    mesh: Mesh,
    strategy: str = "fsdp_tp",
    rules_override: Optional[Dict] = None,
    scan_unroll: int = 1,
) -> LoweredStep:
    """Inference prefill: forward over the full prompt, emit cache + logits."""
    rules = rules_override or shd.STRATEGIES[strategy]()
    model = build_model(cfg)
    param_shapes = shp.param_specs_shapes(cfg, dtype=jnp.bfloat16)
    param_sh = shd.param_shardings(mesh, rules, param_shapes)

    if cfg.is_encoder_decoder:
        batch_shapes = {
            "frames": shp._sds((cell.global_batch, cell.seq_len, cfg.d_model),
                               jnp.bfloat16)
        }
        batch_sh = _batch_shardings(mesh, rules, batch_shapes)

        def prefill(params, batch):
            from repro.models import encdec
            return encdec.encode(params, cfg, batch["frames"],
                                 backend="reference", scan_unroll=scan_unroll)

        with mesh, shd.use_sharding(mesh, rules):
            jitted = jax.jit(prefill, in_shardings=(param_sh, batch_sh))
            lowered = jitted.lower(param_shapes, batch_shapes)
        return LoweredStep("prefill", cfg.name, cell.name, strategy, lowered,
                           (param_shapes, batch_shapes), mesh)

    cache_shapes = shp.cache_specs(cfg, cell.global_batch, cell.seq_len,
                                   jnp.bfloat16)
    cache_sh = shd.cache_shardings(mesh, rules, cache_shapes)
    batch_shapes = {"tokens": shp._sds((cell.global_batch, cell.seq_len),
                                       jnp.int32)}
    if cfg.frontend:
        batch_shapes["prefix_embeds"] = shp._sds(
            (cell.global_batch, cfg.frontend_seq_len, cfg.d_model), jnp.bfloat16
        )
    batch_sh = _batch_shardings(mesh, rules, batch_shapes)

    def prefill(params, batch, cache):
        return model.prefill(params, batch, cache, backend="reference",
                             scan_unroll=scan_unroll)

    with mesh, shd.use_sharding(mesh, rules):
        jitted = jax.jit(
            prefill,
            in_shardings=(param_sh, batch_sh, cache_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(2,),
        )
        lowered = jitted.lower(param_shapes, batch_shapes, cache_shapes)
    return LoweredStep("prefill", cfg.name, cell.name, strategy, lowered,
                       (param_shapes, batch_shapes, cache_shapes), mesh)


def build_serve_step(
    cfg: ModelConfig,
    cell: shp.ShapeCell,
    mesh: Mesh,
    strategy: str = "fsdp_tp",
    rules_override: Optional[Dict] = None,
    scan_unroll: int = 1,
) -> LoweredStep:
    """One-token decode against a seq_len cache."""
    rules = rules_override or shd.STRATEGIES[strategy]()
    model = build_model(cfg)
    param_shapes = shp.param_specs_shapes(cfg, dtype=jnp.bfloat16)
    param_sh = shd.param_shardings(mesh, rules, param_shapes)

    cache_len = cell.seq_len
    cache_shapes = shp.cache_specs(cfg, cell.global_batch, cache_len,
                                   jnp.bfloat16)
    cache_sh = shd.cache_shardings(mesh, rules, cache_shapes)
    tok_shapes = shp._sds((cell.global_batch, 1), jnp.int32)
    tok_sh = NamedSharding(
        mesh, shd.resolve_spec(mesh, rules, ("batch", None), tok_shapes.shape)
    )

    mem_shapes = shp.memory_specs(cfg, cell)

    if cfg.is_encoder_decoder:
        mem_sh = NamedSharding(
            mesh, shd.resolve_spec(mesh, rules, ("batch", "seq", None),
                                   mem_shapes.shape)
        )

        def serve_step(params, cache, tokens, memory):
            return model.decode_step(params, cache, tokens, memory=memory,
                                     backend="reference",
                                     scan_unroll=scan_unroll)

        with mesh, shd.use_sharding(mesh, rules):
            jitted = jax.jit(
                serve_step,
                in_shardings=(param_sh, cache_sh, tok_sh, mem_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(param_shapes, cache_shapes, tok_shapes,
                                   mem_shapes)
        return LoweredStep("decode", cfg.name, cell.name, strategy, lowered,
                           (param_shapes, cache_shapes, tok_shapes, mem_shapes),
                           mesh)

    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens, backend="reference",
                                 scan_unroll=scan_unroll)

    with mesh, shd.use_sharding(mesh, rules):
        jitted = jax.jit(
            serve_step,
            in_shardings=(param_sh, cache_sh, tok_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(param_shapes, cache_shapes, tok_shapes)
    return LoweredStep("decode", cfg.name, cell.name, strategy, lowered,
                       (param_shapes, cache_shapes, tok_shapes), mesh)


def build_step(
    cfg: ModelConfig,
    cell: shp.ShapeCell,
    mesh: Mesh,
    strategy: str = "fsdp_tp",
    remat_policy: str = "nothing",
    rules_override: Optional[Dict] = None,
    scan_unroll: int = 1,
    constrain_grads: bool = False,
    grad_accum: int = 1,
) -> LoweredStep:
    if cell.kind == "train":
        return build_train_step(cfg, cell, mesh, strategy, remat_policy,
                                rules_override, scan_unroll, constrain_grads,
                                grad_accum)
    if cell.kind == "prefill":
        return build_prefill_step(cfg, cell, mesh, strategy, rules_override,
                                  scan_unroll)
    return build_serve_step(cfg, cell, mesh, strategy, rules_override,
                            scan_unroll)
