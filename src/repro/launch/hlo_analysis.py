"""HLO analysis: collective-traffic extraction + roofline terms.

``cost_analysis()`` gives FLOPs and HBM bytes of the compiled (post-SPMD,
per-device) module but not collective traffic; we parse the HLO text and
sum operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, split by whether the op's replica groups
cross the pod (DCN) axis or stay within a pod (ICI).

Shapes in SPMD HLO are per-partition, so all numbers here are per-device.
Calibration of these semantics is pinned by tests/test_roofline_calibration.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,1024]{1,0}' -> bytes."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dtype, dims = m.groups()
    b = DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes: int
    name: str
    replica_groups: str


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    """Extract collective ops + operand sizes from HLO text.

    We take the *output* shape for all-gather/all-to-all (data received) and
    the operand shape for all-reduce/reduce-scatter/collective-permute (data
    sent) — a consistent per-device wire-traffic estimate.
    """
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?([\w\.\-]+)\s*=\s*(.*)", s)
        if not m:
            continue
        name, rhs = m.groups()
        kind = None
        for ck in COLLECTIVE_KINDS:
            if re.search(rf"=?\s*{ck}\(", s) or rhs.startswith(ck) or (
                f" {ck}(" in s
            ):
                kind = ck
                break
        # also match fused/typed forms like "all-reduce-start"
        if kind is None:
            for ck in COLLECTIVE_KINDS:
                if f"{ck}-start(" in s:
                    kind = ck
                    break
        if kind is None:
            continue
        # output shape(s): tuple or single, directly after '='
        shape_part = rhs.split("=")[0]
        shapes = _SHAPE_RE.findall(rhs.split(kind)[0])
        total = 0
        for dtype, dims in shapes:
            total += _shape_bytes(f"{dtype}[{dims}]")
        groups = ""
        gm = re.search(r"replica_groups=(\{[^}]*\}+|\S+)", s)
        if gm:
            groups = gm.group(1)[:2000]
        ops.append(CollectiveOp(kind, total, name, groups))
    return ops


def _parse_groups(groups: str) -> Optional[List[List[int]]]:
    """'{{0,1},{2,3}}' -> [[0,1],[2,3]]; iota forms handled separately."""
    if not groups or "maximal" in groups:
        return None
    if groups.startswith("[") :
        return None  # iota tile form, handled by caller heuristics
    inner = re.findall(r"\{([\d,\s]+)\}", groups)
    out = []
    for g in inner:
        ids = [int(x) for x in g.split(",") if x.strip()]
        if ids:
            out.append(ids)
    return out or None


def split_by_fabric(
    ops: List[CollectiveOp], pod_size: int
) -> Tuple[int, int, Dict[str, int]]:
    """-> (ici_bytes, dcn_bytes, by_kind).

    A collective whose replica group spans device ids from different pods
    (id // pod_size differs) rides the DCN; otherwise ICI.  Iota-form groups
    that we cannot parse default to ICI unless they span the whole fleet.
    """
    ici = 0
    dcn = 0
    by_kind: Dict[str, int] = {}
    for op in ops:
        by_kind[op.kind] = by_kind.get(op.kind, 0) + op.bytes
        groups = _parse_groups(op.replica_groups)
        crosses = False
        if groups:
            for g in groups:
                pods = {d // pod_size for d in g}
                if len(pods) > 1:
                    crosses = True
                    break
        if crosses:
            dcn += op.bytes
        else:
            ici += op.bytes
    return ici, dcn, by_kind


def collective_summary(hlo_text: str, pod_size: int = 256) -> Dict:
    ops = parse_collectives(hlo_text)
    ici, dcn, by_kind = split_by_fabric(ops, pod_size)
    return {
        "n_collectives": len(ops),
        "total_bytes": ici + dcn,
        "ici_bytes": ici,
        "dcn_bytes": dcn,
        "by_kind": by_kind,
    }
