"""Bandwidth surrogate models in pure JAX (Sec. 4.2).

Three models share one Transformer-encoder trunk:

* **HierarchicalSurrogate** (the paper's design): tokens are per-host feature
  tuples (Stage-1 intra-host bandwidth lookup, GPU count); a 6-layer,
  d_model=32 encoder with a 3-layer MLP head predicts normalized end-to-end
  bandwidth.  ~89k params ~= 356 KB fp32, matching the paper's "354 KB".
* **NaiveSurrogate** (ablation baseline, Sec. 5.5.1): tokens are raw GPU
  identifiers passed through a learned embedding; the model must infer the
  physical hierarchy from scratch.
* **ContendedSurrogate** (the learned-contention head): the same encoder
  trunk, warm-started from the isolated surrogate, plus a zero-initialized
  *context embedding* over the ledger channels of
  :func:`repro.core.features.featurize_contended_batch`.  At init it is
  exactly the isolated model on any zero-context input; training on a
  curriculum of (subset, ledger, contended-bw) triples teaches it the rail
  split the analytic estimator only approximates.

Everything is written against plain parameter pytrees (dicts) so the model
is trivially checkpointable and shardable with the rest of the framework.
"""

from __future__ import annotations

import functools
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features as feat_lib
from repro.core.bandwidth_sim import BW_SCALE
from repro.core.cluster import Cluster
from repro.core.intra_host import IntraHostTables
from repro.core.predict_cache import PredictorStats

PyTree = Any

D_MODEL = 32
N_LAYERS = 6
N_HEADS = 4
D_FF = 128
HEAD_HIDDEN = 64

# The model regresses log-bandwidth: collective bandwidths span ~2.5 orders
# of magnitude across heterogeneous clusters, and the paper's accuracy
# metric (MAPE) is a *relative* error — log-space MSE optimizes it directly.
LOG_SCALE = 5.0


def encode_bw(bw_gbps):
    """GB/s -> normalized log-space target."""
    return jnp.log1p(jnp.asarray(bw_gbps)) / LOG_SCALE


def decode_bw(y):
    """normalized log-space prediction -> GB/s."""
    return jnp.expm1(jnp.clip(y, 0.0, 2.0) * LOG_SCALE)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _dense_init(key, d_in, d_out, scale=None):
    if scale is None:
        scale = 1.0 / np.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    return {"w": w, "b": jnp.zeros((d_out,), jnp.float32)}


def _layer_init(key, d=D_MODEL, d_ff=D_FF):
    ks = jax.random.split(key, 6)
    return {
        "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "qkv": _dense_init(ks[0], d, 3 * d),
        "o": _dense_init(ks[1], d, d),
        "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "ff1": _dense_init(ks[2], d, d_ff),
        "ff2": _dense_init(ks[3], d_ff, d),
    }


def _trunk_init(key, d=D_MODEL, n_layers=N_LAYERS):
    ks = jax.random.split(key, n_layers + 2)
    head_keys = jax.random.split(ks[-1], 3)
    return {
        "layers": [_layer_init(ks[i], d) for i in range(n_layers)],
        "ln_f": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "head": [
            _dense_init(head_keys[0], d, HEAD_HIDDEN),
            _dense_init(head_keys[1], HEAD_HIDDEN, HEAD_HIDDEN),
            _dense_init(head_keys[2], HEAD_HIDDEN, 1),
        ],
    }


def init_hierarchical_params(key) -> PyTree:
    k_embed, k_trunk = jax.random.split(key)
    embed = _dense_init(k_embed, feat_lib.N_FEATURES, D_MODEL, scale=1.0)
    # The per-host-type normalized channel (features.py channel 4) starts
    # inert: a zero embed row means an un-trained (or legacy-trained) model
    # is bit-for-bit unaffected by it; training opts in where it helps.
    embed["w"] = embed["w"].at[feat_lib.N_FEATURES - 1].set(0.0)
    return {
        "embed": embed,
        "trunk": _trunk_init(k_trunk),
    }


def init_naive_params(key, n_gpus: int) -> PyTree:
    k_embed, k_trunk = jax.random.split(key)
    return {
        "id_embed": jax.random.normal(k_embed, (n_gpus, D_MODEL)) * 0.1,
        "trunk": _trunk_init(k_trunk),
    }


def init_contended_params(base_params: PyTree) -> PyTree:
    """ContendedSurrogate init: the isolated trunk + embed (warm start) plus
    a ZERO context embedding — so at init the contended model computes
    exactly the isolated prediction wherever the ledger channels are zero.
    Deterministic (no rng): all the randomness came from the base params."""
    copied = jax.tree_util.tree_map(jnp.array, base_params)
    return {
        "embed": copied["embed"],
        "ctx_embed": {
            "w": jnp.zeros((feat_lib.N_LEDGER_FEATURES, D_MODEL), jnp.float32)
        },
        "trunk": copied["trunk"],
    }


def param_count(params: PyTree) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


def param_bytes(params: PyTree) -> int:
    return sum(
        int(np.prod(p.shape)) * p.dtype.itemsize
        for p in jax.tree_util.tree_leaves(params)
    )


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _dense(p, x):
    return x @ p["w"] + p["b"]


def _layernorm(p, x, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def _mha(p, x, mask):
    """Masked multi-head self-attention.  x: [B,H,D], mask: [B,H]."""
    B, H, D = x.shape
    dh = D // N_HEADS
    qkv = _dense(p["qkv"], x)  # [B,H,3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, H, N_HEADS, dh).transpose(0, 2, 1, 3)
    k = k.reshape(B, H, N_HEADS, dh).transpose(0, 2, 1, 3)
    v = v.reshape(B, H, N_HEADS, dh).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bnid,bnjd->bnij", q, k) / np.sqrt(dh)
    neg = jnp.finfo(scores.dtype).min
    scores = jnp.where(mask[:, None, None, :] > 0, scores, neg)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnij,bnjd->bnid", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(B, H, D)
    return _dense(p["o"], out)


def _encoder(trunk: PyTree, x: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Pre-LN Transformer encoder + masked mean-pool + MLP head -> [B]."""
    for layer in trunk["layers"]:
        x = x + _mha(layer, _layernorm(layer["ln1"], x), mask)
        h = _layernorm(layer["ln2"], x)
        h = _dense(layer["ff2"], jax.nn.gelu(_dense(layer["ff1"], h)))
        x = x + h
    x = _layernorm(trunk["ln_f"], x)
    denom = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)
    pooled = jnp.sum(x * mask[..., None], axis=1) / denom  # [B, D]
    h = jax.nn.gelu(_dense(trunk["head"][0], pooled))
    h = jax.nn.gelu(_dense(trunk["head"][1], h))
    return _dense(trunk["head"][2], h)[..., 0]


def apply_hierarchical(params: PyTree, feats: jnp.ndarray, mask: jnp.ndarray):
    """feats: [B, H, F], mask: [B, H] -> normalized bandwidth [B]."""
    x = _dense(params["embed"], feats)
    return _encoder(params["trunk"], x, mask)


def apply_naive(params: PyTree, ids: jnp.ndarray, mask: jnp.ndarray):
    """ids: [B, K] int32 GPU identifiers, mask: [B, K] -> normalized bw [B]."""
    x = params["id_embed"][ids]
    return _encoder(params["trunk"], x, mask)


# Module-level jitted apply+decode functions, SHARED by every predictor
# instance: jax's compilation cache is keyed on the function object, so a
# per-predictor ``jax.jit(...)`` closure would re-trace and re-compile every
# (B, H) shape bucket for every fresh predictor — benchmarks and scratch
# searches build many.  decode_bw is fused in (elementwise, bit-identical)
# so each call costs exactly one dispatch + one sync.

@jax.jit
def _apply_hierarchical_bw(params, feats, mask):
    return decode_bw(apply_hierarchical(params, feats, mask))


@jax.jit
def _apply_naive_bw(params, ids, mask):
    return decode_bw(apply_naive(params, ids, mask))


@jax.jit
def _apply_contended_bw(params, feats, mask):
    return decode_bw(apply_contended(params, feats, mask))


def apply_contended(params: PyTree, feats: jnp.ndarray, mask: jnp.ndarray):
    """feats: [B, T, N_CONTENDED_FEATURES], mask: [B, T] -> normalized bw [B].

    The ledger channels enter through a bias-free context embedding added to
    the base-token embedding; with an all-zero context the forward pass is
    the isolated :func:`apply_hierarchical` of the embedded trunk."""
    base = feats[..., : feat_lib.N_FEATURES]
    ctx = feats[..., feat_lib.N_FEATURES:]
    x = _dense(params["embed"], base) + ctx @ params["ctx_embed"]["w"]
    return _encoder(params["trunk"], x, mask)


# ---------------------------------------------------------------------------
# Predictor: the deployable surrogate B̂(S)
# ---------------------------------------------------------------------------

def _round_up_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class SurrogatePredictor:
    """Deployable B̂(S): Stage-1 exact lookup for single-host allocations,
    Stage-2 Transformer for multi-host ones (Fig. 4).

    Batched evaluation pads the batch to a power of two so the jitted apply
    function compiles only O(log B_max) times; with ``bucket_shapes`` (the
    default) the *token* dimension is likewise bucketed to the power-of-two
    cover of the batch's max participating-host count instead of always
    ``cluster.n_hosts`` — padded tokens are exactly masked out, so the
    pinned trace goldens select identical subsets (``tests/test_fast_path``).
    ``vectorized=False`` falls back to the legacy per-candidate loop
    featurizer (the throughput bench's before-side).
    """

    def __init__(
        self,
        cluster: Cluster,
        tables: IntraHostTables,
        params: PyTree,
        naive: bool = False,
        max_k: Optional[int] = None,
        host_norm: bool = True,
        vectorized: bool = True,
        bucket_shapes: bool = True,
    ):
        self.cluster = cluster
        self.tables = tables
        self.params = params
        self.naive = naive
        self.host_norm = host_norm
        self.vectorized = vectorized
        self.bucket_shapes = bucket_shapes
        self.max_k = max_k or cluster.n_gpus
        self.stats = PredictorStats()  # instrumentation for Fig. 8
        self._apply = _apply_naive_bw if naive else _apply_hierarchical_bw

    # legacy instrumentation names (benchmarks read/reset these directly)
    @property
    def n_model_calls(self) -> int:
        return self.stats.n_model_calls

    @n_model_calls.setter
    def n_model_calls(self, v: int) -> None:
        self.stats.n_model_calls = v

    @property
    def predict_seconds(self) -> float:
        return self.stats.predict_seconds

    @predict_seconds.setter
    def predict_seconds(self, v: float) -> None:
        self.stats.predict_seconds = v

    # hierarchical stage dispatch --------------------------------------------

    def predict(self, subsets: Sequence[Sequence[int]]) -> np.ndarray:
        """B̂ for a batch of allocations (GB/s, denormalized)."""
        t0 = time.time()
        out = np.zeros((len(subsets),), np.float64)
        model_idx: List[int] = []
        model_subsets: List[Sequence[int]] = []
        for i, s in enumerate(subsets):
            if not self.naive and len(self.cluster.partition_by_host(s)) == 1:
                out[i] = self.tables.lookup_global(list(s))  # Stage-1: exact
            else:
                model_idx.append(i)
                model_subsets.append(s)
        if model_subsets:
            preds = self._predict_model(model_subsets)
            for i, p in zip(model_idx, preds):
                out[i] = p
        self.stats.predict_seconds += time.time() - t0
        return out

    def predict_one(self, subset: Sequence[int]) -> float:
        return float(self.predict([subset])[0])

    def predict_children(self, parent: Sequence[int]) -> np.ndarray:
        """Fused featurize+predict of one PTS elimination round: all
        ``|parent|`` remove-one children in parent order, with the child
        token batch assembled incrementally from the parent's per-host
        grids (:func:`repro.core.features.featurize_children` machinery)
        and single-host children answered by Stage-1 gathers — no
        per-candidate Python.  Predictions are bit-identical to
        ``predict(children)``: same channels, same shape buckets."""
        parent = list(parent)
        n = len(parent)
        if self.naive or n < 2 or not self.vectorized:
            # vectorized=False is the pre-PR reference: every child goes
            # through the ordinary batch predict (loop featurizer)
            return self.predict(
                [parent[:i] + parent[i + 1:] for i in range(n)]
            )
        t0 = time.time()
        arrays = feat_lib.host_arrays(self.cluster, self.tables)
        bits, counts = feat_lib.child_bits_counts(arrays, parent)
        part = counts > 0
        n_part = part.sum(axis=1)
        out = np.zeros((n,), np.float64)
        for i in np.nonzero(n_part == 1)[0]:
            h = int(np.argmax(part[i]))
            out[i] = arrays.intra_bw[h, bits[i, h]]  # Stage-1: exact
        model = np.nonzero(n_part > 1)[0]
        if len(model):
            ks = np.full((len(model),), n - 1, np.int64)
            tokens = feat_lib._isolated_channels(
                arrays, bits[model], counts[model], ks, self.host_norm
            )
            feats, mask = feat_lib._pack_tokens(
                tokens, counts[model], self.cluster.n_hosts,
                feat_lib.N_FEATURES,
            )
            self.stats.featurize_seconds += time.time() - t0
            out[model] = self._apply_model(feats, mask)
        else:
            self.stats.featurize_seconds += time.time() - t0
        self.stats.predict_seconds += time.time() - t0
        return out

    def _predict_model(self, subsets: Sequence[Sequence[int]]) -> np.ndarray:
        if self.naive:
            t0 = time.time()
            B = len(subsets)
            Bp = _round_up_pow2(max(B, 1))
            ids, mask = feat_lib.featurize_gpu_ids(self.cluster, subsets, self.max_k)
            ids = np.pad(ids, ((0, Bp - B), (0, 0)))
            mask_p = np.pad(mask, ((0, Bp - B), (0, 0)))
            mask_p[B:, 0] = 1.0  # keep padded rows non-degenerate
            self.stats.featurize_seconds += time.time() - t0
            t1 = time.time()
            preds = self._apply(self.params, jnp.asarray(ids), jnp.asarray(mask_p))
            self.stats.n_model_calls += B
            decoded = np.asarray(preds)[:B]
            self.stats.infer_seconds += time.time() - t1
            return decoded
        t0 = time.time()
        featurize = (
            feat_lib.featurize_batch if self.vectorized
            else feat_lib.featurize_batch_loop
        )
        feats, mask = featurize(
            self.cluster, self.tables, subsets, host_norm=self.host_norm
        )
        self.stats.featurize_seconds += time.time() - t0
        return self._apply_model(feats, mask)

    def _apply_model(self, feats: np.ndarray, mask: np.ndarray) -> np.ndarray:
        """Bucket + pad + jitted apply, shared by the batch and fused-round
        paths so the two produce identical floats for identical batches."""
        t1 = time.time()
        B = feats.shape[0]
        if self.bucket_shapes:
            used = int(mask.sum(axis=1).max()) if B else 1
            H = _round_up_pow2(max(used, 1))
            if H < feats.shape[1]:
                feats = feats[:, :H]
                mask = mask[:, :H]
        Bp = _round_up_pow2(max(B, 1))
        feats = np.pad(feats, ((0, Bp - B), (0, 0), (0, 0)))
        mask_p = np.pad(mask, ((0, Bp - B), (0, 0)))
        mask_p[B:, 0] = 1.0  # keep padded rows non-degenerate
        preds = self._apply(self.params, jnp.asarray(feats), jnp.asarray(mask_p))
        self.stats.n_model_calls += B
        decoded = np.asarray(preds)[:B]
        self.stats.infer_seconds += time.time() - t1
        return decoded


# ---------------------------------------------------------------------------
# Contended predictor: the deployable B̂(S | L)
# ---------------------------------------------------------------------------

class ContendedSurrogatePredictor:
    """Deployable learned-contention B̂(S | L) (the ContendedSurrogate).

    Same two-stage dispatch as :class:`SurrogatePredictor`: single-host
    allocations never touch a NIC, so Stage-1 exact lookups answer them
    regardless of the ledger; multi-host allocations are featurized together
    with their ledger context and scored by the contended Transformer.

    ``predict(subsets, ledger)`` scores a batch against one live ledger (the
    search path); ``predict_pairs`` takes explicit (subset, ledger) pairs
    (the dataset-evaluation path, where every sample has its own ledger).
    """

    def __init__(
        self,
        cluster: Cluster,
        tables: IntraHostTables,
        params: PyTree,
        max_tokens: Optional[int] = None,
        include_contenders: bool = True,
        host_norm: bool = True,
        vectorized: bool = True,
        bucket_shapes: bool = True,
    ):
        self.cluster = cluster
        self.tables = tables
        self.params = params
        self.max_tokens = max_tokens or feat_lib.default_max_tokens(cluster)
        self.include_contenders = include_contenders
        self.host_norm = host_norm
        self.vectorized = vectorized
        self.bucket_shapes = bucket_shapes
        self.stats = PredictorStats()
        self._apply = _apply_contended_bw

    @property
    def n_model_calls(self) -> int:
        return self.stats.n_model_calls

    @n_model_calls.setter
    def n_model_calls(self, v: int) -> None:
        self.stats.n_model_calls = v

    @property
    def predict_seconds(self) -> float:
        return self.stats.predict_seconds

    @predict_seconds.setter
    def predict_seconds(self, v: float) -> None:
        self.stats.predict_seconds = v

    def predict(self, subsets: Sequence[Sequence[int]], ledger) -> np.ndarray:
        """Contended B̂ for a batch of allocations against one live ledger."""
        return self.predict_pairs([(s, ledger) for s in subsets])

    def predict_one(self, subset: Sequence[int], ledger) -> float:
        return float(self.predict([subset], ledger)[0])

    def predict_pairs(self, pairs: Sequence[Tuple[Sequence[int], Any]]) -> np.ndarray:
        t0 = time.time()
        out = np.zeros((len(pairs),), np.float64)
        model_idx: List[int] = []
        model_pairs: List[Tuple[Sequence[int], Any]] = []
        for i, (s, ledger) in enumerate(pairs):
            if len(self.cluster.partition_by_host(s)) == 1:
                out[i] = self.tables.lookup_global(list(s))  # Stage-1: exact
            else:
                model_idx.append(i)
                model_pairs.append((s, ledger))
        if model_pairs:
            tf = time.time()
            B = len(model_pairs)
            Bp = _round_up_pow2(B)
            featurize = (
                feat_lib.featurize_contended_batch if self.vectorized
                else feat_lib.featurize_contended_batch_loop
            )
            feats, mask = featurize(
                self.cluster, self.tables, model_pairs,
                max_tokens=self.max_tokens,
                include_contenders=self.include_contenders,
                host_norm=self.host_norm,
            )
            if self.bucket_shapes:
                used = int(mask.sum(axis=1).max())
                T = _round_up_pow2(max(used, 1))
                if T < feats.shape[1]:
                    feats = feats[:, :T]
                    mask = mask[:, :T]
            feats = np.pad(feats, ((0, Bp - B), (0, 0), (0, 0)))
            mask_p = np.pad(mask, ((0, Bp - B), (0, 0)))
            mask_p[B:, 0] = 1.0
            self.stats.featurize_seconds += time.time() - tf
            ti = time.time()
            preds = self._apply(
                self.params, jnp.asarray(feats), jnp.asarray(mask_p)
            )
            self.stats.n_model_calls += B
            decoded = np.asarray(preds)[:B]
            self.stats.infer_seconds += time.time() - ti
            for i, p in zip(model_idx, decoded):
                out[i] = p
        self.stats.predict_seconds += time.time() - t0
        return out
