"""Bandwidth surrogate models in pure JAX (Sec. 4.2).

Three models share one Transformer-encoder trunk:

* **HierarchicalSurrogate** (the paper's design): tokens are per-host feature
  tuples (Stage-1 intra-host bandwidth lookup, GPU count); a 6-layer,
  d_model=32 encoder with a 3-layer MLP head predicts normalized end-to-end
  bandwidth.  ~89k params ~= 356 KB fp32, matching the paper's "354 KB".
* **NaiveSurrogate** (ablation baseline, Sec. 5.5.1): tokens are raw GPU
  identifiers passed through a learned embedding; the model must infer the
  physical hierarchy from scratch.
* **ContendedSurrogate** (the learned-contention head): the same encoder
  trunk, warm-started from the isolated surrogate, plus a zero-initialized
  *context embedding* over the ledger channels of
  :func:`repro.core.features.featurize_contended_batch`.  At init it is
  exactly the isolated model on any zero-context input; training on a
  curriculum of (subset, ledger, contended-bw) triples teaches it the rail
  split the analytic estimator only approximates.

Everything is written against plain parameter pytrees (dicts) so the model
is trivially checkpointable and shardable with the rest of the framework.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import features as feat_lib
from repro.core.bandwidth_sim import BW_SCALE
from repro.core.cluster import Cluster
from repro.core.intra_host import IntraHostTables
from repro.core.predict_cache import PredictorStats, active_batcher

PyTree = Any

D_MODEL = 32
N_LAYERS = 6
N_HEADS = 4
D_FF = 128
HEAD_HIDDEN = 64

# The model regresses log-bandwidth: collective bandwidths span ~2.5 orders
# of magnitude across heterogeneous clusters, and the paper's accuracy
# metric (MAPE) is a *relative* error — log-space MSE optimizes it directly.
LOG_SCALE = 5.0


def encode_bw(bw_gbps):
    """GB/s -> normalized log-space target."""
    return jnp.log1p(jnp.asarray(bw_gbps)) / LOG_SCALE


def decode_bw(y):
    """normalized log-space prediction -> GB/s."""
    return jnp.expm1(jnp.clip(y, 0.0, 2.0) * LOG_SCALE)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _dense_init(key, d_in, d_out, scale=None):
    if scale is None:
        scale = 1.0 / np.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    return {"w": w, "b": jnp.zeros((d_out,), jnp.float32)}


def _layer_init(key, d=D_MODEL, d_ff=D_FF):
    ks = jax.random.split(key, 6)
    return {
        "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "qkv": _dense_init(ks[0], d, 3 * d),
        "o": _dense_init(ks[1], d, d),
        "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "ff1": _dense_init(ks[2], d, d_ff),
        "ff2": _dense_init(ks[3], d_ff, d),
    }


def _trunk_init(key, d=D_MODEL, n_layers=N_LAYERS):
    ks = jax.random.split(key, n_layers + 2)
    head_keys = jax.random.split(ks[-1], 3)
    return {
        "layers": [_layer_init(ks[i], d) for i in range(n_layers)],
        "ln_f": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "head": [
            _dense_init(head_keys[0], d, HEAD_HIDDEN),
            _dense_init(head_keys[1], HEAD_HIDDEN, HEAD_HIDDEN),
            _dense_init(head_keys[2], HEAD_HIDDEN, 1),
        ],
    }


def init_hierarchical_params(key) -> PyTree:
    k_embed, k_trunk = jax.random.split(key)
    embed = _dense_init(k_embed, feat_lib.N_FEATURES, D_MODEL, scale=1.0)
    # The per-host-type normalized channel (features.py channel 4) starts
    # inert: a zero embed row means an un-trained (or legacy-trained) model
    # is bit-for-bit unaffected by it; training opts in where it helps.
    embed["w"] = embed["w"].at[feat_lib.N_FEATURES - 1].set(0.0)
    return {
        "embed": embed,
        "trunk": _trunk_init(k_trunk),
    }


def init_naive_params(key, n_gpus: int) -> PyTree:
    k_embed, k_trunk = jax.random.split(key)
    return {
        "id_embed": jax.random.normal(k_embed, (n_gpus, D_MODEL)) * 0.1,
        "trunk": _trunk_init(k_trunk),
    }


def init_contended_params(base_params: PyTree) -> PyTree:
    """ContendedSurrogate init: the isolated trunk + embed (warm start) plus
    a ZERO context embedding — so at init the contended model computes
    exactly the isolated prediction wherever the ledger channels are zero.
    Deterministic (no rng): all the randomness came from the base params."""
    copied = jax.tree_util.tree_map(jnp.array, base_params)
    return {
        "embed": copied["embed"],
        "ctx_embed": {
            "w": jnp.zeros((feat_lib.N_LEDGER_FEATURES, D_MODEL), jnp.float32)
        },
        "trunk": copied["trunk"],
    }


def param_count(params: PyTree) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


def param_bytes(params: PyTree) -> int:
    return sum(
        int(np.prod(p.shape)) * p.dtype.itemsize
        for p in jax.tree_util.tree_leaves(params)
    )


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _dense(p, x):
    return x @ p["w"] + p["b"]


def _layernorm(p, x, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def _mha(p, x, mask):
    """Masked multi-head self-attention.  x: [B,H,D], mask: [B,H]."""
    B, H, D = x.shape
    dh = D // N_HEADS
    qkv = _dense(p["qkv"], x)  # [B,H,3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, H, N_HEADS, dh).transpose(0, 2, 1, 3)
    k = k.reshape(B, H, N_HEADS, dh).transpose(0, 2, 1, 3)
    v = v.reshape(B, H, N_HEADS, dh).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bnid,bnjd->bnij", q, k) / np.sqrt(dh)
    neg = jnp.finfo(scores.dtype).min
    scores = jnp.where(mask[:, None, None, :] > 0, scores, neg)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnij,bnjd->bnid", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(B, H, D)
    return _dense(p["o"], out)


def _encoder(trunk: PyTree, x: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Pre-LN Transformer encoder + masked mean-pool + MLP head -> [B]."""
    for layer in trunk["layers"]:
        x = x + _mha(layer, _layernorm(layer["ln1"], x), mask)
        h = _layernorm(layer["ln2"], x)
        h = _dense(layer["ff2"], jax.nn.gelu(_dense(layer["ff1"], h)))
        x = x + h
    x = _layernorm(trunk["ln_f"], x)
    denom = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)
    pooled = jnp.sum(x * mask[..., None], axis=1) / denom  # [B, D]
    h = jax.nn.gelu(_dense(trunk["head"][0], pooled))
    h = jax.nn.gelu(_dense(trunk["head"][1], h))
    return _dense(trunk["head"][2], h)[..., 0]


def apply_hierarchical(params: PyTree, feats: jnp.ndarray, mask: jnp.ndarray):
    """feats: [B, H, F], mask: [B, H] -> normalized bandwidth [B]."""
    x = _dense(params["embed"], feats)
    return _encoder(params["trunk"], x, mask)


def apply_naive(params: PyTree, ids: jnp.ndarray, mask: jnp.ndarray):
    """ids: [B, K] int32 GPU identifiers, mask: [B, K] -> normalized bw [B]."""
    x = params["id_embed"][ids]
    return _encoder(params["trunk"], x, mask)


# Module-level jitted apply+decode functions, SHARED by every predictor
# instance: jax's compilation cache is keyed on the function object, so a
# per-predictor ``jax.jit(...)`` closure would re-trace and re-compile every
# (B, H) shape bucket for every fresh predictor — benchmarks and scratch
# searches build many.  decode_bw is fused in (elementwise, bit-identical)
# so each call costs exactly one dispatch + one sync.

@jax.jit
def _apply_hierarchical_bw(params, feats, mask):
    return decode_bw(apply_hierarchical(params, feats, mask))


@jax.jit
def _apply_naive_bw(params, ids, mask):
    return decode_bw(apply_naive(params, ids, mask))


@jax.jit
def _apply_contended_bw(params, feats, mask):
    return decode_bw(apply_contended(params, feats, mask))


def apply_contended(params: PyTree, feats: jnp.ndarray, mask: jnp.ndarray):
    """feats: [B, T, N_CONTENDED_FEATURES], mask: [B, T] -> normalized bw [B].

    The ledger channels enter through a bias-free context embedding added to
    the base-token embedding; with an all-zero context the forward pass is
    the isolated :func:`apply_hierarchical` of the embedded trunk."""
    base = feats[..., : feat_lib.N_FEATURES]
    ctx = feats[..., feat_lib.N_FEATURES:]
    x = _dense(params["embed"], base) + ctx @ params["ctx_embed"]["w"]
    return _encoder(params["trunk"], x, mask)


# ---------------------------------------------------------------------------
# Predictor: the deployable surrogate B̂(S)
# ---------------------------------------------------------------------------

def _round_up_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


# ---------------------------------------------------------------------------
# Fused on-device elimination scan: the whole PTS descent as ONE device call
# ---------------------------------------------------------------------------
#
# The host PTS loop pays one featurize + one jitted apply + one host<->device
# round-trip per elimination round.  The scan below moves the entire descent
# |S0| -> k into a single XLA program: a ``lax.scan`` whose body re-expresses
# the per-round child patching of ``features.featurize_children`` as pure
# gathers over precomputed per-(host, bitmask) tables
# (:class:`features.DeviceTables`), dispatches Stage-1 single-host children
# to an exact table lookup, runs the Stage-2 Transformer apply on the rest,
# applies the (tabulated) analytic contention cap, and takes the per-round
# argmax — so one device call replaces |S0|-k applies.  This is the
# ``predict_children_scan`` of the ISSUE, surfaced as
# ``SurrogatePredictor.eliminate_to`` (a whole descent, not one round).
#
# Identity contract: every per-round device score equals
# ``np.float32(host-path float64 score)`` *by construction* — the channel
# tables are the host's float64 programs cast once, the small-integer ratio
# channels are exactly representable, min is monotone under the f32 cast,
# and the model apply embedded in the scan is bitwise identical to the
# standalone jitted apply (row/pad/position independence is regression-
# pinned in ``tests/test_ondevice_scan.py``, which also audits every round
# of real descents against the host loop).  The per-round *argmax* over f32
# scores matching the host's argmax over f64 scores is an empirical
# contract (a near-tie collapsing under the cast could differ) enforced by
# the pinned trace goldens and the audit tests; ``pts_search`` keeps the
# host loop as the documented fallback for any configuration the scan
# declines.

SCAN_MIN_SLOTS = 8    # slot-bucket floor: descent buckets are {8, 16, 32, 64}
SCAN_MAX_SLOTS = 64   # largest parent the scan path accepts
_SCAN_MAX_HOST_GPUS = 16   # gather tables are [H, 2**max_g]: bound them
_SCAN_MAX_LATTICE = 1 << 16  # cap-table bound (paper clusters: 9**4 = 6561)


@dataclasses.dataclass
class ScanResult:
    """One whole on-device elimination descent ``|S0| -> k``.

    ``scores``/``sels``/``elims`` expose every round's internal state so the
    audit tests can compare each round against the host loop; ``sels[r]``
    marks the slots still live *entering* round ``r`` (slot i = the i-th
    element of the sorted parent), and ``scores[r]`` holds the f32 child
    scores at those slots (padding / eliminated slots carry mirror-parent
    garbage and are never selected)."""

    subset: List[int]          # the surviving k GPUs, ascending
    n_rounds: int              # active elimination rounds (= |S0| - k)
    n_capped: int              # live children whose cap bound (f32 compare)
    scores: np.ndarray         # [R, N0b] float32 per-round child scores
    sels: np.ndarray           # [R, N0b] bool pre-round live slots
    elims: np.ndarray          # [R] int32 slot eliminated per round


def _pts_scan(params, tok0, tok4, stage1, cap_tab, strides, slot_host,
              slot_bit, sel0, bits0, counts0, k, n_gpus_f):
    """The fused descent: traced once per (N0b, H, W, L) shape bucket.

    All tables and scalars are runtime arguments, so one compiled
    executable serves every cluster/ledger/k sharing the bucket shapes.
    Fixed trip count ``N0b - 1`` with a ``lax.cond`` gate: rounds after the
    descent reaches ``k`` are no-ops (carry passes through unchanged).
    """
    N0b = slot_host.shape[0]
    H = bits0.shape[0]
    harange = jnp.arange(H, dtype=jnp.int32)
    # per-slot one-hot host row / local bit, for child patching + elimination
    host_oh = (slot_host[:, None] == harange[None, :]).astype(jnp.int32)
    sub_bits = host_oh * slot_bit[:, None]
    slot_idx = jnp.arange(N0b)

    def do_round(carry):
        sel, bits, counts, n = carry
        # child i = parent minus slot i.  Eliminated/padded slots mirror the
        # parent itself (valid tokens, no NaN enters the model) and are
        # excluded from the argmax below.
        bits_c = jnp.where(sel[:, None], bits[None, :] - sub_bits,
                           bits[None, :])
        counts_c = jnp.where(sel[:, None], counts[None, :] - host_oh,
                             counts[None, :])
        part = counts_c > 0
        kc = (n - 1).astype(jnp.float32)
        cf = counts_c.astype(jnp.float32)
        # the five isolated channels of features._isolated_channels, as
        # where-gated gathers (never multiply-by-mask: NaN-safe)
        ch0 = jnp.where(part, tok0[harange[None, :], bits_c], 0.0)
        ch1 = jnp.where(part, cf / 8.0, 0.0)
        ch2 = jnp.where(part, cf / kc, 0.0)
        ch3 = jnp.where(part, kc / n_gpus_f, 0.0)
        ch4 = jnp.where(part, tok4[harange[None, :], bits_c], 0.0)
        feats = jnp.stack([ch0, ch1, ch2, ch3, ch4], axis=-1)
        # pack participating tokens into the leading slots, hosts ascending
        # (the order features._pack_tokens scatters into)
        order = jnp.argsort(
            jnp.logical_not(part).astype(jnp.int32), axis=1, stable=True
        )
        feats_p = jnp.take_along_axis(feats, order[..., None], axis=1)
        mask = jnp.take_along_axis(part, order, axis=1).astype(jnp.float32)
        bw = decode_bw(apply_hierarchical(params, feats_p, mask))
        # Stage-1 dispatch: single-host children read the exact lookup
        h_star = jnp.argmax(part, axis=1)
        s1 = stage1[h_star, bits_c[slot_idx, h_star]]
        n_part = part.sum(axis=1)
        iso = jnp.where(n_part == 1, s1, bw)
        # analytic contention cap: one gather on the count-vector lattice
        cap = cap_tab[(counts_c * strides[None, :]).sum(axis=1)]
        score = jnp.minimum(iso, cap)
        n_capped = ((cap < iso) & sel).sum().astype(jnp.int32)
        elim = jnp.argmax(jnp.where(sel, score, -jnp.inf))
        oh = (harange == slot_host[elim]).astype(jnp.int32)
        new_carry = (
            sel.at[elim].set(False),
            bits - oh * slot_bit[elim],
            counts - oh,
            n - 1,
        )
        return new_carry, (score, sel, elim.astype(jnp.int32),
                           jnp.bool_(True), n_capped)

    def skip_round(carry):
        ys = (
            jnp.zeros((N0b,), jnp.float32),
            jnp.zeros((N0b,), bool),
            jnp.int32(0),
            jnp.bool_(False),
            jnp.int32(0),
        )
        return carry, ys

    def body(carry, _):
        return lax.cond(carry[3] > k, do_round, skip_round, carry)

    carry0 = (sel0, bits0, counts0, sel0.sum().astype(jnp.int32))
    _, ys = lax.scan(body, carry0, None, length=N0b - 1)
    return ys


# (N0b, H_all, 2**max_g, lattice_size) -> AOT-compiled executable.  Tables
# and scalars are runtime args, so e.g. H100 and Het-4Mix (both 4x8) share
# every bucket's executable — and so do every ledger state and every k.
_SCAN_COMPILED: Dict[Tuple[int, int, int, int], Any] = {}

_pts_scan_jit = jax.jit(_pts_scan)


def _scan_args(params, dt, cap_tab, slot_host, slot_bit, sel0, bits0,
               counts0, k, host_norm):
    """Build a descent's argument tuple — ONE code path used at both AOT
    lower time and call time, so avals (shape/dtype/weak_type) always match
    the compiled executable's signature."""
    tok4 = dt.tok4 if host_norm else dt.tok4_zero
    return (
        params,
        jnp.asarray(dt.tok0),
        jnp.asarray(tok4),
        jnp.asarray(dt.stage1),
        jnp.asarray(cap_tab),
        jnp.asarray(dt.strides.astype(np.int32)),
        jnp.asarray(slot_host),
        jnp.asarray(slot_bit),
        jnp.asarray(sel0),
        jnp.asarray(bits0),
        jnp.asarray(counts0),
        jnp.int32(k),
        jnp.float32(dt.n_gpus_f),
    )


def _compiled_scan(key: Tuple[int, int, int, int], args):
    """Fetch (or AOT lower+compile) the executable for one shape bucket."""
    exe = _SCAN_COMPILED.get(key)
    if exe is None:
        exe = _pts_scan_jit.lower(*args).compile()
        _SCAN_COMPILED[key] = exe
    return exe


class SurrogatePredictor:
    """Deployable B̂(S): Stage-1 exact lookup for single-host allocations,
    Stage-2 Transformer for multi-host ones (Fig. 4).

    Batched evaluation pads the batch to a power of two so the jitted apply
    function compiles only O(log B_max) times; with ``bucket_shapes`` (the
    default) the *token* dimension is likewise bucketed to the power-of-two
    cover of the batch's max participating-host count instead of always
    ``cluster.n_hosts`` — padded tokens are exactly masked out, so the
    pinned trace goldens select identical subsets (``tests/test_fast_path``).
    ``vectorized=False`` falls back to the legacy per-candidate loop
    featurizer (the throughput bench's before-side).

    ``eliminate_to`` runs a whole PTS elimination descent as one fused
    on-device ``lax.scan`` (``use_scan=False`` disables it — the scan-off
    side of the throughput bench and the trace goldens); ``warm_scan``
    AOT-compiles the descent executables ahead of the first admission.
    """

    def __init__(
        self,
        cluster: Cluster,
        tables: IntraHostTables,
        params: PyTree,
        naive: bool = False,
        max_k: Optional[int] = None,
        host_norm: bool = True,
        vectorized: bool = True,
        bucket_shapes: bool = True,
        use_scan: bool = True,
    ):
        self.cluster = cluster
        self.tables = tables
        self.params = params
        self.naive = naive
        self.host_norm = host_norm
        self.vectorized = vectorized
        self.bucket_shapes = bucket_shapes
        self.use_scan = use_scan
        self.max_k = max_k or cluster.n_gpus
        self.stats = PredictorStats()  # instrumentation for Fig. 8
        self._apply = _apply_naive_bw if naive else _apply_hierarchical_bw

    # legacy instrumentation names (benchmarks read/reset these directly)
    @property
    def n_model_calls(self) -> int:
        return self.stats.n_model_calls

    @n_model_calls.setter
    def n_model_calls(self, v: int) -> None:
        self.stats.n_model_calls = v

    @property
    def predict_seconds(self) -> float:
        return self.stats.predict_seconds

    @predict_seconds.setter
    def predict_seconds(self, v: float) -> None:
        self.stats.predict_seconds = v

    # hierarchical stage dispatch --------------------------------------------

    def predict(self, subsets: Sequence[Sequence[int]]) -> np.ndarray:
        """B̂ for a batch of allocations (GB/s, denormalized)."""
        t0 = time.time()
        out = np.zeros((len(subsets),), np.float64)
        model_idx: List[int] = []
        model_subsets: List[Sequence[int]] = []
        for i, s in enumerate(subsets):
            if not self.naive and len(self.cluster.partition_by_host(s)) == 1:
                out[i] = self.tables.lookup_global(list(s))  # Stage-1: exact
            else:
                model_idx.append(i)
                model_subsets.append(s)
        if model_subsets:
            preds = self._predict_model(model_subsets)
            for i, p in zip(model_idx, preds):
                out[i] = p
        self.stats.predict_seconds += time.time() - t0
        return out

    def predict_one(self, subset: Sequence[int]) -> float:
        return float(self.predict([subset])[0])

    def predict_children(self, parent: Sequence[int]) -> np.ndarray:
        """Fused featurize+predict of one PTS elimination round: all
        ``|parent|`` remove-one children in parent order, with the child
        token batch assembled incrementally from the parent's per-host
        grids (:func:`repro.core.features.featurize_children` machinery)
        and single-host children answered by Stage-1 gathers — no
        per-candidate Python.  Predictions are bit-identical to
        ``predict(children)``: same channels, same shape buckets."""
        parent = list(parent)
        n = len(parent)
        if self.naive or n < 2 or not self.vectorized:
            # vectorized=False is the pre-PR reference: every child goes
            # through the ordinary batch predict (loop featurizer)
            return self.predict(
                [parent[:i] + parent[i + 1:] for i in range(n)]
            )
        t0 = time.time()
        arrays = feat_lib.host_arrays(self.cluster, self.tables)
        bits, counts = feat_lib.child_bits_counts(arrays, parent)
        part = counts > 0
        n_part = part.sum(axis=1)
        out = np.zeros((n,), np.float64)
        for i in np.nonzero(n_part == 1)[0]:
            h = int(np.argmax(part[i]))
            out[i] = arrays.intra_bw[h, bits[i, h]]  # Stage-1: exact
        model = np.nonzero(n_part > 1)[0]
        if len(model):
            ks = np.full((len(model),), n - 1, np.int64)
            tokens = feat_lib._isolated_channels(
                arrays, bits[model], counts[model], ks, self.host_norm
            )
            feats, mask = feat_lib._pack_tokens(
                tokens, counts[model], self.cluster.n_hosts,
                feat_lib.N_FEATURES,
            )
            self.stats.featurize_seconds += time.time() - t0
            out[model] = self._apply_model(feats, mask)
        else:
            self.stats.featurize_seconds += time.time() - t0
        self.stats.predict_seconds += time.time() - t0
        return out

    # fused on-device descent --------------------------------------------

    def _scan_envelope(self):
        """The (arrays, device tables) pair when this predictor/cluster is
        inside the scan envelope, else None."""
        if self.naive or not self.vectorized or not self.use_scan:
            return None
        arrays = feat_lib.host_arrays(self.cluster, self.tables)
        if arrays.max_host_gpus > _SCAN_MAX_HOST_GPUS:
            return None
        dt = feat_lib.device_tables(self.cluster, self.tables)
        if dt.lattice_size > _SCAN_MAX_LATTICE:
            return None
        return arrays, dt

    def eliminate_to(
        self,
        parent: Sequence[int],
        k: int,
        caps: Optional[np.ndarray] = None,
    ) -> Optional[ScanResult]:
        """Run the whole PTS elimination descent ``|parent| -> k`` as one
        fused on-device ``lax.scan`` (see the module section above).

        ``caps`` is a float32 ``[lattice_size]`` analytic-cap table (the
        contention wrapper builds one per ledger version); None means
        uncapped (isolated scoring).  Returns a :class:`ScanResult`, or
        None when the configuration is outside the scan envelope — the
        caller falls back to the host loop, which is always correct."""
        env = self._scan_envelope()
        if env is None:
            return None
        arrays, dt = env
        parent = sorted(parent)
        n0 = len(parent)
        if k < 1 or n0 <= k:
            return None
        if len(self.cluster.partition_by_host(parent)) < 2:
            return None  # single-host descent: Stage-1 host loop is exact
        N0b = max(_round_up_pow2(n0), SCAN_MIN_SLOTS)
        if N0b > SCAN_MAX_SLOTS:
            return None
        t0 = time.time()
        if caps is None:
            caps = dt.caps_inf()
        slot_host = np.zeros((N0b,), np.int32)
        slot_bit = np.zeros((N0b,), np.int32)
        slot_host[:n0] = arrays.gpu_host[parent]
        slot_bit[:n0] = arrays.gpu_bit[parent]
        sel0 = np.zeros((N0b,), bool)
        sel0[:n0] = True
        pbits, pcounts, _, _, _ = feat_lib._batch_bits_counts(
            arrays, [parent]
        )
        bits0 = pbits[0].astype(np.int32)
        counts0 = pcounts[0].astype(np.int32)
        H = bits0.shape[0]
        args = _scan_args(self.params, dt, caps, slot_host, slot_bit,
                          sel0, bits0, counts0, k, self.host_norm)
        exe = _compiled_scan((N0b, H, dt.mask_size, caps.shape[0]), args)
        ys = exe(*args)
        scores = np.asarray(ys[0])
        sels = np.asarray(ys[1])
        elims = np.asarray(ys[2])
        actives = np.asarray(ys[3])
        capped = np.asarray(ys[4])
        R = int(actives.sum())
        sel = sel0.copy()
        for r in range(R):
            sel[elims[r]] = False
        subset = [parent[i] for i in np.nonzero(sel[:n0])[0]]
        if R != n0 - k or len(subset) != k:
            return None  # never expected; host loop is the safe fallback
        self.stats.scan_seconds += time.time() - t0
        self.stats.n_scan_steps += R
        return ScanResult(
            subset=subset,
            n_rounds=R,
            n_capped=int(capped[:R].sum()),
            scores=scores[:R],
            sels=sels[:R],
            elims=elims[:R],
        )

    def warm_scan(self, buckets: Optional[Sequence[int]] = None) -> float:
        """AOT-compile (lower + compile, no execution) the descent
        executables for the cluster's slot buckets, so the first admission
        carries no compile spike.  Returns seconds spent; 0.0 when every
        bucket was already compiled (the executables are process-wide and
        shared across same-shaped clusters)."""
        env = self._scan_envelope()
        if env is None:
            return 0.0
        _, dt = env
        if buckets is None:
            top = min(
                max(_round_up_pow2(self.cluster.n_gpus), SCAN_MIN_SLOTS),
                SCAN_MAX_SLOTS,
            )
            buckets = []
            b = SCAN_MIN_SLOTS
            while b <= top:
                buckets.append(b)
                b *= 2
        spent = 0.0
        H = self.cluster.n_hosts
        caps = dt.caps_inf()
        for N0b in buckets:
            key = (N0b, H, dt.mask_size, caps.shape[0])
            if key in _SCAN_COMPILED:
                continue
            args = _scan_args(
                self.params, dt, caps,
                np.zeros((N0b,), np.int32), np.ones((N0b,), np.int32),
                np.ones((N0b,), bool), np.zeros((H,), np.int32),
                np.zeros((H,), np.int32), 1, self.host_norm,
            )
            t0 = time.time()
            _compiled_scan(key, args)
            spent += time.time() - t0
        return spent

    def _predict_model(self, subsets: Sequence[Sequence[int]]) -> np.ndarray:
        if self.naive:
            t0 = time.time()
            B = len(subsets)
            Bp = _round_up_pow2(max(B, 1))
            ids, mask = feat_lib.featurize_gpu_ids(self.cluster, subsets, self.max_k)
            ids = np.pad(ids, ((0, Bp - B), (0, 0)))
            mask_p = np.pad(mask, ((0, Bp - B), (0, 0)))
            mask_p[B:, 0] = 1.0  # keep padded rows non-degenerate
            self.stats.featurize_seconds += time.time() - t0
            t1 = time.time()
            preds = self._apply(self.params, jnp.asarray(ids), jnp.asarray(mask_p))
            self.stats.n_model_calls += B
            decoded = np.asarray(preds)[:B]
            self.stats.infer_seconds += time.time() - t1
            return decoded
        t0 = time.time()
        featurize = (
            feat_lib.featurize_batch if self.vectorized
            else feat_lib.featurize_batch_loop
        )
        feats, mask = featurize(
            self.cluster, self.tables, subsets, host_norm=self.host_norm
        )
        self.stats.featurize_seconds += time.time() - t0
        return self._apply_model(feats, mask)

    def _apply_model(self, feats: np.ndarray, mask: np.ndarray) -> np.ndarray:
        """Bucket + pad + jitted apply, shared by the batch and fused-round
        paths so the two produce identical floats for identical batches."""
        t1 = time.time()
        B = feats.shape[0]
        if self.bucket_shapes:
            used = int(mask.sum(axis=1).max()) if B else 1
            H = _round_up_pow2(max(used, 1))
            if H < feats.shape[1]:
                feats = feats[:, :H]
                mask = mask[:, :H]
        batcher = active_batcher()
        if batcher is not None:
            # cross-search fusion: the batcher performs the same B padding
            # (value-neutral), possibly alongside other searches' requests
            decoded = batcher.apply(self._apply, self.params, feats, mask)
            self.stats.n_model_calls += B
            self.stats.infer_seconds += time.time() - t1
            return decoded
        Bp = _round_up_pow2(max(B, 1))
        feats = np.pad(feats, ((0, Bp - B), (0, 0), (0, 0)))
        mask_p = np.pad(mask, ((0, Bp - B), (0, 0)))
        mask_p[B:, 0] = 1.0  # keep padded rows non-degenerate
        preds = self._apply(self.params, jnp.asarray(feats), jnp.asarray(mask_p))
        self.stats.n_model_calls += B
        decoded = np.asarray(preds)[:B]
        self.stats.infer_seconds += time.time() - t1
        return decoded


# ---------------------------------------------------------------------------
# Contended predictor: the deployable B̂(S | L)
# ---------------------------------------------------------------------------

class ContendedSurrogatePredictor:
    """Deployable learned-contention B̂(S | L) (the ContendedSurrogate).

    Same two-stage dispatch as :class:`SurrogatePredictor`: single-host
    allocations never touch a NIC, so Stage-1 exact lookups answer them
    regardless of the ledger; multi-host allocations are featurized together
    with their ledger context and scored by the contended Transformer.

    ``predict(subsets, ledger)`` scores a batch against one live ledger (the
    search path); ``predict_pairs`` takes explicit (subset, ledger) pairs
    (the dataset-evaluation path, where every sample has its own ledger).
    """

    def __init__(
        self,
        cluster: Cluster,
        tables: IntraHostTables,
        params: PyTree,
        max_tokens: Optional[int] = None,
        include_contenders: bool = True,
        host_norm: bool = True,
        vectorized: bool = True,
        bucket_shapes: bool = True,
    ):
        self.cluster = cluster
        self.tables = tables
        self.params = params
        self.max_tokens = max_tokens or feat_lib.default_max_tokens(cluster)
        self.include_contenders = include_contenders
        self.host_norm = host_norm
        self.vectorized = vectorized
        self.bucket_shapes = bucket_shapes
        self.stats = PredictorStats()
        self._apply = _apply_contended_bw

    @property
    def n_model_calls(self) -> int:
        return self.stats.n_model_calls

    @n_model_calls.setter
    def n_model_calls(self, v: int) -> None:
        self.stats.n_model_calls = v

    @property
    def predict_seconds(self) -> float:
        return self.stats.predict_seconds

    @predict_seconds.setter
    def predict_seconds(self, v: float) -> None:
        self.stats.predict_seconds = v

    def predict(self, subsets: Sequence[Sequence[int]], ledger) -> np.ndarray:
        """Contended B̂ for a batch of allocations against one live ledger."""
        return self.predict_pairs([(s, ledger) for s in subsets])

    def predict_one(self, subset: Sequence[int], ledger) -> float:
        return float(self.predict([subset], ledger)[0])

    def predict_pairs(self, pairs: Sequence[Tuple[Sequence[int], Any]]) -> np.ndarray:
        t0 = time.time()
        out = np.zeros((len(pairs),), np.float64)
        model_idx: List[int] = []
        model_pairs: List[Tuple[Sequence[int], Any]] = []
        for i, (s, ledger) in enumerate(pairs):
            if len(self.cluster.partition_by_host(s)) == 1:
                out[i] = self.tables.lookup_global(list(s))  # Stage-1: exact
            else:
                model_idx.append(i)
                model_pairs.append((s, ledger))
        if model_pairs:
            tf = time.time()
            B = len(model_pairs)
            Bp = _round_up_pow2(B)
            featurize = (
                feat_lib.featurize_contended_batch if self.vectorized
                else feat_lib.featurize_contended_batch_loop
            )
            feats, mask = featurize(
                self.cluster, self.tables, model_pairs,
                max_tokens=self.max_tokens,
                include_contenders=self.include_contenders,
                host_norm=self.host_norm,
            )
            if self.bucket_shapes:
                used = int(mask.sum(axis=1).max())
                T = _round_up_pow2(max(used, 1))
                if T < feats.shape[1]:
                    feats = feats[:, :T]
                    mask = mask[:, :T]
            self.stats.featurize_seconds += time.time() - tf
            ti = time.time()
            batcher = active_batcher()
            if batcher is not None:
                decoded = batcher.apply(self._apply, self.params, feats, mask)
            else:
                feats = np.pad(feats, ((0, Bp - B), (0, 0), (0, 0)))
                mask_p = np.pad(mask, ((0, Bp - B), (0, 0)))
                mask_p[B:, 0] = 1.0
                preds = self._apply(
                    self.params, jnp.asarray(feats), jnp.asarray(mask_p)
                )
                decoded = np.asarray(preds)[:B]
            self.stats.n_model_calls += B
            self.stats.infer_seconds += time.time() - ti
            for i, p in zip(model_idx, decoded):
                out[i] = p
        self.stats.predict_seconds += time.time() - t0
        return out
