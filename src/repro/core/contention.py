"""Virtual-merge contention estimator (Sec. 4.4).

The dispatcher cannot measure a candidate allocation S against the live
cluster — measuring would perturb the tenants it is trying to avoid.  The
paper's answer is to *virtually merge* S with its co-tenants: collect every
live cross-host job that shares one of S's hosts (and hence its NIC rails),
form the merged rail-demand per host, and conservatively split each host's
rail capacity evenly among the competing collectives.  The result is an
upper bound on the inter-host term S can sustain:

  ``cap(S, L) = min_h (rail_bw(h) / c_h) * min_h(n_h) * 2(k-1)/k * eta``

with ``c_h`` = 1 (S itself) + the number of GPU-disjoint live cross-host
jobs on host h in ledger L.  :class:`ContentionAwarePredictor` then wraps
*any* isolated-bandwidth predictor — the hierarchical surrogate or the
ground truth — as ``min(B_iso(S), cap(S, L))``, so the hybrid search ranks
candidates by the bandwidth they would actually see next to the current
tenants.  Single-host candidates never touch a NIC and pass through
unchanged, as do all candidates under an empty ledger.

The cap evaluates the *same* shared term (``bandwidth_sim.
contended_inter_term``) as the contended ground truth — including the
deterministic per-(hosts, counts) fabric variation, which stands in for
calibration a production dispatcher would measure offline — fed from the
dispatcher's own state: the static topology (rail bandwidths) and its
ledger.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import features as feat_lib
from repro.core.bandwidth_sim import (
    INTER_EFF,
    _jitter,
    contended_inter_term,
)
from repro.core.cluster import Cluster
from repro.core.predict_cache import PredictorStats
from repro.core.tenancy import Allocation, JobLedger

Subset = Sequence[int]


@dataclasses.dataclass(frozen=True)
class MergeView:
    """The virtual merge of a candidate subset with its co-tenants."""

    subset: Tuple[int, ...]
    contenders: Tuple[Allocation, ...]   # GPU-disjoint cross-host co-tenants
    merged_gpus: Tuple[int, ...]         # subset U all contender GPUs
    rail_shares: Dict[int, int]          # host id -> c_h (competing collectives)

    @property
    def contended(self) -> bool:
        return bool(self.contenders)


def virtual_merge(cluster: Cluster, ledger: JobLedger, subset: Subset) -> MergeView:
    """Merge ``subset`` with every live cross-host job sharing one of its
    hosts' NIC rails.  Single-host subsets merge with nothing."""
    by_host = cluster.partition_by_host(subset)
    sub = tuple(sorted(subset))
    if len(by_host) <= 1:
        return MergeView(sub, (), sub, {hid: 1 for hid in by_host})
    contenders: Dict[str, Allocation] = {}
    shares: Dict[int, int] = {}
    for hid in by_host:
        jobs = ledger.cross_host_jobs_on(hid, against=sub)
        shares[hid] = 1 + len(jobs)
        for alloc in jobs:
            contenders[alloc.job_id] = alloc
    ordered = tuple(contenders[j] for j in sorted(contenders))
    merged = set(sub)
    for alloc in ordered:
        merged.update(alloc.gpus)
    return MergeView(sub, ordered, tuple(sorted(merged)), shares)


CrossJobsByHost = Dict[int, List[Allocation]]


def _cap_from_snapshot(
    cluster: Cluster, cross_by_host: CrossJobsByHost, subset: Subset,
    eta: float = INTER_EFF, degrade=None,
) -> float:
    by_host = cluster.partition_by_host(subset)
    if len(by_host) <= 1:
        return float("inf")
    sset = set(subset)
    shares = {
        hid: 1 + sum(
            1 for a in cross_by_host.get(hid, ())
            if JobLedger.contends(a, sset)
        )
        for hid in by_host
    }
    # A degraded rail caps the inter term even with zero contenders — the
    # analytic branch's view of nic_flap / link_degrade faults (see
    # repro.core.faults); ``degrade=None`` is the healthy fast path.
    degraded = degrade is not None and any(
        degrade(hid) != 1.0 for hid in by_host
    )
    if all(c == 1 for c in shares.values()) and not degraded:
        return float("inf")
    # Same shared term (and deterministic fabric jitter) the contended
    # ground truth evaluates: the fabric's per-(hosts,counts) variation is
    # measurable offline and independent of tenancy, so folding it in keeps
    # near-symmetric candidates ranked consistently with the truth.
    return contended_inter_term(
        cluster, by_host, lambda hid: shares[hid], eta=eta,
        rail_factor=degrade if degraded else None,
    )


def contended_inter_cap(
    cluster: Cluster, ledger: JobLedger, subset: Subset, eta: float = INTER_EFF
) -> float:
    """Fair-share inter-host rail cap for ``subset`` given the live ledger.

    ``inf`` when no NIC is involved (single-host) or nothing contends — the
    wrapped predictor is then left untouched.
    """
    degrade = (
        ledger.host_degrade
        if getattr(ledger, "health_active", False) else None
    )
    return _cap_from_snapshot(
        cluster, ledger.cross_jobs_by_host(), subset, eta, degrade=degrade
    )


class _SnapshotArrays:
    """Dense per-snapshot arrays for the vectorized cap: contender GPU
    membership masks, per-host touch flags, and static host data.  Built
    once per (ledger uid, version) and reused across every predict call of
    an admission — the hybrid search degrades ~20 candidate batches against
    one unchanged ledger state."""

    def __init__(
        self, cluster: Cluster, cross_by_host: CrossJobsByHost, degrade=None
    ):
        self.gpu_host = np.asarray(cluster.gpu_host, np.int64)
        self.rail_bw = np.asarray(
            [h.host_type.nic_rail_bw for h in cluster.hosts], np.float64
        )
        # Health degrade folded into the rail vector (nic * f, the same
        # float order as the scalar path) + the activation mask that makes
        # a degraded-but-uncontended host still cap the inter term.
        if degrade is None:
            self.degraded = np.zeros(cluster.n_hosts, bool)
        else:
            f = np.asarray(
                [degrade(h.host_id) for h in cluster.hosts], np.float64
            )
            self.degraded = f != 1.0
            self.rail_bw = self.rail_bw * f
        allocs = sorted(
            {a.job_id: a
             for jobs in cross_by_host.values() for a in jobs}.values(),
            key=lambda a: a.job_id,
        )
        nJ = len(allocs)
        self.occ = np.zeros((nJ, cluster.n_gpus), np.int64)
        self.touch = np.zeros((nJ, cluster.n_hosts), np.int64)
        for j, a in enumerate(allocs):
            gs = np.asarray(a.gpus, np.int64)
            self.occ[j, gs] = 1
            self.touch[j, self.gpu_host[gs]] = 1


def _subset_grid(
    snap: _SnapshotArrays, subsets: Sequence[Subset], n_hosts: int, n_gpus: int
):
    """Membership/count grids + contends matrix for a candidate batch."""
    B = len(subsets)
    lens = np.asarray([len(s) for s in subsets], np.int64)
    flat = (
        np.concatenate([np.asarray(s, np.int64) for s in subsets])
        if B and lens.sum() else np.zeros((0,), np.int64)
    )
    rows = np.repeat(np.arange(B, dtype=np.int64), lens)
    counts = np.zeros((B, n_hosts), np.int64)
    np.add.at(counts, (rows, snap.gpu_host[flat]), 1)
    M = np.zeros((B, n_gpus), np.int64)
    M[rows, flat] = 1
    disjoint = ((M @ snap.occ.T) == 0).astype(np.int64)
    return lens, counts, disjoint


def _caps_from_snapshot_batched(
    cluster: Cluster,
    cross_by_host: CrossJobsByHost,
    subsets: Sequence[Subset],
    eta: float = INTER_EFF,
    jitter_cache: Optional[Dict] = None,
    snap: Optional[_SnapshotArrays] = None,
) -> np.ndarray:
    """Vectorized :func:`_cap_from_snapshot` over a candidate batch.

    One numpy program replaces the per-candidate partition + per-host
    contender scan: candidate membership masks matmul against the
    snapshot's contender GPU masks for the disjointness predicate, and the
    per-host contender counts fall out of a second matmul.  The final
    deterministic fabric jitter is the same per-(hosts, counts) hash the
    scalar path evaluates, memoized in ``jitter_cache`` — outputs are
    bit-identical to the loop (regression-pinned in tests/test_fast_path).
    """
    if snap is None:
        snap = _SnapshotArrays(cluster, cross_by_host)
    B = len(subsets)
    lens, counts, disjoint = _subset_grid(
        snap, subsets, cluster.n_hosts, cluster.n_gpus
    )
    part = counts > 0
    n_part = part.sum(axis=1)
    c = 1 + disjoint @ snap.touch                      # [B, n_hosts]

    caps = np.full((B,), np.inf, np.float64)
    # same float program as the scalar path: min over participating hosts
    # of rail_bw / c_h, then rail * min(counts) * (2(k-1)/k) * eta * jitter
    per_host = np.where(part, snap.rail_bw[None, :] / c, np.inf)
    rail = per_host.min(axis=1)
    min_counts = np.where(part, counts, np.iinfo(np.int64).max).min(axis=1)
    active = (n_part > 1) & (((c > 1) | snap.degraded[None, :]) & part).any(
        axis=1
    )
    idx = np.nonzero(active)[0]
    if not len(idx):
        return caps
    ks = lens[idx]
    inter = (
        rail[idx] * min_counts[idx] * (2.0 * (ks - 1) / ks) * eta
    )
    if jitter_cache is None:
        jitter_cache = {}
    for i, b in enumerate(idx):
        key = tuple(
            (int(h), int(counts[b, h])) for h in np.nonzero(part[b])[0]
        )
        j = jitter_cache.get(key)
        if j is None:
            j = _jitter(cluster.name, "inter", key)
            jitter_cache[key] = j
        caps[b] = inter[i] * j
    return caps


PREDICTOR_MODES = ("analytic", "learned")


class ContentionAwarePredictor:
    """Wrap a predictor so ``predict`` returns contention-degraded bandwidth.

    Exposes the same ``predict(list_of_subsets) -> np.ndarray`` protocol the
    hybrid search consumes, so it threads through ``search.hybrid_search``
    unchanged.  The ledger is read live at predict time: one wrapper built at
    service start stays correct across every admit/release.

    Two modes:

    * ``mode="analytic"`` (default) — the virtual-merge fair-share cap:
      ``min(B_iso(S), cap(S, L))``.
    * ``mode="learned"`` — candidates with at least one rail contender are
      scored by a trained :class:`~repro.core.surrogate.
      ContendedSurrogatePredictor` (``contended=...``), clamped by the
      isolated estimate (a co-tenant can never *raise* bandwidth).

    Both modes are exact pass-throughs for single-host candidates,
    uncontended candidates, and the empty ledger — the learned mode returns
    the isolated predictor's output *bit-identically* there
    (regression-pinned in ``tests/test_learned_contention.py``).
    """

    def __init__(
        self,
        cluster: Cluster,
        base,
        ledger: JobLedger,
        mode: str = "analytic",
        contended=None,
        vectorized: bool = True,
    ):
        if mode not in PREDICTOR_MODES:
            raise ValueError(
                f"unknown mode {mode!r}; expected one of {PREDICTOR_MODES}"
            )
        if mode == "learned" and contended is None:
            raise ValueError(
                "mode='learned' needs a contended predictor (contended=...)"
            )
        self.cluster = cluster
        self.base = base
        self.ledger = ledger
        self.mode = mode
        self.contended = contended
        self.vectorized = vectorized
        # Degraded-mode fallback switch: when True (set by faults.
        # install_degraded_fallback on a DriftMonitor alert), the learned
        # branch is bypassed and every candidate is scored by the analytic
        # cap — the surrogate never trained on degraded fabric, so its
        # errors there are structural.
        self.force_analytic = False
        self.stats = PredictorStats()
        self._jitter_cache: Dict = {}
        self._snap_version: Optional[int] = None
        self._snap: Optional[_SnapshotArrays] = None
        self._cap_tab: Optional[np.ndarray] = None
        self._cap_tab_version: Optional[Tuple[int, int]] = None

    # legacy instrumentation names
    @property
    def n_capped(self) -> int:
        return self.stats.n_capped

    @n_capped.setter
    def n_capped(self, v: int) -> None:
        self.stats.n_capped = v

    @property
    def predict_seconds(self) -> float:
        """Wrapper overhead (excl. base/contended predictor time)."""
        return self.stats.wrapper_seconds

    @predict_seconds.setter
    def predict_seconds(self, v: float) -> None:
        self.stats.wrapper_seconds = v

    def predict(self, subsets: Sequence[Subset]) -> np.ndarray:
        iso = np.asarray(self.base.predict(subsets), dtype=np.float64)
        return self._degrade(subsets, iso)

    def predict_children(self, parent: Sequence[int]) -> np.ndarray:
        """One fused PTS elimination round: the base predictor's incremental
        child path (when it has one) plus one batched cap evaluation."""
        parent = list(parent)
        if hasattr(self.base, "predict_children"):
            iso = np.asarray(self.base.predict_children(parent), np.float64)
        else:
            iso = np.asarray(
                self.base.predict(
                    [parent[:i] + parent[i + 1:] for i in range(len(parent))]
                ),
                np.float64,
            )
        children = [parent[:i] + parent[i + 1:] for i in range(len(parent))]
        return self._degrade(children, iso)

    def _snapshot(self) -> _SnapshotArrays:
        """Per-(ledger version) dense snapshot: the ledger cannot change
        within one predict call, and the hybrid search issues ~20 predict
        batches per admission against one unchanged state — build the
        membership arrays once per version, not once per batch."""
        v = (self.ledger.uid, self.ledger.version)
        if self._snap_version != v:
            degrade = (
                self.ledger.host_degrade
                if getattr(self.ledger, "health_active", False) else None
            )
            self._snap = _SnapshotArrays(
                self.cluster, self.ledger.cross_jobs_by_host(),
                degrade=degrade,
            )
            self._snap_version = v
        return self._snap

    # fused on-device descent ------------------------------------------------

    def eliminate_to(self, parent: Sequence[int], k: int):
        """Run a whole PTS descent on-device *through* the contention cap.

        For a PTS parent of free GPUs, every child is GPU-disjoint from
        every live job, so the analytic cap collapses to a pure function of
        the child's per-host count vector — one float32 table over the
        count lattice (built per ledger version, microseconds of numpy)
        that the scan body gathers alongside the isolated score.  Returns
        the base predictor's :class:`~repro.core.surrogate.ScanResult` or
        None (caller falls back to the host loop): learned mode under a
        contended ledger, non-vectorized wrappers, cap-incompatible bases,
        and parents overlapping live jobs all decline."""
        base_elim = getattr(self.base, "eliminate_to", None)
        if base_elim is None:
            return None
        health = getattr(self.ledger, "health_active", False)
        if len(self.ledger) == 0 and not health:
            return base_elim(parent, k)  # exact pass-through, like _degrade
        if not self.ledger.busy().isdisjoint(parent):
            return None  # cap depends on disjointness: not table-gatherable
        snap = self._snapshot()
        if snap.touch.shape[0] == 0 and not health:
            # no cross-host tenants: both modes leave candidates untouched
            return base_elim(parent, k)
        mode = "analytic" if self.force_analytic else self.mode
        if mode != "analytic" or not self.vectorized:
            return None
        tables = getattr(self.base, "tables", None)
        if tables is None:
            return None
        dt = feat_lib.device_tables(self.cluster, tables)
        res = base_elim(parent, k, caps=self._cap_table(dt, snap))
        if res is not None:
            self.stats.n_capped += res.n_capped
        return res

    def _cap_table(
        self, dt: "feat_lib.DeviceTables", snap: _SnapshotArrays
    ) -> np.ndarray:
        """The analytic cap tabulated over the per-host count lattice, for
        GPU-disjoint candidates against this ledger version.  The same
        float64 program as :func:`_caps_from_snapshot_batched` with
        ``disjoint == 1`` (so ``c_h = 1 + cross-jobs touching h``),
        evaluated per lattice point and cast to float32 once — a device
        gather lands on exactly ``np.float32(host-path cap)``."""
        v = (self.ledger.uid, self.ledger.version)
        if self._cap_tab_version != v or self._cap_tab is None:
            lat = dt.cap_lattice()
            c = 1 + snap.touch.sum(axis=0)                  # [n_hosts]
            per_host = np.where(
                lat.part, snap.rail_bw[None, :] / c[None, :], np.inf
            )
            rail = per_host.min(axis=1)
            min_counts = np.where(
                lat.part, lat.counts, np.iinfo(np.int64).max
            ).min(axis=1)
            active = (lat.n_part > 1) & (
                ((c[None, :] > 1) | snap.degraded[None, :]) & lat.part
            ).any(axis=1)
            caps = np.full((lat.counts.shape[0],), np.inf, np.float64)
            idx = np.nonzero(active)[0]
            if len(idx):
                ks = lat.ks[idx]
                inter = (
                    rail[idx] * min_counts[idx]
                    * (2.0 * (ks - 1) / ks) * INTER_EFF
                )
                caps[idx] = inter * lat.jitter[idx]
            self._cap_tab = caps.astype(np.float32)
            self._cap_tab_version = v
        return self._cap_tab

    def _degrade(
        self, subsets: Sequence[Subset], iso: np.ndarray
    ) -> np.ndarray:
        health = getattr(self.ledger, "health_active", False)
        if len(self.ledger) == 0 and not health:
            return iso
        t0 = time.time()
        out = iso.copy()
        inner = 0.0  # time spent inside the contended model, not the wrapper
        mode = "analytic" if self.force_analytic else self.mode
        if mode == "learned" and self.vectorized:
            snap = self._snapshot()
            _, counts, disjoint = _subset_grid(
                snap, subsets, self.cluster.n_hosts, self.cluster.n_gpus
            )
            part = counts > 0
            contended = (part.sum(axis=1) > 1) & (
                ((disjoint @ snap.touch) * part) > 0
            ).any(axis=1)
            learned_mask = contended
            if health:
                # Degraded fabric: every candidate takes the analytic cap
                # (the snapshot's rail vector carries the degrade factors),
                # and the learned head is consulted only for contended
                # candidates that touch no health-perturbed host — the
                # surrogate never saw degraded rails in training.
                caps = _caps_from_snapshot_batched(
                    self.cluster, {}, subsets,
                    jitter_cache=self._jitter_cache, snap=snap,
                )
                capped = caps < out
                out[capped] = caps[capped]
                self.stats.n_capped += int(capped.sum())
                learned_mask = contended & ~(
                    part & snap.degraded[None, :]
                ).any(axis=1)
            idx = np.nonzero(learned_mask)[0].tolist()
            if idx:
                before = self.contended.predict_seconds
                learned = self.contended.predict(
                    [subsets[i] for i in idx], self.ledger
                )
                inner = self.contended.predict_seconds - before
                for i, p in zip(idx, learned):
                    if p < out[i]:
                        out[i] = p
                        self.stats.n_capped += 1
            self.stats.wrapper_seconds += time.time() - t0 - inner
            return out
        if self.vectorized:  # analytic, batched caps over the version snapshot
            caps = _caps_from_snapshot_batched(
                self.cluster, {}, subsets,
                jitter_cache=self._jitter_cache, snap=self._snapshot(),
            )
            capped = caps < out
            out[capped] = caps[capped]
            self.stats.n_capped += int(capped.sum())
            self.stats.wrapper_seconds += time.time() - t0
            return out
        # Legacy scalar paths (the throughput bench's before-side): snapshot
        # the cross-host jobs per host once per call, not per candidate.
        cross_by_host = self.ledger.cross_jobs_by_host()
        degrade = self.ledger.host_degrade if health else None
        if mode == "learned" and health:
            mode = "analytic"  # scalar learned path has no degraded view
        if mode == "learned":
            idx = [
                i for i, s in enumerate(subsets)
                if self._contended_by(cross_by_host, s)
            ]
            if idx:
                # model inference is accounted by the contended predictor's
                # own predict_seconds; keep this counter wrapper-only
                before = self.contended.predict_seconds
                learned = self.contended.predict(
                    [subsets[i] for i in idx], self.ledger
                )
                inner = self.contended.predict_seconds - before
                for i, p in zip(idx, learned):
                    if p < out[i]:
                        out[i] = p
                        self.stats.n_capped += 1
        else:
            for i, s in enumerate(subsets):
                cap = _cap_from_snapshot(
                    self.cluster, cross_by_host, s, degrade=degrade
                )
                if cap < out[i]:
                    out[i] = cap
                    self.stats.n_capped += 1
        self.stats.wrapper_seconds += time.time() - t0 - inner
        return out

    def _contended_by(
        self, cross_by_host: CrossJobsByHost, subset: Subset
    ) -> bool:
        """True iff >=1 live cross-host job contends with ``subset`` — the
        learned head only ever sees inputs with a non-zero ledger context."""
        by_host = self.cluster.partition_by_host(subset)
        if len(by_host) <= 1:
            return False
        sset = set(subset)
        return any(
            JobLedger.contends(a, sset)
            for hid in by_host
            for a in cross_by_host.get(hid, ())
        )

    def predict_one(self, subset: Subset) -> float:
        return float(self.predict([subset])[0])

    def tenant_bandwidths(self) -> Dict[str, float]:
        """Contention-degraded estimate for every *live* tenant, keyed by
        job id.  Each job's own ledger entry self-excludes through the
        ``contends`` predicate, so no bookkeeping is needed to grade a job
        that is already admitted.  This is the predictor-side view the
        defrag planner's gain accounting mirrors (the scheduler's triggers
        evaluate the same sum with the grading simulator — see
        :mod:`repro.core.defrag`)."""
        allocs = list(self.ledger.jobs())
        preds = self.predict([list(a.gpus) for a in allocs])
        return {a.job_id: float(p) for a, p in zip(allocs, preds)}

    def merged_bandwidth(self, subset: Subset) -> float:
        """Isolated-model bandwidth of the merged virtual collective — the
        shared-bottleneck capacity probe from the paper's Sec. 4.4 framing.
        Diagnostic: the fair-share cap, not this probe, drives ``predict``."""
        view = virtual_merge(self.cluster, self.ledger, subset)
        return float(np.asarray(self.base.predict([view.merged_gpus]))[0])
