"""Multi-tenant occupancy: live jobs, their allocations, derived availability.

The seed reproduction treated dispatching as a pure function over an ad-hoc
``avail`` list.  A real dispatcher is a *service*: jobs arrive, hold GPUs for
a while, and depart, and the set of live jobs — not a caller-supplied list —
is the source of truth for both availability and cross-job contention.  The
:class:`JobLedger` is that source of truth; everything contention-related
(:mod:`repro.core.contention`, the contended ground truth in
:mod:`repro.core.bandwidth_sim`) derives its view of the cluster from it.

Terminology used throughout the contention stack:

* an allocation is **cross-host** when it spans >1 host — only those jobs
  drive NIC-rail traffic and therefore contend with other collectives;
* a live job **contends with** a candidate subset S on host h when it is
  cross-host, occupies >=1 GPU of h, and is GPU-disjoint from S (a job is
  never its own contender, which makes re-grading an admitted job safe
  without bookkeeping about which ledger entry "is" S).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.core.cluster import Cluster


@dataclasses.dataclass(frozen=True)
class Allocation:
    """One live job's placement: the unit the ledger admits and releases."""

    job_id: str
    gpus: Tuple[int, ...]
    host_ids: Tuple[int, ...]

    @property
    def k(self) -> int:
        return len(self.gpus)

    @property
    def cross_host(self) -> bool:
        return len(self.host_ids) > 1


@dataclasses.dataclass(frozen=True)
class ContentionSnapshot:
    """Frozen per-host rail-contender counts (and per-contender GPU demands),
    duck-typing the two methods of :class:`JobLedger` the bandwidth simulator
    consumes.

    Valid ONLY for candidate subsets GPU-disjoint from every live allocation
    (anything drawn from ``available()``): the disjointness check is
    pre-resolved, which is what makes hot loops — the exact Oracle's count-
    vector enumeration — skip the per-candidate set work.

    ``frag`` carries the ledger's fragmentation state at snapshot time (a
    :class:`repro.core.defrag.FragmentationMetrics`), so consumers grading
    or planning against the frozen view see the same stranding / clean-host
    picture the defrag subsystem acts on.
    """

    counts: Dict[int, int]
    demands: Dict[int, Tuple[int, ...]] = dataclasses.field(default_factory=dict)
    frag: Optional[object] = None  # defrag.FragmentationMetrics (lazy import)

    def rail_contenders(self, host_id: int, against: Sequence[int] = ()) -> int:
        return self.counts.get(host_id, 0)

    def contender_demands(
        self, host_id: int, against: Sequence[int] = ()
    ) -> Tuple[int, ...]:
        return self.demands.get(host_id, ())


class JobLedger:
    """Tracks live jobs and per-host occupancy for one :class:`Cluster`.

    Invariants (enforced on every mutation):
      * live allocations are pairwise GPU-disjoint;
      * ``available() == all_gpus - union(live allocations)``;
      * ``release(admit(j, S).job_id)`` restores the exact prior state
        (except the :attr:`version` counter, which only ever grows).

    ``version`` is a monotonic counter bumped by every successful admit and
    release — the cache-invalidation token of the dispatch fast path
    (:mod:`repro.core.predict_cache`): any memo keyed by ``(subset,
    version)`` is automatically stale the moment occupancy changes.  ``uid``
    distinguishes ledger *instances* (scratch copies start their own version
    space), so version-keyed entries from different ledgers never collide.
    """

    _next_uid = 0

    def __init__(self, cluster: Cluster):
        self.cluster = cluster
        self._jobs: Dict[str, Allocation] = {}
        self._owner: Dict[int, str] = {}  # gpu id -> job id
        # host id -> job ids with >=1 GPU on that host (cross- or single-host)
        self._host_jobs: Dict[int, Set[str]] = {
            h.host_id: set() for h in cluster.hosts
        }
        self._version = 0
        self.uid = JobLedger._next_uid
        JobLedger._next_uid += 1

    @property
    def version(self) -> int:
        """Monotonic occupancy version: bumped on every admit/release."""
        return self._version

    # -- lifecycle ----------------------------------------------------------

    def admit(self, job_id: str, gpus: Sequence[int]) -> Allocation:
        """Record ``job_id`` as live on ``gpus``.  Returns the allocation."""
        if job_id in self._jobs:
            raise ValueError(f"job {job_id!r} is already live")
        subset = tuple(sorted(gpus))
        if len(subset) == 0:
            raise ValueError("empty allocation")
        if len(set(subset)) != len(subset):
            raise ValueError(f"duplicate GPU ids in allocation: {gpus}")
        for g in subset:
            if g < 0 or g >= self.cluster.n_gpus:
                raise ValueError(f"GPU id {g} outside cluster")
            if g in self._owner:
                raise ValueError(
                    f"GPU {g} is busy (held by job {self._owner[g]!r})"
                )
        host_ids = tuple(sorted(self.cluster.partition_by_host(subset)))
        alloc = Allocation(job_id, subset, host_ids)
        self._jobs[job_id] = alloc
        for g in subset:
            self._owner[g] = job_id
        for hid in host_ids:
            self._host_jobs[hid].add(job_id)
        self._version += 1
        return alloc

    def release(self, job_id: str) -> Allocation:
        """Remove a live job, returning its (now freed) allocation."""
        alloc = self._jobs.pop(job_id, None)
        if alloc is None:
            raise KeyError(f"job {job_id!r} is not live")
        for g in alloc.gpus:
            del self._owner[g]
        for hid in alloc.host_ids:
            self._host_jobs[hid].discard(job_id)
        self._version += 1
        return alloc

    # -- queries ------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._jobs)

    def __contains__(self, job_id: str) -> bool:
        return job_id in self._jobs

    def jobs(self) -> Iterator[Allocation]:
        return iter(self._jobs.values())

    def allocation(self, job_id: str) -> Allocation:
        return self._jobs[job_id]

    def busy(self) -> Set[int]:
        return set(self._owner)

    def available(self) -> List[int]:
        """Sorted global ids of all GPUs not held by any live job."""
        return [g for g in range(self.cluster.n_gpus) if g not in self._owner]

    def n_free(self) -> int:
        """Number of free GPUs — O(1), for scheduler capacity checks."""
        return self.cluster.n_gpus - len(self._owner)

    def occupancy(self, host_id: int) -> int:
        """Number of busy GPUs on one host."""
        host = self.cluster.hosts[host_id]
        return sum(1 for g in host.gpu_ids if g in self._owner)

    def free_by_host(self) -> Dict[int, int]:
        """host id -> free GPU count, for every host (zeros included)."""
        return {
            h.host_id: h.n_gpus - self.occupancy(h.host_id)
            for h in self.cluster.hosts
        }

    def fragmentation(self):
        """Fragmentation state of the current occupancy — stranding score,
        clean-host count, largest placeable single-host block (a
        :class:`repro.core.defrag.FragmentationMetrics`)."""
        from repro.core.defrag import fragmentation_metrics

        return fragmentation_metrics(self.cluster, self)

    @staticmethod
    def contends(alloc: Allocation, against: Set[int]) -> bool:
        """THE rail-contention predicate (see module docstring): a live job
        contends with a candidate iff it is cross-host and GPU-disjoint from
        it.  Shared by the contended ground truth and the virtual-merge
        estimator so the two can never drift apart."""
        return alloc.cross_host and against.isdisjoint(alloc.gpus)

    def cross_host_jobs_on(
        self, host_id: int, against: Sequence[int] = ()
    ) -> List[Allocation]:
        """Live cross-host jobs with >=1 GPU on ``host_id``, excluding any
        job that shares a GPU with ``against`` (i.e. ``against`` itself)."""
        excluded = set(against)
        return [
            self._jobs[job_id]
            for job_id in sorted(self._host_jobs[host_id])
            if self.contends(self._jobs[job_id], excluded)
        ]

    def cross_jobs_by_host(self) -> Dict[int, List[Allocation]]:
        """Snapshot: host id -> live *cross-host* allocations touching it.

        The contention estimator consumes this once per predict batch; hosts
        with no cross-host tenants are omitted.
        """
        out: Dict[int, List[Allocation]] = {}
        for hid, job_ids in self._host_jobs.items():
            cross = [
                self._jobs[j] for j in sorted(job_ids)
                if self._jobs[j].cross_host
            ]
            if cross:
                out[hid] = cross
        return out

    def rail_contenders(self, host_id: int, against: Sequence[int] = ()) -> int:
        """Number of live collectives competing for ``host_id``'s NIC rails
        against a candidate subset (see module docstring for the predicate)."""
        return len(self.cross_host_jobs_on(host_id, against=against))

    def contender_demands(
        self, host_id: int, against: Sequence[int] = ()
    ) -> Tuple[int, ...]:
        """Per-contender GPU counts on ``host_id`` (one entry per contending
        cross-host job, same predicate as :meth:`rail_contenders`) — the rail
        demands the *saturating* contention model weighs shares by."""
        return tuple(
            sum(1 for g in a.gpus if self.cluster.gpu_host[g] == host_id)
            for a in self.cross_host_jobs_on(host_id, against=against)
        )

    def snapshot(self) -> ContentionSnapshot:
        """Pre-resolved contender counts/demands for candidates drawn from
        ``available()`` (always GPU-disjoint from live jobs)."""
        cross = self.cross_jobs_by_host()
        return ContentionSnapshot(
            {hid: len(jobs) for hid, jobs in cross.items()},
            {
                hid: tuple(
                    sum(1 for g in a.gpus if self.cluster.gpu_host[g] == hid)
                    for a in jobs
                )
                for hid, jobs in cross.items()
            },
            frag=self.fragmentation(),
        )

    def describe(self) -> str:
        live = ", ".join(
            f"{a.job_id}:k={a.k}@{list(a.host_ids)}" for a in self.jobs()
        )
        return (
            f"ledger[{self.cluster.name}]: {len(self)} live jobs, "
            f"{len(self._owner)}/{self.cluster.n_gpus} GPUs busy"
            + (f" ({live})" if live else "")
        )
