"""Multi-tenant occupancy: live jobs, their allocations, derived availability.

The seed reproduction treated dispatching as a pure function over an ad-hoc
``avail`` list.  A real dispatcher is a *service*: jobs arrive, hold GPUs for
a while, and depart, and the set of live jobs — not a caller-supplied list —
is the source of truth for both availability and cross-job contention.  The
:class:`JobLedger` is that source of truth; everything contention-related
(:mod:`repro.core.contention`, the contended ground truth in
:mod:`repro.core.bandwidth_sim`) derives its view of the cluster from it.

Terminology used throughout the contention stack:

* an allocation is **cross-host** when it spans >1 host — only those jobs
  drive NIC-rail traffic and therefore contend with other collectives;
* a live job **contends with** a candidate subset S on host h when it is
  cross-host, occupies >=1 GPU of h, and is GPU-disjoint from S (a job is
  never its own contender, which makes re-grading an admitted job safe
  without bookkeeping about which ledger entry "is" S).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.core.cluster import Cluster

_UID_LOCK = threading.Lock()  # guards the class-level uid counter

# Health lattice (see repro.core.faults): GPUs in either of these states
# are unplaceable — excluded from ``available()`` and refused by
# ``admit``/``migrate`` by construction.
_UNPLACEABLE = frozenset(("quarantined", "dead"))

# Fault kinds the ledger itself understands.  The first four mirror
# faults.FAULT_KINDS; ``quarantine`` is the operator/fencing action that
# removes a GPU from placement without declaring it dead.
_LEDGER_FAULT_KINDS = (
    "gpu_down", "host_down", "nic_flap", "link_degrade", "quarantine",
)


class CapacityError(ValueError):
    """An admission cannot be satisfied right now: not enough free GPUs.

    Expected under load — the control plane / scheduler queues the request
    and retries at the next release.  Subclasses :class:`ValueError` so
    legacy ``except ValueError`` call sites keep working.
    """


class InvalidPlacementError(ValueError):
    """A placement policy returned a subset that violates its request
    (wrong size, busy or out-of-range GPUs) — a programmer error, never an
    operational condition.  Callers must crash loudly, not queue."""


class VersionConflict(RuntimeError):
    """A compare-and-swap admission lost the race: the ledger version moved
    past the one the placement was staged against.  The worker re-searches
    against a fresh snapshot (see :mod:`repro.core.controlplane`)."""

    def __init__(self, staged: int, actual: int):
        super().__init__(
            f"ledger version moved: staged against v{staged}, now v{actual}"
        )
        self.staged = staged
        self.actual = actual


@dataclasses.dataclass(frozen=True)
class Allocation:
    """One live job's placement: the unit the ledger admits and releases.

    ``tenant`` is carried through the ledger (and the journal, when one is
    attached) so journal-reconstructed views can answer tenant-scoped
    questions — the forensics ``whatif(drop_tenant=...)`` counterfactual
    in particular.  Empty string means "no tenant" and is omitted from the
    journal encoding, keeping tenant-less streams byte-identical to PR 7.
    """

    job_id: str
    gpus: Tuple[int, ...]
    host_ids: Tuple[int, ...]
    tenant: str = ""

    @property
    def k(self) -> int:
        return len(self.gpus)

    @property
    def cross_host(self) -> bool:
        return len(self.host_ids) > 1


@dataclasses.dataclass(frozen=True)
class ContentionSnapshot:
    """Frozen per-host rail-contender counts (and per-contender GPU demands),
    duck-typing the two methods of :class:`JobLedger` the bandwidth simulator
    consumes.

    Valid ONLY for candidate subsets GPU-disjoint from every live allocation
    (anything drawn from ``available()``): the disjointness check is
    pre-resolved, which is what makes hot loops — the exact Oracle's count-
    vector enumeration — skip the per-candidate set work.

    ``frag`` carries the ledger's fragmentation state at snapshot time (a
    :class:`repro.core.defrag.FragmentationMetrics`), so consumers grading
    or planning against the frozen view see the same stranding / clean-host
    picture the defrag subsystem acts on.
    """

    counts: Dict[int, int]
    demands: Dict[int, Tuple[int, ...]] = dataclasses.field(default_factory=dict)
    frag: Optional[object] = None  # defrag.FragmentationMetrics (lazy import)
    # host id -> rail degrade factor (absent == 1.0, healthy); mirrors the
    # source ledger's health view so grading against the frozen snapshot
    # sees the same degraded fabric the live ledger does.
    degrade: Dict[int, float] = dataclasses.field(default_factory=dict)

    def rail_contenders(self, host_id: int, against: Sequence[int] = ()) -> int:
        return self.counts.get(host_id, 0)

    def contender_demands(
        self, host_id: int, against: Sequence[int] = ()
    ) -> Tuple[int, ...]:
        return self.demands.get(host_id, ())

    @property
    def health_active(self) -> bool:
        return bool(self.degrade)

    def host_degrade(self, host_id: int) -> float:
        return self.degrade.get(host_id, 1.0)

    def gpu_health(self, gpu_id: int) -> str:
        # Snapshots only ever see candidates drawn from ``available()``,
        # which already excludes quarantined/dead GPUs.
        return "healthy"


class JobLedger:
    """Tracks live jobs and per-host occupancy for one :class:`Cluster`.

    Invariants (enforced on every mutation):
      * live allocations are pairwise GPU-disjoint;
      * ``available() == all_gpus - union(live allocations)``;
      * ``release(admit(j, S).job_id)`` restores the exact prior state
        (except the :attr:`version` counter, which only ever grows).

    ``version`` is a monotonic counter bumped by every successful admit and
    release — the cache-invalidation token of the dispatch fast path
    (:mod:`repro.core.predict_cache`): any memo keyed by ``(subset,
    version)`` is automatically stale the moment occupancy changes.  ``uid``
    distinguishes ledger *instances* (scratch copies start their own version
    space), so version-keyed entries from different ledgers never collide.

    Since ISSUE 7 the version counter is also the **CAS token** of the
    concurrent-admission control plane: :meth:`admit_if` commits a staged
    placement only when the version still equals the one its search was
    pinned against (raising :class:`VersionConflict` otherwise), and every
    mutation runs under :attr:`lock` so overlapping workers serialize only
    their cheap commits, never their searches.  When a
    :class:`~repro.core.controlplane.LedgerJournal` is attached, every
    mutation is serialized to the journal *before* the in-memory change
    (write-ahead), so :func:`~repro.core.controlplane.replay_journal`
    rebuilds a bit-identical ledger — same allocations, same version
    counter — after a crash at any point.
    """

    _next_uid = 0

    def __init__(self, cluster: Cluster):
        self.cluster = cluster
        self._jobs: Dict[str, Allocation] = {}
        self._owner: Dict[int, str] = {}  # gpu id -> job id
        # host id -> job ids with >=1 GPU on that host (cross- or single-host)
        self._host_jobs: Dict[int, Set[str]] = {
            h.host_id: set() for h in cluster.hosts
        }
        self._version = 0
        # Sparse health state (absent == healthy / 1.0).  Mutated only by
        # apply_fault/apply_recover, under the same version counter and
        # write-ahead journal as occupancy — a fault IS an occupancy-
        # relevant event (caches keyed on version must go stale).
        self._gpu_health: Dict[int, str] = {}
        self._host_degrade: Dict[int, float] = {}
        # Reentrant: admit_if/migrate call admit/release while holding it,
        # and compound read-harvest sequences (report_bandwidth) nest too.
        self.lock = threading.RLock()
        self.journal = None  # controlplane.LedgerJournal (write-ahead sink)
        # seq of the last journal event this ledger wrote (-1 = none yet).
        # Read under ``lock`` right after a mutation to correlate the commit
        # with its journal line (admission spans / forensics dossiers).
        self.last_journal_seq = -1
        with _UID_LOCK:
            self.uid = JobLedger._next_uid
            JobLedger._next_uid += 1

    @property
    def version(self) -> int:
        """Monotonic occupancy version: bumped on every admit/release."""
        return self._version

    # -- lifecycle ----------------------------------------------------------

    def attach_journal(self, journal, recovered: bool = False) -> None:
        """Attach a write-ahead journal sink: every subsequent mutation is
        serialized to it before the in-memory change.  Requires a fresh
        (empty, version-0) ledger unless ``recovered=True`` — the recovery
        flow re-attaches a journal whose tail already describes the current
        state (see :func:`~repro.core.controlplane.replay_journal`)."""
        if not recovered and (self._jobs or self._version != 0):
            raise ValueError(
                "journal must be attached to a fresh ledger (or pass "
                "recovered=True after replay_journal)"
            )
        self.journal = journal

    def admit(
        self, job_id: str, gpus: Sequence[int], tenant: str = ""
    ) -> Allocation:
        """Record ``job_id`` as live on ``gpus``.  Returns the allocation."""
        with self.lock:
            if job_id in self._jobs:
                raise ValueError(f"job {job_id!r} is already live")
            subset = tuple(sorted(gpus))
            if len(subset) == 0:
                raise InvalidPlacementError("empty allocation")
            if len(set(subset)) != len(subset):
                raise InvalidPlacementError(
                    f"duplicate GPU ids in allocation: {gpus}"
                )
            for g in subset:
                if g < 0 or g >= self.cluster.n_gpus:
                    raise InvalidPlacementError(f"GPU id {g} outside cluster")
                if g in self._owner:
                    raise ValueError(
                        f"GPU {g} is busy (held by job {self._owner[g]!r})"
                    )
                state = self._gpu_health.get(g)
                if state in _UNPLACEABLE:
                    raise ValueError(f"GPU {g} is {state} (unplaceable)")
            if self.journal is not None:  # write-ahead: validated, not applied
                self.last_journal_seq = self.journal.record(
                    "admit", job_id=job_id, gpus=list(subset), tenant=tenant
                )
            host_ids = tuple(sorted(self.cluster.partition_by_host(subset)))
            alloc = Allocation(job_id, subset, host_ids, tenant=tenant)
            self._jobs[job_id] = alloc
            for g in subset:
                self._owner[g] = job_id
            for hid in host_ids:
                self._host_jobs[hid].add(job_id)
            self._version += 1
            return alloc

    def admit_if(
        self, job_id: str, gpus: Sequence[int], version: int, tenant: str = ""
    ) -> Allocation:
        """Compare-and-swap admission: admit ``job_id`` on ``gpus`` only if
        the ledger version still equals ``version`` (the version the
        placement's search was staged against), else raise
        :class:`VersionConflict` without mutating anything.  The concurrent
        control plane's commit primitive: searches overlap freely, commits
        serialize on :attr:`lock`, and a lost race is detected here."""
        with self.lock:
            if self._version != version:
                raise VersionConflict(version, self._version)
            return self.admit(job_id, gpus, tenant=tenant)

    def release(self, job_id: str) -> Allocation:
        """Remove a live job, returning its (now freed) allocation."""
        with self.lock:
            alloc = self._jobs.get(job_id)
            if alloc is None:
                raise KeyError(f"job {job_id!r} is not live")
            if self.journal is not None:
                self.last_journal_seq = self.journal.record(
                    "release", job_id=job_id
                )
            del self._jobs[job_id]
            for g in alloc.gpus:
                del self._owner[g]
            for hid in alloc.host_ids:
                self._host_jobs[hid].discard(job_id)
            self._version += 1
            return alloc

    def migrate(self, job_id: str, gpus: Sequence[int]) -> Allocation:
        """Re-place a live job onto ``gpus`` (which may overlap its current
        allocation) as one atomic release+admit — version bumps by exactly
        2, identical to the manual pair, but the journal records a single
        ``migrate`` event.  Fully validated before anything is journaled or
        mutated, so a failing move leaves ledger and journal untouched."""
        with self.lock:
            old = self._jobs.get(job_id)
            if old is None:
                raise KeyError(f"job {job_id!r} is not live")
            subset = tuple(sorted(gpus))
            if len(subset) == 0:
                raise InvalidPlacementError("empty migration target")
            if len(set(subset)) != len(subset):
                raise InvalidPlacementError(
                    f"duplicate GPU ids in migration target: {gpus}"
                )
            for g in subset:
                if g < 0 or g >= self.cluster.n_gpus:
                    raise InvalidPlacementError(f"GPU id {g} outside cluster")
                owner = self._owner.get(g)
                if owner is not None and owner != job_id:
                    raise ValueError(
                        f"GPU {g} is busy (held by job {owner!r})"
                    )
                state = self._gpu_health.get(g)
                if state in _UNPLACEABLE:
                    raise ValueError(f"GPU {g} is {state} (unplaceable)")
            if self.journal is not None:
                self.last_journal_seq = self.journal.record(
                    "migrate", job_id=job_id, gpus=list(subset),
                    tenant=old.tenant,
                )
            journal, self.journal = self.journal, None
            try:  # inner ops validated above: cannot fail, never journaled
                self.release(job_id)
                return self.admit(job_id, subset, tenant=old.tenant)
            finally:
                self.journal = journal

    def clone(self) -> "JobLedger":
        """Snapshot copy for staged (optimistic) searches: same occupancy,
        same ``version`` value — "searched at version v" is meaningful
        against the parent — but a fresh ``uid`` (its own cache-key space)
        and no journal.  O(live jobs); never aliases parent state."""
        with self.lock:
            other = JobLedger(self.cluster)
            other._jobs = dict(self._jobs)
            other._owner = dict(self._owner)
            other._host_jobs = {
                hid: set(ids) for hid, ids in self._host_jobs.items()
            }
            other._version = self._version
            other._gpu_health = dict(self._gpu_health)
            other._host_degrade = dict(self._host_degrade)
            return other

    # -- health / faults -----------------------------------------------------

    @property
    def health_active(self) -> bool:
        """True iff any GPU or host is currently non-healthy.  Every
        consumer gates its health-conditioned path on this, so a ledger
        that has never seen a fault stays byte-identical to pre-fault
        behavior."""
        return bool(self._gpu_health) or bool(self._host_degrade)

    def gpu_health(self, gpu_id: int) -> str:
        """Health-lattice state of one GPU (absent from the sparse map ==
        ``healthy``)."""
        return self._gpu_health.get(gpu_id, "healthy")

    def host_degrade(self, host_id: int) -> float:
        """Multiplicative rail/NIC degrade factor on one host (1.0 ==
        healthy fabric)."""
        return self._host_degrade.get(host_id, 1.0)

    def placeable(self, gpu_id: int) -> bool:
        """False for quarantined/dead GPUs — the admission refusal
        predicate."""
        return self._gpu_health.get(gpu_id) not in _UNPLACEABLE

    def health_state(self) -> Tuple[Tuple[Tuple[int, str], ...],
                                    Tuple[Tuple[int, float], ...]]:
        """Canonical, comparable snapshot of the full health view —
        ``(sorted gpu states, sorted host degrade factors)``.  Two ledgers
        with equal ``health_state()`` + equal allocations + equal version
        are bit-identical for every consumer in the stack (the journal-
        replay acceptance check)."""
        return (
            tuple(sorted(self._gpu_health.items())),
            tuple(sorted(self._host_degrade.items())),
        )

    def _mark_degraded(self, host_id: int) -> None:
        for g in self.cluster.hosts[host_id].gpu_ids:
            if g not in self._gpu_health:  # only lift healthy -> degraded
                self._gpu_health[g] = "degraded"

    def apply_fault(
        self,
        kind: str,
        gpus: Sequence[int] = (),
        host_id: Optional[int] = None,
        factor: float = 1.0,
    ) -> None:
        """Apply one typed fault (see :mod:`repro.core.faults`): journaled
        write-ahead as a ``fault`` event, version bumped by 1 — caches,
        snapshots and in-flight CAS commits staged against the pre-fault
        version all go stale, exactly as an admission would make them."""
        with self.lock:
            if kind not in _LEDGER_FAULT_KINDS:
                raise ValueError(f"unknown fault kind {kind!r}")
            subset = tuple(sorted(int(g) for g in gpus))
            for g in subset:
                if g < 0 or g >= self.cluster.n_gpus:
                    raise InvalidPlacementError(f"GPU id {g} outside cluster")
            if kind in ("nic_flap", "link_degrade", "host_down") and (
                host_id is None
            ):
                raise ValueError(f"{kind} requires host_id")
            if self.journal is not None:
                self.last_journal_seq = self.journal.record(
                    "fault", job_id="", kind=kind,
                    gpus=list(subset) if subset else None,
                    host=host_id, factor=factor if factor != 1.0 else None,
                )
            if kind in ("gpu_down", "host_down"):
                targets = subset or (
                    tuple(self.cluster.hosts[host_id].gpu_ids)
                    if kind == "host_down" else ()
                )
                for g in targets:
                    self._gpu_health[g] = "dead"
            elif kind == "quarantine":
                for g in subset:
                    if self._gpu_health.get(g) != "dead":
                        self._gpu_health[g] = "quarantined"
            else:  # nic_flap / link_degrade
                self._host_degrade[host_id] = float(factor)
                self._mark_degraded(host_id)
            self._version += 1

    def apply_recover(
        self,
        kind: str,
        gpus: Sequence[int] = (),
        host_id: Optional[int] = None,
    ) -> None:
        """Undo one fault (journaled ``recover`` event, version +1).

        Recovery is state-popping, not state-restoring: a GPU whose host
        is still degraded comes back ``degraded``, not ``healthy``, and a
        host recovery leaves dead/quarantined GPUs alone.  Deterministic
        given the event order, which is all journal replay needs."""
        with self.lock:
            if kind not in _LEDGER_FAULT_KINDS:
                raise ValueError(f"unknown fault kind {kind!r}")
            subset = tuple(sorted(int(g) for g in gpus))
            if self.journal is not None:
                self.last_journal_seq = self.journal.record(
                    "recover", job_id="", kind=kind,
                    gpus=list(subset) if subset else None, host=host_id,
                )
            if kind in ("gpu_down", "host_down", "quarantine"):
                for g in subset:
                    self._gpu_health.pop(g, None)
                    hid = self.cluster.gpu_host[g]
                    if self._host_degrade.get(hid, 1.0) != 1.0:
                        self._gpu_health[g] = "degraded"
            else:  # nic_flap / link_degrade
                self._host_degrade.pop(host_id, None)
                for g in self.cluster.hosts[host_id].gpu_ids:
                    if self._gpu_health.get(g) == "degraded":
                        del self._gpu_health[g]
            self._version += 1

    # -- queries ------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._jobs)

    def __contains__(self, job_id: str) -> bool:
        return job_id in self._jobs

    def jobs(self) -> Iterator[Allocation]:
        return iter(self._jobs.values())

    def allocation(self, job_id: str) -> Allocation:
        return self._jobs[job_id]

    def get(self, job_id: str) -> Optional[Allocation]:
        """Atomic lookup: the job's allocation, or None if not live.  THE
        stale-report-safe entry point — one GIL-atomic read instead of the
        ``in`` + ``allocation()`` TOCTOU pair, which races with concurrent
        releases (the allocation can vanish between the two calls)."""
        return self._jobs.get(job_id)

    def busy(self) -> Set[int]:
        return set(self._owner)

    def available(self) -> List[int]:
        """Sorted global ids of all *placeable* GPUs not held by any live
        job.  Quarantined/dead GPUs are excluded — unplaceable by
        construction; the sparse-health fast path keeps the no-fault case
        byte-identical and allocation-free of extra checks."""
        if not self._gpu_health:
            return [
                g for g in range(self.cluster.n_gpus) if g not in self._owner
            ]
        return [
            g for g in range(self.cluster.n_gpus)
            if g not in self._owner
            and self._gpu_health.get(g) not in _UNPLACEABLE
        ]

    def n_free(self) -> int:
        """Number of free *placeable* GPUs — O(faulted GPUs), for scheduler
        capacity checks."""
        n = self.cluster.n_gpus - len(self._owner)
        for g, state in self._gpu_health.items():
            if state in _UNPLACEABLE and g not in self._owner:
                n -= 1
        return n

    def occupancy(self, host_id: int) -> int:
        """Number of busy GPUs on one host."""
        host = self.cluster.hosts[host_id]
        return sum(1 for g in host.gpu_ids if g in self._owner)

    def free_by_host(self) -> Dict[int, int]:
        """host id -> free GPU count, for every host (zeros included)."""
        return {
            h.host_id: h.n_gpus - self.occupancy(h.host_id)
            for h in self.cluster.hosts
        }

    def fragmentation(self):
        """Fragmentation state of the current occupancy — stranding score,
        clean-host count, largest placeable single-host block (a
        :class:`repro.core.defrag.FragmentationMetrics`)."""
        from repro.core.defrag import fragmentation_metrics

        return fragmentation_metrics(self.cluster, self)

    @staticmethod
    def contends(alloc: Allocation, against: Set[int]) -> bool:
        """THE rail-contention predicate (see module docstring): a live job
        contends with a candidate iff it is cross-host and GPU-disjoint from
        it.  Shared by the contended ground truth and the virtual-merge
        estimator so the two can never drift apart."""
        return alloc.cross_host and against.isdisjoint(alloc.gpus)

    def cross_host_jobs_on(
        self, host_id: int, against: Sequence[int] = ()
    ) -> List[Allocation]:
        """Live cross-host jobs with >=1 GPU on ``host_id``, excluding any
        job that shares a GPU with ``against`` (i.e. ``against`` itself)."""
        excluded = set(against)
        return [
            self._jobs[job_id]
            for job_id in sorted(self._host_jobs[host_id])
            if self.contends(self._jobs[job_id], excluded)
        ]

    def cross_jobs_by_host(self) -> Dict[int, List[Allocation]]:
        """Snapshot: host id -> live *cross-host* allocations touching it.

        The contention estimator consumes this once per predict batch; hosts
        with no cross-host tenants are omitted.
        """
        out: Dict[int, List[Allocation]] = {}
        for hid, job_ids in self._host_jobs.items():
            cross = [
                self._jobs[j] for j in sorted(job_ids)
                if self._jobs[j].cross_host
            ]
            if cross:
                out[hid] = cross
        return out

    def rail_contenders(self, host_id: int, against: Sequence[int] = ()) -> int:
        """Number of live collectives competing for ``host_id``'s NIC rails
        against a candidate subset (see module docstring for the predicate)."""
        return len(self.cross_host_jobs_on(host_id, against=against))

    def contender_demands(
        self, host_id: int, against: Sequence[int] = ()
    ) -> Tuple[int, ...]:
        """Per-contender GPU counts on ``host_id`` (one entry per contending
        cross-host job, same predicate as :meth:`rail_contenders`) — the rail
        demands the *saturating* contention model weighs shares by."""
        return tuple(
            sum(1 for g in a.gpus if self.cluster.gpu_host[g] == host_id)
            for a in self.cross_host_jobs_on(host_id, against=against)
        )

    def snapshot(self) -> ContentionSnapshot:
        """Pre-resolved contender counts/demands for candidates drawn from
        ``available()`` (always GPU-disjoint from live jobs)."""
        cross = self.cross_jobs_by_host()
        return ContentionSnapshot(
            {hid: len(jobs) for hid, jobs in cross.items()},
            {
                hid: tuple(
                    sum(1 for g in a.gpus if self.cluster.gpu_host[g] == hid)
                    for a in jobs
                )
                for hid, jobs in cross.items()
            },
            frag=self.fragmentation(),
            degrade=dict(self._host_degrade),
        )

    def describe(self) -> str:
        live = ", ".join(
            f"{a.job_id}:k={a.k}@{list(a.host_ids)}" for a in self.jobs()
        )
        return (
            f"ledger[{self.cluster.name}]: {len(self)} live jobs, "
            f"{len(self._owner)}/{self.cluster.n_gpus} GPUs busy"
            + (f" ({live})" if live else "")
        )
