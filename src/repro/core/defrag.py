"""Defragmentation: fragmentation metrics, consolidation planner, triggers.

Long Poisson traces leave the cluster *fragmented*: many half-busy hosts,
no host with a large clean block.  The ledger knows this
(:meth:`~repro.core.tenancy.JobLedger.occupancy`) but, before this module,
nothing acted on it — a large arrival was forced into a cross-host,
rail-contended placement even when a cheap consolidation of small
co-tenants could have freed a clean host.  That is exactly the regime
BandPilot's contention model exists to avoid.  Three layers close the gap:

1. **Metrics** — :func:`fragmentation_metrics` condenses a ledger into a
   :class:`FragmentationMetrics`: total free GPUs, clean-host count,
   the largest placeable k that does not cross hosts, and the *stranding
   score* (fraction of free GPUs stuck on partially-busy hosts).  Exposed
   on :meth:`JobLedger.fragmentation`, carried by
   :class:`~repro.core.tenancy.ContentionSnapshot`, and reported per
   admission by ``summarize_trace``.

2. **Planner** — :func:`plan_defrag` builds a greedy multi-move
   consolidation plan against a *scratch copy* of the ledger: candidate
   moves re-place small (single- or partial-host) jobs into best-fit
   slots (:func:`consolidation_proposer` — tightest fit first, premium
   hosts last, the ordinary hybrid search as fallback), each move must
   *consolidate* (:func:`is_consolidating`) and is scored by the change
   in a cluster potential

       ``sum over live tenants of contended bw
         + clean_host_bonus * clean hosts
         + premium_reserve * free switch-fabric GPUs
         [+ make_room_bonus * min(largest quality block, target k)]
         - migration cost``

   and committed only under a **no-harm-per-tenant** guarantee (no live
   job's contended bandwidth may drop).  Charging every move against the
   shared migration cost and requiring a strict potential increase bounds
   the plan and rules out oscillation.

3. **Triggers** — the admission scheduler (``SchedulerConfig(defrag=
   True)``) runs a *background pass* at release time (rate-limited by
   ``DefragConfig.interval``) plus an on-demand **make-room pass** when an
   arrival would otherwise be forced into a cross-host rail-contended
   placement (:func:`forced_rail_contended`) that consolidation could
   avoid.  Fragmentation-awareness also enters placement itself:
   :func:`make_frag_penalty` is the configurable tie-break
   (``frag_weight``) threaded through ``search.hybrid_search`` /
   ``joint_hybrid_search`` that steers otherwise-equal candidates away
   from breaking up clean hosts.

This module is also the shared home of the migration economics used by
the scheduler's release-time re-dispatch, the fault-tolerance rebalance
(:mod:`repro.ft.elastic`), and the planner itself: :func:`migration_cost`,
:func:`net_migration_gain`, and :func:`evaluate_move` (the trial
relocation with the no-harm check, restoring the ledger exactly).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import search
from repro.core.cluster import Cluster
from repro.core.tenancy import Allocation, JobLedger

Subset = List[int]

# propose(ledger, avail, k) -> subset: how a trial relocation picks the new
# placement (the ledger is the scratch state with the moving job released).
Proposer = Callable[[JobLedger, Sequence[int], int], Subset]
# proposals(ledger, avail, k) -> ranked candidate subsets for one mover
# (the planner evaluates them in order and keeps the first that qualifies).
ProposalFan = Callable[[JobLedger, Sequence[int], int], List[Subset]]

_EPS = 1e-9


# ---------------------------------------------------------------------------
# Layer 1: fragmentation metrics
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FragmentationMetrics:
    """How chopped-up a ledger's free capacity is.

    ``largest_free_block`` is the largest k placeable without crossing
    hosts; ``stranding`` is the fraction of free GPUs sitting on
    partially-busy hosts (0.0 on an empty *or* perfectly-packed cluster —
    it measures *mixing*, not load).
    """

    total_free: int
    clean_hosts: int         # hosts with zero busy GPUs
    fragmented_hosts: int    # hosts that are partially busy
    largest_free_block: int  # largest single-host free capacity
    largest_quality_block: int  # ... restricted to switch-fabric hosts
    premium_free: int        # total free GPUs on switch-fabric hosts
    stranding: float         # stranded free GPUs / total free GPUs

    def describe(self) -> str:
        return (
            f"free={self.total_free} clean_hosts={self.clean_hosts} "
            f"largest_block={self.largest_free_block} "
            f"stranding={self.stranding:.2f}"
        )

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def fragmentation_metrics(
    cluster: Cluster, ledger: JobLedger
) -> FragmentationMetrics:
    """Condense per-host occupancy into a :class:`FragmentationMetrics`.

    ``largest_quality_block`` counts only switch-fabric (NVSwitch / ICI)
    hosts: a large free block on a point-to-point host is usually *not*
    room worth making — its full-host ring bottleneck tends to be weaker
    than even a contended cross-host placement, so funnelling a big
    arrival into it would hurt.  On all-switch clusters the two block
    metrics coincide.
    """
    free = ledger.free_by_host()
    clean = fragmented = largest = largest_q = premium = stranded = total = 0
    for host in cluster.hosts:
        f = free[host.host_id]
        total += f
        largest = max(largest, f)
        if host.host_type.nvswitch:
            largest_q = max(largest_q, f)
            premium += f
        if f == host.n_gpus:
            clean += 1
        elif f > 0:  # partially busy; fully-busy hosts are neither
            fragmented += 1
            stranded += f
    return FragmentationMetrics(
        total, clean, fragmented, largest, largest_q, premium,
        stranded / total if total else 0.0,
    )


def room_makeable(cluster: Cluster, k: int, quality_only: bool = True) -> bool:
    """Could any (switch-fabric, when ``quality_only``) host ever offer a
    clean k-block?  Gates the make-room trigger so clusters without a
    suitable host never burn planner passes on an unreachable target."""
    return any(
        h.n_gpus >= k
        for h in cluster.hosts
        if h.host_type.nvswitch or not quality_only
    )


def forced_rail_contended(
    cluster: Cluster, ledger: JobLedger, k: int, quality_only: bool = False
) -> bool:
    """True iff a k-GPU arrival *must* cross hosts (no single-host block
    fits it, though one host is large enough in principle) AND at least one
    host offering free GPUs already carries live cross-host rail traffic —
    i.e. the admission would land rail-contended, and consolidation could
    in principle avoid it.  The make-room trigger predicate.

    With ``quality_only`` (the scheduler passes ``make_room_quality``) only
    a switch-fabric block counts as "already fits" — the same block metric
    the make-room pass targets, so trigger and target never disagree: a
    big free block on a weak point-to-point host does not suppress the
    pass that would open a usable one.
    """
    if k > ledger.n_free():
        return False  # cannot admit at all; queueing, not fragmentation
    if not room_makeable(cluster, k, quality_only=quality_only):
        return False  # cross-host is inherent to the request, not forced
    frag = fragmentation_metrics(cluster, ledger)
    block = frag.largest_quality_block if quality_only \
        else frag.largest_free_block
    if block >= k:
        return False  # a clean block already fits it
    cross = ledger.cross_jobs_by_host()
    return any(
        free > 0 and hid in cross
        for hid, free in ledger.free_by_host().items()
    )


# ---------------------------------------------------------------------------
# Shared migration economics (re-exported by repro.core.scheduler)
# ---------------------------------------------------------------------------

def migration_cost(
    old_gpus: Sequence[int], new_gpus: Sequence[int], cost_per_gpu: float
) -> float:
    """Bandwidth-equivalent charge for moving a live job.

    Each GPU the job vacates means checkpoint/restore traffic and a stall
    for the whole collective, so the charge is proportional to how much of
    the placement actually moves: ``cost_per_gpu * |old \\ new|``.  A
    re-placement equal to the current one is free (and a no-op).
    """
    return cost_per_gpu * len(set(old_gpus) - set(new_gpus))


def net_migration_gain(
    old_gpus: Sequence[int],
    new_gpus: Sequence[int],
    old_bw: float,
    new_bw: float,
    cost_per_gpu: float,
) -> float:
    """THE migrate-or-stay gain rule, shared by the scheduler's release-time
    re-dispatch, ``repro.ft.elastic``'s voluntary rebalance, and the defrag
    planner: the bandwidth delta net of the migration-cost charge."""
    return new_bw - old_bw - migration_cost(old_gpus, new_gpus, cost_per_gpu)


@dataclasses.dataclass(frozen=True)
class MoveEval:
    """One fully-evaluated candidate relocation of a live job.

    ``self_gain`` is the moved job's own contended-bandwidth delta net of
    cost (the release-time re-dispatch objective); ``total_gain`` sums the
    delta across *all* live tenants net of cost (the defrag planner
    objective — moving one job can decongest a neighbour's rails).
    """

    job_id: str
    old_gpus: Tuple[int, ...]
    new_gpus: Tuple[int, ...]
    old_bw: float           # moved job's contended bw before the move
    new_bw: float           # ... after the move
    cost: float             # migration_cost charged against the gain
    self_gain: float        # new_bw - old_bw - cost
    total_gain: float       # sum-over-tenants contended-bw delta - cost
    frag_before: FragmentationMetrics
    frag_after: FragmentationMetrics

    @property
    def clean_hosts_delta(self) -> int:
        return self.frag_after.clean_hosts - self.frag_before.clean_hosts

    @property
    def largest_block_delta(self) -> int:
        return (self.frag_after.largest_free_block
                - self.frag_before.largest_free_block)


def is_consolidating(cluster: Cluster, ev: MoveEval) -> bool:
    """THE defrag-move gate: a planner move must free a clean host, grow
    the largest placeable block, or shrink the mover's own host span (fewer
    spanned hosts = one less rail demand on every host it vacates).

    Without this gate the no-harm/gain framework happily accepts pure
    bandwidth-chasing relocations — e.g. parking a small job on a premium
    host the moment space opens, stranding the cluster's best block.  Those
    moves are the *release-time re-dispatch* hook's job (where the moved
    job's own gain is the objective); defragmentation only makes moves that
    measurably un-fragment the cluster.  Growing the largest
    *switch-fabric* block also qualifies (that is the block make-room
    builds), even when a point-to-point host's larger-but-weak block
    shrinks to pay for it.
    """
    span = (
        len(cluster.partition_by_host(ev.new_gpus))
        - len(cluster.partition_by_host(ev.old_gpus))
    )
    dq = (ev.frag_after.largest_quality_block
          - ev.frag_before.largest_quality_block)
    return (ev.clean_hosts_delta > 0 or ev.largest_block_delta > 0
            or dq > 0 or span < 0)


def evaluate_placement(
    sim,
    ledger: JobLedger,
    alloc: Allocation,
    new_subset: Sequence[int],
    cost_per_gpu: float,
    require_no_harm: bool = True,
    min_self_gain: Optional[float] = None,
    before: Optional[dict] = None,
    frag_before: Optional[FragmentationMetrics] = None,
) -> Optional[MoveEval]:
    """Trial-apply moving ``alloc`` to a *fixed* ``new_subset``; restores
    ``ledger`` exactly on every path.

    Measures every live tenant's contended bandwidth before/after
    (``sim.true_bandwidth(S, ledger=...)`` — the scheduler's grading
    apparatus).  Returns ``None`` when the subset is the current placement,
    or (with ``require_no_harm``) when *any* tenant's contended bandwidth
    would drop — including the moved job itself.  Thresholding the gains is
    otherwise the caller's job: the re-dispatch hook passes
    ``min_self_gain`` so a trial whose mover does not pay for itself is
    rejected cheaply, *before* the per-co-tenant grading (its common case);
    the planner omits it (it scores ``total_gain`` plus fragmentation
    credits and needs the full evaluation anyway).

    ``before``/``frag_before`` let a caller evaluating many candidates
    against the same ledger state (the planner's round loop) grade the
    pre-move state once instead of per candidate; the caller guarantees the
    ledger is unchanged since they were computed — evaluate_placement's own
    exact restore preserves that across successive trials.
    """
    cluster = ledger.cluster
    new_gpus = tuple(sorted(new_subset))
    if new_gpus == alloc.gpus:
        return None
    if before is None:
        before = {
            a.job_id: sim.true_bandwidth(a.gpus, ledger=ledger)
            for a in ledger.jobs()
        }
    if frag_before is None:
        frag_before = fragmentation_metrics(cluster, ledger)
    cost = migration_cost(alloc.gpus, new_gpus, cost_per_gpu)
    ledger.release(alloc.job_id)
    try:
        ledger.admit(alloc.job_id, new_gpus)
        try:
            # post-admit grading sees the right contention: contends()
            # self-excludes each job's own GPU-overlapping ledger entry
            new_bw = sim.true_bandwidth(new_gpus, ledger=ledger)
            self_gain = new_bw - before[alloc.job_id] - cost
            if min_self_gain is not None and self_gain <= min_self_gain:
                return None  # mover does not pay: skip co-tenant grading
            after = {
                a.job_id: (
                    new_bw if a.job_id == alloc.job_id
                    else sim.true_bandwidth(a.gpus, ledger=ledger)
                )
                for a in ledger.jobs()
            }
            if require_no_harm and any(
                after[jid] < before[jid] - _EPS for jid in before
            ):
                return None
            frag_after = fragmentation_metrics(cluster, ledger)
            return MoveEval(
                alloc.job_id, alloc.gpus, new_gpus,
                before[alloc.job_id], new_bw, cost,
                self_gain=self_gain,
                total_gain=sum(after.values()) - sum(before.values()) - cost,
                frag_before=frag_before, frag_after=frag_after,
            )
        finally:
            ledger.release(alloc.job_id)
    finally:
        if alloc.job_id not in ledger:
            ledger.admit(alloc.job_id, alloc.gpus)


def evaluate_move(
    sim,
    ledger: JobLedger,
    alloc: Allocation,
    propose: Proposer,
    cost_per_gpu: float,
    require_no_harm: bool = True,
    min_self_gain: Optional[float] = None,
    before: Optional[dict] = None,
    frag_before: Optional[FragmentationMetrics] = None,
) -> Optional[MoveEval]:
    """Trial-relocate one live job: release it, ask ``propose`` for a new
    subset over the freed availability, then grade the move with
    :func:`evaluate_placement`.  The ledger is restored exactly on every
    path.  This is the shared trial the scheduler's release-time re-dispatch
    runs (``propose`` = the dispatcher's own ``dispatch``).

    ``sim`` may be any object exposing ``true_bandwidth(S, ledger=...)`` —
    the simulator itself or the fast path's
    :class:`~repro.core.predict_cache.GradingCache` memo over it.
    ``before``/``frag_before`` forward to :func:`evaluate_placement`: a
    caller trialling many movers against one unchanged ledger state (the
    re-dispatch hook's candidate loop) grades the pre-move state once."""
    ledger.release(alloc.job_id)
    try:
        subset = propose(ledger, ledger.available(), alloc.k)
    finally:
        ledger.admit(alloc.job_id, alloc.gpus)
    return evaluate_placement(
        sim, ledger, alloc, subset, cost_per_gpu,
        require_no_harm=require_no_harm, min_self_gain=min_self_gain,
        before=before, frag_before=frag_before,
    )


# ---------------------------------------------------------------------------
# Fragmentation-aware placement tie-break
# ---------------------------------------------------------------------------

def make_frag_penalty(
    cluster: Cluster, ledger: JobLedger, weight: float
) -> Callable[[Sequence[int]], float]:
    """Build the placement tie-break term for ``search.hybrid_search``.

    The returned ``penalty(subset)`` is a *relative discount*: ``weight``
    (a fraction, e.g. 0.02) per clean host the subset would leave partially
    occupied — dirtying a fully-free host strands its remaining GPUs, while
    topping up an already-busy host is consolidation and costs nothing.
    Candidate selection maximizes ``predicted_bw * (1 - penalty(S))``, so
    the same weight is a tie-break on a 500 GB/s H100 fabric and a 20 GB/s
    legacy one.  The ledger is read live, so one penalty stays correct as a
    scratch ledger admits batch-mates; the reported predicted bandwidth
    stays undiscounted.
    """
    def penalty(subset: Sequence[int]) -> float:
        p = 0.0
        for hid, gpus in cluster.partition_by_host(subset).items():
            host_n = cluster.hosts[hid].n_gpus
            if ledger.occupancy(hid) == 0 and len(gpus) < host_n:
                p += weight
        return min(p, 1.0)

    return penalty


def hybrid_proposer(
    cluster: Cluster,
    tables,
    base_predictor,
    contention_aware: bool = True,
    contention_mode: str = "analytic",
    contended=None,
    frag_weight: float = 0.0,
    use_cache: bool = True,
    vectorized: bool = True,
    stats_sink=None,
    batcher=None,
) -> Proposer:
    """A :data:`Proposer` that re-places jobs exactly the way BandPilot
    admits them: hybrid search under the contention-aware predictor bound
    to the (scratch) ledger, with the fragmentation tie-break applied.
    The per-proposal predictor is wrapped in a ledger-versioned prediction
    cache (pass the dispatcher's cached ``base_predictor`` to also share
    the isolated memo across trials).  ``batcher`` (an
    :class:`~repro.core.predict_cache.InferenceBatcher`) registers the
    proposal search as a batch worker so its surrogate applies can fuse
    with concurrent searches; value-neutral — single-worker batches pass
    straight through."""
    from repro.core.predict_cache import cached_contention_predictor

    def propose(ledger: JobLedger, avail: Sequence[int], k: int) -> Subset:
        pred = (
            cached_contention_predictor(
                cluster, base_predictor, ledger,
                mode=contention_mode, contended=contended,
                use_cache=use_cache, vectorized=vectorized,
                stats_sink=stats_sink,
            )
            if contention_aware else base_predictor
        )
        penalty = (
            make_frag_penalty(cluster, ledger, frag_weight)
            if frag_weight > 0 else None
        )
        ctx = batcher.worker() if batcher is not None else contextlib.nullcontext()
        with ctx:
            return search.hybrid_search(
                cluster, tables, pred, avail, k, frag_penalty=penalty
            ).subset

    return propose


def consolidation_proposer(
    cluster: Cluster,
    tables,
    base_predictor=None,
    contention_aware: bool = True,
    contention_mode: str = "analytic",
    contended=None,
    frag_weight: float = 0.02,
    use_cache: bool = True,
    vectorized: bool = True,
    stats_sink=None,
    batcher=None,
) -> ProposalFan:
    """Best-fit candidate slots for a defrag mover, cheapest real estate
    first.

    For placement, bandwidth is the objective; for a *defrag move* it is
    only a constraint (no-harm) — the objective is un-fragmenting the
    cluster without consuming capacity future arrivals will want.  So the
    fan ranks every single-host slot that fits the mover by (fewest free
    GPUs first — tightest fit preserves big blocks; slowest host first —
    premium hosts are kept for jobs that need them), with the bw-greedy
    :func:`hybrid_proposer` placement appended last as the
    nothing-else-fits fallback (it is also the only cross-host candidate,
    covering span-reduction moves).  The no-harm check downstream rejects
    any slot actually too slow for the mover.
    """
    hybrid = (
        hybrid_proposer(
            cluster, tables, base_predictor,
            contention_aware=contention_aware,
            contention_mode=contention_mode, contended=contended,
            frag_weight=frag_weight, use_cache=use_cache,
            vectorized=vectorized, stats_sink=stats_sink,
            batcher=batcher,
        )
        if base_predictor is not None else None
    )

    def proposals(ledger: JobLedger, avail: Sequence[int], k: int) -> List[Subset]:
        fits = []
        for hid, gpus in cluster.partition_by_host(avail).items():
            if len(gpus) < k:
                continue
            locals_ = [cluster.gpu_local[g] for g in gpus]
            bw, sub = tables.best_subset(hid, k, locals_)
            fits.append((len(gpus), bw, hid, tables.to_globals(hid, sub)))
        fits.sort(key=lambda f: (f[0], f[1], f[2]))
        out = [f[3] for f in fits]
        if hybrid is not None:
            out.append(hybrid(ledger, avail, k))
        return out

    return proposals


# ---------------------------------------------------------------------------
# Layer 2: the consolidation planner
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DefragConfig:
    """Knobs for the planner and its scheduler triggers."""

    max_moves_per_pass: int = 2      # moves one planner invocation may emit
    max_total_moves: int = 8         # per-trace migration budget (triggers)
    migration_cost_per_gpu: float = 2.0  # shared with SchedulerConfig
    min_gain: float = 1e-6           # strict potential increase per move
    clean_host_bonus: float = 4.0    # GB/s-equiv credit per clean host freed
    make_room_bonus: float = 8.0     # GB/s-equiv per GPU of block progress
    premium_reserve: float = 25.0    # GB/s-equiv per switch-fabric GPU kept
    #   free: the opportunity value of premium-fabric capacity.  A mover
    #   consuming A800/H100 space pays this per GPU, one vacating it earns
    #   it — so consolidation never squats on the hosts large arrivals
    #   need.  Exactly zero on homogeneous clusters (moves conserve it).
    small_job_max_k: Optional[int] = None  # candidate cap; None = host size
    interval: float = 5.0            # min sim-time between background passes
    make_room: bool = True           # on-demand pass before forced admits
    make_room_quality: bool = True   # only switch-fabric blocks count as room
    frag_weight: float = 0.02        # relative tie-break for planner proposals

    def __post_init__(self):
        if self.max_moves_per_pass < 1:
            raise ValueError("max_moves_per_pass must be >= 1")
        if self.max_total_moves < 0:
            raise ValueError("max_total_moves must be >= 0")
        if self.interval < 0:
            raise ValueError("interval must be >= 0")


@dataclasses.dataclass
class DefragPlan:
    """A committed-order list of consolidation moves plus its metric delta.

    ``moves`` apply sequentially (each was evaluated against the scratch
    state left by its predecessors); :func:`apply_plan` replays them onto
    the real ledger.
    """

    moves: List[MoveEval]
    before: FragmentationMetrics
    after: FragmentationMetrics
    target_k: Optional[int] = None

    @property
    def n_moves(self) -> int:
        return len(self.moves)

    @property
    def total_gain(self) -> float:
        return sum(m.total_gain for m in self.moves)


def _target_block(frag: FragmentationMetrics, config: DefragConfig) -> int:
    return (
        frag.largest_quality_block if config.make_room_quality
        else frag.largest_free_block
    )


def _move_score(
    ev: MoveEval, config: DefragConfig, target_k: Optional[int]
) -> float:
    """Potential delta of one move: tenant bandwidth + fragmentation credits,
    net of migration cost.  Every accepted move strictly increases a bounded
    potential, so greedy planning terminates and cannot oscillate."""
    score = ev.total_gain + config.clean_host_bonus * ev.clean_hosts_delta
    score += config.premium_reserve * (
        ev.frag_after.premium_free - ev.frag_before.premium_free
    )
    if target_k is not None:
        score += config.make_room_bonus * (
            min(_target_block(ev.frag_after, config), target_k)
            - min(_target_block(ev.frag_before, config), target_k)
        )
    return score


def plan_defrag(
    cluster: Cluster,
    sim,
    ledger: JobLedger,
    config: DefragConfig,
    proposals: ProposalFan,
    target_k: Optional[int] = None,
    budget: Optional[int] = None,
) -> DefragPlan:
    """Greedily build a consolidation plan against a scratch copy of
    ``ledger`` (the live ledger is never touched).

    Each round considers every candidate mover (live jobs no larger than
    ``small_job_max_k`` — by default one host; bigger jobs are what defrag
    makes room *for*, not what it moves).  Per mover, the ``proposals`` fan
    (usually :func:`consolidation_proposer`) is evaluated best-fit-first
    and the FIRST slot that survives the no-harm check, qualifies as
    *consolidating* (:func:`is_consolidating` — bandwidth-chasing
    relocations belong to the re-dispatch hook) and clears ``min_gain`` is
    that mover's move; the best-scoring mover's move commits to the
    scratch.  With ``target_k`` (the make-room pass) planning additionally
    credits progress toward a ``target_k``-sized block (on switch-fabric
    hosts when ``make_room_quality``) and stops as soon as one exists.
    """
    scratch = JobLedger(cluster)
    for a in ledger.jobs():
        scratch.admit(a.job_id, a.gpus)
    before = fragmentation_metrics(cluster, scratch)
    max_k = config.small_job_max_k
    if max_k is None:
        max_k = max(h.n_gpus for h in cluster.hosts)
    n_moves = config.max_moves_per_pass if budget is None else budget
    moves: List[MoveEval] = []
    while len(moves) < n_moves:
        frag = fragmentation_metrics(cluster, scratch)
        if target_k is not None and _target_block(frag, config) >= target_k:
            break  # room made: the arrival now fits a clean block
        # the pre-move state is identical for every candidate this round
        # (evaluate_placement restores the scratch exactly): grade it once
        round_before = {
            a.job_id: sim.true_bandwidth(a.gpus, ledger=scratch)
            for a in scratch.jobs()
        }
        best: Optional[Tuple[float, MoveEval]] = None
        for alloc in sorted(scratch.jobs(), key=lambda a: a.job_id):
            if alloc.k > max_k:
                continue
            scratch.release(alloc.job_id)
            try:
                cands = proposals(scratch, scratch.available(), alloc.k)
            finally:
                scratch.admit(alloc.job_id, alloc.gpus)
            for subset in cands:
                ev = evaluate_placement(
                    sim, scratch, alloc, subset,
                    config.migration_cost_per_gpu,
                    before=round_before, frag_before=frag,
                )
                if ev is None or not is_consolidating(cluster, ev):
                    continue
                score = _move_score(ev, config, target_k)
                if score > config.min_gain:
                    # best-fit discipline: the first qualifying slot is
                    # this mover's move; cheaper slots never lose to a
                    # higher-bandwidth one
                    if best is None or score > best[0]:
                        best = (score, ev)
                    break
        if best is None:
            break  # no move clears the bar: the ledger is defragmented
        mv = best[1]
        scratch.release(mv.job_id)
        scratch.admit(mv.job_id, mv.new_gpus)
        moves.append(mv)
    return DefragPlan(
        moves, before, fragmentation_metrics(cluster, scratch), target_k
    )


def apply_plan(ledger: JobLedger, plan: DefragPlan) -> None:
    """Replay a plan's moves onto the live ledger, in plan order.

    The ledger must be in the state the plan was built from (the scheduler
    plans and applies atomically); each move's re-admit validates
    disjointness, so a stale plan raises rather than corrupts.
    """
    for mv in plan.moves:
        # one atomic journal event per move (version bumps by 2, identical
        # to the release+admit pair this replaces)
        ledger.migrate(mv.job_id, mv.new_gpus)
