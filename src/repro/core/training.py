"""Surrogate training: offline initialization + online adaptation (Sec. 4.1.2).

The paper trains the hierarchical Transformer on a deliberately sparse set of
inter-host measurements (250 samples in the headline results) and keeps it
fresh by fine-tuning on bandwidths observed from live jobs.  Both paths share
one jitted AdamW step.

The learned-contention subsystem adds a third trainee: the
**ContendedSurrogate** (`train_contended_surrogate`), fitted on (subset,
ledger, contended-bandwidth) triples — synthetic ones from
:mod:`repro.core.contended_dataset` or live ones from its telemetry
harvester (`online_finetune_contended`, the Sec. 4.1.2 adaptation loop under
tenancy).  The curriculum deliberately mixes isolated (empty-ledger) and
contended samples so the model keeps its isolated accuracy while absorbing
the rail split.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features as feat_lib
from repro.core import surrogate as surr
from repro.core.bandwidth_sim import BW_SCALE, BandwidthSimulator
from repro.core.cluster import Cluster
from repro.core.intra_host import IntraHostTables
from repro.train.optimizer import AdamWConfig, adamw, cosine_schedule

PyTree = Any

# One contended-training sample: (subset, ledger-or-None, bandwidth GB/s).
# ``ledger`` duck-types JobLedger (the featurizer reads contender_demands /
# cross_host_jobs_on / busy); None means isolated.
ContendedTriple = Tuple[Sequence[int], Any, float]


@dataclasses.dataclass
class TrainConfig:
    steps: int = 3000
    batch_size: int = 64
    lr: float = 3e-3
    weight_decay: float = 1e-4
    warmup_steps: int = 100
    seed: int = 0
    log_every: int = 0  # 0 = silent


def _mse_loss(apply_fn, params, x, mask, y):
    pred = apply_fn(params, x, mask)
    return jnp.mean(jnp.square(pred - y))


def _fit(
    apply_fn,
    params: PyTree,
    x: jnp.ndarray,
    mask: jnp.ndarray,
    targets: jnp.ndarray,
    config: TrainConfig,
) -> Tuple[PyTree, Dict[str, float]]:
    """The shared AdamW loop: minibatch MSE on normalized log-bandwidth."""
    n = int(x.shape[0])
    opt_cfg = AdamWConfig(
        lr=config.lr, weight_decay=config.weight_decay, grad_clip_norm=1.0
    )
    opt_init, opt_update = adamw(
        opt_cfg, cosine_schedule(config.steps, config.warmup_steps)
    )
    opt_state = opt_init(params)

    @jax.jit
    def step(params, opt_state, idx):
        xb, mb, yb = x[idx], mask[idx], targets[idx]
        loss, grads = jax.value_and_grad(
            lambda p: _mse_loss(apply_fn, p, xb, mb, yb)
        )(params)
        params, opt_state, metrics = opt_update(grads, opt_state, params)
        return params, opt_state, loss

    rng = np.random.default_rng(config.seed)
    t0 = time.time()
    loss = np.inf
    for i in range(config.steps):
        idx = jnp.asarray(rng.integers(0, n, size=min(config.batch_size, n)))
        params, opt_state, loss = step(params, opt_state, idx)
        if config.log_every and (i + 1) % config.log_every == 0:
            print(f"  surrogate step {i + 1}/{config.steps} loss={float(loss):.5f}")
    info = {
        "train_seconds": time.time() - t0,
        "final_loss": float(loss),
        "n_samples": n,
        "param_bytes": surr.param_bytes(params),
    }
    return params, info


def train_surrogate(
    cluster: Cluster,
    tables: IntraHostTables,
    dataset: Sequence[Tuple[Sequence[int], float]],
    config: TrainConfig = TrainConfig(),
    naive: bool = False,
    init_params: Optional[PyTree] = None,
    host_norm: bool = True,
) -> Tuple[PyTree, Dict[str, float]]:
    """Train hierarchical (or naive) surrogate on (allocation, bandwidth) pairs.

    Returns (params, info) where info records wall time and final loss.
    """
    key = jax.random.PRNGKey(config.seed)
    subsets = [list(s) for s, _ in dataset]
    targets = np.asarray(
        surr.encode_bw(np.asarray([bw for _, bw in dataset], np.float32))
    )

    if naive:
        x, mask = feat_lib.featurize_gpu_ids(cluster, subsets, cluster.n_gpus)
        apply_fn = surr.apply_naive
        params = init_params or surr.init_naive_params(key, cluster.n_gpus)
    else:
        x, mask = feat_lib.featurize_batch(
            cluster, tables, subsets, host_norm=host_norm
        )
        apply_fn = surr.apply_hierarchical
        params = init_params or surr.init_hierarchical_params(key)

    return _fit(
        apply_fn, params, jnp.asarray(x), jnp.asarray(mask),
        jnp.asarray(targets), config,
    )


def online_finetune(
    cluster: Cluster,
    tables: IntraHostTables,
    params: PyTree,
    new_measurements: Sequence[Tuple[Sequence[int], float]],
    steps: int = 200,
    lr: float = 5e-4,
    seed: int = 1,
) -> PyTree:
    """Online adaptation: a few low-LR steps on freshly observed bandwidths
    (Sec. 4.2.2).  No architecture change, no full retraining."""
    cfg = TrainConfig(steps=steps, lr=lr, warmup_steps=0, seed=seed)
    params, _ = train_surrogate(
        cluster, tables, new_measurements, cfg, init_params=params
    )
    return params


# ---------------------------------------------------------------------------
# ContendedSurrogate training (the learned-contention subsystem)
# ---------------------------------------------------------------------------

def train_contended_surrogate(
    cluster: Cluster,
    tables: IntraHostTables,
    dataset: Sequence[ContendedTriple],
    config: TrainConfig = TrainConfig(),
    base_params: Optional[PyTree] = None,
    init_params: Optional[PyTree] = None,
    include_contenders: bool = True,
    max_tokens: Optional[int] = None,
    host_norm: bool = True,
) -> Tuple[PyTree, Dict[str, float]]:
    """Fit the ContendedSurrogate on (subset, ledger, bandwidth) triples.

    ``base_params`` (the trained isolated surrogate) warm-starts the trunk;
    without it a fresh isolated init is used.  ``init_params`` resumes an
    existing contended model (the online fine-tune path).  The dataset is
    the curriculum: :func:`repro.core.contended_dataset.build_contended_dataset`
    mixes isolated (empty-ledger) and contended samples so the model's
    zero-context behaviour stays anchored to the isolated one.
    """
    key = jax.random.PRNGKey(config.seed)
    pairs = [(list(s), led) for s, led, _ in dataset]
    targets = np.asarray(
        surr.encode_bw(np.asarray([bw for _, _, bw in dataset], np.float32))
    )
    x, mask = feat_lib.featurize_contended_batch(
        cluster, tables, pairs, max_tokens=max_tokens,
        include_contenders=include_contenders, host_norm=host_norm,
    )
    if init_params is None:
        init_params = surr.init_contended_params(
            base_params
            if base_params is not None
            else surr.init_hierarchical_params(key)
        )
    return _fit(
        surr.apply_contended, init_params, jnp.asarray(x), jnp.asarray(mask),
        jnp.asarray(targets), config,
    )


def online_finetune_contended(
    cluster: Cluster,
    tables: IntraHostTables,
    params: PyTree,
    new_samples: Sequence[ContendedTriple],
    steps: int = 200,
    lr: float = 5e-4,
    seed: int = 1,
    **featurize_kwargs,
) -> PyTree:
    """Online adaptation under tenancy: a few low-LR steps on contended
    bandwidths harvested from live admissions (telemetry harvester)."""
    cfg = TrainConfig(steps=steps, lr=lr, warmup_steps=0, seed=seed)
    params, _ = train_contended_surrogate(
        cluster, tables, new_samples, cfg, init_params=params,
        **featurize_kwargs,
    )
    return params


# ---------------------------------------------------------------------------
# Accuracy metrics (Sec. 5.2): R^2 and MAPE
# ---------------------------------------------------------------------------

def _accuracy(y: np.ndarray, pred: np.ndarray) -> Dict[str, float]:
    resid = y - pred
    ss_res = float(np.sum(resid**2))
    ss_tot = float(np.sum((y - y.mean()) ** 2))
    r2 = 1.0 - ss_res / max(ss_tot, 1e-12)
    mape = float(np.mean(np.abs(resid) / np.maximum(np.abs(y), 1e-9))) * 100.0
    return {"r2": r2, "mape": mape, "n": len(y)}


def evaluate_surrogate(
    predictor: "surr.SurrogatePredictor",
    dataset: Sequence[Tuple[Sequence[int], float]],
) -> Dict[str, float]:
    subsets = [list(s) for s, _ in dataset]
    y = np.asarray([bw for _, bw in dataset], np.float64)
    return _accuracy(y, predictor.predict(subsets))


def evaluate_contended_predictor(
    predictor,
    dataset: Sequence[ContendedTriple],
) -> Dict[str, float]:
    """R^2 / MAPE of a contended predictor over (subset, ledger, bw)
    triples.  ``predictor`` must expose ``predict_pairs`` (the
    ContendedSurrogate): each sample is scored against its *own* ledger.
    For the analytic even-split baseline use :func:`evaluate_analytic_cap`
    — a plain ``predict(subsets)`` wrapper reads only the single ledger it
    wraps and would silently mis-score a per-sample-ledger dataset."""
    if not hasattr(predictor, "predict_pairs"):
        raise TypeError(
            "evaluate_contended_predictor needs a predict_pairs predictor; "
            "for the analytic cap baseline use evaluate_analytic_cap"
        )
    y = np.asarray([bw for _, _, bw in dataset], np.float64)
    pred = predictor.predict_pairs([(list(s), led) for s, led, _ in dataset])
    return _accuracy(y, np.asarray(pred, np.float64))


def evaluate_analytic_cap(
    cluster: Cluster,
    base_predictor,
    dataset: Sequence[ContendedTriple],
) -> Tuple[np.ndarray, Dict[str, float]]:
    """The analytic baseline over (subset, ledger, bw) triples:
    ``min(B̂_iso(S), even-split cap(S, L))`` with each sample's own ledger.
    One batched isolated predict; the caps are closed-form (no model
    calls).  Returns (predictions, accuracy dict)."""
    from repro.core.contention import contended_inter_cap

    subsets = [list(s) for s, _, _ in dataset]
    preds = np.asarray(base_predictor.predict(subsets), np.float64).copy()
    for i, (s, ledger, _) in enumerate(dataset):
        if ledger is not None and len(ledger) > 0:
            cap = contended_inter_cap(cluster, ledger, s)
            if cap < preds[i]:
                preds[i] = cap
    y = np.asarray([bw for _, _, bw in dataset], np.float64)
    return preds, _accuracy(y, preds)


def make_train_test_split(
    sim: BandwidthSimulator,
    n_train: int,
    test_mult: int = 5,
    seed: int = 0,
) -> Tuple[List, List]:
    """Paper protocol: test set is 5x the training set, all inter-host, and
    disjoint from the training allocations."""
    rng = np.random.default_rng(seed)
    total = sim.build_dataset(n_train * (test_mult + 1), rng, noisy=True)
    train = total[:n_train]
    # test targets are *noiseless* ground truth: we grade the model against
    # reality, not against one noisy measurement of it.
    test = [(s, sim.true_bandwidth(s)) for s, _ in total[n_train:]]
    return train, test
