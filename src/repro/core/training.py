"""Surrogate training: offline initialization + online adaptation (Sec. 4.1.2).

The paper trains the hierarchical Transformer on a deliberately sparse set of
inter-host measurements (250 samples in the headline results) and keeps it
fresh by fine-tuning on bandwidths observed from live jobs.  Both paths share
one jitted AdamW step.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features as feat_lib
from repro.core import surrogate as surr
from repro.core.bandwidth_sim import BW_SCALE, BandwidthSimulator
from repro.core.cluster import Cluster
from repro.core.intra_host import IntraHostTables
from repro.train.optimizer import AdamWConfig, adamw, cosine_schedule

PyTree = Any


@dataclasses.dataclass
class TrainConfig:
    steps: int = 3000
    batch_size: int = 64
    lr: float = 3e-3
    weight_decay: float = 1e-4
    warmup_steps: int = 100
    seed: int = 0
    log_every: int = 0  # 0 = silent


def _mse_loss(apply_fn, params, x, mask, y):
    pred = apply_fn(params, x, mask)
    return jnp.mean(jnp.square(pred - y))


def train_surrogate(
    cluster: Cluster,
    tables: IntraHostTables,
    dataset: Sequence[Tuple[Sequence[int], float]],
    config: TrainConfig = TrainConfig(),
    naive: bool = False,
    init_params: Optional[PyTree] = None,
) -> Tuple[PyTree, Dict[str, float]]:
    """Train hierarchical (or naive) surrogate on (allocation, bandwidth) pairs.

    Returns (params, info) where info records wall time and final loss.
    """
    key = jax.random.PRNGKey(config.seed)
    subsets = [list(s) for s, _ in dataset]
    targets = np.asarray(
        surr.encode_bw(np.asarray([bw for _, bw in dataset], np.float32))
    )

    if naive:
        x, mask = feat_lib.featurize_gpu_ids(cluster, subsets, cluster.n_gpus)
        apply_fn = surr.apply_naive
        params = init_params or surr.init_naive_params(key, cluster.n_gpus)
    else:
        x, mask = feat_lib.featurize_batch(cluster, tables, subsets)
        apply_fn = surr.apply_hierarchical
        params = init_params or surr.init_hierarchical_params(key)

    x = jnp.asarray(x)
    mask = jnp.asarray(mask)
    targets = jnp.asarray(targets)
    n = len(subsets)

    opt_cfg = AdamWConfig(
        lr=config.lr, weight_decay=config.weight_decay, grad_clip_norm=1.0
    )
    opt_init, opt_update = adamw(
        opt_cfg, cosine_schedule(config.steps, config.warmup_steps)
    )
    opt_state = opt_init(params)

    @jax.jit
    def step(params, opt_state, idx):
        xb, mb, yb = x[idx], mask[idx], targets[idx]
        loss, grads = jax.value_and_grad(
            lambda p: _mse_loss(apply_fn, p, xb, mb, yb)
        )(params)
        params, opt_state, metrics = opt_update(grads, opt_state, params)
        return params, opt_state, loss

    rng = np.random.default_rng(config.seed)
    t0 = time.time()
    loss = np.inf
    for i in range(config.steps):
        idx = jnp.asarray(rng.integers(0, n, size=min(config.batch_size, n)))
        params, opt_state, loss = step(params, opt_state, idx)
        if config.log_every and (i + 1) % config.log_every == 0:
            print(f"  surrogate step {i + 1}/{config.steps} loss={float(loss):.5f}")
    info = {
        "train_seconds": time.time() - t0,
        "final_loss": float(loss),
        "n_samples": n,
        "param_bytes": surr.param_bytes(params),
    }
    return params, info


def online_finetune(
    cluster: Cluster,
    tables: IntraHostTables,
    params: PyTree,
    new_measurements: Sequence[Tuple[Sequence[int], float]],
    steps: int = 200,
    lr: float = 5e-4,
    seed: int = 1,
) -> PyTree:
    """Online adaptation: a few low-LR steps on freshly observed bandwidths
    (Sec. 4.2.2).  No architecture change, no full retraining."""
    cfg = TrainConfig(steps=steps, lr=lr, warmup_steps=0, seed=seed)
    params, _ = train_surrogate(
        cluster, tables, new_measurements, cfg, init_params=params
    )
    return params


# ---------------------------------------------------------------------------
# Accuracy metrics (Sec. 5.2): R^2 and MAPE
# ---------------------------------------------------------------------------

def evaluate_surrogate(
    predictor: "surr.SurrogatePredictor",
    dataset: Sequence[Tuple[Sequence[int], float]],
) -> Dict[str, float]:
    subsets = [list(s) for s, _ in dataset]
    y = np.asarray([bw for _, bw in dataset], np.float64)
    pred = predictor.predict(subsets)
    resid = y - pred
    ss_res = float(np.sum(resid**2))
    ss_tot = float(np.sum((y - y.mean()) ** 2))
    r2 = 1.0 - ss_res / max(ss_tot, 1e-12)
    mape = float(np.mean(np.abs(resid) / np.maximum(np.abs(y), 1e-9))) * 100.0
    return {"r2": r2, "mape": mape, "n": len(dataset)}


def make_train_test_split(
    sim: BandwidthSimulator,
    n_train: int,
    test_mult: int = 5,
    seed: int = 0,
) -> Tuple[List, List]:
    """Paper protocol: test set is 5x the training set, all inter-host, and
    disjoint from the training allocations."""
    rng = np.random.default_rng(seed)
    total = sim.build_dataset(n_train * (test_mult + 1), rng, noisy=True)
    train = total[:n_train]
    # test targets are *noiseless* ground truth: we grade the model against
    # reality, not against one noisy measurement of it.
    test = [(s, sim.true_bandwidth(s)) for s, _ in total[n_train:]]
    return train, test
