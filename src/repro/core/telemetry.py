"""Dispatch observability: admission tracer, metrics registry, drift recorder.

BandPilot's pitch is that the dispatcher's *predicted* contention-degraded
bandwidth matches what tenants actually get — this module is how you watch
that claim live.  Three layers, each consumable on its own:

**Span-based admission tracer** (:class:`AdmissionTracer`).  Every
``submit -> search -> commit`` path emits nested spans: the admission root,
EHA construction, the PTS descent (host rounds or fused on-device scan
steps), the contention branch taken (analytic cap vs learned head), cache
hit/miss deltas, control-plane stage/validate/retry/serialize commits,
park/pump events, and defrag background / make-room passes.  Spans land in
a bounded ring buffer and nest through a *per-thread* stack, so spans from
racing control-plane workers interleave freely without corrupting either
structure (hammer-tested in ``tests/test_telemetry.py``).  Tracing is a
process-wide opt-in (:func:`trace` / :func:`install`): when no tracer is
installed every instrumented site is a single module-global ``None`` check
returning a shared no-op span, and the tracer only ever *records* — it
never touches an rng, a predictor, or a ledger — so placements are
byte-identical with tracing on or off (regression-pinned across fifo /
batched x analytic / learned x concurrent workers).

**Unified metrics registry** (:class:`MetricsRegistry`).  One
counters/gauges/histograms surface (with labels) that absorbs every stats
object grown across PRs 1-7 — :class:`~repro.core.predict_cache.
PredictorStats`, :class:`~repro.core.controlplane.ControlPlaneStats`,
``summarize_trace`` summaries, :class:`~repro.core.defrag.
FragmentationMetrics`, drift state — behind ``MetricsRegistry.snapshot()``,
with Prometheus text exposition (:meth:`MetricsRegistry.to_prometheus`,
label escaping and histogram grammar validated in tests) and JSONL export
(:meth:`MetricsRegistry.write_jsonl` / :func:`read_metrics_jsonl`).

*Double-count rules* (the one contract every absorb follows):
``absorb_*`` helpers **set** the cumulative value of the source object —
re-absorbing the same source is idempotent, absorbing two *distinct*
sources into the same labelset is the caller's double-count bug.  Predictor
chains must be merged exactly once via ``collect_stats`` (which dedups
shared bases by id) *before* absorbing — pass
``dispatcher.predictor_stats()``, never the per-wrapper ``.stats`` objects,
whose times nest.  ``ControlPlaneStats`` commit kinds partition:
``n_cas_commits + n_validated + n_serialized == n_admitted`` (asserted at
absorb time), so the labelled commit counter sums to the admission total by
construction.

**Prediction-drift flight recorder** (:class:`DriftMonitor`).  For every
graded admission and every ``report_bandwidth`` callback the monitor pairs
predicted B-hat with the realized contended bandwidth (wired through the
existing :class:`~repro.core.contended_dataset.TelemetryHarvester` —
attach the monitor as ``TelemetryHarvester(cluster, drift=...)`` and the
scheduler/service observation path feeds it; there is no second
observation pipeline).  It keeps windowed MAPE and signed bias per tenant
and overall, a bounded ring of :class:`DecisionRecord` (candidate subset,
contention-snapshot digest, predicted/realized scores), and raises a
structured :class:`DriftAlert` — carrying the last-N decision records —
when the window degrades past the thresholds.  ``on_alert`` is the action
hook: :func:`finetune_on_drift` builds one that feeds the harvester's
triples to :func:`repro.core.training.online_finetune_contended`, closing
the paper's online-adaptation loop from a *measured* drift signal instead
of a wall clock.

See ``docs/observability.md`` for the span taxonomy, metric names, drift
semantics, and measured overhead.
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
import json
import math
import threading
import time
import zlib
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "AdmissionTracer",
    "Span",
    "trace",
    "install",
    "active_tracer",
    "span",
    "event",
    "current_trace_id",
    "MetricsRegistry",
    "read_metrics_jsonl",
    "absorb_predictor_stats",
    "absorb_controlplane_stats",
    "absorb_fragmentation",
    "absorb_trace_summary",
    "absorb_drift",
    "collect_scheduler_metrics",
    "DecisionRecord",
    "DriftAlert",
    "DriftMonitor",
    "snapshot_digest",
    "finetune_on_drift",
]


# ---------------------------------------------------------------------------
# Span-based admission tracer
# ---------------------------------------------------------------------------

_TLS = threading.local()          # per-thread span stack (nesting)
_ACTIVE: Optional["AdmissionTracer"] = None   # process-wide opt-in
_INSTALL_LOCK = threading.Lock()


class Span:
    """One timed, attributed region of an admission path.

    Mutable while open (``sp["key"] = value`` adds attributes; the null
    span swallows writes), frozen in practice once it lands in the ring.
    ``trace_id`` groups every span of one admission; ``parent_id`` / the
    per-thread stack give the nesting; ``thread`` disambiguates racing
    control-plane workers.
    """

    __slots__ = (
        "name", "trace_id", "span_id", "parent_id", "thread",
        "t0", "t1", "attrs",
    )

    def __init__(self, name, trace_id, span_id, parent_id, thread, t0, attrs):
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.thread = thread
        self.t0 = t0
        self.t1 = float("nan")
        self.attrs = attrs

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def __setitem__(self, key: str, value) -> None:
        self.attrs[key] = value

    def __getitem__(self, key: str):
        return self.attrs[key]

    def __bool__(self) -> bool:
        return True

    def to_dict(self) -> Dict:
        return {
            "name": self.name,
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "thread": self.thread,
            "t0": self.t0,
            "t1": self.t1,
            "attrs": dict(self.attrs),
        }

    def __repr__(self) -> str:
        return (
            f"Span({self.name!r}, trace={self.trace_id}, "
            f"dur={self.duration * 1e3:.3f}ms, attrs={self.attrs})"
        )


class _NullSpan:
    """Shared no-op span: attribute writes vanish, truthiness is False so
    call sites can gate optional (more expensive) annotation work."""

    __slots__ = ()

    def __setitem__(self, key, value) -> None:
        pass

    def __bool__(self) -> bool:
        return False

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _OpenSpan:
    """Context manager for one live span: pushes on the caller thread's
    stack at enter, stamps ``t1``, pops, and appends to the tracer's ring
    at exit.  Exceptions propagate (a crashed admission still records its
    spans, flagged with ``error``)."""

    __slots__ = ("tracer", "span")

    def __init__(self, tracer: "AdmissionTracer", sp: Span):
        self.tracer = tracer
        self.span = sp

    def __enter__(self) -> Span:
        _stack().append(self.span)
        return self.span

    def __exit__(self, exc_type, exc, tb) -> bool:
        sp = self.span
        sp.t1 = time.time()
        if exc_type is not None:
            sp.attrs["error"] = exc_type.__name__
        stack = _stack()
        # tolerate a corrupted stack rather than masking the real exception
        if stack and stack[-1] is sp:
            stack.pop()
        elif sp in stack:
            stack.remove(sp)
        self.tracer._record(sp)
        return False


def _stack() -> List[Span]:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


class AdmissionTracer:
    """Bounded ring buffer of completed :class:`Span` records.

    Thread-safe by construction: nesting state is per-thread (TLS), ring
    appends take the tracer lock, and a full ring drops the *oldest* span.
    ``capacity`` bounds memory no matter how long the service runs —
    tracing is a flight recorder, not an archive (export with
    :meth:`write_jsonl` if you need one).
    """

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._ring: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._trace_ids = itertools.count()
        self._span_ids = itertools.count()
        self.n_spans = 0          # lifetime count (before ring eviction)
        self.n_dropped = 0        # evicted by the capacity bound

    # -- emission (normally via the module-level span()/event()) ------------

    def span(self, name: str, **attrs) -> _OpenSpan:
        """Open a span.  The first span on a thread's empty stack starts a
        fresh trace (one trace == one admission path); nested spans inherit
        the enclosing trace id."""
        stack = _stack()
        if stack:
            parent = stack[-1]
            trace_id, parent_id = parent.trace_id, parent.span_id
        else:
            with self._lock:
                trace_id = next(self._trace_ids)
            parent_id = -1
        with self._lock:
            span_id = next(self._span_ids)
        sp = Span(
            name, trace_id, span_id, parent_id,
            threading.get_ident(), time.time(), attrs,
        )
        return _OpenSpan(self, sp)

    def event(self, name: str, **attrs) -> None:
        """Zero-duration span (park/pump notifications and the like)."""
        with self.span(name, **attrs):
            pass

    def _record(self, sp: Span) -> None:
        with self._lock:
            if len(self._ring) == self.capacity:
                self.n_dropped += 1
            self._ring.append(sp)
            self.n_spans += 1

    # -- inspection ----------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def spans(
        self, name: Optional[str] = None, trace_id: Optional[int] = None
    ) -> List[Span]:
        """Completed spans, oldest first, optionally filtered."""
        with self._lock:
            out = list(self._ring)
        if name is not None:
            out = [s for s in out if s.name == name]
        if trace_id is not None:
            out = [s for s in out if s.trace_id == trace_id]
        return out

    def traces(self) -> Dict[int, List[Span]]:
        """trace id -> its spans (in completion order)."""
        out: Dict[int, List[Span]] = {}
        for s in self.spans():
            out.setdefault(s.trace_id, []).append(s)
        return out

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def summary(self) -> Dict[str, Dict[str, float]]:
        """span name -> {count, total_seconds, mean_seconds} over the ring."""
        agg: Dict[str, List[float]] = {}
        for s in self.spans():
            if not math.isnan(s.t1):
                agg.setdefault(s.name, []).append(s.duration)
        return {
            name: {
                "count": float(len(ds)),
                "total_seconds": float(sum(ds)),
                "mean_seconds": float(sum(ds) / len(ds)),
            }
            for name, ds in sorted(agg.items())
        }

    def write_jsonl(self, path) -> int:
        """Dump the ring as one JSON object per line; returns the count."""
        spans = self.spans()
        with open(path, "w", encoding="utf-8") as fh:
            for s in spans:
                fh.write(json.dumps(s.to_dict(), sort_keys=True) + "\n")
        return len(spans)


def install(tracer: Optional[AdmissionTracer]) -> Optional[AdmissionTracer]:
    """Install ``tracer`` process-wide (None disables).  Returns the
    previous tracer.  Process-wide on purpose: control-plane pool threads
    and joint-order workers must see the same tracer as the submitting
    thread, which thread-local installation cannot provide."""
    global _ACTIVE
    with _INSTALL_LOCK:
        prev, _ACTIVE = _ACTIVE, tracer
    return prev


def active_tracer() -> Optional[AdmissionTracer]:
    return _ACTIVE


@contextlib.contextmanager
def trace(tracer: AdmissionTracer):
    """``with telemetry.trace(AdmissionTracer()) as tr:`` — install for the
    block, restore the previous tracer after."""
    prev = install(tracer)
    try:
        yield tracer
    finally:
        install(prev)


def span(name: str, **attrs):
    """THE instrumentation entry point: a context manager that is a live
    span under an installed tracer and a shared no-op otherwise.  The
    disabled cost is one global read per call site."""
    tr = _ACTIVE
    if tr is None:
        return _NULL_SPAN
    return tr.span(name, **attrs)


def event(name: str, **attrs) -> None:
    """Zero-duration notification (no-op when tracing is disabled)."""
    tr = _ACTIVE
    if tr is not None:
        tr.event(name, **attrs)


def current_trace_id() -> int:
    """Trace id of the innermost open span on the calling thread, or -1
    when no span is open (or no tracer installed).  The trace <-> journal
    linkage primitive: forensics dossiers stamp it next to the commit's
    ``journal_seq`` so one admission can be followed across the span ring,
    the journal, and the dossier store."""
    if _ACTIVE is None:
        return -1
    st = getattr(_TLS, "stack", None)
    return st[-1].trace_id if st else -1


# ---------------------------------------------------------------------------
# Unified metrics registry
# ---------------------------------------------------------------------------

def _escape_label_value(v: str) -> str:
    """Prometheus exposition escaping for label values: backslash, double
    quote, and newline (in that order — backslash first)."""
    return (
        str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")
    )


def _escape_help(v: str) -> str:
    """HELP lines escape backslash and newline (quotes stay bare)."""
    return str(v).replace("\\", "\\\\").replace("\n", "\\n")


def _format_value(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    return repr(float(v))


_NAME_OK = set(
    "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_:"
)
_LABEL_OK = set(
    "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_"
)


def _check_name(name: str, charset, kind: str) -> str:
    if not name or name[0].isdigit() or not set(name) <= charset:
        raise ValueError(f"invalid {kind} name {name!r}")
    return name


class _Metric:
    """Shared machinery: one named metric, samples keyed by label values."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labels: Sequence[str] = ()):
        self.name = _check_name(name, _NAME_OK, "metric")
        self.help = help
        self.label_names: Tuple[str, ...] = tuple(
            _check_name(ln, _LABEL_OK, "label") for ln in labels
        )
        self._samples: Dict[Tuple[str, ...], float] = {}
        self._lock = threading.Lock()

    def _key(self, labels: Dict[str, object]) -> Tuple[str, ...]:
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"{self.name}: expected labels {self.label_names}, "
                f"got {tuple(sorted(labels))}"
            )
        return tuple(str(labels[ln]) for ln in self.label_names)

    def value(self, **labels) -> float:
        with self._lock:
            return self._samples[self._key(labels)]

    def samples(self) -> List[Tuple[Dict[str, str], float]]:
        with self._lock:
            items = list(self._samples.items())
        return [
            (dict(zip(self.label_names, key)), v) for key, v in sorted(items)
        ]

    def _label_str(self, key: Tuple[str, ...], extra: str = "") -> str:
        parts = [
            f'{ln}="{_escape_label_value(lv)}"'
            for ln, lv in zip(self.label_names, key)
        ]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""

    def expose(self) -> List[str]:
        lines = [
            f"# HELP {self.name} {_escape_help(self.help)}",
            f"# TYPE {self.name} {self.kind}",
        ]
        with self._lock:
            items = sorted(self._samples.items())
        for key, v in items:
            lines.append(f"{self.name}{self._label_str(key)} {_format_value(v)}")
        return lines

    def snapshot(self) -> Dict:
        return {
            "type": self.kind,
            "help": self.help,
            "samples": [
                {"labels": labels, "value": v} for labels, v in self.samples()
            ],
        }


class Counter(_Metric):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up")
        key = self._key(labels)
        with self._lock:
            self._samples[key] = self._samples.get(key, 0.0) + float(amount)

    def set(self, value: float, **labels) -> None:
        """Set the cumulative value — the absorb-idempotency primitive (the
        source object owns the accumulation; re-absorbing must not double).
        Monotonicity is the source's contract, not re-checked here."""
        if value < 0:
            raise ValueError(f"{self.name}: counters are non-negative")
        with self._lock:
            self._samples[self._key(labels)] = float(value)


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._samples[self._key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._samples[key] = self._samples.get(key, 0.0) + float(amount)


DEFAULT_BUCKETS = (
    0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0, 50.0, 100.0,
)


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help, labels=(), buckets=DEFAULT_BUCKETS):
        super().__init__(name, help, labels)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs or any(b1 <= b0 for b0, b1 in zip(bs, bs[1:])):
            raise ValueError("buckets must be non-empty and strictly increasing")
        self.buckets = bs
        # per labelset: cumulative bucket counts (+Inf implicit last), sum
        self._counts: Dict[Tuple[str, ...], List[int]] = {}
        self._sums: Dict[Tuple[str, ...], float] = {}

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            counts = self._counts.get(key)
            if counts is None:
                counts = self._counts[key] = [0] * (len(self.buckets) + 1)
                self._sums[key] = 0.0
                self._samples[key] = 0.0   # observation count (for value())
            for i, b in enumerate(self.buckets):
                if value <= b:
                    counts[i] += 1
            counts[-1] += 1                # +Inf
            self._sums[key] += float(value)
            self._samples[key] += 1.0

    def expose(self) -> List[str]:
        lines = [
            f"# HELP {self.name} {_escape_help(self.help)}",
            f"# TYPE {self.name} {self.kind}",
        ]
        with self._lock:
            items = sorted(self._counts.items())
            sums = dict(self._sums)
        for key, counts in items:
            for b, c in zip(self.buckets, counts):
                le = f'le="{_format_value(b)}"'
                lines.append(
                    f"{self.name}_bucket{self._label_str(key, le)} {c}"
                )
            inf_label = 'le="+Inf"'
            lines.append(
                f"{self.name}_bucket{self._label_str(key, inf_label)} "
                f"{counts[-1]}"
            )
            lines.append(
                f"{self.name}_sum{self._label_str(key)} "
                f"{_format_value(sums[key])}"
            )
            lines.append(
                f"{self.name}_count{self._label_str(key)} {counts[-1]}"
            )
        return lines

    def snapshot(self) -> Dict:
        with self._lock:
            items = sorted(self._counts.items())
            sums = dict(self._sums)
        return {
            "type": self.kind,
            "help": self.help,
            "buckets": list(self.buckets),
            "samples": [
                {
                    "labels": dict(zip(self.label_names, key)),
                    "counts": list(counts),
                    "sum": sums[key],
                    "count": counts[-1],
                }
                for key, counts in items
            ],
        }


class MetricsRegistry:
    """One process-wide (or per-test) home for every dispatch metric.

    ``counter``/``gauge``/``histogram`` get-or-create (re-registration with
    a different type or labelset is an error — one name, one schema);
    ``snapshot()`` returns the whole registry as plain dicts,
    ``to_prometheus()`` the text exposition, ``write_jsonl``/
    :func:`read_metrics_jsonl` the file round-trip.
    """

    def __init__(self, namespace: str = "bandpilot"):
        self.namespace = namespace
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _full(self, name: str) -> str:
        return f"{self.namespace}_{name}" if self.namespace else name

    def _get_or_create(self, cls, name, help, labels, **kw) -> _Metric:
        full = self._full(name)
        with self._lock:
            m = self._metrics.get(full)
            if m is None:
                m = cls(full, help, labels, **kw)
                self._metrics[full] = m
                return m
        if not isinstance(m, cls) or m.label_names != tuple(labels):
            raise ValueError(
                f"metric {full!r} already registered as {m.kind} with "
                f"labels {m.label_names}"
            )
        want = kw.get("buckets")
        if want is not None and isinstance(m, Histogram):
            norm = tuple(sorted(float(b) for b in want))
            if norm != m.buckets:
                # one name, one schema: silently keeping the first buckets
                # would make the second caller's distribution unreadable
                raise ValueError(
                    f"metric {full!r} already registered with buckets "
                    f"{m.buckets}; re-registration asked for {norm}"
                )
        return m

    def counter(self, name, help="", labels=()) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name, help="", labels=()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(
        self, name, help="", labels=(), buckets=DEFAULT_BUCKETS
    ) -> Histogram:
        return self._get_or_create(
            Histogram, name, help, labels, buckets=buckets
        )

    def get(self, name: str) -> Optional[_Metric]:
        """Look up by short name or the fully-namespaced exposition name."""
        m = self._metrics.get(self._full(name))
        return m if m is not None else self._metrics.get(name)

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self) -> Dict[str, Dict]:
        with self._lock:
            metrics = sorted(self._metrics.items())
        return {name: m.snapshot() for name, m in metrics}

    def to_prometheus(self) -> str:
        with self._lock:
            metrics = sorted(self._metrics.items())
        lines: List[str] = []
        for _, m in metrics:
            lines.extend(m.expose())
        return "\n".join(lines) + ("\n" if lines else "")

    def write_jsonl(self, path) -> int:
        """One ``{"name": ..., **snapshot}`` object per line."""
        snap = self.snapshot()
        with open(path, "w", encoding="utf-8") as fh:
            for name, m in snap.items():
                fh.write(
                    json.dumps({"name": name, **m}, sort_keys=True) + "\n"
                )
        return len(snap)


def read_metrics_jsonl(path) -> Dict[str, Dict]:
    """Load a :meth:`MetricsRegistry.write_jsonl` file back into the same
    ``snapshot()`` shape (the round-trip is pinned in tests)."""
    out: Dict[str, Dict] = {}
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            name = obj.pop("name")
            out[name] = obj
    return out


# -- absorption: the existing stats surfaces behind one snapshot ------------

def absorb_predictor_stats(reg: MetricsRegistry, stats, **labels) -> None:
    """Absorb one *merged* :class:`~repro.core.predict_cache.PredictorStats`
    (``dispatcher.predictor_stats()`` — already chain-deduped).  Set
    semantics: idempotent per (source, labelset)."""
    names = tuple(sorted(labels))
    for field, help in (
        ("n_model_calls", "candidates sent through a surrogate apply"),
        ("n_capped", "candidates degraded by a contention branch"),
        ("n_scan_steps", "fused on-device elimination rounds"),
        ("cache_hits", "prediction-cache hits"),
        ("cache_misses", "prediction-cache misses"),
    ):
        reg.counter(f"predictor_{field}_total", help, names).set(
            getattr(stats, field), **labels
        )
    for field, help in (
        ("predict_seconds", "wall seconds inside predict()"),
        ("featurize_seconds", "wall seconds building token batches"),
        ("infer_seconds", "wall seconds in jitted applies"),
        ("scan_seconds", "wall seconds in fused on-device descents"),
        ("wrapper_seconds", "contention-wrapper overhead seconds"),
    ):
        reg.counter(f"predictor_{field}_total", help, names).set(
            getattr(stats, field), **labels
        )
    reg.gauge(
        "predictor_cache_hit_rate", "hits / (hits + misses)", names
    ).set(stats.hit_rate, **labels)


def absorb_controlplane_stats(reg: MetricsRegistry, stats, **labels) -> None:
    """Absorb a :class:`~repro.core.controlplane.ControlPlaneStats`.

    The commit-kind partition is the documented invariant: cas + validated
    + serialized == admitted.  Exposed as ONE labelled counter (so the sum
    over the ``commit`` label is the admission total by construction) and
    asserted here — drift between the partition and the total is a stats
    bug, caught at absorb time rather than on a dashboard.
    """
    parts = {
        "cas": stats.n_cas_commits,
        "validated": stats.n_validated,
        "serialized": stats.n_serialized,
    }
    if sum(parts.values()) != stats.n_admitted:
        raise ValueError(
            f"commit kinds {parts} do not partition "
            f"n_admitted={stats.n_admitted}"
        )
    names = tuple(sorted(labels))
    commit = reg.counter(
        "cplane_commits_total",
        "admissions by commit kind (sums to admissions)",
        names + ("commit",),
    )
    for kind, v in parts.items():
        commit.set(v, commit=kind, **labels)
    for field, help in (
        ("n_admitted", "admissions committed"),
        ("n_conflicts", "re-searches forced by moved read-sets"),
        ("n_parked", "park events (capacity / tenant caps)"),
        ("n_rejected", "rejections (queue caps)"),
    ):
        reg.counter(f"cplane_{field[2:]}_total", help, names).set(
            getattr(stats, field), **labels
        )
    for field, help in (
        ("search_seconds", "wall seconds staging searches"),
        ("commit_seconds", "wall seconds in commit attempts"),
    ):
        reg.counter(f"cplane_{field}_total", help, names).set(
            getattr(stats, field), **labels
        )


def absorb_fragmentation(reg: MetricsRegistry, frag, **labels) -> None:
    """Absorb a :class:`~repro.core.defrag.FragmentationMetrics` (gauges:
    fragmentation is instantaneous state, not a cumulative count)."""
    names = tuple(sorted(labels))
    for field, help in (
        ("total_free", "free GPUs"),
        ("clean_hosts", "fully-free hosts"),
        ("fragmented_hosts", "partially-busy hosts"),
        ("largest_free_block", "largest single-host free capacity"),
        ("largest_quality_block", "largest switch-fabric free block"),
        ("premium_free", "free GPUs on switch-fabric hosts"),
        ("stranding", "stranded free GPUs / total free GPUs"),
    ):
        reg.gauge(f"frag_{field}", help, names).set(
            getattr(frag, field), **labels
        )


def absorb_trace_summary(reg: MetricsRegistry, records, **labels) -> None:
    """Absorb graded :class:`~repro.core.scheduler.TenantRecord` rows: the
    ``summarize_trace`` means as gauges plus wait/GBE histograms.  One
    labelset per dispatcher name found in the records (merged with
    ``labels``)."""
    from repro.core.scheduler import summarize_trace

    summary = summarize_trace(records)
    names = tuple(sorted(labels)) + ("dispatcher",)
    waits = reg.histogram(
        "admission_wait_seconds", "queueing delay per admission", names,
        buckets=(0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0),
    )
    gbes = reg.histogram(
        "admission_gbe", "contention-degraded GBE per admission", names,
        buckets=(0.2, 0.4, 0.6, 0.8, 0.9, 0.95, 0.99, 1.0),
    )
    count = reg.counter(
        "admissions_total", "graded admissions", names + ("policy",)
    )
    for r in records:
        waits.observe(r.wait, dispatcher=r.dispatcher, **labels)
        if not math.isnan(r.gbe):
            gbes.observe(r.gbe, dispatcher=r.dispatcher, **labels)
        count.inc(1, dispatcher=r.dispatcher, policy=r.policy, **labels)
    for disp, row in summary.items():
        for field, value in row.items():
            if field == "n":
                continue
            reg.gauge(
                f"trace_{field}", f"summarize_trace {field}", names
            ).set(value, dispatcher=disp, **labels)


def absorb_drift(reg: MetricsRegistry, monitor: "DriftMonitor", **labels):
    """Absorb a :class:`DriftMonitor`'s windowed state."""
    names = tuple(sorted(labels))
    reg.gauge("drift_mape", "windowed MAPE of B-hat vs realized", names).set(
        monitor.mape(), **labels
    )
    reg.gauge("drift_bias", "windowed signed bias of B-hat", names).set(
        monitor.bias(), **labels
    )
    reg.counter("drift_samples_total", "paired observations", names).set(
        monitor.n_observed, **labels
    )
    reg.counter("drift_alerts_total", "drift alerts raised", names).set(
        len(monitor.alerts), **labels
    )
    per_tenant = reg.gauge(
        "drift_mape_tenant", "windowed MAPE per tenant", names + ("tenant",)
    )
    for tenant in monitor.tenants():
        per_tenant.set(monitor.mape(tenant=tenant), tenant=tenant, **labels)


def absorb_recovery(reg: MetricsRegistry, scheduler, **labels) -> None:
    """Absorb the failure-domain outcome of a scheduler run: injected
    fault counts per kind, MTTR over completed recoveries, abandoned
    requeues, and the bandwidth retained across the storm (aggregate live
    contended bw after the last fault's drain / before the first fault).
    No-op when the run carried no fault schedule."""
    fault_log = getattr(scheduler, "fault_log", None) or []
    recoveries = list(getattr(scheduler, "recoveries", ()) or [])
    if not fault_log and not recoveries:
        return
    names = tuple(sorted(labels))
    faults_rows = [r for r in fault_log if r["op"] == "fault"]
    cnt = reg.counter(
        "faults_injected_total", "fault events applied", names + ("kind",)
    )
    for kind in sorted({r["kind"] for r in faults_rows}):
        cnt.set(sum(1 for r in faults_rows if r["kind"] == kind),
                kind=kind, **labels)
    done = [r for r in recoveries if not r.gave_up]
    reg.counter("recoveries_total", "victims re-admitted", names).set(
        len(done), **labels
    )
    reg.counter(
        "recoveries_gave_up_total", "requeues abandoned after max retries",
        names,
    ).set(len(recoveries) - len(done), **labels)
    if done:
        reg.gauge(
            "recovery_mttr_mean", "mean fault-to-readmission time", names
        ).set(sum(r.mttr for r in done) / len(done), **labels)
        reg.gauge(
            "recovery_mttr_max", "worst fault-to-readmission time", names
        ).set(float(max(r.mttr for r in done)), **labels)
        reg.gauge(
            "recovery_attempts_mean", "mean re-admission attempts", names
        ).set(sum(r.attempts for r in done) / len(done), **labels)
    if faults_rows:
        pre = faults_rows[0]["agg_bw_before"]
        post = faults_rows[-1]["agg_bw_after"]
        if pre > 0:
            reg.gauge(
                "recovered_bandwidth_frac",
                "aggregate live contended bw retained across the storm",
                names,
            ).set(post / pre, **labels)


def collect_scheduler_metrics(
    scheduler, registry: Optional[MetricsRegistry] = None
) -> MetricsRegistry:
    """One-call snapshot of everything a finished (or live)
    :class:`~repro.core.scheduler.AdmissionScheduler` knows: trace
    summaries, merged predictor stats, grading-cache counters, current
    fragmentation, migration counts, control-plane stats (when concurrent),
    and drift state (when the harvester carries a monitor)."""
    reg = registry if registry is not None else MetricsRegistry()
    disp = scheduler.dispatcher
    name = getattr(disp, "name", "dispatcher")
    if scheduler.records:
        absorb_trace_summary(reg, scheduler.records)
    stats_fn = getattr(disp, "predictor_stats", None)
    if stats_fn is not None:
        absorb_predictor_stats(reg, stats_fn(), dispatcher=name)
    absorb_predictor_stats(
        reg, scheduler.grading_cache.stats, dispatcher=f"{name}/grading"
    )
    absorb_fragmentation(
        reg, disp.ledger.fragmentation(), dispatcher=name
    )
    reg.counter(
        "migrations_total", "committed live-job moves", ("dispatcher", "kind")
    )
    for kind in ("redispatch", "defrag", "make-room", "flap-migrate"):
        reg.get("migrations_total").set(
            sum(1 for m in scheduler.migrations if m.kind == kind),
            dispatcher=name, kind=kind,
        )
    absorb_recovery(reg, scheduler, dispatcher=name)
    cplane = getattr(scheduler, "_cplane", None)
    if cplane is not None:
        absorb_controlplane_stats(reg, cplane.stats, dispatcher=name)
    drift = getattr(scheduler.harvester, "drift", None)
    if drift is not None:
        absorb_drift(reg, drift, dispatcher=name)
    return reg


# ---------------------------------------------------------------------------
# Prediction-drift flight recorder
# ---------------------------------------------------------------------------

def snapshot_digest(ledger, subset: Sequence[int] = ()) -> str:
    """Stable 8-hex digest of the contention context a prediction was made
    against: the sorted GPU tuples of every live job disjoint from
    ``subset`` (the same co-tenant predicate the harvester and the
    contended ground truth use).  Cheap enough to stamp on every decision
    record; two records with equal digests saw byte-identical co-tenant
    sets."""
    sset = set(subset)
    cot = sorted(
        a.gpus for a in ledger.jobs() if sset.isdisjoint(a.gpus)
    )
    blob = ";".join(",".join(str(g) for g in gs) for gs in cot)
    return f"{zlib.crc32(blob.encode('utf-8')) & 0xFFFFFFFF:08x}"


@dataclasses.dataclass
class DecisionRecord:
    """One graded dispatch decision, as the flight recorder keeps it."""

    job_id: str
    tenant: str
    subset: Tuple[int, ...]
    predicted: float          # B-hat the search committed on
    realized: float           # contended bandwidth actually measured/graded
    ape: float                # |predicted - realized| / realized
    err: float                # signed (predicted - realized) / realized
    digest: str               # contention-snapshot digest at decision time
    t: float = 0.0            # trace clock of the observation
    source: str = "grade"     # "grade" | "report" (report_bandwidth)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class DriftAlert:
    """Structured drift notification: the windowed stats that tripped the
    threshold plus the last-N decision records behind them."""

    t: float                   # observation clock when raised
    n_window: int              # paired observations in the window
    mape: float
    bias: float
    mape_threshold: float
    bias_threshold: float
    tenant: str                # "" = the global window tripped
    records: List[DecisionRecord] = dataclasses.field(default_factory=list)

    @property
    def kind(self) -> str:
        return "bias" if abs(self.bias) >= self.bias_threshold else "mape"

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["kind"] = self.kind
        return d


class DriftMonitor:
    """Windowed predicted-vs-realized drift tracking with structured alerts.

    Wire it through the existing telemetry path —
    ``TelemetryHarvester(cluster, drift=monitor)`` — and every graded
    admission / ``report_bandwidth`` callback that reaches the harvester
    also reaches the monitor; there is no second observation pipeline.

    * :meth:`note_prediction` stamps the B-hat an admission committed on
      (the scheduler and control plane call it with the search's predicted
      bandwidth, the subset, and the contention-snapshot digest).
    * :meth:`observe` pairs a realized bandwidth with the stamped
      prediction (grading passes ``predicted`` inline; a later
      ``report_bandwidth`` resolves through the pending map by job id).
    * windowed **MAPE** (mean |err|) and **bias** (mean signed err — a
      systematically optimistic predictor shows positive bias long before
      MAPE looks alarming) are kept overall and per tenant over the last
      ``window`` pairs.
    * when a window of at least ``min_samples`` exceeds a threshold, a
      :class:`DriftAlert` carrying the last ``dump_last`` decision records
      is appended to :attr:`alerts` and handed to ``on_alert`` — with at
      least ``min_samples`` fresh pairs between alerts, so a persistently
      bad predictor alerts periodically, not per admission.

    Thread-safe (the control plane grades from pool threads).  NaN or
    non-positive realized values are dropped (a stale report carries no
    drift signal).
    """

    def __init__(
        self,
        window: int = 64,
        min_samples: int = 16,
        mape_threshold: float = 0.25,
        bias_threshold: float = 0.20,
        dump_last: int = 32,
        max_records: int = 1024,
        on_alert: Optional[Callable[["DriftAlert"], None]] = None,
    ):
        if window < 1 or min_samples < 1:
            raise ValueError("window and min_samples must be >= 1")
        self.window = int(window)
        self.min_samples = int(min_samples)
        self.mape_threshold = float(mape_threshold)
        self.bias_threshold = float(bias_threshold)
        self.dump_last = int(dump_last)
        self.on_alert = on_alert
        self._lock = threading.Lock()
        self._pending: Dict[str, Tuple[float, Tuple[int, ...], str, str]] = {}
        self._errs: deque = deque(maxlen=self.window)   # signed rel. errors
        self._tenant_errs: Dict[str, deque] = {}
        self._records: deque = deque(maxlen=int(max_records))
        self._since_alert = 0
        self.alerts: List[DriftAlert] = []
        self.n_observed = 0    # paired observations (lifetime)
        self.n_unmatched = 0   # realized values with no stamped prediction

    # -- feeding -------------------------------------------------------------

    def note_prediction(
        self,
        job_id: str,
        subset: Sequence[int],
        predicted: float,
        digest: str = "",
        tenant: str = "",
    ) -> None:
        """Stamp the B-hat an admission committed on (pairs with a later
        ``report_bandwidth`` for the same job)."""
        if math.isnan(predicted):
            return  # baselines search without a predictor: nothing to grade
        with self._lock:
            self._pending[job_id] = (
                float(predicted), tuple(subset), digest, tenant
            )

    def observe(
        self,
        realized: float,
        job_id: str = "",
        subset: Sequence[int] = (),
        predicted: Optional[float] = None,
        digest: str = "",
        tenant: str = "",
        t: float = 0.0,
        source: str = "grade",
    ) -> Optional[DriftAlert]:
        """Pair one realized bandwidth with its prediction; returns the
        alert if this observation tripped one."""
        with self._lock:
            if predicted is None or math.isnan(predicted):
                pend = self._pending.get(job_id)
                if pend is None:
                    self.n_unmatched += 1
                    return None
                predicted, psubset, pdigest, ptenant = pend
                subset = subset or psubset
                digest = digest or pdigest
                tenant = tenant or ptenant
            if math.isnan(realized) or realized <= 0.0:
                return None
            err = (float(predicted) - float(realized)) / float(realized)
            rec = DecisionRecord(
                job_id, tenant, tuple(subset), float(predicted),
                float(realized), abs(err), err, digest, t=t, source=source,
            )
            self._records.append(rec)
            self._errs.append(err)
            self._tenant_errs.setdefault(
                tenant, deque(maxlen=self.window)
            ).append(err)
            self.n_observed += 1
            self._since_alert += 1
            return self._check_locked(t)

    def release(self, job_id: str) -> None:
        """Forget a departed job's stamped prediction (frees the pending
        map; an un-reported job simply never pairs)."""
        with self._lock:
            self._pending.pop(job_id, None)

    # -- windows -------------------------------------------------------------

    def _window_for(self, tenant: Optional[str]) -> Iterable[float]:
        if tenant is None:
            return self._errs
        return self._tenant_errs.get(tenant, ())

    def mape(self, tenant: Optional[str] = None) -> float:
        with self._lock:
            errs = list(self._window_for(tenant))
        if not errs:
            return float("nan")
        return float(sum(abs(e) for e in errs) / len(errs))

    def bias(self, tenant: Optional[str] = None) -> float:
        with self._lock:
            errs = list(self._window_for(tenant))
        if not errs:
            return float("nan")
        return float(sum(errs) / len(errs))

    def tenants(self) -> List[str]:
        with self._lock:
            return sorted(self._tenant_errs)

    def records(self, last: Optional[int] = None) -> List[DecisionRecord]:
        with self._lock:
            out = list(self._records)
        return out[-last:] if last is not None else out

    def dump(self, last: Optional[int] = None, path=None) -> List[Dict]:
        """The last-N decision records as dicts; optionally written to
        ``path`` as JSONL (the on-demand side of the flight recorder)."""
        rows = [r.to_dict() for r in self.records(last or self.dump_last)]
        if path is not None:
            with open(path, "w", encoding="utf-8") as fh:
                for row in rows:
                    fh.write(json.dumps(row, sort_keys=True) + "\n")
        return rows

    # -- alerting ------------------------------------------------------------

    def _check_locked(self, t: float) -> Optional[DriftAlert]:
        if self._since_alert < self.min_samples:
            return None
        errs = self._errs
        if len(errs) < self.min_samples:
            return None
        mape = sum(abs(e) for e in errs) / len(errs)
        bias = sum(errs) / len(errs)
        if mape < self.mape_threshold and abs(bias) < self.bias_threshold:
            return None
        alert = DriftAlert(
            t, len(errs), float(mape), float(bias),
            self.mape_threshold, self.bias_threshold, tenant="",
            records=list(self._records)[-self.dump_last:],
        )
        self.alerts.append(alert)
        self._since_alert = 0
        cb = self.on_alert
        if cb is not None:
            # outside the lock would be nicer, but the callback may touch
            # the monitor; RLock semantics via re-acquire are avoided by
            # keeping callbacks read-only on the monitor (documented)
            cb(alert)
        event("drift.alert", mape=alert.mape, bias=alert.bias,
              n=alert.n_window)
        return alert


def finetune_on_drift(
    harvester,
    predictor,
    tables=None,
    steps: int = 100,
    lr: float = 5e-4,
    min_contended: int = 8,
    trainer: Optional[Callable] = None,
) -> Callable[[DriftAlert], None]:
    """Build an ``on_alert`` hook that closes the online-adaptation loop:
    on drift, fine-tune the dispatcher's
    :class:`~repro.core.surrogate.ContendedSurrogatePredictor` on the
    harvester's accumulated (subset, ledger, bw) triples
    (:func:`repro.core.training.online_finetune_contended`) and swap the
    new params into ``predictor`` in place — the next admission searches
    with the adapted model.

    ``trainer`` substitutes the training call (tests inject a stub; the
    default resolves the real one lazily so the hook itself stays
    jax-free).  The hook is a no-op until the harvester holds at least
    ``min_contended`` contended samples — fine-tuning on an empty or
    isolated-only buffer would only destabilize the head.
    """

    def _alert(alert: DriftAlert) -> None:
        triples = harvester.triples()
        contended = [tr for tr in triples if tr[1] is not None]
        if len(contended) < min_contended:
            return
        fit = trainer
        if fit is None:
            from repro.core.training import online_finetune_contended

            def fit(cluster, tbl, params, samples):  # noqa: F811
                return online_finetune_contended(
                    cluster, tbl, params, samples, steps=steps, lr=lr,
                )

        new_params = fit(
            harvester.cluster,
            tables if tables is not None else predictor.tables,
            predictor.params,
            triples,
        )
        predictor.params = new_params
        event("drift.finetune", n_samples=len(triples),
              n_contended=len(contended))

    return _alert
