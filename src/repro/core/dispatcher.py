"""BandPilot dispatching service + evaluation harnesses (Secs. 4.1, 4.4, 5.3).

Two layers:

* **Service** — every dispatcher is stateful: it owns a
  :class:`~repro.core.tenancy.JobLedger` and exposes an
  ``admit(job_id, k)`` / ``release(job_id)`` lifecycle.  Availability is
  derived from the ledger, and BandPilot's search runs against a
  contention-aware predictor (the virtual-merge wrapper of
  :mod:`repro.core.contention`) so placements route around live cross-host
  tenants.  The legacy pure ``dispatch(avail, k)`` remains for single-shot
  use — with an empty ledger the two are identical.

* **Harnesses** — ``evaluate_dispatchers`` reproduces the paper's
  single-request GBE protocol (Sec. 5.3); ``replay_trace`` is the
  multi-tenant protocol: seeded Poisson arrivals with sampled durations
  stream through a dispatcher, and every admission is graded with
  contention-degraded GBE against the ledger-aware exact Oracle.  The
  queue/clock now live in :mod:`repro.core.scheduler` (pluggable admission
  policies); ``replay_trace`` is a thin wrapper over it with the ``fifo``
  policy, which reproduces the legacy records bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import baselines, forensics, search, telemetry
from repro.core.bandwidth_sim import BandwidthSimulator
from repro.core.cluster import Cluster, availability_scenario
from repro.core.contention import ContentionAwarePredictor
from repro.core.intra_host import IntraHostTables
from repro.core.predict_cache import (
    PredictionCache,
    PredictorStats,
    collect_stats,
)
from repro.core.scheduler import (  # re-exported: the public trace surface
    AdmissionScheduler,
    SchedulerConfig,
    TenantRecord,
    TraceJob,
    poisson_trace,
    summarize_trace,
)
from repro.core.surrogate import SurrogatePredictor
from repro.core.tenancy import (  # typed admit errors re-exported here
    Allocation,
    CapacityError,
    InvalidPlacementError,
    JobLedger,
)

Subset = List[int]

__all__ = [  # keeps `from repro.core.dispatcher import TraceJob, ...` valid
    "AdmissionScheduler", "SchedulerConfig", "TenantRecord", "TraceJob",
    "poisson_trace", "summarize_trace", "replay_trace",
    "BandPilotDispatcher", "BaselineDispatcher", "DispatcherService",
    "CapacityError", "InvalidPlacementError",
    "GroundTruthPredictor", "EvalRecord", "evaluate_dispatchers",
    "summarize", "gbe_by_k", "bw_loss_by_k", "compare_contention_awareness",
]


class GroundTruthPredictor:
    """Predictor view over the true B(S) — powers Ideal-BP and the Oracle
    comparisons (isolates search quality from surrogate error)."""

    def __init__(self, sim: BandwidthSimulator):
        self.sim = sim
        self.stats = PredictorStats()

    @property
    def n_model_calls(self) -> int:
        return self.stats.n_model_calls

    @n_model_calls.setter
    def n_model_calls(self, v: int) -> None:
        self.stats.n_model_calls = v

    @property
    def predict_seconds(self) -> float:
        return self.stats.predict_seconds

    @predict_seconds.setter
    def predict_seconds(self, v: float) -> None:
        self.stats.predict_seconds = v

    def predict(self, subsets: Sequence[Sequence[int]]) -> np.ndarray:
        t0 = time.time()
        out = np.asarray([self.sim.true_bandwidth(s) for s in subsets])
        self.stats.predict_seconds += time.time() - t0
        self.stats.n_model_calls += len(subsets)
        return out


class DispatcherService:
    """Stateful lifecycle shared by every dispatcher.

    Subclasses implement the placement policy as ``dispatch(avail, k)``;
    this base turns it into a long-lived service over a job ledger.
    """

    name = "Dispatcher"
    needs_rng = False  # True when dispatch() requires an rng (Random baseline)

    def __init__(self, cluster: Cluster):
        self.cluster = cluster
        self.ledger = JobLedger(cluster)
        # Optional telemetry sink (repro.core.contended_dataset.
        # TelemetryHarvester): measured bandwidths reported by live jobs are
        # recorded with their co-tenant context for online fine-tuning.
        self.harvester = None

    def dispatch(self, avail: Sequence[int], k: int, rng=None) -> Subset:
        raise NotImplementedError

    def report_bandwidth(self, job_id: str, bw: float) -> Optional[Allocation]:
        """Production telemetry entry point: a live job reports the
        collective bandwidth it actually measured.  Forwarded (with the
        job's current co-tenant ledger context) to the attached harvester;
        a no-op sink otherwise.  Returns the job's allocation, or None for
        a stale report (job already released — an ordinary race between a
        job's last measurement and its departure; the sample is dropped
        because its co-tenant context is gone).

        The lookup is a single atomic ``ledger.get`` — the historical
        ``in`` + ``allocation()`` pair was a TOCTOU that turns into a real
        KeyError once releases commit concurrently — and the harvest runs
        under the ledger lock so the co-tenant snapshot it records belongs
        to the same version as the allocation it saw."""
        with self.ledger.lock:
            alloc = self.ledger.get(job_id)
            if alloc is None:
                return None
            if self.harvester is not None:
                # job_id lets an attached DriftMonitor pair this realized
                # measurement with the B-hat stamped at admission
                self.harvester.observe(
                    self.ledger, alloc.gpus, bw,
                    job_id=job_id, source="report",
                )
        return alloc

    def admit(self, job_id: str, k: int, rng=None,
              tenant: str = "") -> Allocation:
        """Place a k-GPU job on currently-free GPUs and record it live.

        ``tenant`` tags the allocation (and its journal line) for
        per-tenant accounting — forensics regret, QoS — without affecting
        placement.

        Raises :class:`CapacityError` (queueable: retry at the next
        release) when too few GPUs are free, and
        :class:`InvalidPlacementError` (a policy bug: crash loudly, never
        queue) when the policy returns a subset violating the request.
        Both subclass ValueError, so legacy catch sites keep working.
        """
        avail = self.ledger.available()
        if k > len(avail):
            raise CapacityError(
                f"admit({job_id!r}, k={k}): only {len(avail)} GPUs free"
            )
        subset = self.dispatch(avail, k, rng=rng)
        if len(subset) != k or not set(subset) <= set(avail):
            raise InvalidPlacementError(
                f"{self.name} returned an invalid allocation for k={k}: "
                f"{subset}"
            )
        return self.ledger.admit(job_id, subset, tenant=tenant)

    def release(self, job_id: str) -> Allocation:
        """Free a live job's GPUs."""
        return self.ledger.release(job_id)


class BandPilotDispatcher(DispatcherService):
    """The full system: hierarchical surrogate + hybrid EHA/PTS search.

    ``contention_aware=True`` (default) wraps the predictor with the
    virtual-merge estimator, so ``admit`` degrades candidate scores by the
    fair-share rail capacity left next to live cross-host tenants.
    ``contention_mode="learned"`` (with a trained ``contended_predictor``)
    swaps the analytic cap for the ContendedSurrogate, so the search ranks
    candidates by *learned* contended bandwidth.  With an empty ledger both
    wrappers are an exact no-op, so single-shot ``dispatch`` behaviour (and
    the Sec. 5.3 harness) is unchanged.

    ``frag_weight > 0`` additionally applies the fragmentation tie-break
    (:func:`repro.core.defrag.make_frag_penalty`) to every search this
    dispatcher runs — near-equal candidates prefer topping up partially
    busy hosts over cracking open clean ones, keeping large blocks intact
    for future arrivals.  The default 0.0 is bit-identical to the previous
    behaviour.

    ``cache=True`` (the default) enables the dispatch fast path's
    prediction memo (:mod:`repro.core.predict_cache`): isolated B̂(S) —
    ledger-independent while the params are fixed — is memoized for the
    service lifetime, and contention-degraded scores are memoized per
    ledger version, so re-scoring the same subset within an admission is
    free and any admit/release invalidates by construction.  Cached values
    are stored predictor outputs, so subset selection is bit-identical with
    the cache on or off (regression-pinned in ``tests/test_fast_path.py``).

    ``aot_warm=True`` (the default) AOT-compiles the on-device elimination
    scan's hot shape buckets at construction (``warm_scan`` on the raw
    predictor, when present), eliminating the first-admission compile
    spike; the wall time spent is recorded in ``aot_warm_seconds`` so the
    throughput bench can report cold-start separately from warm latency.
    """

    def __init__(
        self,
        cluster: Cluster,
        tables: IntraHostTables,
        predictor,
        name: str = "BandPilot",
        contention_aware: bool = True,
        contention_mode: str = "analytic",
        contended_predictor=None,
        frag_weight: float = 0.0,
        cache: bool = True,
        aot_warm: bool = True,
    ):
        super().__init__(cluster)
        self.tables = tables
        self.raw_predictor = predictor
        self.contention_aware = contention_aware
        self.contention_mode = contention_mode
        self.contended_predictor = contended_predictor
        self.frag_weight = frag_weight
        self.iso_cache: Optional[PredictionCache] = None
        self.prediction_cache: Optional[PredictionCache] = None
        if cache:
            self.iso_cache = PredictionCache()  # ledger-independent memo
            predictor = self.iso_cache.wrap(
                predictor, mode="isolated", versioned=False
            )
        # base_predictor is what joint search / defrag proposers re-wrap per
        # scratch ledger: keeping the isolated memo inside it shares the
        # expensive inference across orders, trials, and passes.
        self.base_predictor = predictor
        if contention_aware:
            self.contention_predictor = ContentionAwarePredictor(
                cluster, predictor, self.ledger,
                mode=contention_mode, contended=contended_predictor,
            )
            if cache:
                self.prediction_cache = PredictionCache(self.ledger)
                self.predictor = self.prediction_cache.wrap(
                    self.contention_predictor, mode=contention_mode
                )
            else:
                self.predictor = self.contention_predictor
        else:
            self.predictor = predictor
        self.name = name
        self.last_result: Optional[search.HybridResult] = None
        # AOT-compile the on-device elimination scan's hot (bucket, H)
        # shapes now, at construction, so the first admission pays warm
        # per-descent latency instead of an XLA compile spike.  Predictors
        # without a scan path (naive featurizer, ground truth) expose no
        # ``warm_scan`` and skip this.
        self.aot_warm_seconds = 0.0
        if aot_warm:
            warm = getattr(self.raw_predictor, "warm_scan", None)
            if warm is not None:
                self.aot_warm_seconds = warm()

    def predictor_stats(self) -> PredictorStats:
        """Merged instrumentation across the dispatcher's predictor chain
        (cache wrappers, contention wrapper, base model) — what the
        benchmarks report per configuration."""
        return collect_stats(
            self.predictor, self.base_predictor,
            getattr(self, "contended_predictor", None),
        )

    def dispatch(self, avail: Sequence[int], k: int, rng=None) -> Subset:
        with telemetry.span(
            "dispatcher.dispatch", k=k, n_avail=len(avail),
            mode=self.contention_mode if self.contention_aware else "off",
        ) as sp:
            before = self.predictor_stats() if sp else None
            penalty = None
            if self.frag_weight > 0:
                from repro.core.defrag import make_frag_penalty

                penalty = make_frag_penalty(
                    self.cluster, self.ledger, self.frag_weight
                )
            res = search.hybrid_search(
                self.cluster, self.tables, self.predictor, avail, k,
                frag_penalty=penalty,
            )
            self.last_result = res
            df = forensics.draft()
            if df is not None:  # post-selection: cannot alter the choice
                df.note_decomposition(forensics.bandwidth_decomposition(
                    self.cluster, self.tables, self.ledger, res.subset,
                    self.base_predictor,
                    predicted_bw=float(res.predicted_bw),
                    contention_mode=(
                        self.contention_mode if self.contention_aware
                        else "off"
                    ),
                ))
            if sp:
                after = self.predictor_stats()
                sp["winner"] = res.winner
                sp["predicted_bw"] = res.predicted_bw
                sp["cache_hits"] = after.cache_hits - before.cache_hits
                sp["cache_misses"] = after.cache_misses - before.cache_misses
                sp["n_capped"] = after.n_capped - before.n_capped
                sp["n_model_calls"] = (
                    after.n_model_calls - before.n_model_calls
                )
                sp["n_scan_steps"] = after.n_scan_steps - before.n_scan_steps
            return res.subset


class BaselineDispatcher(DispatcherService):
    def __init__(self, cluster: Cluster, kind: str):
        super().__init__(cluster)
        self.name = {"random": "Random", "default": "Default", "topo": "Topo"}[kind]
        self.kind = kind
        self.needs_rng = kind == "random"

    def dispatch(self, avail: Sequence[int], k: int, rng=None) -> Subset:
        if self.kind == "random":
            if rng is None:
                raise ValueError("Random dispatcher needs an rng")
            return baselines.random_dispatch(self.cluster, avail, k, rng)
        if self.kind == "default":
            return baselines.default_dispatch(self.cluster, avail, k)
        return baselines.topo_dispatch(self.cluster, avail, k)


# ---------------------------------------------------------------------------
# Evaluation harness (Sec. 5.3 protocol)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EvalRecord:
    dispatcher: str
    k: int
    scenario: int
    gbe: float
    bw: float
    optimal_bw: float
    seconds: float


def evaluate_dispatchers(
    cluster: Cluster,
    sim: BandwidthSimulator,
    tables: IntraHostTables,
    dispatchers: Sequence,
    request_sizes: Optional[Sequence[int]] = None,
    n_scenarios: int = 50,
    seed: int = 0,
) -> List[EvalRecord]:
    """For every request size and availability scenario, run each dispatcher
    and grade it with GBE against the exact Oracle."""
    rng = np.random.default_rng(seed)
    if request_sizes is None:
        request_sizes = range(1, cluster.n_gpus + 1)
    records: List[EvalRecord] = []
    for k in request_sizes:
        for s in range(n_scenarios):
            avail = availability_scenario(cluster, rng)
            if len(avail) < k:
                avail = cluster.all_gpus()  # k must be satisfiable
            _, opt_bw = baselines.oracle_dispatch(cluster, sim, tables, avail, k)
            for d in dispatchers:
                t0 = time.time()
                subset = d.dispatch(avail, k, rng=rng)
                dt = time.time() - t0
                assert len(subset) == k and set(subset) <= set(avail), (
                    f"{d.name} returned invalid allocation"
                )
                bw = sim.true_bandwidth(subset)
                records.append(
                    EvalRecord(d.name, k, s, bw / opt_bw, bw, opt_bw, dt)
                )
    return records


def summarize(records: Sequence[EvalRecord]) -> Dict[str, Dict[str, float]]:
    """-> {dispatcher: {mean_gbe, mean_bw_loss, mean_seconds}} (Table 2)."""
    out: Dict[str, Dict[str, float]] = {}
    names = sorted({r.dispatcher for r in records})
    for name in names:
        rs = [r for r in records if r.dispatcher == name]
        out[name] = {
            "mean_gbe": float(np.mean([r.gbe for r in rs])),
            "mean_bw_loss": float(np.mean([r.optimal_bw - r.bw for r in rs])),
            "mean_seconds": float(np.mean([r.seconds for r in rs])),
            "n": len(rs),
        }
    return out


def gbe_by_k(records: Sequence[EvalRecord]) -> Dict[str, Dict[int, float]]:
    """-> {dispatcher: {k: mean_gbe}} (Fig. 6 curves)."""
    out: Dict[str, Dict[int, float]] = {}
    for r in records:
        out.setdefault(r.dispatcher, {}).setdefault(r.k, []).append(r.gbe)
    return {
        name: {k: float(np.mean(v)) for k, v in sorted(ks.items())}
        for name, ks in out.items()
    }


def bw_loss_by_k(records: Sequence[EvalRecord]) -> Dict[str, Dict[int, float]]:
    """-> {dispatcher: {k: mean bandwidth loss vs oracle}} (Fig. 7)."""
    out: Dict[str, Dict[int, List[float]]] = {}
    for r in records:
        out.setdefault(r.dispatcher, {}).setdefault(r.k, []).append(
            r.optimal_bw - r.bw
        )
    return {
        name: {k: float(np.mean(v)) for k, v in sorted(ks.items())}
        for name, ks in out.items()
    }


# ---------------------------------------------------------------------------
# Multi-tenant trace harness (Sec. 4.4 protocol)
# ---------------------------------------------------------------------------
# TraceJob / TenantRecord / poisson_trace / summarize_trace live in
# repro.core.scheduler (imported above); replay_trace remains here as the
# legacy entry point.

def replay_trace(
    cluster: Cluster,
    sim: BandwidthSimulator,
    tables: IntraHostTables,
    dispatcher: DispatcherService,
    trace: Sequence[TraceJob],
    rng: Optional[np.random.Generator] = None,
    config: Optional[SchedulerConfig] = None,
) -> List[TenantRecord]:
    """Stream a trace through one dispatcher service, grading each admission.

    Thin wrapper over :class:`repro.core.scheduler.AdmissionScheduler`.  The
    default ``fifo`` config reproduces the historical behaviour bit-for-bit
    (regression-pinned in ``tests/test_scheduler.py``): arrivals in time
    order, departures release GPUs, jobs that do not fit wait in a FIFO
    queue (head-of-line) and are admitted at the release that frees enough
    capacity.  Pass a :class:`SchedulerConfig` for backfill/batched queue
    policies or release-time re-dispatch.
    """
    sched = AdmissionScheduler(
        cluster, sim, tables, dispatcher, config=config, rng=rng
    )
    return sched.run(trace)


def compare_contention_awareness(
    cluster: Cluster,
    sim: BandwidthSimulator,
    tables: IntraHostTables,
    predictor_factory: Callable[[], object],
    trace: Sequence[TraceJob],
    seed: int = 0,
    name: str = "BandPilot",
    include_baselines: bool = True,
) -> Dict[str, List[TenantRecord]]:
    """Replay one trace through contention-aware vs -oblivious BandPilot plus
    (optionally) the three baselines (fresh rng per replay: identical
    arrivals, identical randomness).  -> {variant name: records}."""
    out: Dict[str, List[TenantRecord]] = {}
    variants: List[DispatcherService] = [
        BandPilotDispatcher(
            cluster, tables, predictor_factory(), name=name,
            contention_aware=True,
        ),
        BandPilotDispatcher(
            cluster, tables, predictor_factory(), name=f"{name}-oblivious",
            contention_aware=False,
        ),
    ]
    if include_baselines:
        variants += [
            BaselineDispatcher(cluster, "topo"),
            BaselineDispatcher(cluster, "default"),
            BaselineDispatcher(cluster, "random"),
        ]
    for disp in variants:
        rng = np.random.default_rng(seed)
        out[disp.name] = replay_trace(cluster, sim, tables, disp, trace, rng=rng)
    return out
