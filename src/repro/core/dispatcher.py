"""BandPilot dispatcher service + evaluation harness (Secs. 4.1, 5.3).

The ``Dispatcher`` interface is what the rest of the framework consumes
(``repro.launch`` builds meshes from dispatched device sets).  The harness
reproduces the paper's protocol: randomized availability scenarios, request
sizes 1..N, GBE = B(S_sol) / B(S*) against the exact Oracle.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import baselines, search
from repro.core.bandwidth_sim import BandwidthSimulator
from repro.core.cluster import Cluster, availability_scenario
from repro.core.intra_host import IntraHostTables
from repro.core.surrogate import SurrogatePredictor

Subset = List[int]


class GroundTruthPredictor:
    """Predictor view over the true B(S) — powers Ideal-BP and the Oracle
    comparisons (isolates search quality from surrogate error)."""

    def __init__(self, sim: BandwidthSimulator):
        self.sim = sim
        self.n_model_calls = 0
        self.predict_seconds = 0.0

    def predict(self, subsets: Sequence[Sequence[int]]) -> np.ndarray:
        t0 = time.time()
        out = np.asarray([self.sim.true_bandwidth(s) for s in subsets])
        self.predict_seconds += time.time() - t0
        self.n_model_calls += len(subsets)
        return out


class BandPilotDispatcher:
    """The full system: hierarchical surrogate + hybrid EHA/PTS search."""

    def __init__(
        self,
        cluster: Cluster,
        tables: IntraHostTables,
        predictor,
        name: str = "BandPilot",
    ):
        self.cluster = cluster
        self.tables = tables
        self.predictor = predictor
        self.name = name
        self.last_result: Optional[search.HybridResult] = None

    def dispatch(self, avail: Sequence[int], k: int, rng=None) -> Subset:
        res = search.hybrid_search(
            self.cluster, self.tables, self.predictor, avail, k
        )
        self.last_result = res
        return res.subset


class BaselineDispatcher:
    def __init__(self, cluster: Cluster, kind: str):
        self.cluster = cluster
        self.name = {"random": "Random", "default": "Default", "topo": "Topo"}[kind]
        self.kind = kind

    def dispatch(self, avail: Sequence[int], k: int, rng=None) -> Subset:
        if self.kind == "random":
            assert rng is not None
            return baselines.random_dispatch(self.cluster, avail, k, rng)
        if self.kind == "default":
            return baselines.default_dispatch(self.cluster, avail, k)
        return baselines.topo_dispatch(self.cluster, avail, k)


# ---------------------------------------------------------------------------
# Evaluation harness (Sec. 5.3 protocol)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EvalRecord:
    dispatcher: str
    k: int
    scenario: int
    gbe: float
    bw: float
    optimal_bw: float
    seconds: float


def evaluate_dispatchers(
    cluster: Cluster,
    sim: BandwidthSimulator,
    tables: IntraHostTables,
    dispatchers: Sequence,
    request_sizes: Optional[Sequence[int]] = None,
    n_scenarios: int = 50,
    seed: int = 0,
) -> List[EvalRecord]:
    """For every request size and availability scenario, run each dispatcher
    and grade it with GBE against the exact Oracle."""
    rng = np.random.default_rng(seed)
    if request_sizes is None:
        request_sizes = range(1, cluster.n_gpus + 1)
    records: List[EvalRecord] = []
    for k in request_sizes:
        for s in range(n_scenarios):
            avail = availability_scenario(cluster, rng)
            if len(avail) < k:
                avail = cluster.all_gpus()  # k must be satisfiable
            _, opt_bw = baselines.oracle_dispatch(cluster, sim, tables, avail, k)
            for d in dispatchers:
                t0 = time.time()
                subset = d.dispatch(avail, k, rng=rng)
                dt = time.time() - t0
                assert len(subset) == k and set(subset) <= set(avail), (
                    f"{d.name} returned invalid allocation"
                )
                bw = sim.true_bandwidth(subset)
                records.append(
                    EvalRecord(d.name, k, s, bw / opt_bw, bw, opt_bw, dt)
                )
    return records


def summarize(records: Sequence[EvalRecord]) -> Dict[str, Dict[str, float]]:
    """-> {dispatcher: {mean_gbe, mean_bw_loss, mean_seconds}} (Table 2)."""
    out: Dict[str, Dict[str, float]] = {}
    names = sorted({r.dispatcher for r in records})
    for name in names:
        rs = [r for r in records if r.dispatcher == name]
        out[name] = {
            "mean_gbe": float(np.mean([r.gbe for r in rs])),
            "mean_bw_loss": float(np.mean([r.optimal_bw - r.bw for r in rs])),
            "mean_seconds": float(np.mean([r.seconds for r in rs])),
            "n": len(rs),
        }
    return out


def gbe_by_k(records: Sequence[EvalRecord]) -> Dict[str, Dict[int, float]]:
    """-> {dispatcher: {k: mean_gbe}} (Fig. 6 curves)."""
    out: Dict[str, Dict[int, float]] = {}
    for r in records:
        out.setdefault(r.dispatcher, {}).setdefault(r.k, []).append(r.gbe)
    return {
        name: {k: float(np.mean(v)) for k, v in sorted(ks.items())}
        for name, ks in out.items()
    }


def bw_loss_by_k(records: Sequence[EvalRecord]) -> Dict[str, Dict[int, float]]:
    """-> {dispatcher: {k: mean bandwidth loss vs oracle}} (Fig. 7)."""
    out: Dict[str, Dict[int, List[float]]] = {}
    for r in records:
        out.setdefault(r.dispatcher, {}).setdefault(r.k, []).append(
            r.optimal_bw - r.bw
        )
    return {
        name: {k: float(np.mean(v)) for k, v in sorted(ks.items())}
        for name, ks in out.items()
    }
