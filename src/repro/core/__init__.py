"""BandPilot core: the paper's contribution as a composable library.

Public surface:
  Cluster / bandwidth simulation:
    cluster.Cluster, cluster.PAPER_CLUSTERS, bandwidth_sim.BandwidthSimulator
  Hierarchical surrogate (Sec. 4.2):
    intra_host.IntraHostTables, surrogate.SurrogatePredictor,
    training.train_surrogate / online_finetune / evaluate_surrogate
  Hybrid search (Sec. 4.3):
    search.eha_search / pts_search / hybrid_search
  Dispatchers + evaluation (Sec. 5):
    dispatcher.BandPilotDispatcher / BaselineDispatcher / evaluate_dispatchers,
    baselines.oracle_dispatch
  Multi-tenant contention (Sec. 4.4):
    tenancy.JobLedger / Allocation, contention.ContentionAwarePredictor /
    virtual_merge, dispatcher.replay_trace / poisson_trace /
    compare_contention_awareness (admit/release service lifecycle)
  Admission scheduling (queue policies, joint batching, re-dispatch):
    scheduler.AdmissionScheduler / SchedulerConfig / compare_policies /
    migration_cost, search.joint_hybrid_search
  Learned contention (trained contended surrogate + telemetry pipeline):
    contended_dataset.build_contended_dataset / make_contended_split /
    TelemetryHarvester / harvest_trace, surrogate.ContendedSurrogatePredictor,
    training.train_contended_surrogate / online_finetune_contended /
    evaluate_contended_predictor, ContentionAwarePredictor(mode="learned")
  Defragmentation (metrics, consolidation planner, scheduler triggers):
    defrag.fragmentation_metrics / FragmentationMetrics, plan_defrag /
    apply_plan / DefragConfig, evaluate_move / net_migration_gain (shared
    migration economics), make_frag_penalty (placement tie-break),
    SchedulerConfig(defrag=True)
  Dispatch fast path (vectorized featurization + ledger-versioned memos):
    predict_cache.PredictionCache / CachedPredictor / GradingCache /
    PredictorStats / cached_contention_predictor, features.featurize_batch
    (vectorized) / featurize_children (incremental PTS rounds),
    BandPilotDispatcher(cache=True), JobLedger.version
  Concurrent-admission control plane (CAS admissions, journal, QoS):
    controlplane.AdmissionControlPlane / AdmissionOutcome / TenantPolicy,
    LedgerJournal / read_journal / replay_journal, JobLedger.admit_if /
    migrate / get, CapacityError / InvalidPlacementError / VersionConflict,
    SchedulerConfig(tenant_policies=..., concurrent_workers=...,
    journal_path=...)
  Observability (admission tracer, metrics registry, drift recorder):
    telemetry.AdmissionTracer / trace / span, MetricsRegistry /
    collect_scheduler_metrics / read_metrics_jsonl, DriftMonitor /
    DriftAlert / DecisionRecord / finetune_on_drift,
    TelemetryHarvester(drift=...) (see docs/observability.md)
  Dispatch forensics (attribution, time-travel, counterfactual replay):
    forensics.DossierRecorder / capture / DecisionDossier,
    reconstruct / replay_decision / whatif, RegretLedger / absorb_regret,
    bandwidth_decomposition (see docs/observability.md §Forensics)
"""

from repro.core.bandwidth_sim import BW_SCALE, BandwidthSimulator
from repro.core.contended_dataset import (
    ContendedSample,
    TelemetryHarvester,
    build_contended_dataset,
    harvest_trace,
    make_contended_split,
    materialize_ledger,
    sample_cotenant_ledger,
    to_triples,
)
from repro.core.contention import (
    ContentionAwarePredictor,
    MergeView,
    contended_inter_cap,
    virtual_merge,
)
from repro.core.defrag import (
    DefragConfig,
    DefragPlan,
    FragmentationMetrics,
    MoveEval,
    apply_plan,
    consolidation_proposer,
    evaluate_move,
    evaluate_placement,
    forced_rail_contended,
    fragmentation_metrics,
    hybrid_proposer,
    is_consolidating,
    make_frag_penalty,
    net_migration_gain,
    plan_defrag,
    room_makeable,
)
from repro.core.cluster import (
    Cluster,
    PAPER_CLUSTERS,
    h100_cluster,
    het_4mix_cluster,
    het_ra_cluster,
    het_va_cluster,
    tpu_pod_cluster,
)
from repro.core.dispatcher import (
    BandPilotDispatcher,
    BaselineDispatcher,
    DispatcherService,
    GroundTruthPredictor,
    bw_loss_by_k,
    compare_contention_awareness,
    evaluate_dispatchers,
    gbe_by_k,
    replay_trace,
    summarize,
)
from repro.core.controlplane import (
    AdmissionControlPlane,
    AdmissionOutcome,
    ControlPlaneStats,
    JournalEvent,
    LedgerJournal,
    TenantPolicy,
    read_journal,
    replay_journal,
)
from repro.core.forensics import (
    DecisionDossier,
    DossierRecorder,
    RegretLedger,
    ReplayResult,
    WhatIfReport,
    absorb_regret,
    bandwidth_decomposition,
    reconstruct,
    replay_decision,
    whatif,
)
from repro.core.intra_host import IntraHostTables
from repro.core.predict_cache import (
    CachedPredictor,
    GradingCache,
    PredictionCache,
    PredictorStats,
    cached_contention_predictor,
    collect_stats,
)
from repro.core.scheduler import (
    AdmissionScheduler,
    MigrationEvent,
    SchedulerConfig,
    TenantRecord,
    TraceJob,
    compare_policies,
    migration_cost,
    poisson_trace,
    summarize_trace,
)
from repro.core.telemetry import (
    AdmissionTracer,
    DecisionRecord,
    DriftAlert,
    DriftMonitor,
    MetricsRegistry,
    collect_scheduler_metrics,
    finetune_on_drift,
    read_metrics_jsonl,
    snapshot_digest,
)
from repro.core.tenancy import (
    Allocation,
    CapacityError,
    InvalidPlacementError,
    JobLedger,
    VersionConflict,
)
from repro.core.search import (
    eha_search,
    hybrid_search,
    joint_hybrid_search,
    pts_search,
)
from repro.core.surrogate import (
    ContendedSurrogatePredictor,
    SurrogatePredictor,
    init_contended_params,
)
from repro.core.training import (
    TrainConfig,
    evaluate_analytic_cap,
    evaluate_contended_predictor,
    evaluate_surrogate,
    make_train_test_split,
    online_finetune,
    online_finetune_contended,
    train_contended_surrogate,
    train_surrogate,
)

__all__ = [
    "BW_SCALE",
    "BandwidthSimulator",
    "Cluster",
    "PAPER_CLUSTERS",
    "h100_cluster",
    "het_4mix_cluster",
    "het_ra_cluster",
    "het_va_cluster",
    "tpu_pod_cluster",
    "BandPilotDispatcher",
    "BaselineDispatcher",
    "DispatcherService",
    "GroundTruthPredictor",
    "bw_loss_by_k",
    "evaluate_dispatchers",
    "gbe_by_k",
    "summarize",
    "Allocation",
    "JobLedger",
    "CapacityError",
    "InvalidPlacementError",
    "VersionConflict",
    "AdmissionControlPlane",
    "AdmissionOutcome",
    "ControlPlaneStats",
    "JournalEvent",
    "LedgerJournal",
    "TenantPolicy",
    "read_journal",
    "replay_journal",
    "ContentionAwarePredictor",
    "MergeView",
    "contended_inter_cap",
    "virtual_merge",
    "TenantRecord",
    "TraceJob",
    "compare_contention_awareness",
    "poisson_trace",
    "replay_trace",
    "summarize_trace",
    "AdmissionScheduler",
    "MigrationEvent",
    "SchedulerConfig",
    "compare_policies",
    "migration_cost",
    "DefragConfig",
    "DefragPlan",
    "FragmentationMetrics",
    "MoveEval",
    "apply_plan",
    "consolidation_proposer",
    "evaluate_move",
    "evaluate_placement",
    "forced_rail_contended",
    "fragmentation_metrics",
    "hybrid_proposer",
    "is_consolidating",
    "make_frag_penalty",
    "room_makeable",
    "net_migration_gain",
    "plan_defrag",
    "IntraHostTables",
    "CachedPredictor",
    "GradingCache",
    "PredictionCache",
    "PredictorStats",
    "cached_contention_predictor",
    "collect_stats",
    "eha_search",
    "hybrid_search",
    "joint_hybrid_search",
    "pts_search",
    "SurrogatePredictor",
    "ContendedSurrogatePredictor",
    "init_contended_params",
    "AdmissionTracer",
    "DecisionRecord",
    "DriftAlert",
    "DriftMonitor",
    "MetricsRegistry",
    "collect_scheduler_metrics",
    "finetune_on_drift",
    "read_metrics_jsonl",
    "snapshot_digest",
    "DecisionDossier",
    "DossierRecorder",
    "RegretLedger",
    "ReplayResult",
    "WhatIfReport",
    "absorb_regret",
    "bandwidth_decomposition",
    "reconstruct",
    "replay_decision",
    "whatif",
    "ContendedSample",
    "TelemetryHarvester",
    "build_contended_dataset",
    "harvest_trace",
    "make_contended_split",
    "materialize_ledger",
    "sample_cotenant_ledger",
    "to_triples",
    "TrainConfig",
    "evaluate_surrogate",
    "evaluate_analytic_cap",
    "evaluate_contended_predictor",
    "make_train_test_split",
    "online_finetune",
    "online_finetune_contended",
    "train_contended_surrogate",
    "train_surrogate",
]
