"""Failure-domain subsystem: typed fault events, seeded schedules, and the
injector that threads them through the :class:`~repro.core.tenancy.JobLedger`.

The fault model is deliberately small — four event kinds cover the failure
patterns that dominate multi-tenant GPU clusters (the regime of
arXiv:2207.07817's ring-all-reduce co-scheduling study):

``gpu_down``
    One or more GPUs die.  Dead GPUs are unplaceable: the ledger's
    ``available()`` excludes them, ``admit``/``migrate`` refuse them, and
    the ground truth returns 0.0 for any subset that touches one.
``host_down``
    Every GPU on a host dies at once (PSU / kernel panic).  Semantically a
    ``gpu_down`` over the whole host; kept distinct so schedules, spans and
    dossiers carry the blast radius.
``nic_flap``
    Transient: the host's NIC rail degrades by ``factor`` until
    ``t_recover``.  Jobs on the host keep running (degraded); the recovery
    pipeline prices wait-out vs migrate against expected downtime.
``link_degrade``
    Persistent multiplicative ``factor`` on a host's rail/NIC bandwidth
    (until an explicit ``recover`` event, if the schedule emits one).

Health is a four-state lattice per GPU — ``healthy < degraded <
quarantined < dead`` — stored sparsely on the ledger (absent == healthy)
under the existing version counter, so every fault/recover bumps
``ledger.version`` and invalidates prediction caches, snapshots and CAS
commits exactly like an admission would.  ``fault``/``recover`` are
journaled event kinds in the same canonical-JSON + crc32 grammar as
admit/release/migrate, so :func:`~repro.core.controlplane.replay_journal`
rebuilds post-fault state bit-identically, torn tails included.

Everything here is value-neutral when unused: a ledger that has never seen
a fault reports ``health_active == False`` and every consumer (simulator,
features, analytic cap, scheduler) takes its pre-existing byte-identical
path.  See ``docs/faults.md``.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .cluster import Cluster

# The health lattice, weakest to strongest.  Transitions only ever move a
# GPU *up* the lattice within one fault application; recovery pops states
# explicitly (see JobLedger.apply_recover) so the order is deterministic
# and journal replay reproduces it exactly.
HEALTH_STATES: Tuple[str, ...] = ("healthy", "degraded", "quarantined", "dead")

FAULT_KINDS: Tuple[str, ...] = ("gpu_down", "host_down", "nic_flap", "link_degrade")

#: kinds whose recovery the schedule generator emits automatically
_TRANSIENT: Tuple[str, ...] = ("nic_flap", "gpu_down", "host_down", "link_degrade")


@dataclass(frozen=True)
class FaultEvent:
    """One typed fault (or its recovery).  ``t_recover`` is the absolute
    time the matching ``recover`` event fires; ``None`` means permanent
    (no recovery is scheduled)."""

    t: float
    kind: str                       # one of FAULT_KINDS
    host_id: int
    gpus: Tuple[int, ...] = ()      # global GPU ids (gpu_down / host_down)
    factor: float = 1.0             # rail multiplier (nic_flap / link_degrade)
    t_recover: Optional[float] = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind in ("nic_flap", "link_degrade") and not (
            0.0 < self.factor <= 1.0
        ):
            raise ValueError("factor must be in (0, 1] for degrade events")
        if self.t_recover is not None and self.t_recover <= self.t:
            raise ValueError("t_recover must be strictly after t")

    @property
    def transient(self) -> bool:
        return self.t_recover is not None


@dataclass
class FaultSchedule:
    """A deterministic, seeded storm: a time-sorted list of
    :class:`FaultEvent`.  Two schedules built with the same (cluster,
    seed, knobs) are element-wise identical — the generator draws from a
    single ``np.random.default_rng(seed)`` stream in a fixed order."""

    events: List[FaultEvent] = field(default_factory=list)

    def __iter__(self):
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    @staticmethod
    def generate(
        cluster: Cluster,
        *,
        seed: int,
        n_events: int = 3,
        t_start: float = 0.0,
        t_end: float = 100.0,
        kinds: Sequence[str] = FAULT_KINDS,
        mean_downtime: float = 20.0,
        degrade_range: Tuple[float, float] = (0.3, 0.8),
        recover: bool = True,
    ) -> "FaultSchedule":
        """Draw ``n_events`` faults uniformly over ``[t_start, t_end)``.

        With ``recover=True`` (default) every event carries a
        ``t_recover`` drawn from an exponential with mean
        ``mean_downtime`` — so a scheduler consuming the storm always
        drains.  ``recover=False`` leaves gpu_down/host_down/link_degrade
        permanent (nic_flap is transient by definition and always gets a
        recovery time).
        """
        for k in kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        for _ in range(int(n_events)):
            t = float(rng.uniform(t_start, t_end))
            kind = str(kinds[int(rng.integers(len(kinds)))])
            hid = int(rng.integers(len(cluster.hosts)))
            host = cluster.hosts[hid]
            gpus: Tuple[int, ...] = ()
            factor = 1.0
            if kind == "gpu_down":
                n = int(rng.integers(1, max(2, host.n_gpus // 2 + 1)))
                picks = rng.choice(host.n_gpus, size=n, replace=False)
                gpus = tuple(sorted(int(host.gpu_ids[i]) for i in picks))
            elif kind == "host_down":
                gpus = tuple(int(g) for g in host.gpu_ids)
            else:
                factor = float(rng.uniform(*degrade_range))
            t_rec: Optional[float] = None
            if recover or kind == "nic_flap":
                t_rec = t + max(1e-6, float(rng.exponential(mean_downtime)))
            events.append(
                FaultEvent(
                    t=t, kind=kind, host_id=hid, gpus=gpus,
                    factor=factor, t_recover=t_rec,
                )
            )
        events.sort(key=lambda e: (e.t, e.host_id, e.kind))
        return FaultSchedule(events)


class FaultInjector:
    """Applies :class:`FaultEvent`\\ s to a ledger (journaled, versioned)
    and undoes them at recovery time.  Stateless beyond the ledger — the
    ledger's sparse health maps are the single source of truth, which is
    what makes journal replay rebuild post-fault state bit-identically.
    """

    def __init__(self, ledger):
        self.ledger = ledger
        self.n_applied = 0
        self.n_recovered = 0

    def apply(self, ev: FaultEvent) -> None:
        self.ledger.apply_fault(
            ev.kind, gpus=ev.gpus, host_id=ev.host_id, factor=ev.factor
        )
        self.n_applied += 1

    def recover(self, ev: FaultEvent) -> None:
        self.ledger.apply_recover(ev.kind, gpus=ev.gpus, host_id=ev.host_id)
        self.n_recovered += 1

    def affected_jobs(self, ev: FaultEvent) -> Dict[str, Tuple[int, ...]]:
        """Live jobs whose allocation touches a GPU this event killed or
        quarantined — the set the recovery pipeline must requeue.  Degrade
        events (nic_flap / link_degrade) leave jobs in place, so they
        return an empty dict; the wait-vs-migrate policy handles those."""
        if ev.kind not in ("gpu_down", "host_down"):
            return {}
        hit = set(ev.gpus)
        if ev.kind == "host_down" and not hit and ev.host_id is not None:
            # empty gpus means the whole host (mirrors apply_fault's
            # fallback) — the blast radius is every GPU the host carries
            hit = set(self.ledger.cluster.hosts[ev.host_id].gpu_ids)
        out: Dict[str, Tuple[int, ...]] = {}
        for alloc in list(self.ledger.jobs()):
            if hit.intersection(alloc.gpus):
                out[alloc.job_id] = alloc.gpus
        return out


@dataclass(frozen=True)
class RecoveryOutcome:
    """One requeued tenant's journey through the recovery pipeline —
    sealed into metrics (MTTR) and forensics dossiers."""

    job_id: str
    t_fault: float
    t_readmitted: float
    attempts: int
    kind: str
    gave_up: bool = False

    @property
    def mttr(self) -> float:
        return self.t_readmitted - self.t_fault


def expected_downtime(ev: FaultEvent, now: float, default: float = 20.0) -> float:
    """Remaining downtime of a transient event as seen at ``now`` — the
    price of *waiting out* a nic_flap instead of migrating off the host."""
    if ev.t_recover is None:
        return default
    return max(0.0, ev.t_recover - now)


def install_degraded_fallback(monitor, predictor) -> Callable:
    """Wire graceful degradation through the :class:`DriftMonitor`: when
    mispredictions on health-perturbed fabric trip a drift alert, force
    the contention-aware predictor onto its analytic cap (the learned
    surrogate never trained on degraded rails, so its errors there are
    structural, not noise).  Returns the installed hook.  Chains any
    pre-existing ``on_alert`` (e.g. ``finetune_on_drift``)."""
    prev = getattr(monitor, "on_alert", None)

    def _hook(alert):
        ledger = getattr(predictor, "ledger", None)
        if ledger is not None and getattr(ledger, "health_active", False):
            predictor.force_analytic = True
        if prev is not None:
            prev(alert)

    monitor.on_alert = _hook
    return _hook
