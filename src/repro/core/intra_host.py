"""Stage-1 of the hierarchical surrogate: exhaustive intra-host lookup tables.

The paper (Sec. 4.2.1) measures end-to-end collective bandwidth for *all*
2^8 - 1 = 255 non-empty GPU combinations of every host once, offline, and
stores them in per-host key-value dictionaries (~12 KB each).  The same
tables power:

  * Stage-1 of the hierarchical surrogate (perfect intra-host features),
  * EHA's single-host prioritization (best k-subset on one host),
  * PTS's node-insertion pruning,
  * the exact Oracle (per-count best subsets, see baselines.py).
"""

from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bandwidth_sim import BandwidthSimulator
from repro.core.cluster import Cluster

LocalSubset = Tuple[int, ...]


class IntraHostTables:
    """Per-host-instance dictionaries: local GPU subset -> measured bandwidth."""

    def __init__(self, cluster: Cluster, sim: BandwidthSimulator):
        self.cluster = cluster
        self.tables: List[Dict[LocalSubset, float]] = []
        # measurement_seconds mirrors the paper's Table 3 cost accounting:
        # one nccl-tests invocation per combination (few seconds each).
        self.n_measurements = 0
        for host in cluster.hosts:
            table: Dict[LocalSubset, float] = {}
            n = host.n_gpus
            for size in range(1, n + 1):
                for sub in itertools.combinations(range(n), size):
                    table[sub] = sim.intra_bandwidth(host.host_id, sub)
                    self.n_measurements += 1
            self.tables.append(table)
        # best-subset-by-count index used by oracle/EHA:
        #   best[host_id][n] = (bw, subset) over *all* local subsets of size n
        self._best_full: List[Dict[int, Tuple[float, LocalSubset]]] = []
        for host in cluster.hosts:
            per_n: Dict[int, Tuple[float, LocalSubset]] = {}
            for sub, bw in self.tables[host.host_id].items():
                n = len(sub)
                if n not in per_n or bw > per_n[n][0]:
                    per_n[n] = (bw, sub)
            self._best_full.append(per_n)

    # -- queries --------------------------------------------------------------

    def lookup(self, host_id: int, local_subset: Sequence[int]) -> float:
        return self.tables[host_id][tuple(sorted(local_subset))]

    def lookup_global(self, gpu_ids: Sequence[int]) -> float:
        """Lookup for a set of *global* ids known to live on one host."""
        hid = self.cluster.gpu_host[gpu_ids[0]]
        return self.lookup(hid, self.cluster.local_tuple(hid, gpu_ids))

    def best_subset(
        self, host_id: int, n: int, avail_locals: Optional[Sequence[int]] = None
    ) -> Tuple[float, LocalSubset]:
        """Best bandwidth n-subset on a host, optionally restricted to
        available local indices.  Returns (bw, local_subset)."""
        if avail_locals is None:
            return self._best_full[host_id][n]
        avail = tuple(sorted(avail_locals))
        if len(avail) < n:
            raise ValueError(f"host {host_id}: {len(avail)} available < {n}")
        if len(avail) == self.cluster.hosts[host_id].n_gpus:
            return self._best_full[host_id][n]
        table = self.tables[host_id]
        best_bw, best_sub = -1.0, None
        for sub in itertools.combinations(avail, n):
            bw = table[sub]
            if bw > best_bw:
                best_bw, best_sub = bw, sub
        return best_bw, best_sub

    def to_globals(self, host_id: int, local_subset: Sequence[int]) -> List[int]:
        host = self.cluster.hosts[host_id]
        return [host.gpu_ids[i] for i in local_subset]

    def storage_bytes(self) -> int:
        """~12 KB per 8-GPU host, as reported in Sec. 5.4."""
        total = 0
        for table in self.tables:
            # key: packed bitmask (2 bytes) + float32 value + dict overhead
            # approximated at the paper's accounting of ~48 B/entry
            total += len(table) * 48
        return total
