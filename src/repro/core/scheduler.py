"""Event-driven admission scheduler: queue policies over the dispatch service.

PR 1 made dispatching stateful (``DispatcherService`` over a
:class:`~repro.core.tenancy.JobLedger`), but the trace harness still admitted
strictly FIFO: one job at a time against a stale ledger, with head-of-line
blocking.  This module owns the queue and the clock — the event loop that
used to be hard-coded inside ``replay_trace`` — and makes the admission
*policy* pluggable:

* ``fifo`` — bit-for-bit the legacy behaviour: arrivals admit in order, a
  job that does not fit blocks everything behind it (regression-pinned in
  ``tests/test_scheduler.py``).
* ``backfill`` — smaller waiting jobs may overtake a blocked job, guarded
  by an **aging bound**: every overtake increments the skipped jobs'
  counters, and a job whose counter reaches ``aging_limit`` becomes a hard
  fence that nothing behind it may pass, so nothing starves.
* ``batched`` — arrivals within ``batch_window`` of each other form a
  batch.  Batches drain strictly FIFO, but *within* the head batch jobs may
  be selected and placed **jointly** (``search.joint_hybrid_search``): the
  batch is ordered, a scratch ledger is threaded through per-job hybrid
  searches so each placement sees its batch-mates as live co-tenants, and
  the order with the best total contention-degraded estimate wins.  A job
  arriving to spare capacity with an empty queue is never held back, so the
  window costs no latency; with ``batch_window=0`` every batch is a
  singleton placed in arrival order and the policy degenerates to ``fifo``
  exactly.

On every ``release`` the scheduler can additionally run an **elastic
re-dispatch hook** (``redispatch=True``): among the live cross-host jobs it
re-places the one whose contention-degraded bandwidth would improve the
most, charged with a migration-cost term (``migration_cost``, shared with
:mod:`repro.ft.elastic`), and only if no other live job's degraded
bandwidth drops.  A declined move restores the exact prior placement.
The trial-move machinery (gain rule, no-harm check, exact ledger restore)
lives in :mod:`repro.core.defrag` and is shared with the **defragmentation
triggers** (``defrag=True``): a rate-limited background consolidation pass
at release time plus an on-demand make-room pass when an admission would
otherwise be forced into a cross-host rail-contended placement that a
cheap consolidation could avoid (see ``docs/defrag.md``).

``repro.core.dispatcher.replay_trace`` is now a thin wrapper over this
module with the ``fifo`` policy.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (
    baselines,
    defrag as defrag_mod,
    faults as faults_mod,
    forensics,
    search,
    telemetry,
)
from repro.core.bandwidth_sim import BandwidthSimulator
from repro.core.cluster import Cluster
from repro.core.controlplane import TenantPolicy  # per-tenant QoS rows
from repro.core.defrag import (  # shared migration economics (moved there)
    DefragConfig,
    migration_cost,
)
from repro.core.intra_host import IntraHostTables
from repro.core.predict_cache import GradingCache, InferenceBatcher
from repro.core.tenancy import Allocation, InvalidPlacementError, JobLedger

Subset = List[int]

POLICIES = ("fifo", "backfill", "batched")


# ---------------------------------------------------------------------------
# Trace model (moved here from dispatcher.py; re-exported there)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TraceJob:
    """One job of a tenancy trace: arrives, holds k GPUs, departs.

    ``tenant`` attributes the job to a QoS policy row
    (``SchedulerConfig(tenant_policies=...)``); the default "" tenant has
    no policy, so legacy traces behave exactly as before."""

    job_id: str
    arrival: float
    duration: float
    k: int
    tenant: str = ""


@dataclasses.dataclass
class TenantRecord:
    """Grading of one admission under the live ledger at admit time."""

    dispatcher: str
    job_id: str
    k: int
    t_admit: float
    wait: float            # t_admit - arrival (queueing delay)
    gbe: float             # contention-degraded B(S) / B(S*_ledger)
    bw: float              # contention-degraded B(S | ledger)
    isolated_bw: float     # B(S) with co-tenants ignored
    optimal_bw: float      # ledger-aware exact-Oracle bandwidth
    n_live: int            # live jobs at admit time (excl. this one)
    n_contended_hosts: int  # hosts where S's rails are shared (0 unless S is
    #                         cross-host: single-host jobs never touch a NIC)
    # -- queue-policy fields (defaults keep legacy constructions valid) -----
    policy: str = "fifo"   # admission policy that placed this job
    overtakes: int = 0     # waiting jobs this admission jumped ahead of
    batch_size: int = 1    # jobs co-admitted in the same joint flush
    migrations: int = 0    # times this job was re-placed while live
    # -- fragmentation state right after this admission (defrag metrics) ----
    stranding: float = 0.0  # fraction of free GPUs on partially-busy hosts
    clean_hosts: int = 0    # fully-free hosts left after this admission
    # -- observability: the B-hat the search committed on (NaN for baseline
    #    dispatchers that place without a predictor) — paired with ``bw`` by
    #    the drift flight recorder (docs/observability.md)
    predicted_bw: float = float("nan")

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def poisson_trace(
    cluster: Cluster,
    n_jobs: int,
    rng: np.random.Generator,
    mean_interarrival: float = 1.0,
    mean_duration: float = 4.0,
    k_choices: Optional[Sequence[int]] = None,
) -> List[TraceJob]:
    """Seeded Poisson arrival process with exponential durations.

    ``k_choices`` defaults to 2..max(n_gpus/2, 3), clamped to the cluster
    size: large enough that placements regularly span hosts (the
    contention-relevant regime) while — on the paper-scale clusters —
    several jobs fit concurrently.  Pass explicit ``k_choices`` on clusters
    below ~6 GPUs, where the default load serializes.
    """
    if k_choices is None:
        hi = min(max(cluster.n_gpus // 2, 3), cluster.n_gpus)
        k_choices = range(min(2, hi), hi + 1)
    k_choices = list(k_choices)
    if max(k_choices) > cluster.n_gpus:
        raise ValueError("k_choices exceed cluster size")
    jobs: List[TraceJob] = []
    t = 0.0
    for i in range(n_jobs):
        t += float(rng.exponential(mean_interarrival))
        dur = max(float(rng.exponential(mean_duration)), 1e-3)
        k = int(k_choices[rng.integers(len(k_choices))])
        jobs.append(TraceJob(f"job-{i:04d}", t, dur, k))
    return jobs


def summarize_trace(
    records: Sequence[TenantRecord],
) -> Dict[str, Dict[str, float]]:
    """-> {dispatcher: mean contention-degraded GBE / bw / wait / contention
    + the queue-policy fields (overtakes, batch size, migrations)}."""
    out: Dict[str, Dict[str, float]] = {}
    for name in sorted({r.dispatcher for r in records}):
        rs = [r for r in records if r.dispatcher == name]
        contended = [r for r in rs if r.n_contended_hosts > 0]
        out[name] = {
            "mean_gbe": float(np.mean([r.gbe for r in rs])),
            "mean_bw": float(np.mean([r.bw for r in rs])),
            "mean_degradation": float(
                np.mean([1.0 - r.bw / r.isolated_bw for r in rs])
            ),
            "mean_wait": float(np.mean([r.wait for r in rs])),
            "frac_contended": len(contended) / max(len(rs), 1),
            # NaN, not 1.0: "no contended admissions" must stay visibly
            # different from "perfect GBE under contention"
            "mean_gbe_contended": float(
                np.mean([r.gbe for r in contended]) if contended
                else float("nan")
            ),
            "mean_batch_size": float(np.mean([r.batch_size for r in rs])),
            "total_overtakes": int(sum(r.overtakes for r in rs)),
            "total_migrations": int(sum(r.migrations for r in rs)),
            # fragmentation state faced across the trace (defrag metrics)
            "mean_stranding": float(np.mean([r.stranding for r in rs])),
            "mean_clean_hosts": float(np.mean([r.clean_hosts for r in rs])),
            "n": len(rs),
        }
    return out


# ---------------------------------------------------------------------------
# Migration events.  migration_cost itself now lives in repro.core.defrag
# (one home for the migration economics shared by re-dispatch, the defrag
# planner, and repro.ft.elastic) and is re-exported above.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MigrationEvent:
    """One committed live-job move, for inspection/benchmarks."""

    t: float
    job_id: str
    old_gpus: Tuple[int, ...]
    new_gpus: Tuple[int, ...]
    old_bw: float    # contention-degraded, before the move
    new_bw: float    # contention-degraded, after the move
    cost: float      # migration_cost charged against the gain
    kind: str = "redispatch"  # or "defrag" / "make-room" (trigger passes)

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SchedulerConfig:
    policy: str = "fifo"
    batch_window: float = 0.0        # batched: co-arrival coalescing window
    aging_limit: int = 4             # backfill: overtakes before a job fences
    redispatch: bool = False         # elastic re-dispatch on release
    migration_cost_per_gpu: float = 2.0  # GB/s of degraded-bw gain per moved GPU
    defrag: bool = False             # background + make-room consolidation
    defrag_config: Optional[DefragConfig] = None  # knobs; defaults when None
    batch_applies: bool = False      # fuse surrogate applies across the
    # concurrent scratch searches of one joint plan (batched policy) into
    # shared device calls; value-neutral (padding identity), default off
    # -- ISSUE 7: control-plane integration (all default-off) ---------------
    tenant_policies: Optional[Dict[str, TenantPolicy]] = None  # QoS rows:
    # max_concurrent gates admission, max_queued rejects at enqueue,
    # priority_boost reorders backfill/batched candidates
    concurrent_workers: int = 0      # >0: fifo admissions go through the
    # AdmissionControlPlane with this many staging workers (opt-in; serial
    # replay is byte-identical at 0)
    journal_path: Optional[str] = None  # write-ahead ledger journal file;
    # journaling never changes placements (regression-pinned)
    # -- ISSUE 10: failure domain (fault-free runs are byte-identical) ------
    fault_schedule: Optional[object] = None  # faults.FaultSchedule (or any
    # iterable of FaultEvent); None disables injection entirely — the event
    # loop then never consults the fault heap and replays exactly as before
    recovery: bool = True            # checkpoint-and-requeue affected jobs
    # (False = measure the no-recovery counterfactual: victims stay placed
    # on dead GPUs and their contended bandwidth grades as 0.0)
    requeue_backoff: float = 0.5     # base re-admission retry delay; doubles
    # per attempt (0.5, 1, 2, ...) up to max_requeue_retries, after which
    # the job is abandoned (RecoveryOutcome.gave_up) instead of wedging the
    # drain assertion forever on a permanently shrunk cluster
    max_requeue_retries: int = 5
    flap_migrate: bool = True        # nic_flap: price waiting out the flap
    # against migrating off the host (expected-downtime x bandwidth gain
    # vs the shared migration_cost charge)

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(
                f"unknown policy {self.policy!r}; expected one of {POLICIES}"
            )
        if self.batch_window < 0:
            raise ValueError("batch_window must be >= 0")
        if self.aging_limit < 1:
            raise ValueError("aging_limit must be >= 1")
        if self.concurrent_workers < 0:
            raise ValueError("concurrent_workers must be >= 0")
        if self.concurrent_workers > 0 and self.policy != "fifo":
            raise ValueError(
                "concurrent admission is only defined for the fifo policy "
                "(backfill/batched drain logic is inherently sequential)"
            )
        if self.requeue_backoff <= 0:
            raise ValueError("requeue_backoff must be > 0")
        if self.max_requeue_retries < 0:
            raise ValueError("max_requeue_retries must be >= 0")
        if self.defrag:
            # within one scheduler there is ONE migration price: redispatch
            # and defrag moves must never charge different costs per GPU
            # (replace, not mutate: the caller's DefragConfig may be shared)
            self.defrag_config = dataclasses.replace(
                self.defrag_config or DefragConfig(),
                migration_cost_per_gpu=self.migration_cost_per_gpu,
            )


@dataclasses.dataclass
class _QueueEntry:
    job: TraceJob
    overtaken: int = 0   # times a later arrival was admitted past this job
    batch: int = 0       # batched policy: co-arrival batch id


class AdmissionScheduler:
    """Owns the event loop (arrivals, departures, queue) for one dispatcher.

    One scheduler drives one ``DispatcherService`` (duck-typed: ``ledger``,
    ``admit``, ``release``, ``dispatch``, ``name``, ``needs_rng``) through a
    trace, grading every admission with contention-degraded GBE against the
    ledger-aware exact Oracle exactly like the legacy ``replay_trace``:
    the oracle runs pre-admit, and grading the job post-admit is equivalent
    because ``JobLedger.contends`` excludes GPU-overlapping entries.
    """

    def __init__(
        self,
        cluster: Cluster,
        sim: BandwidthSimulator,
        tables: IntraHostTables,
        dispatcher,
        config: Optional[SchedulerConfig] = None,
        rng: Optional[np.random.Generator] = None,
        harvester=None,
        grade: bool = True,
    ):
        self.cluster = cluster
        self.sim = sim
        self.tables = tables
        self.dispatcher = dispatcher
        self.config = config or SchedulerConfig()
        self.rng = rng
        # Fast-path grading memo: trial moves and defrag planning re-grade
        # the same (subset, occupancy) pairs; keys carry the ledger's
        # (uid, version) so every admit/release invalidates by construction.
        self.grading_cache = GradingCache(sim)
        # Optional telemetry sink (contended_dataset.TelemetryHarvester):
        # every graded admission is also recorded as a (subset, ledger,
        # contended-bw) observation for the online fine-tuning loop.
        self.harvester = harvester
        # grade=False skips the per-admission exact-Oracle baseline (gbe
        # becomes NaN) — evaluation apparatus, not dispatch work; the
        # throughput bench times replays without it so admissions/sec
        # measures the dispatch path, not the grader.
        self.grade = grade
        self.records: List[TenantRecord] = []
        self.migrations: List[MigrationEvent] = []
        self.rejected: List[TraceJob] = []     # dropped by tenant max_queued
        self._defrag_spent = 0                 # moves charged to the budget
        self._last_defrag = float("-inf")      # last background pass time
        self._rec_by_job: Dict[str, TenantRecord] = {}
        self._departures: List[Tuple[float, int, str]] = []  # (end, seq, id)
        self._waiting: deque = deque()  # _QueueEntry, arrival order
        self._durations: Dict[str, float] = {}
        self._seq = 0
        self._batch_id = -1
        self._batch_close = float("-inf")
        # Cross-search inference batcher: shared by every scratch search this
        # scheduler spawns (joint orders, defrag proposals) so concurrent
        # searches fuse their surrogate applies into one padded device call.
        self._batcher = InferenceBatcher() if self.config.batch_applies else None
        # Tenant QoS accounting (live-job counts per tenant, job -> tenant)
        self._tenant_live: Dict[str, int] = {}
        self._job_tenant: Dict[str, str] = {}
        # Failure domain (ISSUE 10): the fault heap merges with the
        # departure heap in _release_until; with no schedule it stays empty
        # and the loop degenerates to the pre-fault event loop exactly.
        self.recoveries: List[faults_mod.RecoveryOutcome] = []
        self.fault_log: List[Dict] = []  # one row per fault/recover event:
        # aggregate live contended bw just before vs after the post-event
        # drain (the bench's bandwidth-retention measurement)
        self._injector: Optional[faults_mod.FaultInjector] = None
        self._faults: List[Tuple[float, int, int, str, object]] = []
        self._fault_seq = 0
        # live departure bookkeeping: job -> (heap seq, end time); a fault
        # requeue drops the entry so the stale heap tuple is skipped lazily
        self._dep_live: Dict[str, Tuple[int, float]] = {}
        # job -> (t_fault, kind, re-admission attempts) while in the
        # recovery pipeline; popped by _grade when the job re-admits (MTTR)
        self._disrupted: Dict[str, Tuple[float, str, int]] = {}
        if self.config.fault_schedule is not None:
            self._injector = faults_mod.FaultInjector(dispatcher.ledger)
            for ev in self.config.fault_schedule:
                self._push_fault_event(ev.t, "fault", ev)
                if ev.t_recover is not None:
                    self._push_fault_event(ev.t_recover, "recover", ev)
        # Opt-in concurrent fifo admission: eligible queue prefixes are
        # admitted as a group through the control plane (staged searches
        # overlap, commits CAS on the ledger version).  journal_path alone
        # attaches a write-ahead journal to the serial path.
        self._cplane = None
        if self.config.concurrent_workers > 0:
            from repro.core.controlplane import AdmissionControlPlane

            self._cplane = AdmissionControlPlane(
                dispatcher,
                n_workers=self.config.concurrent_workers,
                journal=self.config.journal_path,
                rng=rng,
            )
        elif self.config.journal_path is not None:
            from repro.core.controlplane import LedgerJournal

            dispatcher.ledger.attach_journal(
                LedgerJournal(self.config.journal_path)
            )

    # -- public -------------------------------------------------------------

    def run(self, trace: Sequence[TraceJob]) -> List[TenantRecord]:
        """Stream a trace through the dispatcher under the configured policy.

        Event-driven: arrivals in time order; departures at or before an
        arrival release first; the ledger is fully drained at the end, so a
        run leaves the service empty.
        """
        ledger = self.dispatcher.ledger
        if len(ledger) != 0:
            raise ValueError("scheduler needs a fresh (empty) dispatcher")
        if self.records:
            raise ValueError(
                "scheduler already ran a trace; build a fresh one per replay"
            )
        if self.rng is None and self.dispatcher.needs_rng:
            raise ValueError(
                f"{self.dispatcher.name} needs an rng to replay a trace"
            )
        for j in trace:
            if j.k > self.cluster.n_gpus:
                raise ValueError(
                    f"{j.job_id}: k={j.k} can never fit the "
                    f"{self.cluster.n_gpus}-GPU cluster"
                )
        self._durations = {j.job_id: j.duration for j in trace}
        try:
            for job in sorted(trace, key=lambda j: j.arrival):
                self._release_until(job.arrival)
                self._on_arrival(job)
            self._release_until(float("inf"))
        finally:
            if self._cplane is not None:
                self._cplane.shutdown()
        if self._waiting or len(ledger) != 0:
            raise RuntimeError(
                f"replay did not drain: {len(self._waiting)} jobs still "
                f"waiting, {len(ledger)} still live"
            )
        return self.records

    def aggregate_live_bandwidth(self) -> float:
        """Sum of every live job's contention-degraded bandwidth under the
        current ledger (health included) — the quantity the failure bench
        tracks across a storm."""
        ledger = self.dispatcher.ledger
        return float(sum(
            self.grading_cache.true_bandwidth(a.gpus, ledger=ledger)
            for a in ledger.jobs()
        ))

    # -- event handling -----------------------------------------------------

    def _release_until(self, horizon: float) -> None:
        """Advance the clock to ``horizon``: departures and fault events
        interleave in time order (a departure wins a tie — the job finished
        at the instant the fault landed).  With no fault schedule the fault
        heap is empty and this is exactly the pre-fault departure loop."""
        while True:
            if not self._departures and not self._faults:
                return
            t_dep = self._departures[0][0] if self._departures else math.inf
            t_flt = self._faults[0][0] if self._faults else math.inf
            if min(t_dep, t_flt) > horizon:
                return
            if t_dep <= t_flt:
                self._pop_departure()
            else:
                self._pop_fault_event()

    def _pop_departure(self) -> None:
        t_end, seq, job_id = heapq.heappop(self._departures)
        live = self._dep_live.get(job_id)
        if live is None or live[0] != seq:
            return  # stale: a fault requeued this job before it finished
        del self._dep_live[job_id]
        if self._cplane is not None:
            self._cplane.release(job_id)  # keeps its tenant counts live
        else:
            self.dispatcher.release(job_id)
        tenant = self._job_tenant.pop(job_id, None)
        if tenant is not None:
            self._tenant_live[tenant] -= 1
        self._drain(t_end)
        if self.config.redispatch:
            self._maybe_redispatch(t_end)
        if self.config.defrag:
            self._maybe_background_defrag(t_end)

    # -- failure domain: injection + recovery pipeline ------------------------

    def _push_fault_event(self, t: float, op: str, payload) -> None:
        # rank: recoveries before faults before retries at the same instant
        # (capacity comes back before a co-timed fault takes more away)
        rank = {"recover": 0, "fault": 1, "retry": 2}[op]
        heapq.heappush(
            self._faults, (t, rank, self._fault_seq, op, payload)
        )
        self._fault_seq += 1

    def _pop_fault_event(self) -> None:
        t, _, _, op, payload = heapq.heappop(self._faults)
        if op == "fault":
            self._on_fault(t, payload)
        elif op == "recover":
            self._on_recover(t, payload)
        else:
            self._on_retry(t, payload)

    def _on_fault(self, t: float, ev) -> None:
        """Apply one fault (journaled, version-bumping) and run the
        recovery pipeline: victims are checkpoint-released and requeued at
        the head of the queue; nic_flaps trigger the wait-vs-migrate
        pricing; the post-event drain re-admits whatever fits (make-room
        defrag fires per admission through the existing hook)."""
        agg_before = self.aggregate_live_bandwidth()
        affected = self._injector.affected_jobs(ev)
        requeued: List[TraceJob] = []
        with telemetry.span(
            "sched.fault", kind=ev.kind, host=ev.host_id,
            affected=len(affected),
        ):
            self._injector.apply(ev)
            if self.config.recovery and affected:
                for job_id in sorted(affected):
                    job = self._release_disrupted(job_id, t, ev.kind)
                    if job is not None:
                        requeued.append(job)
                # priority re-admission: victims go to the FRONT of the
                # queue, preserving their relative (sorted) order
                for job in reversed(requeued):
                    self._enqueue_front(job)
            if ev.kind == "nic_flap" and self.config.flap_migrate:
                self._consider_flap_migration(t, ev)
        self._drain(t)
        for job in requeued:
            self._schedule_retry(job.job_id, t)
        self.fault_log.append({
            "t": t, "op": "fault", "kind": ev.kind, "host": ev.host_id,
            "affected": len(affected), "requeued": len(requeued),
            "agg_bw_before": agg_before,
            "agg_bw_after": self.aggregate_live_bandwidth(),
        })

    def _on_recover(self, t: float, ev) -> None:
        agg_before = self.aggregate_live_bandwidth()
        with telemetry.span("sched.recover", kind=ev.kind, host=ev.host_id):
            self._injector.recover(ev)
        self._drain(t)  # restored capacity may admit waiting victims
        if self.config.redispatch:
            self._maybe_redispatch(t)  # e.g. move back onto healed rails
        self.fault_log.append({
            "t": t, "op": "recover", "kind": ev.kind, "host": ev.host_id,
            "affected": 0, "requeued": 0,
            "agg_bw_before": agg_before,
            "agg_bw_after": self.aggregate_live_bandwidth(),
        })

    def _release_disrupted(
        self, job_id: str, t: float, kind: str
    ) -> Optional[TraceJob]:
        """Checkpoint-release one fault victim; returns the requeue stub
        (remaining duration, original tenant) or None when the job is not
        live anymore (already claimed by an overlapping fault)."""
        live = self._dep_live.pop(job_id, None)
        if live is None:
            return None
        _, t_end = live
        remaining = max(t_end - t, 1e-3)
        alloc = self.dispatcher.ledger.allocation(job_id)
        k = alloc.k
        if self._cplane is not None:
            self._cplane.release(job_id)
        else:
            self.dispatcher.release(job_id)
        tenant = self._job_tenant.pop(job_id, "")
        if tenant in self._tenant_live:
            self._tenant_live[tenant] -= 1
        self._disrupted[job_id] = (t, kind, 0)
        telemetry.event(
            "sched.requeue", job_id=job_id, kind=kind, k=k,
            remaining=remaining,
        )
        return TraceJob(job_id, t, remaining, k, tenant)

    def _enqueue_front(self, job: TraceJob) -> None:
        batch = 0
        if self.config.policy == "batched":
            # a singleton batch of its own at the head: the victim drains
            # first and a non-fitting victim blocks later batches (priority)
            self._batch_id += 1
            batch = self._batch_id
        self._waiting.appendleft(_QueueEntry(job, batch=batch))

    def _schedule_retry(self, job_id: str, t: float) -> None:
        info = self._disrupted.get(job_id)
        if info is None:
            return  # re-admitted during the fault drain: no retry needed
        attempts = info[2]
        if attempts >= self.config.max_requeue_retries:
            self._give_up(job_id, t)
            return
        delay = self.config.requeue_backoff * (2.0 ** attempts)
        self._push_fault_event(t + delay, "retry", job_id)

    def _on_retry(self, t: float, job_id: str) -> None:
        info = self._disrupted.get(job_id)
        if info is None:
            return  # re-admitted before this backoff fired
        t_fault, kind, attempts = info
        self._disrupted[job_id] = (t_fault, kind, attempts + 1)
        with telemetry.span(
            "sched.requeue_retry", job_id=job_id, attempt=attempts + 1,
        ):
            self._drain(t)
        self._schedule_retry(job_id, t)

    def _give_up(self, job_id: str, t: float) -> None:
        """Bounded backoff exhausted: abandon the requeue (the victim's
        checkpoint outlives this trace) instead of wedging the drain."""
        t_fault, kind, attempts = self._disrupted.pop(job_id)
        for entry in self._waiting:
            if entry.job.job_id == job_id:
                self._waiting.remove(entry)
                break
        self.recoveries.append(faults_mod.RecoveryOutcome(
            job_id, t_fault, t, attempts, kind, gave_up=True,
        ))
        telemetry.event("sched.requeue_gave_up", job_id=job_id, kind=kind)

    def _consider_flap_migration(self, t: float, ev) -> None:
        """nic_flap wait-out-vs-migrate: a live cross-host job riding the
        flapped host's rails migrates only when the bandwidth recovered
        over the flap's expected remaining downtime exceeds the shared
        migration-cost charge — otherwise waiting out the flap is cheaper.
        At most one move per flap (the first mover invalidates the shared
        pre-move baseline)."""
        ledger = self.dispatcher.ledger
        downtime = faults_mod.expected_downtime(ev, t)
        movers = sorted(
            (a for a in ledger.jobs()
             if a.cross_host and ev.host_id in a.host_ids),
            key=lambda a: a.job_id,
        )
        if not movers or downtime <= 0.0:
            return
        before = {
            a.job_id: self.grading_cache.true_bandwidth(a.gpus, ledger=ledger)
            for a in ledger.jobs()
        }
        frag_before = defrag_mod.fragmentation_metrics(self.cluster, ledger)
        for alloc in movers:
            tenant = self._job_tenant.get(alloc.job_id, "")
            with forensics.decision(
                alloc.job_id, tenant=tenant, k=alloc.k,
                policy=self.config.policy, path="recovery",
            ) as df:
                # no min_self_gain: under downtime pricing a move can pay
                # even when the instantaneous gain is below the cost
                mv = defrag_mod.evaluate_move(
                    self.grading_cache, ledger, alloc,
                    lambda led, avail, k: self.dispatcher.dispatch(
                        avail, k, rng=self.rng
                    ),
                    self.config.migration_cost_per_gpu,
                    before=before, frag_before=frag_before,
                )
                if mv is None or (mv.new_bw - mv.old_bw) * downtime <= mv.cost:
                    continue
                ledger.migrate(alloc.job_id, mv.new_gpus)
                if df is not None:
                    df.commit(mv.new_gpus, mv.new_bw,
                              committed_version=ledger.version)
            telemetry.event(
                "sched.flap_migrate", job_id=alloc.job_id,
                gain=mv.new_bw - mv.old_bw, cost=mv.cost, downtime=downtime,
            )
            self.migrations.append(MigrationEvent(
                t, alloc.job_id, mv.old_gpus, mv.new_gpus,
                mv.old_bw, mv.new_bw, mv.cost, kind="flap-migrate",
            ))
            rec = self._rec_by_job.get(alloc.job_id)
            if rec is not None:
                rec.migrations += 1
            return

    def _on_arrival(self, job: TraceJob) -> None:
        ledger = self.dispatcher.ledger
        fits = job.k <= ledger.n_free()
        if not self._waiting and fits and self._tenant_ok(job.tenant):
            # spare capacity, empty queue: no policy holds the job back
            if self._cplane is not None:
                # concurrent mode admits through the control plane; the
                # singleton group keeps one code path
                self._enqueue(job)
                self._drain(job.arrival)
            else:
                self._admit_via_dispatcher(job, job.arrival)
            return
        pol = self._policy_for(job.tenant)
        if pol is not None and pol.max_queued is not None:
            waiting = sum(
                1 for e in self._waiting if e.job.tenant == job.tenant
            )
            if waiting >= pol.max_queued:
                self.rejected.append(job)  # over the tenant's queue cap
                return
        self._enqueue(job)
        if self.config.policy != "fifo":
            # backfill/batched may admit at arrival time (fifo never does:
            # a non-empty queue means capacity has not changed since the
            # last release, and the head still blocks)
            self._drain(job.arrival)

    def _enqueue(self, job: TraceJob) -> None:
        batch = 0
        if self.config.policy == "batched":
            # window 0 never coalesces — not even identical arrival stamps —
            # so the documented fifo degeneration holds exactly
            if (self._waiting and self.config.batch_window > 0
                    and job.arrival <= self._batch_close):
                batch = self._batch_id
            else:
                self._batch_id += 1
                self._batch_close = job.arrival + self.config.batch_window
                batch = self._batch_id
        self._waiting.append(_QueueEntry(job, batch=batch))

    def _drain(self, t: float) -> None:
        if self.config.policy == "fifo":
            self._drain_fifo(t)
        elif self.config.policy == "backfill":
            self._drain_backfill(t)
        else:
            self._drain_batched(t)

    # -- tenant QoS ---------------------------------------------------------

    def _policy_for(self, tenant: str) -> Optional[TenantPolicy]:
        return (self.config.tenant_policies or {}).get(tenant)

    def _tenant_ok(self, tenant: str, staged: Optional[Dict] = None) -> bool:
        """May this tenant take one more live job right now?  ``staged``
        adds not-yet-committed same-drain admissions to the live count."""
        pol = self._policy_for(tenant)
        if pol is None or pol.max_concurrent is None:
            return True
        live = self._tenant_live.get(tenant, 0)
        if staged:
            live += staged.get(tenant, 0)
        return live < pol.max_concurrent

    def _boost(self, tenant: str) -> int:
        pol = self._policy_for(tenant)
        return pol.priority_boost if pol is not None else 0

    # -- policies -----------------------------------------------------------

    def _drain_fifo(self, t: float) -> None:
        if self._cplane is not None:
            self._drain_fifo_concurrent(t)
            return
        ledger = self.dispatcher.ledger
        while (self._waiting
               and self._waiting[0].job.k <= ledger.n_free()
               and self._tenant_ok(self._waiting[0].job.tenant)):
            self._admit_via_dispatcher(self._waiting.popleft().job, t)

    def _drain_fifo_concurrent(self, t: float) -> None:
        """Admit the maximal fitting+eligible queue prefix as one group
        through the control plane: every member's search is staged against
        a ledger snapshot concurrently, commits CAS on the version.

        Grading replicates the serial protocol exactly: members are graded
        in commit order against an incrementally rebuilt clone (pre-group
        state + members committed before it), with the exact-Oracle
        baseline computed against that same view pre-admit — so a group
        whose commits land in queue order with the serial placements grades
        byte-identically to the serial drain.  Opt-in — the serial fifo
        path is untouched with 0 workers.
        """
        ledger = self.dispatcher.ledger
        free = ledger.n_free()
        staged: Dict[str, int] = {}
        group: List[TraceJob] = []
        for entry in self._waiting:  # strictly the queue prefix (fifo)
            job = entry.job
            if job.k > free or not self._tenant_ok(job.tenant, staged):
                break
            group.append(job)
            free -= job.k
            staged[job.tenant] = staged.get(job.tenant, 0) + 1
        if not group:
            return
        outcomes = self._cplane.admit_many(
            [(j.job_id, j.k, j.tenant) for j in group]
        )
        by_id = {j.job_id: j for j in group}
        # Rewind to pre-group state and replay the commits one by one so
        # each member is graded in the context the serial drain would have
        # given it (earlier commits live, later ones absent).
        view = ledger.clone()
        for out in outcomes:
            view.release(out.job_id)
        for out in sorted(outcomes, key=lambda o: o.committed_version):
            job = by_id[out.job_id]
            with telemetry.span(
                "sched.admit", job_id=job.job_id, k=job.k,
                policy=self.config.policy, path="concurrent",
            ) as sp:
                if sp:  # the worker's cplane.commit span carries it too
                    sp["journal_seq"] = out.journal_seq
                if self.grade:
                    with telemetry.span("sched.oracle", k=job.k):
                        _, opt_bw = baselines.oracle_dispatch(
                            self.cluster, self.sim, self.tables,
                            view.available(), job.k, ledger=view,
                        )
                else:
                    opt_bw = float("nan")
                n_live = len(view)
                view.admit(out.job_id, out.alloc.gpus)
                self._grade(
                    job, t, out.alloc, opt_bw,
                    n_live=n_live, overtakes=0, batch_size=len(group),
                    ledger=view, predicted=out.predicted_bw,
                )
        for _ in group:
            self._waiting.popleft()

    def _shadow(self, head_k: int, t: float) -> Tuple[float, int]:
        """EASY-backfill reservation for a blocked head: the earliest time
        the head could start if no further jobs were admitted (walk the
        departure heap accumulating freed GPUs), and the spare capacity at
        that moment beyond the head's need."""
        ledger = self.dispatcher.ledger
        free = ledger.n_free()
        if head_k <= free:
            return t, free - head_k
        for t_end, seq, job_id in sorted(self._departures):
            live = self._dep_live.get(job_id)
            if live is None or live[0] != seq:
                continue  # stale heap entry: the job was fault-requeued
            free += ledger.allocation(job_id).k
            if free >= head_k:
                return t_end, free - head_k
        return float("inf"), 0  # unreachable: k <= n_gpus is pre-checked

    def _drain_backfill(self, t: float) -> None:
        """Admit the head while it fits; otherwise backfill EASY-style.

        The blocked head holds a *reservation* at its shadow time (earliest
        possible start given current departures): a later job may overtake
        only if it fits now AND either finishes before the shadow time or
        uses capacity the head will not need then — so a backfill never
        delays the head.  Belt-and-braces on top of the reservation, every
        overtake increments the skipped jobs' aging counters and a job
        whose counter reaches ``aging_limit`` becomes a hard fence that
        nothing behind it may pass."""
        ledger = self.dispatcher.ledger
        limit = self.config.aging_limit
        while self._waiting:
            free = ledger.n_free()
            head = self._waiting[0]
            if head.job.k <= free and self._tenant_ok(head.job.tenant):
                self._waiting.popleft()
                self._admit_via_dispatcher(head.job, t)
                continue
            if head.overtaken >= limit:
                return  # head aged out: queue is frozen until it admits
            # a tenant-capped head that fits capacity-wise reserves from
            # now (shadow_t = t): backfillers may only use spare capacity
            shadow_t, extra = self._shadow(head.job.k, t)
            # fence: only entries before the first aged-out one may pass;
            # priority boosts reorder candidates within that prefix (with
            # no boosts the order is untouched — first fit by index)
            fence = len(self._waiting)
            for i in range(1, len(self._waiting)):
                if self._waiting[i].overtaken >= limit:
                    fence = i
                    break
            candidates = list(range(1, fence))
            if any(self._boost(self._waiting[i].job.tenant)
                   for i in candidates):
                candidates.sort(key=lambda i: (
                    -self._boost(self._waiting[i].job.tenant), i
                ))
            pick = None
            for i in candidates:
                entry = self._waiting[i]
                fits_now = (entry.job.k <= free
                            and self._tenant_ok(entry.job.tenant))
                respects_reservation = (
                    t + entry.job.duration <= shadow_t + 1e-9
                    or entry.job.k <= extra
                )
                if fits_now and respects_reservation:
                    pick = i
                    break
            if pick is None:
                return
            entry = self._waiting[pick]
            for j in range(pick):  # every skipped job was overtaken once
                self._waiting[j].overtaken += 1
            del self._waiting[pick]
            self._admit_via_dispatcher(entry.job, t, overtakes=pick)

    def _drain_batched(self, t: float) -> None:
        """Drain whole co-arrival batches FIFO; place the head batch jointly.

        Within the head batch, members are *selected* in arrival order,
        first-fit (a non-fitting member is skipped, never admitted later
        than it would be under fifo), then the selected jobs are committed
        through one joint plan — ``joint_hybrid_search`` picks the
        *placement* order.  A batch with leftover members blocks later
        batches, so unfairness is bounded by the co-arrival window."""
        ledger = self.dispatcher.ledger
        while self._waiting:
            head_batch = self._waiting[0].batch
            members = [
                (i, e) for i, e in enumerate(self._waiting)
                if e.batch == head_batch
            ]
            free = ledger.n_free()
            # selection order: arrival, unless priority boosts are in play
            # (boost affects WHO is selected; placement order is the joint
            # plan's concern, and admission below stays index-sorted)
            sel_order = members
            if any(self._boost(e.job.tenant) for _, e in members):
                sel_order = sorted(members, key=lambda ie: (
                    -self._boost(ie[1].job.tenant), ie[0]
                ))
            staged: Dict[str, int] = {}
            selected: List[Tuple[int, _QueueEntry]] = []
            for i, e in sel_order:  # first-fit under capacity + tenant caps
                if (e.job.k <= free
                        and self._tenant_ok(e.job.tenant, staged)):
                    selected.append((i, e))
                    free -= e.job.k
                    staged[e.job.tenant] = staged.get(e.job.tenant, 0) + 1
            if not selected:
                return
            selected.sort(key=lambda ie: ie[0])
            sel_idx = {i for i, _ in selected}
            # overtakes: unselected earlier entries (head-batch mates — the
            # head batch is always a prefix of the arrival-ordered queue)
            overtakes = {
                i: sum(1 for j in range(i) if j not in sel_idx)
                for i, _ in selected
            }
            jobs = [e.job for _, e in selected]
            self._admit_batch(
                jobs, t,
                overtakes=[overtakes[i] for i, _ in selected],
            )
            for i in sorted(sel_idx, reverse=True):
                del self._waiting[i]
            if any(e.batch == head_batch for e in self._waiting):
                return  # leftover members block later batches (batch FIFO)

    # -- admission + grading ------------------------------------------------

    def _admit_batch(
        self, jobs: List[TraceJob], t: float, overtakes: List[int]
    ) -> None:
        """Place ``jobs`` as one joint batch (falls back to sequential
        admission for dispatchers without the hybrid-search machinery)."""
        n = len(jobs)
        joint_capable = (
            n > 1
            and hasattr(self.dispatcher, "tables")
            and hasattr(self.dispatcher, "base_predictor")
        )
        if joint_capable and self.config.defrag:
            # make room for the batch's largest member BEFORE planning:
            # defrag moves relocate live jobs into free GPUs, so they must
            # never run between a joint plan and its commit.  The sequential
            # fallback below triggers per-admission instead (in
            # _admit_via_dispatcher), never both.
            self._maybe_make_room(max(j.k for j in jobs), t)
        if not joint_capable:
            order = range(n)
            if self.config.batch_window > 0:
                order = sorted(order, key=lambda i: (-jobs[i].k, i))
            for i in order:
                self._admit_via_dispatcher(
                    jobs[i], t, overtakes=overtakes[i], batch_size=n
                )
            return
        orders = (
            search.JOINT_ORDERS if self.config.batch_window > 0
            else ("arrival",)
        )
        plan = search.joint_hybrid_search(
            self.cluster, self.dispatcher.tables,
            self.dispatcher.base_predictor, self.dispatcher.ledger,
            [(j.job_id, j.k) for j in jobs],
            orders=orders,
            contention_aware=getattr(self.dispatcher, "contention_aware", True),
            contention_mode=getattr(
                self.dispatcher, "contention_mode", "analytic"
            ),
            contended=getattr(self.dispatcher, "contended_predictor", None),
            frag_weight=getattr(self.dispatcher, "frag_weight", 0.0),
            **self._scratch_search_kwargs(),
        )
        by_id = {j.job_id: (j, ov) for j, ov in zip(jobs, overtakes)}
        for p in plan.placements:
            job, ov = by_id[p.job_id]
            self._admit_planned(
                job, t, p.subset, overtakes=ov, batch_size=n,
                predicted=p.predicted_bw,
            )

    def _admit_via_dispatcher(
        self, job: TraceJob, t: float, overtakes: int = 0, batch_size: int = 1
    ) -> None:
        with telemetry.span(
            "sched.admit", job_id=job.job_id, k=job.k,
            policy=self.config.policy, path="serial",
        ) as sp, forensics.decision(
            job.job_id, tenant=job.tenant, k=job.k,
            policy=self.config.policy, path="serial",
        ) as df:
            if self.config.defrag:
                self._maybe_make_room(job.k, t)
            ledger = self.dispatcher.ledger
            if self.grade:
                with telemetry.span("sched.oracle", k=job.k):
                    _, opt_bw = baselines.oracle_dispatch(
                        self.cluster, self.sim, self.tables,
                        ledger.available(), job.k, ledger=ledger,
                    )
            else:
                opt_bw = float("nan")
            n_live = len(ledger)
            alloc = self.dispatcher.admit(
                job.job_id, job.k, rng=self.rng, tenant=job.tenant
            )
            # serial path: the admit above was the last journal write
            seq = (
                ledger.last_journal_seq if ledger.journal is not None else -1
            )
            if sp:
                sp["journal_seq"] = seq
            last = getattr(self.dispatcher, "last_result", None)
            predicted = last.predicted_bw if last is not None else float("nan")
            if df is not None:
                df.commit(alloc.gpus, predicted, journal_seq=seq,
                          committed_version=ledger.version)
            self._grade(
                job, t, alloc, opt_bw, n_live, overtakes, batch_size,
                predicted=predicted,
            )

    def _admit_planned(
        self, job: TraceJob, t: float, subset: Subset,
        overtakes: int = 0, batch_size: int = 1,
        predicted: float = float("nan"),
    ) -> None:
        """Commit a jointly-planned placement, grading it like any other."""
        with telemetry.span(
            "sched.admit", job_id=job.job_id, k=job.k,
            policy=self.config.policy, path="planned",
        ) as sp, forensics.decision(
            job.job_id, tenant=job.tenant, k=job.k,
            policy=self.config.policy, path="planned",
        ) as df:
            ledger = self.dispatcher.ledger
            avail = ledger.available()
            if len(subset) != job.k or not set(subset) <= set(avail):
                raise InvalidPlacementError(  # planner bug: crash, never queue
                    f"joint plan produced an invalid allocation for "
                    f"{job.job_id!r}: {subset}"
                )
            if self.grade:
                with telemetry.span("sched.oracle", k=job.k):
                    _, opt_bw = baselines.oracle_dispatch(
                        self.cluster, self.sim, self.tables, avail, job.k,
                        ledger=ledger,
                    )
            else:
                opt_bw = float("nan")
            n_live = len(ledger)
            alloc = ledger.admit(job.job_id, subset, tenant=job.tenant)
            seq = (
                ledger.last_journal_seq if ledger.journal is not None else -1
            )
            if sp:
                sp["journal_seq"] = seq
            if df is not None:
                df.commit(alloc.gpus, predicted, journal_seq=seq,
                          committed_version=ledger.version)
            self._grade(
                job, t, alloc, opt_bw, n_live, overtakes, batch_size,
                predicted=predicted,
            )

    def _grade(
        self, job: TraceJob, t: float, alloc: Allocation, opt_bw: float,
        n_live: int, overtakes: int, batch_size: int, ledger=None,
        predicted: float = float("nan"),
    ) -> None:
        # ledger override: the concurrent fifo drain grades each group
        # member against a rebuilt "commits before me" view, not the live
        # (post-group) ledger — see _drain_fifo_concurrent.
        if ledger is None:
            ledger = self.dispatcher.ledger
        # post-admit grading sees the pre-admit contention: contends()
        # self-excludes the job's own (GPU-overlapping) ledger entry
        bw = self.grading_cache.true_bandwidth(alloc.gpus, ledger=ledger)
        iso = self.grading_cache.true_bandwidth(alloc.gpus)
        # back-fill realized/oracle bandwidth into the admission's dossier
        # and the per-tenant regret ledger (no-op when capture is off)
        forensics.note_grade(job.job_id, bw, oracle_bw=opt_bw,
                             tenant=job.tenant)
        if self.harvester is not None:
            drift = getattr(self.harvester, "drift", None)
            if drift is not None and not math.isnan(predicted):
                # stamp B-hat for the report_bandwidth pairing path too:
                # a later realized measurement resolves through this
                from repro.core.telemetry import snapshot_digest

                drift.note_prediction(
                    job.job_id, alloc.gpus, predicted,
                    digest=snapshot_digest(ledger, alloc.gpus),
                    tenant=job.tenant,
                )
            self.harvester.observe(
                ledger, alloc.gpus, bw,
                job_id=job.job_id, predicted=predicted,
                tenant=job.tenant, t=t, source="grade",
            )
        shared = sum(
            1 for hid in alloc.host_ids
            if ledger.rail_contenders(hid, against=alloc.gpus) > 0
        ) if alloc.cross_host else 0
        frag = ledger.fragmentation()
        rec = TenantRecord(
            self.dispatcher.name, job.job_id, job.k, t, t - job.arrival,
            bw / opt_bw, bw, iso, opt_bw, n_live, shared,
            policy=self.config.policy, overtakes=overtakes,
            batch_size=batch_size,
            stranding=frag.stranding, clean_hosts=frag.clean_hosts,
            predicted_bw=predicted,
        )
        self.records.append(rec)
        self._rec_by_job[job.job_id] = rec
        self._tenant_live[job.tenant] = (
            self._tenant_live.get(job.tenant, 0) + 1
        )
        self._job_tenant[job.job_id] = job.tenant
        heapq.heappush(
            self._departures, (t + job.duration, self._seq, job.job_id)
        )
        self._dep_live[job.job_id] = (self._seq, t + job.duration)
        self._seq += 1
        # this admission closes a recovery: seal MTTR for the pipeline
        info = self._disrupted.pop(job.job_id, None)
        if info is not None:
            t_fault, kind, attempts = info
            self.recoveries.append(faults_mod.RecoveryOutcome(
                job.job_id, t_fault, t, attempts + 1, kind,
            ))
            telemetry.event(
                "sched.recovered", job_id=job.job_id, kind=kind,
                mttr=t - t_fault, attempts=attempts + 1,
            )

    # -- elastic re-dispatch on release --------------------------------------

    def _maybe_redispatch(self, t: float) -> None:
        """Re-place the live cross-host job whose contention-degraded
        bandwidth improves the most net of migration cost — and only if no
        other live job's degraded bandwidth drops."""
        ledger = self.dispatcher.ledger
        candidates = [a for a in ledger.jobs() if a.cross_host]
        if not candidates:
            return
        # every candidate trials against the same (exactly restored) ledger
        # state: grade the pre-move baseline once, not once per candidate
        before = {
            a.job_id: self.grading_cache.true_bandwidth(a.gpus, ledger=ledger)
            for a in ledger.jobs()
        }
        frag_before = defrag_mod.fragmentation_metrics(self.cluster, ledger)
        best: Optional[defrag_mod.MoveEval] = None
        for alloc in list(candidates):
            ev = self._trial_move(alloc, before, frag_before)
            if ev is None:
                continue
            if best is None or ev.self_gain > best.self_gain:
                best = ev
        if best is None:
            return
        # single atomic move: one journal event, version bumps by 2 —
        # identical ledger state to the release+admit pair this replaces
        ledger.migrate(best.job_id, best.new_gpus)
        telemetry.event(
            "sched.redispatch", job_id=best.job_id,
            gain=best.new_bw - best.old_bw, cost=best.cost,
        )
        self.migrations.append(MigrationEvent(
            t, best.job_id, best.old_gpus, best.new_gpus,
            best.old_bw, best.new_bw, best.cost,
        ))
        rec = self._rec_by_job.get(best.job_id)
        if rec is not None:
            rec.migrations += 1

    def _trial_move(
        self, alloc: Allocation, before=None, frag_before=None
    ) -> Optional["defrag_mod.MoveEval"]:
        """Evaluate re-placing one live job via the shared trial-move
        helper (:func:`repro.core.defrag.evaluate_move` — gain rule,
        no-harm check, exact ledger restore); the re-dispatch hook's
        objective is the moved job's own net gain.  Grading runs through
        the ledger-versioned :class:`~repro.core.predict_cache.GradingCache`
        and reuses the caller's once-per-release ``before`` baseline.

        Returns the :class:`~repro.core.defrag.MoveEval` or None when the
        move does not pay or would hurt a co-tenant."""
        return defrag_mod.evaluate_move(
            self.grading_cache, self.dispatcher.ledger, alloc,
            lambda led, avail, k: self.dispatcher.dispatch(
                avail, k, rng=self.rng
            ),
            self.config.migration_cost_per_gpu,
            min_self_gain=1e-9,  # cheap reject before co-tenant grading
            before=before, frag_before=frag_before,
        )

    # -- defragmentation triggers --------------------------------------------

    def _scratch_search_kwargs(self) -> Dict:
        """Fast-path settings for scratch searches (joint plans, defrag
        proposals): follow the dispatcher's own cache/vectorized settings
        so a fast-path-off dispatcher replays the pre-PR path end to end
        (the throughput bench's before side), and sink the throwaway
        wrappers' stats into the dispatcher's contention wrapper so the
        per-phase breakdown keeps their time."""
        d = self.dispatcher
        wrapper = getattr(d, "contention_predictor", None)
        return dict(
            use_cache=(
                getattr(d, "prediction_cache", None) is not None
                or getattr(d, "iso_cache", None) is not None
            ),
            vectorized=getattr(wrapper, "vectorized", True),
            stats_sink=wrapper.stats if wrapper is not None else None,
            batcher=self._batcher,
        )

    def _defrag_proposer(self) -> defrag_mod.ProposalFan:
        """How the planner re-places movers: best-fit consolidation slots
        (with the dispatcher's own contention-aware hybrid machinery as the
        fallback) when available, else the dispatcher's plain ``dispatch``."""
        d = self.dispatcher
        cfg = self.config.defrag_config
        if hasattr(d, "tables") and hasattr(d, "base_predictor"):
            return defrag_mod.consolidation_proposer(
                self.cluster, d.tables, d.base_predictor,
                contention_aware=getattr(d, "contention_aware", True),
                contention_mode=getattr(d, "contention_mode", "analytic"),
                contended=getattr(d, "contended_predictor", None),
                frag_weight=cfg.frag_weight,
                **self._scratch_search_kwargs(),
            )
        return lambda led, avail, k: [d.dispatch(avail, k, rng=self.rng)]

    def _run_defrag_pass(
        self, t: float, kind: str, target_k: Optional[int] = None
    ) -> None:
        cfg = self.config.defrag_config
        remaining = cfg.max_total_moves - self._defrag_spent
        if remaining <= 0:
            return  # trace-level migration budget exhausted
        ledger = self.dispatcher.ledger
        with telemetry.span(
            "sched.defrag", kind=kind, target_k=target_k or 0,
        ) as sp:
            plan = defrag_mod.plan_defrag(
                self.cluster, self.grading_cache, ledger, cfg,
                self._defrag_proposer(),
                target_k=target_k,
                budget=min(cfg.max_moves_per_pass, remaining),
            )
            defrag_mod.apply_plan(ledger, plan)
            sp["moves"] = len(plan.moves)
        for mv in plan.moves:
            self.migrations.append(MigrationEvent(
                t, mv.job_id, mv.old_gpus, mv.new_gpus,
                mv.old_bw, mv.new_bw, mv.cost, kind=kind,
            ))
            rec = self._rec_by_job.get(mv.job_id)
            if rec is not None:
                rec.migrations += 1
            self._defrag_spent += 1

    def _maybe_background_defrag(self, t: float) -> None:
        """Rate-limited consolidation pass at release time (the event-driven
        equivalent of an idle/periodic background sweep)."""
        cfg = self.config.defrag_config
        if t - self._last_defrag < cfg.interval:
            return
        self._last_defrag = t
        self._run_defrag_pass(t, kind="defrag")

    def _maybe_make_room(self, k: int, t: float) -> None:
        """On-demand pass: consolidate just enough to open a k-GPU clean
        block when the admission would otherwise be forced cross-host into
        contended rails (see :func:`repro.core.defrag.forced_rail_contended`)."""
        cfg = self.config.defrag_config
        if not cfg.make_room:
            return
        if defrag_mod.forced_rail_contended(
            self.cluster, self.dispatcher.ledger, k,
            quality_only=cfg.make_room_quality,
        ):
            self._run_defrag_pass(t, kind="make-room", target_k=k)


# ---------------------------------------------------------------------------
# Policy comparison harness
# ---------------------------------------------------------------------------

def compare_policies(
    cluster: Cluster,
    sim: BandwidthSimulator,
    tables: IntraHostTables,
    dispatcher_factory,
    trace: Sequence[TraceJob],
    configs: Optional[Dict[str, SchedulerConfig]] = None,
    seed: int = 0,
) -> Dict[str, AdmissionScheduler]:
    """Replay one trace under several scheduler configs (fresh dispatcher and
    rng per replay: identical randomness).  -> {config name: scheduler}."""
    if configs is None:
        configs = {
            "fifo": SchedulerConfig(policy="fifo"),
            "backfill": SchedulerConfig(policy="backfill"),
            "batched": SchedulerConfig(policy="batched", batch_window=2.0),
        }
    out: Dict[str, AdmissionScheduler] = {}
    for name, cfg in configs.items():
        disp = dispatcher_factory()
        disp.name = f"{disp.name}[{name}]"
        sched = AdmissionScheduler(
            cluster, sim, tables, disp, cfg,
            rng=np.random.default_rng(seed),
        )
        sched.run(trace)
        out[name] = sched
    return out
