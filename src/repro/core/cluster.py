"""Cluster topology model for BandPilot.

Models an AI cluster as a set of hosts, each with a fixed number of
accelerators and a published intra-host interconnect topology.  The five GPU
host classes reproduce the paper's Appendix E tables verbatim (RTX 4090,
V100, A6000, A800, H100); a TPU v5e host class is added for the framework
integration (ICI-connected 8-chip tray).

The cluster object is pure topology + availability state.  Bandwidth
semantics live in :mod:`repro.core.bandwidth_sim`.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Link types.  P2P_BW are *unidirectional effective* GB/s used by the
# ground-truth simulator.  Magnitudes are calibrated so the H100 cluster
# reproduces the paper's Fig. 1 headline numbers (see bandwidth_sim.py);
# relative ordering follows NVIDIA topology classes.  The 4090's SYS > PXB
# inversion reproduces the paper's Fig. 2 "anti-locality" measurement.
# ---------------------------------------------------------------------------

P2P_BW: Dict[str, float] = {
    "NV16": 55.0,   # H100 NVLink4 (per-direction effective, per peer pair)
    "NV8": 28.0,    # A800 NVLink3 x8
    "NV4": 14.0,    # A6000 NVLink3 x4
    "NV2": 7.5,     # V100 NVLink2 x2
    "NV1": 4.0,     # V100 NVLink2 x1
    "PIX": 1.9,     # single PCIe switch hop
    "PXB": 1.55,    # multiple PCIe bridges (no CPU hop)
    "SYS": 1.7,     # cross-NUMA; > PXB on 4090 hosts (anti-locality, Fig. 2)
    "X": 0.0,       # self
    "ICI": 45.0,    # TPU v5e intra-tray inter-chip interconnect (per link)
}

# Static link weights used by the *Topo* compactness baseline (Algorithm 5).
# Higher = "closer".  Deliberately mirrors what a Slurm topology file would
# encode: NVLink > PCIe-switch > PCIe-bridge > cross-NUMA.
TOPO_WEIGHT: Dict[str, float] = {
    "NV16": 100.0, "NV8": 80.0, "NV4": 60.0, "NV2": 40.0, "NV1": 30.0,
    "PIX": 12.0, "PXB": 10.0, "SYS": 4.0, "X": 0.0, "ICI": 90.0,
}
INTER_HOST_TOPO_WEIGHT = 1.0  # any cross-host pair


def _sym(rows: Sequence[str]) -> List[List[str]]:
    """Parse a compact topology table (list of space-separated rows)."""
    mat = [r.split() for r in rows]
    n = len(mat)
    assert all(len(r) == n for r in mat), "topology table must be square"
    return mat


# Appendix E tables (verbatim).
_TOPOLOGY_4090 = _sym([
    "X   PXB PXB PXB SYS SYS SYS SYS",
    "PXB X   PXB PXB SYS SYS SYS SYS",
    "PXB PXB X   PIX SYS SYS SYS SYS",
    "PXB PXB PIX X   SYS SYS SYS SYS",
    "SYS SYS SYS SYS X   PXB PXB PXB",
    "SYS SYS SYS SYS PXB X   PXB PXB",
    "SYS SYS SYS SYS PXB PXB X   PIX",
    "SYS SYS SYS SYS PXB PXB PIX X",
])

_TOPOLOGY_V100 = _sym([
    "X   NV1 NV2 NV1 SYS SYS SYS NV2",
    "NV1 X   NV1 NV2 SYS SYS NV2 SYS",
    "NV2 NV1 X   NV2 SYS NV1 SYS SYS",
    "NV1 NV2 NV2 X   NV1 SYS SYS SYS",
    "SYS SYS SYS NV1 X   NV2 NV2 NV1",
    "SYS SYS NV1 SYS NV2 X   NV1 NV2",
    "SYS NV2 SYS SYS NV2 NV1 X   NV1",
    "NV2 SYS SYS SYS NV1 NV2 NV1 X",
])

_TOPOLOGY_A6000 = _sym([
    "X   NV4 PXB PXB SYS SYS SYS SYS",
    "NV4 X   PXB PXB SYS SYS SYS SYS",
    "PXB PXB X   NV4 SYS SYS SYS SYS",
    "PXB PXB NV4 X   SYS SYS SYS SYS",
    "SYS SYS SYS SYS X   NV4 PXB PXB",
    "SYS SYS SYS SYS NV4 X   PXB PXB",
    "SYS SYS SYS SYS PXB PXB X   NV4",
    "SYS SYS SYS SYS PXB PXB NV4 X",
])


def _uniform_topology(link: str, n: int = 8) -> List[List[str]]:
    return [[("X" if i == j else link) for j in range(n)] for i in range(n)]


_TOPOLOGY_A800 = _uniform_topology("NV8")
_TOPOLOGY_H100 = _uniform_topology("NV16")
_TOPOLOGY_TPU_V5E = _uniform_topology("ICI")  # 2x4 tray modeled as uniform ICI


@dataclasses.dataclass(frozen=True)
class HostType:
    """A host class: accelerator model + intra-host interconnect topology.

    Attributes:
      name: host class name (e.g. "H100").
      topology: n_gpus x n_gpus link-type matrix.
      nic_rail_bw: per-accelerator NIC ("rail") bandwidth in GB/s.  Modern
        H100 boxes are rail-optimized with one 400 Gb/s NIC per GPU; legacy
        hosts share fewer/slower NICs, expressed as a lower per-rail figure.
      nvswitch: True if intra-host fabric is a non-blocking switch (NVSwitch
        or ICI tray) rather than point-to-point links.
    """

    name: str
    topology: Tuple[Tuple[str, ...], ...]
    nic_rail_bw: float
    nvswitch: bool

    @property
    def n_gpus(self) -> int:
        return len(self.topology)

    def link(self, i: int, j: int) -> str:
        return self.topology[i][j]

    def p2p_bw(self, i: int, j: int) -> float:
        return P2P_BW[self.topology[i][j]]


def _ht(name, table, nic_rail_bw, nvswitch) -> HostType:
    return HostType(name, tuple(tuple(r) for r in table), nic_rail_bw, nvswitch)


HOST_TYPES: Dict[str, HostType] = {
    # nic_rail_bw: H100 cluster uses a 400Gb/s (50 GB/s) Quantum IB fabric,
    # rail-optimized (one rail per GPU).  The paper's heterogeneous sims set
    # the switch bandwidth to 1/4 of the H100 fabric.
    "H100": _ht("H100", _TOPOLOGY_H100, 50.0, True),
    "A800": _ht("A800", _TOPOLOGY_A800, 12.5, True),
    "A6000": _ht("A6000", _TOPOLOGY_A6000, 12.5, False),
    "V100": _ht("V100", _TOPOLOGY_V100, 12.5, False),
    "RTX4090": _ht("RTX4090", _TOPOLOGY_4090, 12.5, False),
    "TPU_V5E": _ht("TPU_V5E", _TOPOLOGY_TPU_V5E, 25.0, True),
}


@dataclasses.dataclass(frozen=True)
class Host:
    """A physical host: host class + the global ids of its accelerators."""

    host_id: int
    host_type: HostType
    gpu_ids: Tuple[int, ...]

    @property
    def n_gpus(self) -> int:
        return len(self.gpu_ids)

    def local_index(self, gpu_id: int) -> int:
        return self.gpu_ids.index(gpu_id)


class Cluster:
    """An accelerator pool: hosts, global-id mapping, availability state.

    GPUs are globally numbered 0..N-1; ``gpu_host[g]`` gives the host index
    and ``gpu_local[g]`` the index within the host (row of the topology
    table).
    """

    def __init__(self, hosts: Sequence[Tuple[str, int]], name: str = "cluster"):
        """Args:
        hosts: sequence of (host_type_name, n_hosts_of_that_type).
        """
        self.name = name
        self.hosts: List[Host] = []
        self.gpu_host: List[int] = []
        self.gpu_local: List[int] = []
        gid = 0
        hid = 0
        for type_name, count in hosts:
            ht = HOST_TYPES[type_name]
            for _ in range(count):
                ids = tuple(range(gid, gid + ht.n_gpus))
                self.hosts.append(Host(hid, ht, ids))
                for local, g in enumerate(ids):
                    self.gpu_host.append(hid)
                    self.gpu_local.append(local)
                gid += ht.n_gpus
                hid += 1
        self.n_gpus = gid
        self.n_hosts = hid

    # -- subset utilities ---------------------------------------------------

    def partition_by_host(self, subset: Sequence[int]) -> Dict[int, List[int]]:
        """Partition a set of global GPU ids by host id (Alg. 1 line 1)."""
        out: Dict[int, List[int]] = {}
        for g in subset:
            out.setdefault(self.gpu_host[g], []).append(g)
        return out

    def local_tuple(self, host_id: int, subset: Sequence[int]) -> Tuple[int, ...]:
        """Sorted local indices of ``subset`` (global ids) on ``host_id``."""
        h = self.hosts[host_id]
        return tuple(sorted(h.gpu_ids.index(g) for g in subset))

    def host_of(self, gpu_id: int) -> Host:
        return self.hosts[self.gpu_host[gpu_id]]

    def all_gpus(self) -> List[int]:
        return list(range(self.n_gpus))

    def topo_weight(self, i: int, j: int) -> float:
        """Static pairwise link weight for the Topo baseline."""
        if i == j:
            return 0.0
        hi, hj = self.gpu_host[i], self.gpu_host[j]
        if hi != hj:
            return INTER_HOST_TOPO_WEIGHT
        h = self.hosts[hi]
        return TOPO_WEIGHT[h.host_type.link(self.gpu_local[i], self.gpu_local[j])]

    def describe(self) -> str:
        parts = [f"{h.host_type.name}x{h.n_gpus}" for h in self.hosts]
        return f"{self.name}: {self.n_gpus} GPUs on {self.n_hosts} hosts ({', '.join(parts)})"


# ---------------------------------------------------------------------------
# The paper's four evaluation clusters (Table 1) + TPU pods for integration.
# ---------------------------------------------------------------------------

def h100_cluster() -> Cluster:
    """Homogeneous: 4 hosts x 8 H100 = 32 GPUs (the physical testbed)."""
    return Cluster([("H100", 4)], name="H100")


def het_ra_cluster() -> Cluster:
    """Heterogeneous: 16x RTX4090 + 16x A800 (2+2 hosts)."""
    return Cluster([("RTX4090", 2), ("A800", 2)], name="Het-RA")


def het_va_cluster() -> Cluster:
    """Heterogeneous: 16x V100 + 16x A6000 (2+2 hosts)."""
    return Cluster([("V100", 2), ("A6000", 2)], name="Het-VA")


def het_4mix_cluster() -> Cluster:
    """Heterogeneous: 8 GPUs of each of 4090/V100/A6000/A800 (4 hosts)."""
    return Cluster(
        [("RTX4090", 1), ("V100", 1), ("A6000", 1), ("A800", 1)], name="Het-4Mix"
    )


def tpu_pod_cluster(n_hosts: int = 32) -> Cluster:
    """A TPU v5e pod slice: ``n_hosts`` trays of 8 chips (256 chips default).

    Used by the framework integration: the dispatcher selects chips/hosts to
    build the production mesh from, with DCN as the inter-host fabric.
    """
    return Cluster([("TPU_V5E", n_hosts)], name=f"TPUv5e-{n_hosts * 8}")


PAPER_CLUSTERS = {
    "H100": h100_cluster,
    "Het-RA": het_ra_cluster,
    "Het-VA": het_va_cluster,
    "Het-4Mix": het_4mix_cluster,
}


def enumerate_host_subsets(n: int, k: int) -> List[Tuple[int, ...]]:
    """All k-combinations of local indices 0..n-1 (used for intra lookups)."""
    return list(itertools.combinations(range(n), k))


def availability_scenario(
    cluster: Cluster, rng: np.random.Generator, frac_busy: Optional[float] = None
) -> List[int]:
    """Sample an availability scenario: each GPU is busy w.p. ``frac_busy``.

    Mirrors the paper's evaluation protocol (Sec. 5.3): random subsets of the
    pool are marked unavailable for each request.
    """
    if frac_busy is None:
        frac_busy = float(rng.uniform(0.0, 0.5))
    mask = rng.random(cluster.n_gpus) >= frac_busy
    avail = [g for g in range(cluster.n_gpus) if mask[g]]
    if not avail:  # never return an empty pool
        avail = [int(rng.integers(cluster.n_gpus))]
    return avail
