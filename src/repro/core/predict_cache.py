"""Ledger-versioned prediction memo + unified predictor instrumentation.

The dispatch fast path re-scores the same subsets many times within one
admission: EHA's phase-2 winner is re-scored by PTS and by the hybrid
arbiter, a PTS round's winner is re-predicted as the final subset, joint
batched placement re-scores every plan against the final scratch state, and
trial moves re-grade co-tenants.  All of those are pure functions of
``(subset, ledger occupancy)`` — so one memo keyed by ``(subset tuple,
ledger version, mode)`` makes every repeat free.

**Invalidation contract.**  :class:`~repro.core.tenancy.JobLedger` carries a
monotonic ``version`` counter bumped on every admit/release.  A versioned
cache entry is valid for exactly one version: any occupancy change makes
every outstanding key stale *by construction* (no explicit invalidation
hooks, nothing to forget to call).  Because the counter only grows, entries
from an exactly-restored ledger state are conservatively dropped too —
correctness never depends on state comparison.  Ledger-independent
predictors (the isolated surrogate: B̂(S) never changes while the params are
fixed) opt out with ``versioned=False`` and keep their entries for the
process lifetime (bounded by ``max_entries``).

:class:`PredictorStats` is the one instrumentation record every predictor
in the stack carries (``.stats``): model calls, cumulative predict time,
its featurize/inference split, contention-wrapper overhead, degradation and
cache-hit counters.  Legacy attribute names (``n_model_calls``,
``predict_seconds``, ``n_capped``) remain readable/writable properties on
the predictors themselves.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class PredictorStats:
    """Shared instrumentation for every predictor in the dispatch stack."""

    n_model_calls: int = 0        # candidates sent through a Transformer
    predict_seconds: float = 0.0  # total wall time inside predict()
    featurize_seconds: float = 0.0  # ... spent building token batches
    infer_seconds: float = 0.0      # ... spent in jitted model applies
    wrapper_seconds: float = 0.0    # contention-wrap overhead (excl. base)
    n_capped: int = 0             # candidates whose estimate was degraded
    cache_hits: int = 0
    cache_misses: int = 0

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, f.default)

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)

    @property
    def hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    @staticmethod
    def merged(*stats: "PredictorStats") -> "PredictorStats":
        out = PredictorStats()
        for s in stats:
            for f in dataclasses.fields(PredictorStats):
                setattr(out, f.name, getattr(out, f.name) + getattr(s, f.name))
        return out


def collect_stats(*predictors) -> PredictorStats:
    """Merge the ``.stats`` of every distinct predictor in a chain (wrappers
    expose their wrapped predictor as ``.base``; shared bases dedup by id)."""
    seen = {}
    for p in predictors:
        while p is not None:
            if id(p) in seen:
                break
            seen[id(p)] = p
            p = getattr(p, "base", None)
    return PredictorStats.merged(
        *(p.stats for p in seen.values() if hasattr(p, "stats"))
    )


_UNVERSIONED = -1


class PredictionCache:
    """Memo of predictor outputs keyed by ``(subset, ledger version, mode)``.

    One cache binds one ledger (or none).  Versioned entries live in a
    window store that is cleared whenever the observed ledger version moves,
    so stale keys never accumulate; unversioned (ledger-independent) entries
    persist up to ``max_entries`` with oldest-first eviction.
    ``wrap(predictor, mode)`` returns a :class:`CachedPredictor` view; any
    number of predictors may share one cache under distinct mode tags.
    """

    def __init__(self, ledger=None, max_entries: int = 1 << 18):
        self.ledger = ledger
        self.max_entries = max_entries
        self._static: Dict[Tuple, float] = {}
        self._window: Dict[Tuple, float] = {}
        self._window_version = _UNVERSIONED
        self.stats = PredictorStats()  # aggregate hit/miss across wrappers

    def version(self) -> int:
        return self.ledger.version if self.ledger is not None else _UNVERSIONED

    def wrap(self, predictor, mode: str, versioned: bool = True):
        return CachedPredictor(self, predictor, mode, versioned=versioned)

    def invalidate(self) -> None:
        self._static.clear()
        self._window.clear()

    def __len__(self) -> int:
        return len(self._static) + len(self._window)

    # -- store selection ----------------------------------------------------

    def store_for(self, versioned: bool) -> Dict[Tuple, float]:
        if not versioned:
            if len(self._static) >= self.max_entries:
                # oldest-first eviction: drop the first-inserted half
                for key in list(self._static)[: self.max_entries // 2]:
                    del self._static[key]
            return self._static
        v = self.version()
        if v != self._window_version:
            # occupancy changed: every outstanding versioned entry is stale
            self._window.clear()
            self._window_version = v
        return self._window


class CachedPredictor:
    """Predictor-protocol view over a :class:`PredictionCache`.

    Exposes the same ``predict(list_of_subsets) -> np.ndarray`` protocol the
    hybrid search consumes (plus ``predict_children`` when the wrapped
    predictor has a fused elimination path), so it threads through
    ``search.hybrid_search`` unchanged.  Unknown attributes delegate to the
    wrapped predictor.
    """

    def __init__(self, cache: PredictionCache, base, mode: str,
                 versioned: bool = True):
        self.cache = cache
        self.base = base
        self.mode = mode
        self.versioned = versioned
        self.stats = PredictorStats()  # this wrapper's hit/miss counters

    def __getattr__(self, name):
        return getattr(self.base, name)

    def _lookup(self, subsets: Sequence[Sequence[int]]):
        store = self.cache.store_for(self.versioned)
        keys = [(tuple(s), self.mode) for s in subsets]
        out = np.empty((len(subsets),), np.float64)
        miss = []
        for i, key in enumerate(keys):
            val = store.get(key)
            if val is None:
                miss.append(i)
            else:
                out[i] = val
        return store, keys, out, miss

    def _account(self, n_hits: int, n_misses: int) -> None:
        for s in (self.stats, self.cache.stats):
            s.cache_hits += n_hits
            s.cache_misses += n_misses

    def predict(self, subsets: Sequence[Sequence[int]]) -> np.ndarray:
        store, keys, out, miss = self._lookup(subsets)
        if miss:
            preds = np.asarray(
                self.base.predict([subsets[i] for i in miss]), np.float64
            )
            for i, p in zip(miss, preds):
                out[i] = p
                store[keys[i]] = float(p)
        self._account(len(subsets) - len(miss), len(miss))
        return out

    def predict_children(self, parent: Sequence[int]) -> np.ndarray:
        """One elimination round, deduplicated against the cache: a full
        miss runs the wrapped predictor's fused featurize+predict path; any
        hit degrades only the missing children to the ordinary batch
        predict."""
        parent = list(parent)
        children = [parent[:i] + parent[i + 1:] for i in range(len(parent))]
        store, keys, out, miss = self._lookup(children)
        if miss:
            if len(miss) == len(children) and hasattr(
                self.base, "predict_children"
            ):
                preds = np.asarray(
                    self.base.predict_children(parent), np.float64
                )
            else:
                preds = np.empty((len(children),), np.float64)
                got = np.asarray(
                    self.base.predict([children[i] for i in miss]), np.float64
                )
                preds[miss] = got
            for i in miss:
                out[i] = preds[i]
                store[keys[i]] = float(out[i])
        self._account(len(children) - len(miss), len(miss))
        return out

    def predict_one(self, subset: Sequence[int]) -> float:
        return float(self.predict([subset])[0])


def cached_contention_predictor(
    cluster,
    base,
    ledger,
    mode: str = "analytic",
    contended=None,
    use_cache: bool = True,
    vectorized: bool = True,
    stats_sink: Optional[PredictorStats] = None,
):
    """The standard fast-path predictor chain for one ledger: a
    :class:`~repro.core.contention.ContentionAwarePredictor` over ``base``,
    wrapped in a ledger-versioned cache.  ``use_cache=False`` /
    ``vectorized=False`` reproduce the pre-PR path (the before-side of the
    throughput bench).  ``stats_sink`` substitutes a caller-owned
    :class:`PredictorStats` for the chain's counters — scratch searches
    (joint orders, defrag proposals) pass their dispatcher's wrapper stats
    so per-phase breakdowns do not lose the throwaway wrappers' time."""
    from repro.core.contention import ContentionAwarePredictor

    inner = ContentionAwarePredictor(
        cluster, base, ledger, mode=mode, contended=contended,
        vectorized=vectorized,
    )
    if stats_sink is not None:
        inner.stats = stats_sink
    if not use_cache:
        return inner
    cached = PredictionCache(ledger).wrap(inner, mode=mode, versioned=True)
    if stats_sink is not None:
        cached.stats = stats_sink
    return cached


class GradingCache:
    """Ledger-versioned memo over ``sim.true_bandwidth(S, ledger)`` — the
    grading-side twin of :class:`PredictionCache`, for the trial-move /
    defrag machinery that scores placements with the simulator rather than
    a predictor.  Duck-types the one method those paths consume; keys carry
    the ledger's ``(uid, version)`` so scratch copies never collide."""

    def __init__(self, sim, max_entries: int = 1 << 17):
        self.sim = sim
        self.max_entries = max_entries
        self._memo: Dict[Tuple, float] = {}
        self.stats = PredictorStats()

    def true_bandwidth(self, subset, ledger=None) -> float:
        if ledger is None:
            key = (tuple(sorted(subset)), _UNVERSIONED, _UNVERSIONED)
        else:
            key = (tuple(sorted(subset)), ledger.uid, ledger.version)
        val = self._memo.get(key)
        if val is None:
            self.stats.cache_misses += 1
            val = self.sim.true_bandwidth(subset, ledger=ledger)
            if len(self._memo) >= self.max_entries:
                for k in list(self._memo)[: self.max_entries // 2]:
                    del self._memo[k]
            self._memo[key] = val
        else:
            self.stats.cache_hits += 1
        return val
