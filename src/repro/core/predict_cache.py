"""Ledger-versioned prediction memo + unified predictor instrumentation.

The dispatch fast path re-scores the same subsets many times within one
admission: EHA's phase-2 winner is re-scored by PTS and by the hybrid
arbiter, a PTS round's winner is re-predicted as the final subset, joint
batched placement re-scores every plan against the final scratch state, and
trial moves re-grade co-tenants.  All of those are pure functions of
``(subset, ledger occupancy)`` — so one memo keyed by ``(subset tuple,
ledger version, mode)`` makes every repeat free.

**Invalidation contract.**  :class:`~repro.core.tenancy.JobLedger` carries a
monotonic ``version`` counter bumped on every admit/release.  A versioned
cache entry is valid for exactly one version: any occupancy change makes
every outstanding key stale *by construction* (no explicit invalidation
hooks, nothing to forget to call).  Because the counter only grows, entries
from an exactly-restored ledger state are conservatively dropped too —
correctness never depends on state comparison.  Ledger-independent
predictors (the isolated surrogate: B̂(S) never changes while the params are
fixed) opt out with ``versioned=False`` and keep their entries for the
process lifetime (bounded by ``max_entries``).

:class:`PredictorStats` is the one instrumentation record every predictor
in the stack carries (``.stats``): model calls, cumulative predict time,
its featurize/inference split, contention-wrapper overhead, degradation and
cache-hit counters.  Legacy attribute names (``n_model_calls``,
``predict_seconds``, ``n_capped``) remain readable/writable properties on
the predictors themselves.  The fused on-device elimination path
(``SurrogatePredictor.eliminate_to``) cannot split featurize from inference
per round — the whole descent is one device call — so it reports a single
``scan_seconds`` bucket plus the device-step count, and bumps *neither*
``n_model_calls`` nor the featurize/infer split (no double-counting when
``collect_stats`` merges a chain).

This module is also home to :class:`InferenceBatcher`, the cross-search
apply fuser: threads running concurrent hybrid searches (joint batched
placement order-candidates, defrag trial moves) register with
``with batcher.worker():`` and their surrogate applies are padded and fused
into one shared jitted call — the same continuous-batching trick serving
engines use.  Fusion is value-neutral: the Transformer is row- and
pad-independent (regression-pinned in ``tests/test_ondevice_scan.py``), so
batched outputs are bit-identical to per-search applies.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class PredictorStats:
    """Shared instrumentation for every predictor in the dispatch stack."""

    n_model_calls: int = 0        # candidates sent through a Transformer
    predict_seconds: float = 0.0  # total wall time inside predict()
    featurize_seconds: float = 0.0  # ... spent building token batches
    infer_seconds: float = 0.0      # ... spent in jitted model applies
    scan_seconds: float = 0.0     # wall time inside fused on-device descents
    n_scan_steps: int = 0         # elimination rounds executed on-device
    wrapper_seconds: float = 0.0    # contention-wrap overhead (excl. base)
    n_capped: int = 0             # candidates whose estimate was degraded
    cache_hits: int = 0
    cache_misses: int = 0

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, f.default)

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)

    # legacy name (pre-dates the unified to_dict convention across stats)
    def as_dict(self) -> Dict[str, float]:
        return self.to_dict()

    @property
    def hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    @staticmethod
    def merged(*stats: "PredictorStats") -> "PredictorStats":
        out = PredictorStats()
        for s in stats:
            for f in dataclasses.fields(PredictorStats):
                setattr(out, f.name, getattr(out, f.name) + getattr(s, f.name))
        return out


def collect_stats(*predictors) -> PredictorStats:
    """Merge the ``.stats`` of every distinct predictor in a chain (wrappers
    expose their wrapped predictor as ``.base``; shared bases dedup by id)."""
    seen = {}
    for p in predictors:
        while p is not None:
            if id(p) in seen:
                break
            seen[id(p)] = p
            p = getattr(p, "base", None)
    return PredictorStats.merged(
        *(p.stats for p in seen.values() if hasattr(p, "stats"))
    )


_UNVERSIONED = -1


class LruDict(OrderedDict):
    """Bounded dict with least-recently-used eviction.

    Reads (``get`` / ``[]``) refresh recency; inserts past ``max_entries``
    evict the least-recently-used entry.  Eviction only forgets memoized
    values — every value is a pure function of its key — so capping a cache
    can never change what a lookup-or-recompute path returns, only how often
    it recomputes (property-tested in ``tests/test_ondevice_scan.py``).

    Every operation is a read-modify-write *pair* (lookup + move_to_end,
    insert + evict), so GIL atomicity of the individual C calls is not
    enough once admissions overlap: interleaved pairs can corrupt the
    recency order (move_to_end on a concurrently evicted key) or evict the
    entry another thread just promoted.  A reentrant lock makes each
    operation atomic — it is uncontended in the serial paths and the
    hammer test in ``tests/test_controlplane.py`` pins the concurrent
    behaviour.
    """

    def __init__(self, max_entries: int):
        super().__init__()
        self.max_entries = int(max_entries)
        self._lock = threading.RLock()  # get() re-enters via __getitem__

    def __getitem__(self, key):
        with self._lock:
            val = super().__getitem__(key)
            self.move_to_end(key)
            return val

    def get(self, key, default=None):
        with self._lock:
            try:
                return self[key]
            except KeyError:
                return default

    def __setitem__(self, key, value):
        with self._lock:
            super().__setitem__(key, value)
            self.move_to_end(key)
            # evict with del, not popitem(): OrderedDict.popitem re-enters
            # the subclass __getitem__ after unlinking the key, which would
            # trip the recency refresh on a half-removed entry
            while len(self) > self.max_entries:
                del self[next(iter(self))]


class PredictionCache:
    """Memo of predictor outputs keyed by ``(subset, ledger version, mode)``.

    One cache binds one ledger (or none).  Versioned entries live in a
    window store that is cleared whenever the observed ledger version moves,
    so stale keys never accumulate; unversioned (ledger-independent) entries
    persist up to ``max_entries`` with oldest-first eviction.
    ``wrap(predictor, mode)`` returns a :class:`CachedPredictor` view; any
    number of predictors may share one cache under distinct mode tags.
    """

    def __init__(self, ledger=None, max_entries: int = 1 << 18):
        self.ledger = ledger
        self.max_entries = max_entries
        self._static: Dict[Tuple, float] = LruDict(max_entries)
        self._window: Dict[Tuple, float] = {}
        self._window_version = _UNVERSIONED
        self.stats = PredictorStats()  # aggregate hit/miss across wrappers

    def version(self) -> int:
        return self.ledger.version if self.ledger is not None else _UNVERSIONED

    def wrap(self, predictor, mode: str, versioned: bool = True):
        return CachedPredictor(self, predictor, mode, versioned=versioned)

    def invalidate(self) -> None:
        self._static.clear()
        self._window.clear()

    def __len__(self) -> int:
        return len(self._static) + len(self._window)

    # -- store selection ----------------------------------------------------

    def store_for(self, versioned: bool) -> Dict[Tuple, float]:
        if not versioned:
            # the lifetime memo self-bounds: LruDict evicts on insert
            return self._static
        v = self.version()
        if v != self._window_version:
            # occupancy changed: clear for memory hygiene.  Correctness no
            # longer depends on this — entry keys carry the version (see
            # CachedPredictor._lookup), so a racing clear/insert can only
            # leave an unreachable entry behind, never serve a stale one.
            self._window.clear()
            self._window_version = v
        return self._window


class CachedPredictor:
    """Predictor-protocol view over a :class:`PredictionCache`.

    Exposes the same ``predict(list_of_subsets) -> np.ndarray`` protocol the
    hybrid search consumes (plus ``predict_children`` when the wrapped
    predictor has a fused elimination path), so it threads through
    ``search.hybrid_search`` unchanged.  Unknown attributes delegate to the
    wrapped predictor.
    """

    def __init__(self, cache: PredictionCache, base, mode: str,
                 versioned: bool = True):
        self.cache = cache
        self.base = base
        self.mode = mode
        self.versioned = versioned
        self.stats = PredictorStats()  # this wrapper's hit/miss counters

    def __getattr__(self, name):
        return getattr(self.base, name)

    def _lookup(self, subsets: Sequence[Sequence[int]]):
        store = self.cache.store_for(self.versioned)
        # the ledger version is part of the KEY, not just the window-clear
        # trigger: a worker that looked up at version v, computed through
        # the base predictor while another thread committed (bumping the
        # version and clearing the window), then stored its result, writes
        # an entry reachable only by v-keyed lookups — a cross-version hit
        # is impossible by construction, not just by clearing discipline
        v = self.cache.version() if self.versioned else _UNVERSIONED
        keys = [(tuple(s), self.mode, v) for s in subsets]
        out = np.empty((len(subsets),), np.float64)
        miss = []
        for i, key in enumerate(keys):
            val = store.get(key)
            if val is None:
                miss.append(i)
            else:
                out[i] = val
        return store, keys, out, miss

    def _account(self, n_hits: int, n_misses: int) -> None:
        for s in (self.stats, self.cache.stats):
            s.cache_hits += n_hits
            s.cache_misses += n_misses

    def predict(self, subsets: Sequence[Sequence[int]]) -> np.ndarray:
        store, keys, out, miss = self._lookup(subsets)
        if miss:
            preds = np.asarray(
                self.base.predict([subsets[i] for i in miss]), np.float64
            )
            for i, p in zip(miss, preds):
                out[i] = p
                store[keys[i]] = float(p)
        self._account(len(subsets) - len(miss), len(miss))
        return out

    def predict_children(self, parent: Sequence[int]) -> np.ndarray:
        """One elimination round, deduplicated against the cache: a full
        miss runs the wrapped predictor's fused featurize+predict path; any
        hit degrades only the missing children to the ordinary batch
        predict."""
        parent = list(parent)
        children = [parent[:i] + parent[i + 1:] for i in range(len(parent))]
        store, keys, out, miss = self._lookup(children)
        if miss:
            if len(miss) == len(children) and hasattr(
                self.base, "predict_children"
            ):
                preds = np.asarray(
                    self.base.predict_children(parent), np.float64
                )
            else:
                preds = np.empty((len(children),), np.float64)
                got = np.asarray(
                    self.base.predict([children[i] for i in miss]), np.float64
                )
                preds[miss] = got
            for i in miss:
                out[i] = preds[i]
                store[keys[i]] = float(out[i])
        self._account(len(children) - len(miss), len(miss))
        return out

    def predict_one(self, subset: Sequence[int]) -> float:
        return float(self.predict([subset])[0])


def cached_contention_predictor(
    cluster,
    base,
    ledger,
    mode: str = "analytic",
    contended=None,
    use_cache: bool = True,
    vectorized: bool = True,
    stats_sink: Optional[PredictorStats] = None,
):
    """The standard fast-path predictor chain for one ledger: a
    :class:`~repro.core.contention.ContentionAwarePredictor` over ``base``,
    wrapped in a ledger-versioned cache.  ``use_cache=False`` /
    ``vectorized=False`` reproduce the pre-PR path (the before-side of the
    throughput bench).  ``stats_sink`` substitutes a caller-owned
    :class:`PredictorStats` for the chain's counters — scratch searches
    (joint orders, defrag proposals) pass their dispatcher's wrapper stats
    so per-phase breakdowns do not lose the throwaway wrappers' time."""
    from repro.core.contention import ContentionAwarePredictor

    inner = ContentionAwarePredictor(
        cluster, base, ledger, mode=mode, contended=contended,
        vectorized=vectorized,
    )
    if stats_sink is not None:
        inner.stats = stats_sink
    if not use_cache:
        return inner
    cached = PredictionCache(ledger).wrap(inner, mode=mode, versioned=True)
    if stats_sink is not None:
        cached.stats = stats_sink
    return cached


class GradingCache:
    """Ledger-versioned memo over ``sim.true_bandwidth(S, ledger)`` — the
    grading-side twin of :class:`PredictionCache`, for the trial-move /
    defrag machinery that scores placements with the simulator rather than
    a predictor.  Duck-types the one method those paths consume; keys carry
    the ledger's ``(uid, version)`` so scratch copies never collide."""

    def __init__(self, sim, max_entries: int = 1 << 17):
        self.sim = sim
        self.max_entries = max_entries
        self._memo: Dict[Tuple, float] = LruDict(max_entries)
        self.stats = PredictorStats()

    def true_bandwidth(self, subset, ledger=None) -> float:
        if ledger is None:
            key = (tuple(sorted(subset)), _UNVERSIONED, _UNVERSIONED)
        else:
            key = (tuple(sorted(subset)), ledger.uid, ledger.version)
        val = self._memo.get(key)
        if val is None:
            self.stats.cache_misses += 1
            val = self.sim.true_bandwidth(subset, ledger=ledger)
            self._memo[key] = val
        else:
            self.stats.cache_hits += 1
        return val


# ---------------------------------------------------------------------------
# Cross-search inference batching
# ---------------------------------------------------------------------------

_TLS = threading.local()


def active_batcher() -> Optional["InferenceBatcher"]:
    """The :class:`InferenceBatcher` the calling thread registered with (via
    ``batcher.worker()``), or None.  Consulted by the surrogate apply paths
    so batching needs no plumbing through the predictor protocol."""
    return getattr(_TLS, "batcher", None)


class _PendingApply:
    __slots__ = ("key", "fn", "params", "feats", "mask", "out", "done")

    def __init__(self, fn, params, feats, mask):
        self.key = (id(fn), id(params))
        self.fn = fn
        self.params = params
        self.feats = feats
        self.mask = mask
        self.out = None
        self.done = False


def _round_up_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


class InferenceBatcher:
    """Fuses surrogate applies from concurrent searches into shared calls.

    Worker threads (one per joint-order candidate, or the single defrag
    proposal thread) register with ``with batcher.worker():``.  Inside the
    block every jitted apply routes through :meth:`apply`, which parks the
    request until each registered worker has one pending — or a short
    timeout fires, so a worker stuck featurizing never stalls the others —
    then pads all same-model requests into ONE fused apply and hands every
    caller its own rows back.

    Value-neutrality: requests are grouped by ``(model fn, params)``; token
    dims are zero-padded to the group maximum and the batch dim to a power
    of two with sentinel rows (``mask[:, 0] = 1``), exactly the padding the
    un-batched apply path performs.  The Transformer is row-independent and
    pad-independent (regression-pinned), so whichever requests happen to fuse,
    every caller receives bit-identical outputs to a solo apply.  Timing
    variation can change *grouping*, never *values*.
    """

    def __init__(self, wait_timeout: float = 0.005):
        self.wait_timeout = wait_timeout
        self._cv = threading.Condition()
        self._workers = 0
        self._pending: List[_PendingApply] = []
        self.n_requests = 0
        self.n_fused_applies = 0

    @contextlib.contextmanager
    def worker(self):
        prev = getattr(_TLS, "batcher", None)
        _TLS.batcher = self
        with self._cv:
            self._workers += 1
        try:
            yield self
        finally:
            _TLS.batcher = prev
            with self._cv:
                self._workers -= 1
                # a departing worker may be the one a barrier was waiting
                # on: wake parked requests so they flush without it
                self._cv.notify_all()

    def apply(self, fn, params, feats: np.ndarray, mask: np.ndarray):
        """Submit one ``fn(params, feats, mask)`` apply; blocks until the
        fused call containing it completes.  Returns exactly ``len(feats)``
        decoded rows."""
        entry = _PendingApply(fn, params, feats, mask)
        with self._cv:
            self._pending.append(entry)
            self.n_requests += 1
            while not entry.done:
                if len(self._pending) >= max(self._workers, 1):
                    self._flush_locked()
                else:
                    self._cv.wait(self.wait_timeout)
                    if not entry.done:
                        # timeout or a worker departed: flush what we have
                        self._flush_locked()
        return entry.out

    def _flush_locked(self) -> None:
        pending, self._pending = self._pending, []
        groups: Dict[Tuple[int, int], List[_PendingApply]] = {}
        for e in pending:
            groups.setdefault(e.key, []).append(e)
        for entries in groups.values():
            self._fuse(entries)
        self.n_fused_applies += len(groups)
        self._cv.notify_all()

    @staticmethod
    def _fuse(entries: List[_PendingApply]) -> None:
        import jax.numpy as jnp  # deferred: keep module import jax-free

        fn, params = entries[0].fn, entries[0].params
        T = max(e.feats.shape[1] for e in entries)
        B = sum(e.feats.shape[0] for e in entries)
        Bp = _round_up_pow2(max(B, 1))
        F = entries[0].feats.shape[2]
        feats = np.zeros((Bp, T, F), entries[0].feats.dtype)
        mask = np.zeros((Bp, T), entries[0].mask.dtype)
        mask[B:, 0] = 1.0  # sentinel rows, same as the solo apply path
        off = 0
        for e in entries:
            b, t = e.feats.shape[:2]
            feats[off:off + b, :t] = e.feats
            mask[off:off + b, :t] = e.mask
            off += b
        out = np.asarray(fn(params, jnp.asarray(feats), jnp.asarray(mask)))
        off = 0
        for e in entries:
            b = e.feats.shape[0]
            e.out = out[off:off + b]
            e.done = True
            off += b
