"""Ground-truth collective-bandwidth simulator.

The paper's own heterogeneous evaluation (Sec. 5.1.1) synthesizes end-to-end
bandwidth as *"the minimum of the pre-computed intra-host bandwidths of the
involved hosts and the modeled inter-host link bandwidth"*.  We implement
exactly that bottleneck composition, with the two terms modeled as:

**Intra-host term** (per host h with n_h selected GPUs):
  - switch-fabric hosts (NVSwitch H100/A800, TPU ICI tray): uniform links, so
    aggregate bandwidth = p2p * n_h, derated to 0.82 for counts not in
    {1,2,4,8} (Li et al. [11]: NVSwitch is near-ideal only at balanced
    counts).
  - point-to-point hosts (4090/V100/A6000): NCCL builds a ring through the
    best links; we brute-force the max-bottleneck Hamiltonian cycle over the
    selected GPUs and take aggregate = bottleneck_p2p * n_h.
  The whole-collective constraint contributed by host h is
  ``C_intra(h) = k * intra_aggregate(S_h) / n_h`` (every rank of the k-way
  collective is rate-limited by the slowest host's per-GPU throughput).

**Inter-host term** (rail model): modern fabrics are rail-optimized (one NIC
rail per GPU).  Cross-host rings can only keep ``min_h n_h`` rails fully
busy; hosts with more selected GPUs funnel traffic through the partner
host's fewer rails.  With all-reduce accounting (2(k-1)/k) and a fabric
efficiency eta:

  ``C_inter = rail_bw * min_h(n_h) * 2(k-1)/k * eta``.

This reproduces the paper's Fig. 1 headline measurements on the H100 cluster
(paper -> model): 4+4: 337.2 -> 322.0; 6+2: 153.4 -> 161.0; 5+5: 412.5 ->
414.0; 8+2: 157.3 -> 165.6 GB/s — within 5% everywhere, with the *ordering*
(the thing dispatchers are graded on) exactly preserved.

``B(S) = min(min_h C_intra(h), C_inter)`` for multi-host S, else the intra
aggregate.  A deterministic +-2% per-(host,subset) jitter makes the
landscape non-degenerate (distinct optima) while remaining reproducible; an
optional Gaussian noise models nccl-tests measurement error for training
data only.

**Multi-tenant contention** (Sec. 4.4): when a :class:`~repro.core.tenancy.
JobLedger` of live jobs is supplied, each host's NIC rails are fair-shared
among the collectives crossing them.  With ``c_h`` concurrent cross-host
collectives on host h (the candidate plus every GPU-disjoint live cross-host
job occupying h), the effective per-rail bandwidth on h drops to
``rail_bw(h) / c_h`` and the inter-host term becomes

  ``C_inter = min_h(rail_bw(h) / c_h) * min_h(n_h) * 2(k-1)/k * eta``.

Intra-host terms are unaffected (NVSwitch/ring traffic stays private to the
job's own GPUs).  With an empty ledger every ``c_h`` is 1 and the expression
— including the deterministic jitter — reduces *exactly* to the isolated
``B(S)``, so releasing all co-tenants provably restores isolated bandwidth.

**Contention models.**  The fair split above is ``contention="fair"`` (the
default, bit-identical to the PR-1 behaviour).  ``contention="saturating"``
is the richer ground truth the *learned* contention subsystem trains
against: real fabrics neither split evenly nor multiplex for free.  The
candidate's share of host h's rail capacity becomes

  ``share_h = (n_h / (n_h + sum_j w_jh)) * 1 / (1 + alpha_h * (c_h - 1))``

where ``w_jh`` is contender j's GPU count on h (demand-weighted sharing: a
2-GPU tail of a cross-host job draws less rail traffic than an 8-GPU one)
and the second factor models the non-linear goodput loss of multiplexing
``c_h`` collectives through one NIC stack, with ``alpha_h`` keyed to the
host class (link heterogeneity: legacy shared-NIC hosts degrade ~2.5x
harder than modern rail-optimized fabrics).  With an empty ledger
``share_h = 1`` and the model is again *exactly* the isolated ``B(S)``.
The analytic virtual-merge estimator keeps predicting the even split — by
design: the gap between the two is what the learned surrogate absorbs
(see ``docs/contention.md``).
"""

from __future__ import annotations

import hashlib
import itertools
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import Cluster, Host, HostType, P2P_BW

# Calibration constants (see module docstring).
UNBALANCED_SWITCH_EFF = 0.82   # NVSwitch derate for counts not in {1,2,4,8}
INTER_EFF = 0.92               # fabric efficiency eta
SINGLE_GPU_BW = 500.0          # "bandwidth" of a 1-GPU allocation (no comm)
JITTER = 0.02                  # deterministic per-subset jitter amplitude
BW_SCALE = 500.0               # normalization scale for model features/targets
BALANCED_COUNTS = (1, 2, 4, 8)

# Saturating contention model (see module docstring): per-host-class
# multiplexing loss.  Modern rail-optimized fabrics (>= 25 GB/s per rail:
# H100, TPU trays) time-slice collectives with little overhead; legacy
# shared-NIC hosts pay heavily for concurrent flows.
CONTENTION_MODELS = ("fair", "saturating")
SATURATION_ALPHA_FAST = 0.08
SATURATION_ALPHA_SLOW = 0.20
_FAST_RAIL_BW = 25.0


def _stable_unit_hash(*key) -> float:
    """Deterministic hash of ``key`` -> float in [-1, 1)."""
    h = hashlib.md5(repr(key).encode()).digest()
    v = int.from_bytes(h[:8], "little") / 2**64  # [0, 1)
    return 2.0 * v - 1.0


def _jitter(*key) -> float:
    return 1.0 + JITTER * _stable_unit_hash(*key)


def ring_bottleneck_bw(host_type: HostType, local_subset: Sequence[int]) -> float:
    """Max-over-rings of the min p2p link along the ring (GB/s).

    NCCL searches for the best ring through the topology; for <=8 GPUs we can
    afford exact enumeration (fix the first element, permute the rest).
    """
    sub = tuple(sorted(local_subset))
    n = len(sub)
    if n == 1:
        return SINGLE_GPU_BW
    if n == 2:
        return host_type.p2p_bw(sub[0], sub[1])
    best = 0.0
    first = sub[0]
    for perm in itertools.permutations(sub[1:]):
        ring = (first,) + perm
        bottleneck = min(
            host_type.p2p_bw(ring[i], ring[(i + 1) % n]) for i in range(n)
        )
        if bottleneck > best:
            best = bottleneck
    return best


def intra_aggregate_bw(host_type: HostType, local_subset: Sequence[int]) -> float:
    """Aggregate effective collective bandwidth of a within-host subset."""
    n = len(local_subset)
    if n == 0:
        raise ValueError("empty subset")
    if n == 1:
        return SINGLE_GPU_BW
    if host_type.nvswitch:
        link = host_type.link(local_subset[0], local_subset[1])
        eff = 1.0 if n in BALANCED_COUNTS else UNBALANCED_SWITCH_EFF
        return P2P_BW[link] * n * eff
    return ring_bottleneck_bw(host_type, local_subset) * n


def inter_constraint_bw(
    counts: Sequence[int], rail_bw: float, k: int, eta: float = INTER_EFF
) -> float:
    """Rail-model inter-host capacity for a multi-host allocation."""
    return rail_bw * min(counts) * (2.0 * (k - 1) / k) * eta


def saturation_alpha(host_type) -> float:
    """Multiplexing-loss coefficient of a host class (link heterogeneity)."""
    return (
        SATURATION_ALPHA_FAST
        if host_type.nic_rail_bw >= _FAST_RAIL_BW
        else SATURATION_ALPHA_SLOW
    )


def saturating_rail_share(
    n_h: int, demands: Sequence[int], alpha: float
) -> float:
    """Candidate's share of one host's rail capacity under the saturating
    model: demand-weighted split times the non-linear multiplexing loss.
    No contenders -> exactly 1.0 (the isolated rail)."""
    c = 1 + len(demands)
    if c == 1:
        return 1.0
    return (n_h / (n_h + sum(demands))) / (1.0 + alpha * (c - 1))


def contended_inter_term(
    cluster, by_host: Dict[int, List[int]], rail_contenders,
    eta: float = INTER_EFF, rail_share=None, rail_factor=None,
) -> float:
    """THE jittered, fair-shared inter-host term — the single definition the
    contended ground truth and the virtual-merge estimator both evaluate, so
    the two can never drift apart.

    ``rail_contenders(host_id) -> c_h`` supplies the number of collectives
    (candidate included) competing for that host's NIC rails.  When
    ``rail_share(host_id) -> fraction`` is given (the saturating model) it
    replaces the even ``1 / c_h`` split; the default path is bit-identical
    to the historical fair split.  ``rail_factor(host_id) -> f`` is the
    health-degrade multiplier on the host's NIC rail (nic_flap /
    link_degrade faults, see :mod:`repro.core.faults`); applied to the
    rail capacity *before* contention sharing, and absent (None) on
    healthy fabric so the no-fault path is byte-identical.
    """
    counts: List[int] = []
    rail = float("inf")
    for hid, gpus in by_host.items():
        counts.append(len(gpus))
        host = cluster.hosts[hid]
        nic = host.host_type.nic_rail_bw
        if rail_factor is not None:
            nic = nic * rail_factor(hid)
        if rail_share is None:
            rail = min(rail, nic / rail_contenders(hid))
        else:
            rail = min(rail, nic * rail_share(hid))
    k = sum(counts)
    inter = inter_constraint_bw(counts, rail, k, eta=eta)
    return inter * _jitter(
        cluster.name, "inter", tuple(sorted(zip(by_host.keys(), counts)))
    )


class BandwidthSimulator:
    """Ground-truth B(S) for a :class:`Cluster` (the paper's black box).

    Also serves as the *measurement apparatus*: ``measure`` adds Gaussian
    noise emulating an nccl-tests run, ``true_bandwidth`` is noiseless and is
    what GBE is computed against.
    """

    def __init__(
        self,
        cluster: Cluster,
        noise_std: float = 0.01,
        contention: str = "fair",
    ):
        if contention not in CONTENTION_MODELS:
            raise ValueError(
                f"unknown contention model {contention!r}; "
                f"expected one of {CONTENTION_MODELS}"
            )
        self.cluster = cluster
        self.noise_std = noise_std
        self.contention = contention
        self._intra_cache: Dict[Tuple[int, Tuple[int, ...]], float] = {}

    # -- intra-host ---------------------------------------------------------

    def intra_bandwidth(
        self, host_id: int, local_subset: Sequence[int], ledger=None
    ) -> float:
        """Jittered intra-host aggregate bandwidth (per host *instance*).

        With a health-carrying ``ledger``, a degraded host scales its intra
        term by the degrade factor — applied *outside* the cache, which
        stores only the permanent (host, subset) jittered base."""
        key = (host_id, tuple(sorted(local_subset)))
        if key not in self._intra_cache:
            host = self.cluster.hosts[host_id]
            base = intra_aggregate_bw(host.host_type, key[1])
            self._intra_cache[key] = base * _jitter(
                self.cluster.name, host_id, key[1]
            )
        bw = self._intra_cache[key]
        if ledger is not None and getattr(ledger, "health_active", False):
            f = ledger.host_degrade(host_id)
            if f != 1.0:
                bw = bw * f
        return bw

    # -- end-to-end ---------------------------------------------------------

    def true_bandwidth(self, subset: Sequence[int], ledger=None) -> float:
        """Noiseless ground-truth B(S) for a global-id subset.

        When ``ledger`` (a :class:`repro.core.tenancy.JobLedger`) is given,
        the inter-host rail capacity is fair-shared with every live
        cross-host job that occupies one of S's hosts and is GPU-disjoint
        from S (see module docstring).  An empty ledger — or one whose only
        overlapping entry is S itself — yields exactly the isolated B(S).
        """
        if len(subset) == 0:
            raise ValueError("empty allocation")
        if len(set(subset)) != len(subset):
            raise ValueError(f"duplicate GPU ids in allocation: {subset}")
        # Health view (see repro.core.faults): dead GPUs produce no
        # bandwidth, degraded hosts scale both their intra term and their
        # NIC rail.  Gated on health_active so a never-faulted ledger takes
        # the exact historical float program.
        health = ledger is not None and getattr(ledger, "health_active", False)
        if health:
            gpu_health = getattr(ledger, "gpu_health", None)
            if gpu_health is not None and any(
                gpu_health(g) == "dead" for g in subset
            ):
                return 0.0
        hl = ledger if health else None
        by_host = self.cluster.partition_by_host(subset)
        k = len(subset)
        if len(by_host) == 1:
            (hid, gpus), = by_host.items()
            return self.intra_bandwidth(
                hid, self.cluster.local_tuple(hid, gpus), ledger=hl
            )
        constraints: List[float] = []
        for hid, gpus in by_host.items():
            n_h = len(gpus)
            intra = self.intra_bandwidth(
                hid, self.cluster.local_tuple(hid, gpus), ledger=hl
            )
            constraints.append(k * intra / n_h)

        def contenders(hid: int) -> int:
            if ledger is None:
                return 1
            return 1 + ledger.rail_contenders(hid, against=subset)

        rail_share = None
        if ledger is not None and self.contention == "saturating":
            def rail_share(hid: int) -> float:
                return saturating_rail_share(
                    len(by_host[hid]),
                    ledger.contender_demands(hid, against=subset),
                    saturation_alpha(self.cluster.hosts[hid].host_type),
                )

        rail_factor = ledger.host_degrade if health else None
        inter = contended_inter_term(
            self.cluster, by_host, contenders, rail_share=rail_share,
            rail_factor=rail_factor,
        )
        return min(min(constraints), inter)

    def measure(
        self,
        subset: Sequence[int],
        rng: Optional[np.random.Generator] = None,
        ledger=None,
    ) -> float:
        """One simulated nccl-tests measurement (ground truth + noise).

        With a ``ledger`` the measurement is of the *contention-degraded*
        bandwidth — what a live job's telemetry would actually report."""
        bw = self.true_bandwidth(subset, ledger=ledger)
        if rng is not None and self.noise_std > 0:
            bw *= float(1.0 + rng.normal(0.0, self.noise_std))
        return max(bw, 1e-3)

    # -- dataset generation ---------------------------------------------------

    def sample_allocations(
        self,
        n_samples: int,
        rng: np.random.Generator,
        k_range: Optional[Tuple[int, int]] = None,
        multi_host_only: bool = True,
        small_k_weight: float = 0.0,
    ) -> List[List[int]]:
        """Sparse random allocations for surrogate training (Sec. 4.1.2).

        ``multi_host_only`` mirrors the paper: intra-host combinations are
        measured exhaustively (Stage-1), so the *training set* for the
        Transformer consists of inter-host samples.

        ``small_k_weight`` oversamples small-k / near-crossover shapes (the
        ROADMAP's residual Het-VA error mode: allocations where the intra
        and inter constraints nearly cross and uniform-k sampling sees too
        few examples): with that probability, k is drawn from the bottom of
        the range (``lo .. lo+3``) instead of uniformly.  The default 0.0
        draws nothing extra from the rng, so existing seeded datasets are
        reproduced bit-for-bit.
        """
        if not 0.0 <= small_k_weight <= 1.0:
            raise ValueError("small_k_weight must be in [0, 1]")
        n = self.cluster.n_gpus
        lo, hi = k_range if k_range else (2, n)
        small_hi = min(lo + 3, hi)
        out: List[List[int]] = []
        seen = set()
        max_tries = n_samples * 50
        tries = 0
        while len(out) < n_samples and tries < max_tries:
            tries += 1
            if small_k_weight > 0.0 and rng.random() < small_k_weight:
                k = int(rng.integers(lo, small_hi + 1))
            else:
                k = int(rng.integers(lo, hi + 1))
            subset = sorted(rng.choice(n, size=k, replace=False).tolist())
            if multi_host_only and len(self.cluster.partition_by_host(subset)) < 2:
                continue
            key = tuple(subset)
            if key in seen:
                continue
            seen.add(key)
            out.append(subset)
        return out

    def build_dataset(
        self,
        n_samples: int,
        rng: np.random.Generator,
        noisy: bool = True,
        k_range: Optional[Tuple[int, int]] = None,
        small_k_weight: float = 0.0,
    ) -> List[Tuple[List[int], float]]:
        allocs = self.sample_allocations(
            n_samples, rng, k_range=k_range, small_k_weight=small_k_weight
        )
        return [
            (a, self.measure(a, rng if noisy else None)) for a in allocs
        ]
