"""Concurrent-admission control plane: CAS admissions, journal, tenant QoS.

The dispatcher stack below this module is synchronous: one
:class:`~repro.core.dispatcher.DispatcherService` owns one
:class:`~repro.core.tenancy.JobLedger` and admissions mutate it one at a
time.  A production dispatcher fields many simultaneous admission requests
against that single cluster state — and the expensive part of an admission
is the hybrid search, not the ledger mutation.  This module turns the
monotonic ``JobLedger.version`` counter (the cache-invalidation token of
the dispatch fast path) into a concurrency-control token, in three layers:

**Optimistic-concurrency admission** (:class:`AdmissionControlPlane`).  A
worker *stages* a placement: it clones the ledger under its lock (an
O(live jobs) snapshot pinned at ``version = v``), runs the full hybrid
search against the snapshot lock-free, then *commits* via
``JobLedger.admit_if(job_id, gpus, version=v)`` — a compare-and-swap that
succeeds only if no other admission/release landed in between.  On a
version conflict the worker first tries **read-set validation**: a staged
placement's score is a pure function of (its GPUs being free, the
cross-host contender allocations on each of its hosts), so if both facts
are unchanged between the snapshot and the live ledger, the placement is
exactly as good as it was scored and commits at the current version
without re-searching (a *validated* commit — it may no longer be the
global argmax against the moved state; ``strict=True`` disables this and
forces a re-search on any version move).  Only when the read-set itself
moved does the worker re-search against a fresh snapshot, bounded by
``max_retries`` re-searches; past the bound it runs the search while
holding the ledger lock (guaranteed progress).  A request that cannot fit
— or exceeds its tenant's concurrency cap — parks on a FIFO queue pumped
at every release.  Many admissions overlap their searches; only the cheap
commits serialize.

**Crash-safe append-only journal** (:class:`LedgerJournal` /
:func:`replay_journal`).  Every admit/release/migrate is serialized to an
append-only file *before* the in-memory mutation (write-ahead, hooked
inside ``JobLedger``): one line per event, ``<canonical json>#<crc32>``,
with a contiguous sequence number.  Recovery re-applies events in order
and rebuilds a **bit-identical** ledger — same allocations, same version
counter (admit/release bump 1, migrate bumps 2, exactly like the live
mutations), hence identical fragmentation metrics and identical
version-keyed cache behaviour.  A torn tail (truncation mid-record, a
corrupted crc, a sequence gap) ends the replay at the last durable prefix
— property-tested against random event streams with injected truncation
and corruption in ``tests/test_controlplane.py``.

**Per-tenant QoS policies** (:class:`TenantPolicy`).  A tenant carries a
plan tier, a live-job concurrency cap, a queue-depth cap and a priority
boost.  The control plane enforces the caps at admission (over-concurrent
requests park, over-queued requests are rejected); the admission
scheduler's queue policies consume ``priority_boost`` for their candidate
ordering (see ``SchedulerConfig(tenant_policies=...)`` in
:mod:`repro.core.scheduler`).

See ``docs/controlplane.md`` for the protocol walkthrough and the
staleness caveat on validated commits.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
import zlib
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import telemetry
from repro.core.tenancy import (
    Allocation,
    CapacityError,
    InvalidPlacementError,
    JobLedger,
    VersionConflict,
)

Subset = List[int]

__all__ = [
    "AdmissionControlPlane",
    "AdmissionOutcome",
    "CapacityError",
    "ControlPlaneStats",
    "InvalidPlacementError",
    "JournalEvent",
    "LedgerJournal",
    "TenantPolicy",
    "VersionConflict",
    "read_journal",
    "replay_journal",
]


# ---------------------------------------------------------------------------
# Crash-safe append-only journal
# ---------------------------------------------------------------------------

JOURNAL_OPS = ("admit", "release", "migrate", "fault", "recover")


@dataclasses.dataclass(frozen=True)
class JournalEvent:
    """One durable ledger mutation, in commit order."""

    seq: int
    op: str        # "admit" | "release" | "migrate" | "fault" | "recover"
    job_id: str
    gpus: Optional[Tuple[int, ...]] = None  # admit/migrate/fault targets
    tenant: str = ""                        # "" = no tenant (key omitted)
    kind: Optional[str] = None              # fault/recover: fault kind
    host: Optional[int] = None              # fault/recover: host id
    factor: Optional[float] = None          # fault: rail degrade factor


def _encode_event(seq: int, op: str, job_id: str, gpus=None,
                  tenant: str = "", kind=None, host=None,
                  factor=None) -> bytes:
    """``<canonical json>#<crc32 hex>\\n`` — compact, key-sorted json so a
    record's bytes are a pure function of the event.  The ``tenant`` key
    is emitted only when non-empty, and the fault keys (``kind``/``host``/
    ``factor``) only when set, so admit/release/migrate streams are
    byte-identical to the PR 7 grammar."""
    payload: Dict = {"seq": seq, "op": op, "job": job_id}
    if gpus is not None:
        payload["gpus"] = [int(g) for g in gpus]
    if tenant:
        payload["tenant"] = tenant
    if kind is not None:
        payload["kind"] = kind
    if host is not None:
        payload["host"] = int(host)
    if factor is not None:
        payload["factor"] = float(factor)
    line = json.dumps(payload, separators=(",", ":"), sort_keys=True)
    crc = zlib.crc32(line.encode("utf-8")) & 0xFFFFFFFF
    return f"{line}#{crc:08x}\n".encode("utf-8")


def _scan(raw: bytes) -> Tuple[List[JournalEvent], int]:
    """Parse the longest durable prefix of journal bytes.

    Returns ``(events, valid_end)`` where ``valid_end`` is the byte offset
    just past the last valid record.  Stops (without raising) at the first
    torn record: a chunk missing its trailing newline, a crc mismatch,
    unparseable json, an unknown op, or a sequence discontinuity.
    Everything before that point was written and flushed in full, so the
    prefix is exactly the recoverable state.
    """
    events: List[JournalEvent] = []
    pos = valid_end = 0
    expected = 0
    while True:
        nl = raw.find(b"\n", pos)
        if nl < 0:  # no newline: the tail (if any) is torn
            break
        chunk = raw[pos:nl]
        try:
            text = chunk.decode("utf-8")
            payload, sep, crc_hex = text.rpartition("#")
            if not sep or len(crc_hex) != 8:
                break
            if (zlib.crc32(payload.encode("utf-8")) & 0xFFFFFFFF) != int(
                crc_hex, 16
            ):
                break
            ev = json.loads(payload)
            if ev.get("op") not in JOURNAL_OPS or ev.get("seq") != expected:
                break
            gpus = ev.get("gpus")
            factor = ev.get("factor")
            events.append(JournalEvent(
                ev["seq"], ev["op"], ev["job"],
                tuple(int(g) for g in gpus) if gpus is not None else None,
                str(ev.get("tenant", "")),
                kind=ev.get("kind"),
                host=ev.get("host"),
                factor=float(factor) if factor is not None else None,
            ))
        except (ValueError, KeyError, TypeError, UnicodeDecodeError):
            break
        pos = valid_end = nl + 1
        expected += 1
    return events, valid_end


def read_journal(path) -> List[JournalEvent]:
    """The durable event prefix of a journal file (empty if absent)."""
    if not os.path.exists(path):
        return []
    with open(path, "rb") as fh:
        raw = fh.read()
    return _scan(raw)[0]


class LedgerJournal:
    """Append-only write-ahead journal for one :class:`JobLedger`.

    Records are written *before* the in-memory mutation they describe
    (inside the ledger lock, so journal order == commit order) and flushed
    per record; ``sync=True`` additionally fsyncs, trading admission
    latency for power-loss durability.

    Opening an existing journal truncates any torn tail left by a crash
    and resumes the sequence after the last valid record, so recovery
    (:func:`replay_journal` + ``attach_journal(..., recovered=True)``)
    continues the same file seamlessly.
    """

    def __init__(self, path, sync: bool = False):
        self.path = str(path)
        self.sync = sync
        self._lock = threading.Lock()
        self._seq = 0
        self.n_records = 0
        if os.path.exists(self.path):
            with open(self.path, "rb") as fh:
                raw = fh.read()
            events, valid_end = _scan(raw)
            self._seq = len(events)
            if valid_end < len(raw):  # drop the torn tail before appending
                with open(self.path, "r+b") as fh:
                    fh.truncate(valid_end)
        self._fh = open(self.path, "ab")

    def record(self, op: str, job_id: str, gpus=None,
               tenant: str = "", kind=None, host=None,
               factor=None) -> int:
        """Append one event durably (called by the ledger, write-ahead).
        Returns the event's sequence number, so the caller can correlate
        the in-memory commit with its journal line (admission spans and
        forensics dossiers carry it as ``journal_seq``)."""
        if op not in JOURNAL_OPS:
            raise ValueError(f"unknown journal op {op!r}")
        with self._lock:
            seq = self._seq
            data = _encode_event(seq, op, job_id, gpus, tenant=tenant,
                                 kind=kind, host=host, factor=factor)
            self._fh.write(data)
            self._fh.flush()
            if self.sync:
                os.fsync(self._fh.fileno())
            self._seq += 1
            self.n_records += 1
            return seq

    def close(self) -> None:
        self._fh.close()

    def __enter__(self) -> "LedgerJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def replay_journal(path, cluster, upto_seq: Optional[int] = None) -> JobLedger:
    """Rebuild a ledger from a journal: apply the durable event prefix in
    order onto a fresh (journal-less) ledger.  Bit-identical recovery —
    identical allocations, identical ``version`` (admit/release bump 1,
    migrate bumps 2, exactly like the live mutations the journal shadows),
    hence identical fragmentation metrics.  Attach a fresh
    :class:`LedgerJournal` on the same path afterwards (``attach_journal(
    journal, recovered=True)``) to keep appending to the same file.

    ``upto_seq`` stops the replay *before* applying the event with that
    sequence number — the time-travel primitive behind
    :func:`repro.core.forensics.reconstruct`, which rebuilds the exact
    ledger view the admission at ``seq`` was decided against."""
    ledger = JobLedger(cluster)
    for ev in read_journal(path):
        if upto_seq is not None and ev.seq >= upto_seq:
            break
        if ev.op == "admit":
            ledger.admit(ev.job_id, ev.gpus, tenant=ev.tenant)
        elif ev.op == "release":
            ledger.release(ev.job_id)
        elif ev.op == "fault":
            ledger.apply_fault(
                ev.kind, gpus=ev.gpus or (), host_id=ev.host,
                factor=ev.factor if ev.factor is not None else 1.0,
            )
        elif ev.op == "recover":
            ledger.apply_recover(
                ev.kind, gpus=ev.gpus or (), host_id=ev.host
            )
        else:  # migrate
            ledger.migrate(ev.job_id, ev.gpus)
    return ledger


# ---------------------------------------------------------------------------
# Per-tenant QoS policies
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """Admission-time QoS knobs for one tenant (modelops-style plan rows).

    ``max_concurrent`` caps the tenant's simultaneously-live jobs: requests
    beyond it park until one of the tenant's jobs releases.  ``max_queued``
    caps its waiting depth: requests beyond it are *rejected* outright.
    ``priority_boost`` is consumed by the admission scheduler's queue
    policies (higher boost is considered first); the control plane itself
    treats parked requests FIFO.  ``None`` caps mean unlimited — the
    default policy is a no-op.
    """

    plan: str = "standard"
    max_concurrent: Optional[int] = None
    max_queued: Optional[int] = None
    priority_boost: int = 0

    def __post_init__(self):
        if self.max_concurrent is not None and self.max_concurrent < 1:
            raise ValueError("max_concurrent must be >= 1 (or None)")
        if self.max_queued is not None and self.max_queued < 0:
            raise ValueError("max_queued must be >= 0 (or None)")


# ---------------------------------------------------------------------------
# Optimistic-concurrency admission service
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AdmissionOutcome:
    """What happened to one admission request."""

    job_id: str
    tenant: str
    status: str                    # "admitted" | "rejected"
    alloc: Optional[Allocation] = None
    predicted_bw: float = float("nan")
    staged_version: int = -1       # version the committed search ran against
    committed_version: int = -1    # ledger version right after the commit
    retries: int = 0               # re-searches forced by moved read-sets
    validated: bool = False        # committed via read-set validation
    serialized: bool = False       # retry bound hit: searched under the lock
    parked: bool = False           # waited on the capacity/QoS queue
    reason: str = ""               # rejection cause
    seconds: float = 0.0           # submit-to-resolution wall time
    journal_seq: int = -1          # seq of the commit's journal line (-1:
                                   # no journal attached)

    @property
    def admitted(self) -> bool:
        return self.status == "admitted"

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["alloc"] = list(self.alloc.gpus) if self.alloc is not None else None
        return d


@dataclasses.dataclass
class ControlPlaneStats:
    """Aggregate admission-path counters (reported by the bench).

    Commit kinds partition the admissions:
    ``n_cas_commits + n_validated + n_serialized == n_admitted`` — the
    invariant the metrics registry asserts at absorb time
    (:func:`repro.core.telemetry.absorb_controlplane_stats`).  Reset/merge
    semantics mirror :class:`~repro.core.predict_cache.PredictorStats`:
    one stats object per control plane, no nesting, so ``merged`` over
    *distinct* planes never double-counts.
    """

    n_admitted: int = 0
    n_cas_commits: int = 0       # committed at the staged version (clean CAS)
    n_validated: int = 0         # committed after read-set validation
    n_conflicts: int = 0         # re-searches forced by moved read-sets
    n_serialized: int = 0        # retry bound hit: search ran under the lock
    n_parked: int = 0            # park events (capacity / tenant caps)
    n_rejected: int = 0
    search_seconds: float = 0.0
    commit_seconds: float = 0.0

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)

    # legacy name (benchmarks/tests predate the unified to_dict convention)
    def as_dict(self) -> Dict[str, float]:
        return self.to_dict()

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, f.default)

    @classmethod
    def merged(cls, *stats: "ControlPlaneStats") -> "ControlPlaneStats":
        """Field-wise sum over stats of *distinct* control planes."""
        out = cls()
        for s in stats:
            for f in dataclasses.fields(cls):
                setattr(out, f.name, getattr(out, f.name) + getattr(s, f.name))
        return out


@dataclasses.dataclass
class _Request:
    job_id: str
    k: int
    tenant: str
    future: Future
    t_submit: float
    retries: int = 0
    parked: bool = False


class AdmissionControlPlane:
    """Async admission service over one dispatcher: staged searches commit
    via ledger-version CAS, with write-ahead journaling and tenant QoS.

    ``dispatcher`` is any :class:`~repro.core.dispatcher.DispatcherService`
    — a BandPilot dispatcher's ``tables``/``base_predictor`` unlock the
    snapshot-pinned hybrid-search staging path; anything else stages
    through its plain ``dispatch`` against the snapshot's availability.
    :meth:`submit` returns a ``Future[AdmissionOutcome]``; parked requests
    (capacity or tenant caps) resolve when a later :meth:`release` admits
    them, or immediately with ``status="rejected"`` when a queue cap is
    hit.  ``batch_applies=True`` registers every staged search with a
    shared :class:`~repro.core.predict_cache.InferenceBatcher`, fusing
    overlapping workers' surrogate applies into shared device calls —
    fused applies amortize XLA dispatch overhead, and the applies
    themselves release the GIL so multi-core hosts overlap them with
    peer searches.  ``batch_wait`` bounds the fusion rendezvous; keep it
    well under one search's runtime or fusion degrades into convoy
    stalls (see ``benchmarks/bench_controlplane.py``).
    """

    def __init__(
        self,
        dispatcher,
        n_workers: int = 4,
        policies: Optional[Dict[str, TenantPolicy]] = None,
        journal=None,
        max_retries: int = 3,
        strict: bool = False,
        batch_applies: bool = True,
        batch_wait: float = 0.0005,
        rng=None,
    ):
        if n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        self.dispatcher = dispatcher
        self.cluster = dispatcher.cluster
        self.ledger: JobLedger = dispatcher.ledger
        self.policies = dict(policies or {})
        self.max_retries = max_retries
        self.strict = strict
        self.n_workers = n_workers
        self.rng = rng
        self._rng_lock = threading.Lock()
        self.stats = ControlPlaneStats()
        self._stats_lock = threading.Lock()
        # tenant accounting + parked queue share one state lock; lock order
        # is serial -> ledger -> state -> stats (never the reverse)
        self._state_lock = threading.Lock()
        self._tenant_live: Dict[str, int] = {}
        self._tenant_waiting: Dict[str, int] = {}
        self._job_tenant: Dict[str, str] = {}
        self._parked: deque = deque()  # _Request, FIFO
        self._serial_lock = threading.Lock()  # one serialized search at once
        self._pool = ThreadPoolExecutor(
            max_workers=n_workers, thread_name_prefix="admission"
        )
        self._batcher = None
        if batch_applies and n_workers > 1:
            from repro.core.predict_cache import InferenceBatcher

            # A short rendezvous beats the batcher's 5 ms default here:
            # an admission worker stalls every peer parked in apply() while
            # it grinds through GIL-bound Python between its own applies,
            # so long waits turn fusion into convoy stalls
            self._batcher = InferenceBatcher(wait_timeout=batch_wait)
        if journal is not None:
            if isinstance(journal, (str, os.PathLike)):
                journal = LedgerJournal(journal)
            self.ledger.attach_journal(
                journal,
                recovered=len(self.ledger) > 0 or self.ledger.version > 0,
            )
        self.journal = self.ledger.journal

    # -- public -------------------------------------------------------------

    def submit(self, job_id: str, k: int, tenant: str = "") -> Future:
        """Enqueue one admission; resolves at admission or rejection (a
        capacity/QoS wait resolves when a later release admits it)."""
        if k < 1 or k > self.cluster.n_gpus:
            raise CapacityError(
                f"k={k} can never fit the {self.cluster.n_gpus}-GPU cluster"
            )
        req = _Request(job_id, int(k), tenant, Future(), time.time())
        pol = self.policies.get(tenant)
        with self._state_lock:
            reject = (
                pol is not None and pol.max_queued is not None
                and self._tenant_waiting.get(tenant, 0) >= pol.max_queued
            )
            if not reject:
                self._tenant_waiting[tenant] = (
                    self._tenant_waiting.get(tenant, 0) + 1
                )
        if reject:
            self._finish_rejected(
                req, f"tenant {tenant!r} queue full "
                f"(max_queued={pol.max_queued})"
            )
        else:
            self._pool.submit(self._run_request, req)
        return req.future

    def admit_many(
        self, requests: Sequence[Tuple], timeout: Optional[float] = None
    ) -> List[AdmissionOutcome]:
        """Submit ``(job_id, k[, tenant])`` tuples and wait for them all."""
        futures = [self.submit(*r) for r in requests]
        return [f.result(timeout=timeout) for f in futures]

    def release(self, job_id: str) -> Allocation:
        """Release a live job (journaled via the ledger) and pump the
        parked queue — the admission side of the release path."""
        alloc = self.ledger.release(job_id)
        with self._state_lock:
            tenant = self._job_tenant.pop(job_id, None)
            if tenant is not None:
                self._tenant_live[tenant] -= 1
        self._pump()
        return alloc

    def pending(self) -> int:
        """Requests parked for capacity or tenant caps right now."""
        with self._state_lock:
            return len(self._parked)

    def shutdown(self, wait: bool = True) -> None:
        """Stop the worker pool.  Parked requests stay unresolved — drain
        them (via releases) before shutting down if their futures matter."""
        self._pool.shutdown(wait=wait)

    def __enter__(self) -> "AdmissionControlPlane":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- request lifecycle --------------------------------------------------

    def _finish_rejected(self, req: _Request, reason: str) -> None:
        with self._stats_lock:
            self.stats.n_rejected += 1
        req.future.set_result(AdmissionOutcome(
            req.job_id, req.tenant, "rejected", reason=reason,
            parked=req.parked, seconds=time.time() - req.t_submit,
        ))

    def _park(self, req: _Request) -> None:
        """Capacity / tenant-cap wait: requeue FIFO, pumped at releases."""
        req.parked = True
        with self._state_lock:
            self._parked.append(req)
        with self._stats_lock:
            self.stats.n_parked += 1
        telemetry.event("cplane.park", job_id=req.job_id, k=req.k)

    def _pump(self) -> None:
        """Re-dispatch every parked request: a release may have opened any
        of their gates (re-parking the still-blocked ones is cheap)."""
        with self._state_lock:
            parked, self._parked = list(self._parked), deque()
        if parked:
            telemetry.event("cplane.pump", n_requeued=len(parked))
        for req in parked:
            self._pool.submit(self._run_request, req)

    def _run_request(self, req: _Request) -> None:
        try:
            outcome = self._admit_one(req)
        except BaseException as e:  # noqa: BLE001 — surface via the future
            self._done_waiting(req)
            req.future.set_exception(e)
            return
        if outcome is not None:  # None: parked, resolves at a later pump
            self._done_waiting(req)
            req.future.set_result(outcome)

    def _done_waiting(self, req: _Request) -> None:
        with self._state_lock:
            self._tenant_waiting[req.tenant] = max(
                self._tenant_waiting.get(req.tenant, 1) - 1, 0
            )

    def _admit_one(self, req: _Request) -> Optional[AdmissionOutcome]:
        """Stage/commit cycle for one request; None means parked.  Runs
        entirely on one pool worker thread, so the (thread-local) forensics
        decision draft opened here collects the staged search's provenance
        and seals into a dossier iff the request commits."""
        from repro.core import forensics

        with forensics.decision(
            req.job_id, tenant=req.tenant, k=req.k, path="cplane",
        ) as draft:
            outcome = self._admit_one_inner(req)
            if draft is not None and outcome is not None and outcome.admitted:
                draft.commit(
                    subset=outcome.alloc.gpus,
                    predicted_bw=outcome.predicted_bw,
                    journal_seq=outcome.journal_seq,
                    staged_version=outcome.staged_version,
                    committed_version=outcome.committed_version,
                    validated=outcome.validated,
                    serialized=outcome.serialized,
                    retries=outcome.retries,
                )
            return outcome

    def _admit_one_inner(self, req: _Request) -> Optional[AdmissionOutcome]:
        pol = self.policies.get(req.tenant)
        if pol is not None and pol.max_concurrent is not None:
            with self._state_lock:
                over = (self._tenant_live.get(req.tenant, 0)
                        >= pol.max_concurrent)
            if over:
                self._park(req)
                return None
        ledger = self.ledger
        while True:
            snapshot = ledger.clone()  # clones under the ledger lock
            if req.k > snapshot.n_free():
                self._park(req)
                return None
            t0 = time.time()
            with telemetry.span(
                "cplane.stage", job_id=req.job_id, k=req.k,
                staged_version=snapshot.version, retry=req.retries,
            ):
                subset, predicted = self._search(snapshot, req.k)
            with self._stats_lock:
                self.stats.search_seconds += time.time() - t0
            self._check_placement(subset, snapshot, req)
            t1 = time.time()
            with telemetry.span(
                "cplane.commit", job_id=req.job_id,
                staged_version=snapshot.version,
            ) as sp:
                outcome = self._try_commit(req, subset, predicted, snapshot)
                if sp:
                    sp["result"] = (
                        "conflict" if outcome is None
                        else "validated" if outcome.validated else "cas"
                    )
                    if outcome is not None:
                        sp["journal_seq"] = outcome.journal_seq
            with self._stats_lock:
                self.stats.commit_seconds += time.time() - t1
            if outcome is not None:
                return outcome
            # read-set moved underneath the search: re-search (bounded)
            req.retries += 1
            with self._stats_lock:
                self.stats.n_conflicts += 1
            if req.retries > self.max_retries:
                return self._admit_serialized(req)

    def _try_commit(
        self, req: _Request, subset: Subset, predicted: float,
        snapshot: JobLedger,
    ) -> Optional[AdmissionOutcome]:
        """CAS first; on version movement, read-set validation; else None
        (the caller re-searches)."""
        ledger = self.ledger
        staged = snapshot.version
        with ledger.lock:
            if ledger.version == staged:
                alloc = ledger.admit_if(
                    req.job_id, subset, staged, tenant=req.tenant
                )
                validated = False
            elif not self.strict and self._placement_unaffected(
                subset, snapshot
            ):
                alloc = ledger.admit(req.job_id, subset, tenant=req.tenant)
                validated = True
            else:
                return None
            committed = ledger.version
            # under the lock, so this is *our* commit's journal line
            seq = ledger.last_journal_seq if ledger.journal is not None else -1
            self._note_admitted(req, validated)
        return AdmissionOutcome(
            req.job_id, req.tenant, "admitted", alloc=alloc,
            predicted_bw=predicted, staged_version=staged,
            committed_version=committed, retries=req.retries,
            validated=validated, parked=req.parked,
            seconds=time.time() - req.t_submit, journal_seq=seq,
        )

    def _admit_serialized(self, req: _Request) -> Optional[AdmissionOutcome]:
        """Retry bound exhausted: search while holding the ledger lock (no
        one can move the state mid-search, so the commit cannot conflict).
        Other workers' searches keep running; only their commits block."""
        ledger = self.ledger
        with self._serial_lock, ledger.lock, telemetry.span(
            "cplane.serialized", job_id=req.job_id, k=req.k,
            retries=req.retries,
        ) as sp:
            if req.k > ledger.n_free():
                parked = True
            else:
                parked = False
                v = ledger.version
                subset, predicted = self._search(ledger, req.k)
                self._check_placement(subset, ledger, req)
                alloc = ledger.admit_if(
                    req.job_id, subset, v, tenant=req.tenant
                )
                seq = (ledger.last_journal_seq
                       if ledger.journal is not None else -1)
                if sp:
                    sp["journal_seq"] = seq
                self._note_admitted(req, validated=False, serialized=True)
        if parked:
            self._park(req)
            return None
        return AdmissionOutcome(
            req.job_id, req.tenant, "admitted", alloc=alloc,
            predicted_bw=predicted, staged_version=v, committed_version=v + 1,
            retries=req.retries, serialized=True, parked=req.parked,
            seconds=time.time() - req.t_submit, journal_seq=seq,
        )

    def _note_admitted(
        self, req: _Request, validated: bool, serialized: bool = False
    ) -> None:
        with self._state_lock:
            self._tenant_live[req.tenant] = (
                self._tenant_live.get(req.tenant, 0) + 1
            )
            self._job_tenant[req.job_id] = req.tenant
        with self._stats_lock:
            self.stats.n_admitted += 1
            if serialized:
                self.stats.n_serialized += 1
            elif validated:
                self.stats.n_validated += 1
            else:
                self.stats.n_cas_commits += 1

    # -- staged search ------------------------------------------------------

    def _search(self, view: JobLedger, k: int) -> Tuple[Subset, float]:
        """Run the dispatcher's placement policy against a ledger view
        (snapshot clone, or the live ledger under lock for the serialized
        fallback).  BandPilot dispatchers get the full snapshot-pinned
        chain — contention wrapper over the *view*, fresh version-keyed
        prediction cache, the dispatcher's shared isolated memo inside
        ``base_predictor``, optional fragmentation tie-break; plain
        dispatchers stage through ``dispatch``."""
        d = self.dispatcher
        avail = view.available()
        if hasattr(d, "tables") and hasattr(d, "base_predictor"):
            from repro.core import search as search_mod
            from repro.core.predict_cache import cached_contention_predictor

            if d.contention_aware:
                pred = cached_contention_predictor(
                    self.cluster, d.base_predictor, view,
                    mode=d.contention_mode, contended=d.contended_predictor,
                    use_cache=d.prediction_cache is not None,
                )
            else:
                pred = d.base_predictor
            penalty = None
            if d.frag_weight > 0:
                from repro.core.defrag import make_frag_penalty

                penalty = make_frag_penalty(self.cluster, view, d.frag_weight)

            def run():
                from repro.core import forensics

                res = search_mod.hybrid_search(
                    self.cluster, d.tables, pred, avail, k,
                    frag_penalty=penalty,
                )
                df = forensics.draft()
                if df is not None:  # post-selection: cannot alter the choice
                    df.note_decomposition(forensics.bandwidth_decomposition(
                        self.cluster, d.tables, view, res.subset,
                        d.base_predictor,
                        predicted_bw=float(res.predicted_bw),
                        contention_mode=(d.contention_mode
                                         if d.contention_aware else "off"),
                    ))
                return list(res.subset), float(res.predicted_bw)

        else:
            def run():
                if getattr(d, "needs_rng", False):
                    with self._rng_lock:
                        return list(d.dispatch(avail, k, rng=self.rng)), \
                            float("nan")
                return list(d.dispatch(avail, k)), float("nan")

        if self._batcher is not None:
            with self._batcher.worker():
                return run()
        return run()

    def _check_placement(self, subset, view: JobLedger, req: _Request):
        if len(subset) != req.k or not set(subset) <= set(view.available()):
            raise InvalidPlacementError(
                f"policy returned an invalid allocation for "
                f"{req.job_id!r} (k={req.k}): {subset}"
            )

    def _placement_unaffected(
        self, subset: Subset, snapshot: JobLedger
    ) -> bool:
        """Read-set validation, called under the ledger lock: the staged
        placement's contention-degraded score is a pure function of (its
        GPUs being free, the cross-host contender allocations on each of
        its hosts).  Compare both facts between the live ledger and the
        snapshot the search actually saw — :class:`Allocation` records are
        frozen and compare by value, and ``cross_host_jobs_on`` sorts by
        job id, so list equality is exact.  A fragmentation tie-break
        makes the score depend on *global* occupancy, so any version move
        invalidates it outright."""
        ledger = self.ledger
        if not set(subset).isdisjoint(ledger.busy()):
            return False
        # Any active health perturbation invalidates the staged score
        # outright: the fault that bumped the version may have killed one
        # of these GPUs (free != placeable) or degraded a rail the score
        # depends on.  Faults are rare; re-searching is the cheap safe
        # answer, and admit() refuses unplaceable GPUs regardless.
        if ledger.health_active:
            return False
        if getattr(self.dispatcher, "frag_weight", 0.0) > 0:
            return False
        for hid in self.cluster.partition_by_host(subset):
            if (ledger.cross_host_jobs_on(hid, against=subset)
                    != snapshot.cross_host_jobs_on(hid, against=subset)):
                return False
        return True
