"""Feature extraction for the hierarchical surrogate (Sec. 4.2.1, Fig. 4).

For an allocation S, the Transformer receives one token per *participating
host*: a feature tuple of (i) the Stage-1 measured intra-host bandwidth of
the GPUs selected on that host and (ii) the number of GPUs selected there.
Padding + mask make the representation batchable; the architecture itself is
size-agnostic (any number of hosts / any k).
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.core.bandwidth_sim import BW_SCALE
from repro.core.cluster import Cluster
from repro.core.intra_host import IntraHostTables

# Per-host token features.  The paper's tuple is (intra-host bandwidth from
# the Stage-1 lookup, GPU count on that host); we encode the bandwidth in
# log-space (it spans ~2.5 decades across heterogeneous hosts) and append
# two request-context features the dispatcher trivially knows — the host's
# share of the request (n_h/k) and the normalized request size — which
# resolve the inter-host rail term without asking pooling to count tokens.
N_FEATURES = 4
_LOG_SCALE = 5.0  # keep in sync with surrogate.LOG_SCALE


def featurize_one(
    cluster: Cluster,
    tables: IntraHostTables,
    subset: Sequence[int],
    max_hosts: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """-> (feats [max_hosts, N_FEATURES] float32, mask [max_hosts] float32)."""
    by_host = cluster.partition_by_host(subset)
    feats = np.zeros((max_hosts, N_FEATURES), np.float32)
    mask = np.zeros((max_hosts,), np.float32)
    k = len(subset)
    for i, (hid, gpus) in enumerate(sorted(by_host.items())):
        intra = tables.lookup(hid, cluster.local_tuple(hid, gpus))
        feats[i, 0] = np.log1p(intra) / _LOG_SCALE
        feats[i, 1] = len(gpus) / 8.0
        feats[i, 2] = len(gpus) / k
        feats[i, 3] = k / max(cluster.n_gpus, 1)
        mask[i] = 1.0
    return feats, mask


def featurize_batch(
    cluster: Cluster,
    tables: IntraHostTables,
    subsets: Sequence[Sequence[int]],
    max_hosts: int | None = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """-> (feats [B, H, F], mask [B, H]) for a batch of allocations."""
    if max_hosts is None:
        max_hosts = cluster.n_hosts
    B = len(subsets)
    feats = np.zeros((B, max_hosts, N_FEATURES), np.float32)
    mask = np.zeros((B, max_hosts), np.float32)
    for b, subset in enumerate(subsets):
        feats[b], mask[b] = featurize_one(cluster, tables, subset, max_hosts)
    return feats, mask


def featurize_gpu_ids(
    cluster: Cluster, subsets: Sequence[Sequence[int]], max_k: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Raw-identifier featurization for the *naive* baseline (Sec. 5.5.1):
    one token per GPU, feature = global GPU id (embedded by the model).
    -> (ids [B, max_k] int32, mask [B, max_k])."""
    B = len(subsets)
    ids = np.zeros((B, max_k), np.int32)
    mask = np.zeros((B, max_k), np.float32)
    for b, subset in enumerate(subsets):
        for i, g in enumerate(sorted(subset)):
            ids[b, i] = g
            mask[b, i] = 1.0
    return ids, mask
