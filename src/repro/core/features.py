"""Feature extraction for the hierarchical surrogate (Sec. 4.2.1, Fig. 4).

For an allocation S, the Transformer receives one token per *participating
host*: a feature tuple of (i) the Stage-1 measured intra-host bandwidth of
the GPUs selected on that host and (ii) the number of GPUs selected there.
Padding + mask make the representation batchable; the architecture itself is
size-agnostic (any number of hosts / any k).

Two featurizations live here:

* **Isolated** (``featurize_one`` / ``featurize_batch``): per-host tokens of
  ``N_FEATURES`` channels.  Channel 4 is the per-host-type *normalized*
  intra-host bandwidth: ``(log1p(intra) - log1p(rail_bw * n_h)) / 5`` —
  the intra bandwidth measured against the host type's NIC rail capacity
  at the selected count.  Mixed NVLink generations span ~2.5 decades in
  log-space, and the raw log channel leaves the model to recover each host
  class's operating point (and hence which of the intra/inter constraints
  binds — where the Het-VA errors concentrate, see ROADMAP) on its own;
  this channel hands it the normalized position directly.  The matching
  embed row is zero-initialized (``surrogate.init_hierarchical_params``) so
  an un-trained or legacy-trained model is unaffected.  ``host_norm=False``
  zeroes the channel (the ablation knob ``bench_surrogate_accuracy`` uses
  to report the delta).

* **Contended** (``featurize_contended_one`` / ``featurize_contended_batch``):
  the isolated channels plus ``N_LEDGER_FEATURES`` ledger-context channels
  per token — segment flag, rail-contender count ``c_h``, contender GPU
  demand on the host, and disjoint occupancy — and (optionally) one extra
  token per (contending job, shared host) pair carrying the contender's own
  intra-host features with the segment flag set.  Under an **empty ledger**
  the first ``N_FEATURES`` channels are bit-identical to the isolated
  featurization, every context channel is exactly zero, and no contender
  token is emitted (regression-pinned): the contended representation is a
  strict superset of the isolated one.

**Fast path.**  The batch featurizers are *array programs*: per-GPU host
indices and per-(host, local-subset-bitmask) Stage-1 bandwidths are
precomputed once per :class:`~repro.core.intra_host.IntraHostTables`
(:func:`host_arrays`) and every candidate's tokens are produced by numpy
gathers/scatters — no per-candidate Python loops over hosts.  The legacy
loop implementations are kept (``featurize_batch_loop`` /
``featurize_contended_batch_loop``) as the bit-identity reference
(``tests/test_fast_path.py`` pins exact array equality) and as the
before-side of ``benchmarks/bench_dispatch_throughput.py``.
:func:`featurize_children` is the incremental entry point for PTS: one
elimination round's candidates are the parent's token matrix with a patched
row per child (plus the two cheap k-dependent request-context channels
recomputed), skipping the per-GPU accumulation entirely.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bandwidth_sim import BW_SCALE, _jitter
from repro.core.cluster import Cluster
from repro.core.intra_host import IntraHostTables
from repro.core.tenancy import JobLedger

# Per-host token features.  The paper's tuple is (intra-host bandwidth from
# the Stage-1 lookup, GPU count on that host); we encode the bandwidth in
# log-space (it spans ~2.5 decades across heterogeneous hosts) and append
# two request-context features the dispatcher trivially knows — the host's
# share of the request (n_h/k) and the normalized request size — plus the
# per-host-type normalized bandwidth (see module docstring).
N_FEATURES = 5
_LOG_SCALE = 5.0  # keep in sync with surrogate.LOG_SCALE

# Ledger-context channels appended by the contended featurizer:
#   [segment flag, c_h / C_NORM, contender demand / 8, disjoint occupancy,
#    health degradation (1 - rail degrade factor; 0.0 on healthy fabric)]
# The health channel (ISSUE 10) is exactly 0.0 for every healthy host, and
# the surrogate's ledger-context embedding is zero-initialized, so widening
# it leaves untrained and healthy-fabric predictions bit-identical.
N_LEDGER_FEATURES = 5
N_CONTENDED_FEATURES = N_FEATURES + N_LEDGER_FEATURES
_C_NORM = 4.0  # rail-contender count normalizer

def _host_token(
    cluster: Cluster,
    tables: IntraHostTables,
    hid: int,
    gpus: Sequence[int],
    k: int,
    host_norm: bool,
) -> np.ndarray:
    """The isolated feature tuple of one (host, selected GPUs) token."""
    host_type = cluster.hosts[hid].host_type
    intra = tables.lookup(hid, cluster.local_tuple(hid, gpus))
    out = np.zeros((N_FEATURES,), np.float32)
    out[0] = np.log1p(intra) / _LOG_SCALE
    out[1] = len(gpus) / 8.0
    out[2] = len(gpus) / k
    out[3] = k / max(cluster.n_gpus, 1)
    if host_norm:
        out[4] = (
            np.log1p(intra) - np.log1p(host_type.nic_rail_bw * len(gpus))
        ) / _LOG_SCALE
    return out


def featurize_one(
    cluster: Cluster,
    tables: IntraHostTables,
    subset: Sequence[int],
    max_hosts: int,
    host_norm: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """-> (feats [max_hosts, N_FEATURES] float32, mask [max_hosts] float32)."""
    by_host = cluster.partition_by_host(subset)
    if len(by_host) > max_hosts:
        raise ValueError(
            f"subset spans {len(by_host)} hosts > max_hosts={max_hosts}"
        )
    feats = np.zeros((max_hosts, N_FEATURES), np.float32)
    mask = np.zeros((max_hosts,), np.float32)
    k = len(subset)
    for i, (hid, gpus) in enumerate(sorted(by_host.items())):
        feats[i] = _host_token(cluster, tables, hid, gpus, k, host_norm)
        mask[i] = 1.0
    return feats, mask


# ---------------------------------------------------------------------------
# Precomputed host arrays (the vectorized featurizers' lookup substrate)
# ---------------------------------------------------------------------------

class HostArrays:
    """Dense per-GPU / per-host arrays derived once from the Stage-1 tables.

    ``intra_bw[hid, bitmask]`` is the exact Stage-1 lookup value for the
    local subset encoded by ``bitmask`` (NaN for combinations the tables do
    not hold, i.e. the empty mask) — the same float64 objects the dict
    holds, so gathers reproduce ``tables.lookup`` bit-for-bit.
    """

    def __init__(self, cluster: Cluster, tables: IntraHostTables):
        self.cluster = cluster
        n_hosts = cluster.n_hosts
        max_g = max(h.n_gpus for h in cluster.hosts)
        self.max_host_gpus = max_g
        self.gpu_host = np.asarray(cluster.gpu_host, np.int64)
        self.gpu_bit = np.asarray(
            [np.int64(1) << cluster.gpu_local[g] for g in range(cluster.n_gpus)],
            np.int64,
        )
        self.intra_bw = np.full((n_hosts, 1 << max_g), np.nan, np.float64)
        for hid in range(n_hosts):
            for sub, bw in tables.tables[hid].items():
                m = 0
                for i in sub:
                    m |= 1 << i
                self.intra_bw[hid, m] = bw
        self.host_n_gpus = np.asarray(
            [h.n_gpus for h in cluster.hosts], np.int64
        )
        rail = np.asarray(
            [h.host_type.nic_rail_bw for h in cluster.hosts], np.float64
        )
        self.nic_rail_bw = rail
        # log1p(rail_bw * n) for n = 0..max_g (n = 0 is never gathered)
        self.log_rail = np.log1p(
            rail[:, None] * np.arange(max_g + 1, dtype=np.float64)[None, :]
        )
        # ledger uid -> (version, _LedgerArrays): the contended featurizer's
        # per-occupancy-state snapshot, reused across the ~20 predict
        # batches one admission issues against an unchanged ledger.  Bounded:
        # training/dataset paths materialize a FRESH ledger per sample (new
        # uid each), which would otherwise retain dense arrays forever.
        self.ledger_cache: Dict[int, Tuple[int, object]] = {}
        self.max_ledger_entries = 64


def host_arrays(cluster: Cluster, tables: IntraHostTables) -> HostArrays:
    """The (cached) :class:`HostArrays` of one tables instance."""
    arrays = getattr(tables, "_host_arrays", None)
    if arrays is None or arrays.cluster is not cluster:
        arrays = HostArrays(cluster, tables)
        tables._host_arrays = arrays
    return arrays


def _batch_bits_counts(
    arrays: HostArrays, subsets: Sequence[Sequence[int]]
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Per-(candidate, host) local bitmasks and GPU counts for a batch.

    -> (bits [B, H_all] int64, counts [B, H_all] int64, ks [B] int64,
        rows [sum k] int64, flat [sum k] int64) — ``rows``/``flat`` are the
    flattened (candidate index, GPU id) pairs, reusable by callers needing
    another scatter over the same batch (e.g. busy-GPU overlap counts).
    """
    B = len(subsets)
    n_hosts = len(arrays.host_n_gpus)
    lens = np.asarray([len(s) for s in subsets], np.int64)
    if B:
        flat = np.concatenate(
            [np.asarray(s, np.int64) for s in subsets]
        ) if lens.sum() else np.zeros((0,), np.int64)
    else:
        flat = np.zeros((0,), np.int64)
    rows = np.repeat(np.arange(B, dtype=np.int64), lens)
    hosts = arrays.gpu_host[flat]
    bits = np.zeros((B, n_hosts), np.int64)
    counts = np.zeros((B, n_hosts), np.int64)
    np.add.at(bits, (rows, hosts), arrays.gpu_bit[flat])
    np.add.at(counts, (rows, hosts), 1)
    return bits, counts, lens, rows, flat


def _isolated_channels(
    arrays: HostArrays,
    bits: np.ndarray,
    counts: np.ndarray,
    ks: np.ndarray,
    host_norm: bool,
) -> np.ndarray:
    """[B, H_all, N_FEATURES] float64 token grid (garbage where count==0).

    Channel math is the elementwise float64 program of :func:`_host_token`,
    so a cast to float32 lands on identical bits.
    """
    B, n_hosts = counts.shape
    hid_grid = np.arange(n_hosts, dtype=np.int64)[None, :]
    intra = arrays.intra_bw[hid_grid, bits]            # NaN where count == 0
    with np.errstate(invalid="ignore"):
        log_intra = np.log1p(intra)
        tokens = np.zeros((B, n_hosts, N_FEATURES), np.float64)
        tokens[..., 0] = log_intra / _LOG_SCALE
        tokens[..., 1] = counts / 8.0
        tokens[..., 2] = counts / ks[:, None]
        tokens[..., 3] = (ks / max(arrays.cluster.n_gpus, 1))[:, None]
        if host_norm:
            safe = np.minimum(counts, arrays.max_host_gpus)
            tokens[..., 4] = (
                log_intra - arrays.log_rail[hid_grid, safe]
            ) / _LOG_SCALE
    return tokens


def _pack_tokens(
    tokens: np.ndarray,
    counts: np.ndarray,
    max_hosts: int,
    n_channels: int,
    extra: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Scatter the participating-host rows of a [B, H_all, F] grid into the
    leading token slots of a zero-padded [B, max_hosts, n_channels] batch
    (hosts ascending — the order ``sorted(by_host.items())`` produces)."""
    B = counts.shape[0]
    part = counts > 0
    n_part = part.sum(axis=1)
    if n_part.size and int(n_part.max()) > max_hosts:
        b = int(np.argmax(n_part))
        raise ValueError(
            f"subset spans {int(n_part[b])} hosts > max_tokens={max_hosts}"
            if extra is not None else
            f"subset spans {int(n_part[b])} hosts > max_hosts={max_hosts}"
        )
    feats = np.zeros((B, max_hosts, n_channels), np.float32)
    mask = np.zeros((B, max_hosts), np.float32)
    b_idx, h_idx = np.nonzero(part)
    pos = np.cumsum(part, axis=1)[b_idx, h_idx] - 1
    feats[b_idx, pos, : tokens.shape[-1]] = tokens[b_idx, h_idx]
    if extra is not None:
        feats[b_idx, pos, tokens.shape[-1]:] = extra[b_idx, h_idx]
    mask[b_idx, pos] = 1.0
    return feats, mask


def featurize_batch(
    cluster: Cluster,
    tables: IntraHostTables,
    subsets: Sequence[Sequence[int]],
    max_hosts: int | None = None,
    host_norm: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """-> (feats [B, H, F], mask [B, H]) for a batch of allocations.

    Vectorized: one numpy program over the precomputed :func:`host_arrays`,
    bit-identical to :func:`featurize_batch_loop` (regression-pinned).
    """
    if max_hosts is None:
        max_hosts = cluster.n_hosts
    arrays = host_arrays(cluster, tables)
    bits, counts, ks, _, _ = _batch_bits_counts(arrays, subsets)
    tokens = _isolated_channels(arrays, bits, counts, ks, host_norm)
    return _pack_tokens(tokens, counts, max_hosts, N_FEATURES)


def featurize_batch_loop(
    cluster: Cluster,
    tables: IntraHostTables,
    subsets: Sequence[Sequence[int]],
    max_hosts: int | None = None,
    host_norm: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """Legacy per-candidate loop featurizer (the vectorized path's bit-
    identity reference and the throughput bench's before-side)."""
    if max_hosts is None:
        max_hosts = cluster.n_hosts
    B = len(subsets)
    feats = np.zeros((B, max_hosts, N_FEATURES), np.float32)
    mask = np.zeros((B, max_hosts), np.float32)
    for b, subset in enumerate(subsets):
        feats[b], mask[b] = featurize_one(
            cluster, tables, subset, max_hosts, host_norm=host_norm
        )
    return feats, mask


def child_bits_counts(
    arrays: HostArrays, parent: Sequence[int]
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-(child, host) local bitmasks and GPU counts for every single-GPU
    elimination of ``parent`` (child i = parent minus its i-th element).

    THE incremental child-patching step: the parent's grids repeated, with
    one (host, bit) subtraction per child.  Shared by
    :func:`featurize_children` and ``SurrogatePredictor.predict_children``
    so the two can never drift apart on the bit-identity contract.
    """
    parent = list(parent)
    n = len(parent)
    if n < 2:
        raise ValueError("parent needs >=2 GPUs to have elimination children")
    pbits, pcounts, _, _, flat = _batch_bits_counts(arrays, [parent])
    hosts = arrays.gpu_host[flat]                      # host of each element
    bits = np.repeat(pbits, n, axis=0)                 # [n, H_all]
    counts = np.repeat(pcounts, n, axis=0)
    child_idx = np.arange(n)
    bits[child_idx, hosts] -= arrays.gpu_bit[flat]
    counts[child_idx, hosts] -= 1
    return bits, counts


def featurize_children(
    cluster: Cluster,
    tables: IntraHostTables,
    parent: Sequence[int],
    max_hosts: int | None = None,
    host_norm: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """Featurize every single-GPU elimination of ``parent`` (the PTS round).

    Child i is ``parent`` minus its i-th element (parent order).  A child
    differs from its parent in exactly one host token — plus the two cheap
    k-dependent request-context channels — so the whole [|S|, H, F] round
    batch is assembled from the parent's per-host grids with one patched
    (host, bitmask) gather per child, skipping the per-GPU accumulation of
    :func:`featurize_batch`.  Bit-identical to featurizing the children
    list directly (regression-pinned).
    """
    if max_hosts is None:
        max_hosts = cluster.n_hosts
    arrays = host_arrays(cluster, tables)
    bits, counts = child_bits_counts(arrays, parent)
    n = bits.shape[0]
    ks = np.full((n,), n - 1, np.int64)
    tokens = _isolated_channels(arrays, bits, counts, ks, host_norm)
    return _pack_tokens(tokens, counts, max_hosts, N_FEATURES)


# ---------------------------------------------------------------------------
# Contended featurization: (subset, ledger) -> tokens with context channels
# ---------------------------------------------------------------------------

def default_max_tokens(cluster: Cluster) -> int:
    """Token budget for the contended featurizer: every candidate host plus
    up to two contender tokens per host (overflow is truncated; the count
    and demand *channels* still carry the dropped contenders)."""
    return 3 * cluster.n_hosts


def featurize_contended_one(
    cluster: Cluster,
    tables: IntraHostTables,
    subset: Sequence[int],
    ledger: Optional[JobLedger],
    max_tokens: int,
    include_contenders: bool = True,
    host_norm: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """-> (feats [max_tokens, N_CONTENDED_FEATURES], mask [max_tokens]).

    Candidate host tokens come first (segment flag 0) with their isolated
    channels computed by the *same* code path as :func:`featurize_one`;
    contender tokens (one per contending job per shared host, segment flag
    1) follow in deterministic (host, job id) order and are truncated at
    ``max_tokens``.
    """
    by_host = cluster.partition_by_host(subset)
    feats = np.zeros((max_tokens, N_CONTENDED_FEATURES), np.float32)
    mask = np.zeros((max_tokens,), np.float32)
    k = len(subset)
    sset = set(subset)
    busy = ledger.busy() if ledger is not None else set()

    hosts = sorted(by_host.items())
    if len(hosts) > max_tokens:
        raise ValueError(
            f"subset spans {len(hosts)} hosts > max_tokens={max_tokens}"
        )
    # One ledger traversal per host: the contender jobs drive both the
    # context channels and the contender tokens (this is the hot path —
    # learned-mode search featurizes hundreds of candidates per admission).
    jobs_by_host = {
        hid: (
            ledger.cross_host_jobs_on(hid, against=subset)
            if ledger is not None else []
        )
        for hid, _ in hosts
    }
    hd = (
        ledger.host_degrade
        if ledger is not None and getattr(ledger, "health_active", False)
        else None
    )
    ctx_by_host = {}
    for hid, _ in hosts:
        jobs = jobs_by_host[hid]
        host = cluster.hosts[hid]
        on_host = {
            a.job_id: [g for g in a.gpus if cluster.gpu_host[g] == hid]
            for a in jobs
        }
        occ = sum(
            1 for g in host.gpu_ids if g in busy and g not in sset
        ) / host.n_gpus if ledger is not None else 0.0
        demand = sum(len(g) for g in on_host.values())
        health = 1.0 - hd(hid) if hd is not None else 0.0
        ctx_by_host[hid] = (len(jobs) / _C_NORM, demand / 8.0, occ, health)
        jobs_by_host[hid] = [(a, on_host[a.job_id]) for a in jobs]
    for i, (hid, gpus) in enumerate(hosts):
        feats[i, :N_FEATURES] = _host_token(
            cluster, tables, hid, gpus, k, host_norm
        )
        feats[i, N_FEATURES + 1:] = ctx_by_host[hid]  # segment stays 0
        mask[i] = 1.0
    n = len(hosts)
    if include_contenders and ledger is not None and len(hosts) > 1:
        for hid, _ in hosts:
            for alloc, on_host in jobs_by_host[hid]:
                if n >= max_tokens:
                    return feats, mask  # truncate; channels keep the counts
                feats[n, :N_FEATURES] = _host_token(
                    cluster, tables, hid, on_host, alloc.k, host_norm
                )
                feats[n, N_FEATURES] = 1.0  # segment: contender token
                feats[n, N_FEATURES + 1:] = ctx_by_host[hid]
                mask[n] = 1.0
                n += 1
    return feats, mask


class _LedgerArrays:
    """Per-ledger dense view the vectorized contended featurizer consumes:
    cross-host allocations as membership masks and per-host GPU demands."""

    def __init__(self, cluster: Cluster, arrays: HostArrays, ledger: JobLedger):
        n_hosts = cluster.n_hosts
        cross = ledger.cross_jobs_by_host()
        order: Dict[str, int] = {}
        allocs = []
        for hid in sorted(cross):
            for a in cross[hid]:         # already sorted by job id per host
                if a.job_id not in order:
                    order[a.job_id] = len(allocs)
                    allocs.append(a)
        nJ = len(allocs)
        self.allocs = allocs
        self.occ = np.zeros((nJ, cluster.n_gpus), np.int64)
        self.onhost_count = np.zeros((nJ, n_hosts), np.int64)
        self.onhost_bits = np.zeros((nJ, n_hosts), np.int64)
        self.alloc_k = np.asarray([a.k for a in allocs], np.int64)
        for j, a in enumerate(allocs):
            gs = np.asarray(a.gpus, np.int64)
            self.occ[j, gs] = 1
            np.add.at(self.onhost_count[j], arrays.gpu_host[gs], 1)
            np.add.at(self.onhost_bits[j], arrays.gpu_host[gs],
                      arrays.gpu_bit[gs])
        # host -> contender indices in job-id order (cross_host_jobs_on order)
        self.jobs_on_host: List[List[int]] = [
            sorted(
                (j for j in range(nJ) if self.onhost_count[j, hid] > 0),
                key=lambda j: allocs[j].job_id,
            )
            for hid in range(n_hosts)
        ]
        busy = np.zeros((cluster.n_gpus,), np.int64)
        for g in ledger.busy():
            busy[g] = 1
        self.busy = busy
        self.busy_per_host = np.zeros((n_hosts,), np.int64)
        np.add.at(self.busy_per_host, arrays.gpu_host[busy.nonzero()[0]], 1)


def _contender_token_rows(
    arrays: HostArrays, led: "_LedgerArrays", host_norm: bool
) -> Tuple[np.ndarray, np.ndarray]:
    """Base features of every (contender job, host) token, plus the dense
    (job, host) -> row index map (-1 where the job has no GPUs there)."""
    j_idx, h_idx = np.nonzero(led.onhost_count)
    cnt = led.onhost_count[j_idx, h_idx]
    intra = arrays.intra_bw[h_idx, led.onhost_bits[j_idx, h_idx]]
    log_intra = np.log1p(intra)
    kj = led.alloc_k[j_idx]
    rowsf = np.zeros((len(j_idx), N_FEATURES), np.float64)
    rowsf[:, 0] = log_intra / _LOG_SCALE
    rowsf[:, 1] = cnt / 8.0
    rowsf[:, 2] = cnt / kj
    rowsf[:, 3] = kj / max(arrays.cluster.n_gpus, 1)
    if host_norm:
        rowsf[:, 4] = (log_intra - arrays.log_rail[h_idx, cnt]) / _LOG_SCALE
    index = np.full(led.onhost_count.shape, -1, np.int64)
    index[j_idx, h_idx] = np.arange(len(j_idx))
    return rowsf.astype(np.float32), index


def _featurize_contended_group(
    cluster: Cluster,
    arrays: HostArrays,
    ledger: Optional[JobLedger],
    subsets: Sequence[Sequence[int]],
    max_tokens: int,
    include_contenders: bool,
    host_norm: bool,
) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized contended featurization of one ledger's candidate batch."""
    B = len(subsets)
    bits, counts, ks, rows, flat = _batch_bits_counts(arrays, subsets)
    tokens = _isolated_channels(arrays, bits, counts, ks, host_norm)
    n_hosts = counts.shape[1]
    if ledger is None or len(ledger) == 0:
        ctx = np.zeros((B, n_hosts, N_LEDGER_FEATURES), np.float64)
        led = None
        disjoint = None
    else:
        cached = arrays.ledger_cache.get(ledger.uid)
        if cached is not None and cached[0] == ledger.version:
            led = cached[1]
        else:
            led = _LedgerArrays(cluster, arrays, ledger)
            if len(arrays.ledger_cache) >= arrays.max_ledger_entries:
                # oldest-first eviction (insertion order): single-use
                # ledgers from dataset generation must not accumulate.
                # pop() tolerates a concurrent joint-order thread having
                # already evicted the same uid.
                for uid in list(arrays.ledger_cache)[
                        : arrays.max_ledger_entries // 2]:
                    arrays.ledger_cache.pop(uid, None)
            arrays.ledger_cache[ledger.uid] = (ledger.version, led)
        M = np.zeros((B, cluster.n_gpus), np.int64)
        M[rows, flat] = 1
        disjoint = (M @ led.occ.T) == 0 if led.occ.shape[0] else \
            np.zeros((B, 0), bool)
        dj = disjoint.astype(np.int64)
        c = dj @ (led.onhost_count > 0).astype(np.int64)      # [B, H_all]
        demand = dj @ led.onhost_count
        overlap = np.zeros((B, n_hosts), np.int64)
        np.add.at(overlap, (rows, arrays.gpu_host[flat]), led.busy[flat])
        occ = (led.busy_per_host[None, :] - overlap) / arrays.host_n_gpus
        ctx = np.zeros((B, n_hosts, N_LEDGER_FEATURES), np.float64)
        ctx[..., 1] = c / _C_NORM
        ctx[..., 2] = demand / 8.0
        ctx[..., 3] = occ
    # Health channel — filled in BOTH branches (a degraded-but-empty ledger
    # must still expose its perturbed fabric, or the loop and vectorized
    # paths would diverge).
    if ledger is not None and getattr(ledger, "health_active", False):
        degv = np.asarray(
            [ledger.host_degrade(h.host_id) for h in cluster.hosts],
            np.float64,
        )
        ctx[..., 4] = (1.0 - degv)[None, :]
    feats, mask = _pack_tokens(
        tokens, counts, max_tokens, N_CONTENDED_FEATURES, extra=ctx
    )
    if led is None or not include_contenders or not led.allocs:
        return feats, mask
    # Contender tokens: per candidate, (host ascending, job id ascending),
    # truncated at max_tokens — all feature math precomputed above; the
    # remaining per-candidate work is index assembly over <= max_tokens rows.
    memo = getattr(led, "ctok_memo", None)
    if memo is None:
        memo = led.ctok_memo = {}
    if host_norm not in memo:
        memo[host_norm] = _contender_token_rows(arrays, led, host_norm)
    ctok, index = memo[host_norm]
    ctx32 = ctx.astype(np.float32)
    part = counts > 0
    for b in range(B):
        hids = np.nonzero(part[b])[0]
        if len(hids) <= 1:
            continue
        n = len(hids)
        for hid in hids:
            for j in led.jobs_on_host[hid]:
                if not disjoint[b, j]:
                    continue
                if n >= max_tokens:
                    break
                feats[b, n, :N_FEATURES] = ctok[index[j, hid]]
                feats[b, n, N_FEATURES] = 1.0
                feats[b, n, N_FEATURES + 1:] = ctx32[b, hid, 1:]
                mask[b, n] = 1.0
                n += 1
            if n >= max_tokens:
                break
    return feats, mask


def featurize_contended_batch(
    cluster: Cluster,
    tables: IntraHostTables,
    pairs: Sequence[Tuple[Sequence[int], Optional[JobLedger]]],
    max_tokens: Optional[int] = None,
    include_contenders: bool = True,
    host_norm: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """-> (feats [B, T, N_CONTENDED_FEATURES], mask [B, T]) for a batch of
    (subset, ledger) pairs; ``ledger=None`` means isolated.

    Vectorized per ledger group: the search path (every pair sharing one
    live ledger) runs as a single array program; mixed-ledger training
    batches fall back to per-group programs.  Bit-identical to
    :func:`featurize_contended_batch_loop` (regression-pinned).
    """
    if max_tokens is None:
        max_tokens = default_max_tokens(cluster)
    arrays = host_arrays(cluster, tables)
    B = len(pairs)
    feats = np.zeros((B, max_tokens, N_CONTENDED_FEATURES), np.float32)
    mask = np.zeros((B, max_tokens), np.float32)
    groups: Dict[int, List[int]] = {}
    ledgers: Dict[int, Optional[JobLedger]] = {}
    for i, (_, ledger) in enumerate(pairs):
        key = id(ledger) if ledger is not None else -1
        groups.setdefault(key, []).append(i)
        ledgers[key] = ledger
    for key, idx in groups.items():
        f, m = _featurize_contended_group(
            cluster, arrays, ledgers[key], [pairs[i][0] for i in idx],
            max_tokens, include_contenders, host_norm,
        )
        feats[idx] = f
        mask[idx] = m
    return feats, mask


def featurize_contended_batch_loop(
    cluster: Cluster,
    tables: IntraHostTables,
    pairs: Sequence[Tuple[Sequence[int], Optional[JobLedger]]],
    max_tokens: Optional[int] = None,
    include_contenders: bool = True,
    host_norm: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """Legacy per-pair loop featurizer (bit-identity reference)."""
    if max_tokens is None:
        max_tokens = default_max_tokens(cluster)
    B = len(pairs)
    feats = np.zeros((B, max_tokens, N_CONTENDED_FEATURES), np.float32)
    mask = np.zeros((B, max_tokens), np.float32)
    for b, (subset, ledger) in enumerate(pairs):
        feats[b], mask[b] = featurize_contended_one(
            cluster, tables, subset, ledger, max_tokens,
            include_contenders=include_contenders, host_norm=host_norm,
        )
    return feats, mask


# ---------------------------------------------------------------------------
# Device tables: the on-device elimination scan's gather substrate
# ---------------------------------------------------------------------------

class _CapLattice:
    """Geometry of the per-host GPU-count lattice the analytic contention
    cap is tabulated over (see :class:`DeviceTables`)."""

    def __init__(self, counts, part, n_part, ks, jitter):
        self.counts = counts    # [L, H_all] int64 per-host count vectors
        self.part = part        # [L, H_all] bool  count > 0
        self.n_part = n_part    # [L] participating-host count
        self.ks = ks            # [L] subset size
        self.jitter = jitter    # [L] deterministic fabric jitter factor


class DeviceTables:
    """Float32 gather tables for the fused on-device PTS scan.

    The scan body re-expresses :func:`featurize_children` as pure gathers:
    channels 0 and 4 of a token depend only on ``(host, local bitmask)``, so
    both are precomputed here as ``[H_all, 2**max_g]`` tables — evaluated in
    the *same float64 program* as :func:`_isolated_channels` and cast to
    float32 once, so a device gather lands on exactly
    ``np.float32(host-path value)``.  ``stage1`` is the raw Stage-1 lookup
    (the single-host dispatch branch).

    For the analytic contention cap, observe that once the candidate is
    GPU-disjoint from every live job (always true for PTS over free GPUs),
    the cap depends only on the candidate's per-host GPU-count vector.
    Those vectors live on a mixed-radix lattice (radix ``n_gpus_h + 1`` per
    host, |L| = 6561 on the paper's 4x8 clusters), so any ledger's cap
    function is a ``[L]`` table built in microseconds of numpy
    (:meth:`cap_lattice` holds the ledger-independent geometry and the
    per-point fabric jitter, computed once per cluster).
    """

    def __init__(self, cluster: Cluster, tables: IntraHostTables):
        self.cluster = cluster
        arrays = host_arrays(cluster, tables)
        self.arrays = arrays
        n_hosts = cluster.n_hosts
        max_g = arrays.max_host_gpus
        W = 1 << max_g
        self.mask_size = W
        with np.errstate(invalid="ignore"):
            log_intra = np.log1p(arrays.intra_bw)          # [H, W], NaN at 0
            self.tok0 = (log_intra / _LOG_SCALE).astype(np.float32)
            pop = np.asarray(
                [bin(m).count("1") for m in range(W)], np.int64
            )
            safe = np.minimum(pop, max_g)
            self.tok4 = (
                (log_intra - arrays.log_rail[:, safe]) / _LOG_SCALE
            ).astype(np.float32)
        self.tok4_zero = np.zeros_like(self.tok4)          # host_norm=False
        self.stage1 = arrays.intra_bw.astype(np.float32)   # [H, W]
        self.rail_bw = arrays.nic_rail_bw                  # [H] float64
        radix = arrays.host_n_gpus + 1
        strides = np.ones((n_hosts,), np.int64)
        for h in range(1, n_hosts):
            strides[h] = strides[h - 1] * radix[h - 1]
        self.strides = strides
        self.lattice_size = int(strides[-1] * radix[-1])
        self.n_gpus_f = np.float32(max(cluster.n_gpus, 1))
        self._lattice: Optional[_CapLattice] = None
        self._caps_inf: Optional[np.ndarray] = None

    def cap_lattice(self) -> _CapLattice:
        """Lazy per-cluster lattice geometry + per-point fabric jitter.

        The jitter key of an inter-host candidate is its sorted
        ``(host, count)`` participation tuple — a pure function of the
        lattice point and the cluster name, never of the ledger — so it is
        evaluated once here and reused by every per-ledger cap table."""
        if self._lattice is None:
            L = self.lattice_size
            n_hosts = len(self.strides)
            radix = self.arrays.host_n_gpus + 1
            idx = np.arange(L, dtype=np.int64)
            counts = np.stack(
                [(idx // self.strides[h]) % radix[h] for h in range(n_hosts)],
                axis=1,
            )
            part = counts > 0
            n_part = part.sum(axis=1)
            ks = counts.sum(axis=1)
            jitter = np.ones((L,), np.float64)
            name = self.cluster.name
            for i in np.nonzero(n_part > 1)[0]:
                key = tuple(
                    (int(h), int(counts[i, h]))
                    for h in np.nonzero(part[i])[0]
                )
                jitter[i] = _jitter(name, "inter", key)
            self._lattice = _CapLattice(counts, part, n_part, ks, jitter)
        return self._lattice

    def caps_inf(self) -> np.ndarray:
        """The capless (isolated / empty-ledger) cap table: all +inf."""
        if self._caps_inf is None:
            self._caps_inf = np.full(
                (self.lattice_size,), np.inf, np.float32
            )
        return self._caps_inf


def device_tables(cluster: Cluster, tables: IntraHostTables) -> DeviceTables:
    """The (cached) :class:`DeviceTables` of one tables instance."""
    dt = getattr(tables, "_device_tables", None)
    if dt is None or dt.cluster is not cluster:
        dt = DeviceTables(cluster, tables)
        tables._device_tables = dt
    return dt


def featurize_gpu_ids(
    cluster: Cluster, subsets: Sequence[Sequence[int]], max_k: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Raw-identifier featurization for the *naive* baseline (Sec. 5.5.1):
    one token per GPU, feature = global GPU id (embedded by the model).
    -> (ids [B, max_k] int32, mask [B, max_k])."""
    B = len(subsets)
    ids = np.zeros((B, max_k), np.int32)
    mask = np.zeros((B, max_k), np.float32)
    for b, subset in enumerate(subsets):
        for i, g in enumerate(sorted(subset)):
            ids[b, i] = g
            mask[b, i] = 1.0
    return ids, mask
