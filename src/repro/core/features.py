"""Feature extraction for the hierarchical surrogate (Sec. 4.2.1, Fig. 4).

For an allocation S, the Transformer receives one token per *participating
host*: a feature tuple of (i) the Stage-1 measured intra-host bandwidth of
the GPUs selected on that host and (ii) the number of GPUs selected there.
Padding + mask make the representation batchable; the architecture itself is
size-agnostic (any number of hosts / any k).

Two featurizations live here:

* **Isolated** (``featurize_one`` / ``featurize_batch``): per-host tokens of
  ``N_FEATURES`` channels.  Channel 4 is the per-host-type *normalized*
  intra-host bandwidth: ``(log1p(intra) - log1p(rail_bw * n_h)) / 5`` —
  the intra bandwidth measured against the host type's NIC rail capacity
  at the selected count.  Mixed NVLink generations span ~2.5 decades in
  log-space, and the raw log channel leaves the model to recover each host
  class's operating point (and hence which of the intra/inter constraints
  binds — where the Het-VA errors concentrate, see ROADMAP) on its own;
  this channel hands it the normalized position directly.  The matching
  embed row is zero-initialized (``surrogate.init_hierarchical_params``) so
  an un-trained or legacy-trained model is unaffected.  ``host_norm=False``
  zeroes the channel (the ablation knob ``bench_surrogate_accuracy`` uses
  to report the delta).

* **Contended** (``featurize_contended_one`` / ``featurize_contended_batch``):
  the isolated channels plus ``N_LEDGER_FEATURES`` ledger-context channels
  per token — segment flag, rail-contender count ``c_h``, contender GPU
  demand on the host, and disjoint occupancy — and (optionally) one extra
  token per (contending job, shared host) pair carrying the contender's own
  intra-host features with the segment flag set.  Under an **empty ledger**
  the first ``N_FEATURES`` channels are bit-identical to the isolated
  featurization, every context channel is exactly zero, and no contender
  token is emitted (regression-pinned): the contended representation is a
  strict superset of the isolated one.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bandwidth_sim import BW_SCALE
from repro.core.cluster import Cluster
from repro.core.intra_host import IntraHostTables
from repro.core.tenancy import JobLedger

# Per-host token features.  The paper's tuple is (intra-host bandwidth from
# the Stage-1 lookup, GPU count on that host); we encode the bandwidth in
# log-space (it spans ~2.5 decades across heterogeneous hosts) and append
# two request-context features the dispatcher trivially knows — the host's
# share of the request (n_h/k) and the normalized request size — plus the
# per-host-type normalized bandwidth (see module docstring).
N_FEATURES = 5
_LOG_SCALE = 5.0  # keep in sync with surrogate.LOG_SCALE

# Ledger-context channels appended by the contended featurizer:
#   [segment flag, c_h / C_NORM, contender demand / 8, disjoint occupancy]
N_LEDGER_FEATURES = 4
N_CONTENDED_FEATURES = N_FEATURES + N_LEDGER_FEATURES
_C_NORM = 4.0  # rail-contender count normalizer

def _host_token(
    cluster: Cluster,
    tables: IntraHostTables,
    hid: int,
    gpus: Sequence[int],
    k: int,
    host_norm: bool,
) -> np.ndarray:
    """The isolated feature tuple of one (host, selected GPUs) token."""
    host_type = cluster.hosts[hid].host_type
    intra = tables.lookup(hid, cluster.local_tuple(hid, gpus))
    out = np.zeros((N_FEATURES,), np.float32)
    out[0] = np.log1p(intra) / _LOG_SCALE
    out[1] = len(gpus) / 8.0
    out[2] = len(gpus) / k
    out[3] = k / max(cluster.n_gpus, 1)
    if host_norm:
        out[4] = (
            np.log1p(intra) - np.log1p(host_type.nic_rail_bw * len(gpus))
        ) / _LOG_SCALE
    return out


def featurize_one(
    cluster: Cluster,
    tables: IntraHostTables,
    subset: Sequence[int],
    max_hosts: int,
    host_norm: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """-> (feats [max_hosts, N_FEATURES] float32, mask [max_hosts] float32)."""
    by_host = cluster.partition_by_host(subset)
    feats = np.zeros((max_hosts, N_FEATURES), np.float32)
    mask = np.zeros((max_hosts,), np.float32)
    k = len(subset)
    for i, (hid, gpus) in enumerate(sorted(by_host.items())):
        feats[i] = _host_token(cluster, tables, hid, gpus, k, host_norm)
        mask[i] = 1.0
    return feats, mask


def featurize_batch(
    cluster: Cluster,
    tables: IntraHostTables,
    subsets: Sequence[Sequence[int]],
    max_hosts: int | None = None,
    host_norm: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """-> (feats [B, H, F], mask [B, H]) for a batch of allocations."""
    if max_hosts is None:
        max_hosts = cluster.n_hosts
    B = len(subsets)
    feats = np.zeros((B, max_hosts, N_FEATURES), np.float32)
    mask = np.zeros((B, max_hosts), np.float32)
    for b, subset in enumerate(subsets):
        feats[b], mask[b] = featurize_one(
            cluster, tables, subset, max_hosts, host_norm=host_norm
        )
    return feats, mask


# ---------------------------------------------------------------------------
# Contended featurization: (subset, ledger) -> tokens with context channels
# ---------------------------------------------------------------------------

def default_max_tokens(cluster: Cluster) -> int:
    """Token budget for the contended featurizer: every candidate host plus
    up to two contender tokens per host (overflow is truncated; the count
    and demand *channels* still carry the dropped contenders)."""
    return 3 * cluster.n_hosts


def featurize_contended_one(
    cluster: Cluster,
    tables: IntraHostTables,
    subset: Sequence[int],
    ledger: Optional[JobLedger],
    max_tokens: int,
    include_contenders: bool = True,
    host_norm: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """-> (feats [max_tokens, N_CONTENDED_FEATURES], mask [max_tokens]).

    Candidate host tokens come first (segment flag 0) with their isolated
    channels computed by the *same* code path as :func:`featurize_one`;
    contender tokens (one per contending job per shared host, segment flag
    1) follow in deterministic (host, job id) order and are truncated at
    ``max_tokens``.
    """
    by_host = cluster.partition_by_host(subset)
    feats = np.zeros((max_tokens, N_CONTENDED_FEATURES), np.float32)
    mask = np.zeros((max_tokens,), np.float32)
    k = len(subset)
    sset = set(subset)
    busy = ledger.busy() if ledger is not None else set()

    hosts = sorted(by_host.items())
    if len(hosts) > max_tokens:
        raise ValueError(
            f"subset spans {len(hosts)} hosts > max_tokens={max_tokens}"
        )
    # One ledger traversal per host: the contender jobs drive both the
    # context channels and the contender tokens (this is the hot path —
    # learned-mode search featurizes hundreds of candidates per admission).
    jobs_by_host = {
        hid: (
            ledger.cross_host_jobs_on(hid, against=subset)
            if ledger is not None else []
        )
        for hid, _ in hosts
    }
    ctx_by_host = {}
    for hid, _ in hosts:
        jobs = jobs_by_host[hid]
        host = cluster.hosts[hid]
        on_host = {
            a.job_id: [g for g in a.gpus if cluster.gpu_host[g] == hid]
            for a in jobs
        }
        occ = sum(
            1 for g in host.gpu_ids if g in busy and g not in sset
        ) / host.n_gpus if ledger is not None else 0.0
        demand = sum(len(g) for g in on_host.values())
        ctx_by_host[hid] = (len(jobs) / _C_NORM, demand / 8.0, occ)
        jobs_by_host[hid] = [(a, on_host[a.job_id]) for a in jobs]
    for i, (hid, gpus) in enumerate(hosts):
        feats[i, :N_FEATURES] = _host_token(
            cluster, tables, hid, gpus, k, host_norm
        )
        feats[i, N_FEATURES + 1:] = ctx_by_host[hid]  # segment stays 0
        mask[i] = 1.0
    n = len(hosts)
    if include_contenders and ledger is not None and len(hosts) > 1:
        for hid, _ in hosts:
            for alloc, on_host in jobs_by_host[hid]:
                if n >= max_tokens:
                    return feats, mask  # truncate; channels keep the counts
                feats[n, :N_FEATURES] = _host_token(
                    cluster, tables, hid, on_host, alloc.k, host_norm
                )
                feats[n, N_FEATURES] = 1.0  # segment: contender token
                feats[n, N_FEATURES + 1:] = ctx_by_host[hid]
                mask[n] = 1.0
                n += 1
    return feats, mask


def featurize_contended_batch(
    cluster: Cluster,
    tables: IntraHostTables,
    pairs: Sequence[Tuple[Sequence[int], Optional[JobLedger]]],
    max_tokens: Optional[int] = None,
    include_contenders: bool = True,
    host_norm: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """-> (feats [B, T, N_CONTENDED_FEATURES], mask [B, T]) for a batch of
    (subset, ledger) pairs; ``ledger=None`` means isolated."""
    if max_tokens is None:
        max_tokens = default_max_tokens(cluster)
    B = len(pairs)
    feats = np.zeros((B, max_tokens, N_CONTENDED_FEATURES), np.float32)
    mask = np.zeros((B, max_tokens), np.float32)
    for b, (subset, ledger) in enumerate(pairs):
        feats[b], mask[b] = featurize_contended_one(
            cluster, tables, subset, ledger, max_tokens,
            include_contenders=include_contenders, host_norm=host_norm,
        )
    return feats, mask


def featurize_gpu_ids(
    cluster: Cluster, subsets: Sequence[Sequence[int]], max_k: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Raw-identifier featurization for the *naive* baseline (Sec. 5.5.1):
    one token per GPU, feature = global GPU id (embedded by the model).
    -> (ids [B, max_k] int32, mask [B, max_k])."""
    B = len(subsets)
    ids = np.zeros((B, max_k), np.int32)
    mask = np.zeros((B, max_k), np.float32)
    for b, subset in enumerate(subsets):
        for i, g in enumerate(sorted(subset)):
            ids[b, i] = g
            mask[b, i] = 1.0
    return ids, mask
