"""Dispatch forensics: decision attribution, journal time-travel, what-if.

After ISSUE 8 the dispatch stack could say *that* a decision happened —
spans, metrics, drift alerts — but not *why* the search picked this subset
over that one, or what the choice cost the tenant.  This module closes
that gap with three layers, all read-only with respect to the dispatch
decision (capture ON commits byte-identical placements to capture OFF —
pinned by ``tests/test_forensics.py`` and the
``dispatch_forensics_overhead`` bench row):

**Attribution** (:class:`DecisionDossier` / :class:`DossierRecorder`).
Every committed admission produces a structured dossier: the journal
``seq`` and span ``trace_id`` it committed under, per-round search
provenance (candidates scored, PTS prune-and-why, per-round bottleneck
eliminations, the EHA-vs-PTS winner and its margin), the Stage-1
intra-host vs inter-host rail decomposition of the predicted bandwidth,
the analytic/learned contention-cap delta, and the fragmentation
tie-break state.  Capture rides the same falsy-null-guard pattern as the
tracer: hooks in ``search.py`` / ``dispatcher.py`` / ``scheduler.py`` /
``controlplane.py`` call :func:`draft`, which costs one module-global
read when no recorder is installed.  Drafts are thread-local (one
admission runs on one thread — pool workers included), so racing
control-plane workers never interleave provenance.

**Time-travel** (:func:`reconstruct` / :func:`replay_decision`).
``reconstruct(path, cluster, seq)`` rebuilds the exact ledger view the
admission at journal ``seq`` was decided against (via
``replay_journal(..., upto_seq=seq)``), and ``replay_decision`` re-runs
the dispatcher's search recipe against it — reproducing the journaled
placement byte-identically for every deterministic admission path
(serial, planned, serialized, and CAS commits; a *validated* concurrent
commit was staged against an older snapshot, so re-searching the
commit-time state legitimately may differ — see docs/observability.md).

**Counterfactual what-if** (:func:`whatif`).  Re-dispatch the same
request against the reconstructed ledger under perturbed config —
``drop_tenant=`` / ``drop_jobs=`` evict co-tenants, ``frag_weight=`` /
``contention_mode=`` / ``policy=`` override the search recipe — and
report the true-bandwidth delta.  Deltas feed the per-tenant
:class:`RegretLedger` (realized vs oracle vs best-counterfactual),
exported into a :class:`~repro.core.telemetry.MetricsRegistry` by
:func:`absorb_regret` and rendered by ``scripts/render_forensics.py``.

See ``docs/observability.md`` §5 for the dossier schema and regret
semantics.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import threading
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import telemetry
from repro.core.controlplane import JournalEvent, read_journal, replay_journal
from repro.core.tenancy import JobLedger

__all__ = [
    "DecisionDossier",
    "DossierRecorder",
    "RegretLedger",
    "ReplayResult",
    "WhatIfReport",
    "absorb_regret",
    "bandwidth_decomposition",
    "capture",
    "decision",
    "draft",
    "active_recorder",
    "install_forensics",
    "note_grade",
    "reconstruct",
    "replay_decision",
    "whatif",
]

_TLS = threading.local()                       # per-thread draft stack
_ACTIVE: Optional["DossierRecorder"] = None    # process-wide opt-in
_INSTALL_LOCK = threading.Lock()

_MAX_ROUNDS = 512  # provenance bound: drop round detail past this, not data


def _isfinite(x: float) -> bool:
    return isinstance(x, (int, float)) and math.isfinite(x)


# ---------------------------------------------------------------------------
# Drafts and dossiers (attribution)
# ---------------------------------------------------------------------------

class DecisionDraft:
    """Mutable per-admission scratchpad the hook sites write into.

    Opened by :func:`decision` on the admitting thread, filled by the
    search/dispatch/control-plane hooks (via :func:`draft`), sealed into a
    :class:`DecisionDossier` iff the admission commits.  A make-room defrag
    pass (or a control-plane re-search after a conflict) runs extra hybrid
    searches inside the same admission: each ``hybrid_search`` call resets
    the search provenance (:meth:`note_search_begin`), so the sealed
    dossier always describes the search whose subset actually committed.
    """

    __slots__ = (
        "job_id", "tenant", "k", "policy", "path", "trace_id",
        "subset", "predicted_bw", "journal_seq",
        "staged_version", "committed_version",
        "validated", "serialized", "retries", "committed",
        "n_avail", "frag_active", "n_searches",
        "winner", "winner_margin", "eha", "pts",
        "eha_score", "pts_score",
        "pts_prune", "pts_fused_steps", "pts_rounds",
        "decomposition",
    )

    def __init__(self, job_id: str, tenant: str, k: int,
                 policy: str, path: str):
        self.job_id = job_id
        self.tenant = tenant
        self.k = k
        self.policy = policy
        self.path = path
        self.trace_id = -1
        self.subset: Optional[Tuple[int, ...]] = None
        self.predicted_bw = float("nan")
        self.journal_seq = -1
        self.staged_version = -1
        self.committed_version = -1
        self.validated = False
        self.serialized = False
        self.retries = 0
        self.committed = False
        self.n_avail = 0
        self.frag_active = False
        self.n_searches = 0
        self.winner = ""
        self.winner_margin = float("nan")
        self.eha: Optional[Dict] = None
        self.pts: Optional[Dict] = None
        self.eha_score = float("nan")
        self.pts_score = float("nan")
        self.pts_prune: Optional[Dict] = None
        self.pts_fused_steps = 0
        self.pts_rounds: List[Dict] = []
        self.decomposition: Optional[Dict] = None

    # -- hook-site API (all O(1) per call) ----------------------------------

    def note_search_begin(self, k: int, n_avail: int,
                          frag_active: bool) -> None:
        """A hybrid search starts: reset per-search provenance (later
        searches within one admission overwrite earlier ones — the last
        search is the one whose result commits)."""
        self.n_avail = n_avail
        self.frag_active = frag_active
        self.n_searches += 1
        self.winner = ""
        self.winner_margin = float("nan")
        self.eha = self.pts = None
        self.eha_score = self.pts_score = float("nan")
        self.pts_prune = None
        self.pts_fused_steps = 0
        self.pts_rounds = []
        if self.trace_id < 0:
            self.trace_id = telemetry.current_trace_id()

    def note_hybrid(self, eha, pts, eha_score: float, pts_score: float,
                    winner: str) -> None:
        self.eha = _search_summary(eha)
        self.pts = _search_summary(pts)
        self.eha_score = float(eha_score)
        self.pts_score = float(pts_score)
        self.winner = winner
        self.winner_margin = abs(float(eha_score) - float(pts_score))

    def note_pts_prune(self, host_id: int, pruned: int) -> None:
        self.pts_prune = {"kind": "single_host", "host_id": int(host_id),
                          "pruned": int(pruned)}

    def note_pts_fused(self, steps: int) -> None:
        self.pts_fused_steps = int(steps)

    def note_pts_round(self, eliminated_gpu: int, score: float,
                       n_children: int) -> None:
        if len(self.pts_rounds) < _MAX_ROUNDS:
            self.pts_rounds.append({
                "eliminated": int(eliminated_gpu),
                "score": float(score),
                "n_children": int(n_children),
            })

    def note_decomposition(self, decomp: Dict) -> None:
        self.decomposition = decomp

    def commit(self, subset: Sequence[int], predicted_bw: float,
               journal_seq: int = -1, staged_version: int = -1,
               committed_version: int = -1, validated: bool = False,
               serialized: bool = False, retries: int = 0) -> None:
        """The admission committed: stamp the outcome; the enclosing
        :func:`decision` context seals the draft into a dossier."""
        self.subset = tuple(int(g) for g in subset)
        self.predicted_bw = float(predicted_bw)
        self.journal_seq = int(journal_seq)
        self.staged_version = int(staged_version)
        self.committed_version = int(committed_version)
        self.validated = bool(validated)
        self.serialized = bool(serialized)
        if self.committed:
            return
        self.retries = int(retries)
        self.committed = True
        if self.trace_id < 0:
            self.trace_id = telemetry.current_trace_id()
        # Seal NOW, not at context exit: the grading path runs inside the
        # decision context (right after commit), and its note_grade must
        # find the dossier already recorded to back-fill realized/oracle.
        rec = _ACTIVE
        if rec is not None:
            rec._record(_seal(self))


def _search_summary(res) -> Dict:
    """Compact provenance of one :class:`~repro.core.search.SearchResult`."""
    return {
        "subset": list(res.subset),
        "predicted_bw": float(res.predicted_bw),
        "seconds": float(res.seconds),
        "n_candidates": int(res.n_candidates),
        "single_host_shortcut": res.n_candidates == 1,
    }


@dataclasses.dataclass
class DecisionDossier:
    """One committed admission's full attribution record.

    ``realized_bw`` / ``oracle_bw`` / ``regret`` are back-filled when the
    grading path reports (:func:`note_grade`); NaN until then.  ``regret``
    is ``oracle_bw - realized_bw`` in GB/s — how much bandwidth the best
    ledger-aware placement would have bought this admission.
    """

    job_id: str
    tenant: str
    k: int
    policy: str
    path: str                    # serial | planned | concurrent | cplane
    subset: Tuple[int, ...]
    predicted_bw: float
    journal_seq: int             # -1: no journal attached
    trace_id: int                # -1: no tracer installed
    staged_version: int
    committed_version: int
    validated: bool
    serialized: bool
    retries: int
    winner: str                  # "EHA" | "PTS" | "" (no hybrid provenance)
    winner_margin: float         # |eha_score - pts_score| (penalized scores)
    eha: Optional[Dict]
    pts: Optional[Dict]
    eha_score: float
    pts_score: float
    pts_prune: Optional[Dict]
    pts_fused_steps: int
    pts_rounds: Tuple[Dict, ...]
    frag_active: bool
    n_searches: int              # >1: make-room / conflict re-searches ran
    n_avail: int
    decomposition: Optional[Dict]
    realized_bw: float = float("nan")
    oracle_bw: float = float("nan")
    regret: float = float("nan")

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["subset"] = list(self.subset)
        d["pts_rounds"] = list(self.pts_rounds)
        return d


def _seal(d: DecisionDraft) -> DecisionDossier:
    return DecisionDossier(
        job_id=d.job_id, tenant=d.tenant, k=d.k, policy=d.policy,
        path=d.path, subset=d.subset or (), predicted_bw=d.predicted_bw,
        journal_seq=d.journal_seq, trace_id=d.trace_id,
        staged_version=d.staged_version,
        committed_version=d.committed_version,
        validated=d.validated, serialized=d.serialized, retries=d.retries,
        winner=d.winner, winner_margin=d.winner_margin,
        eha=d.eha, pts=d.pts,
        eha_score=d.eha_score, pts_score=d.pts_score,
        pts_prune=d.pts_prune, pts_fused_steps=d.pts_fused_steps,
        pts_rounds=tuple(d.pts_rounds), frag_active=d.frag_active,
        n_searches=d.n_searches, n_avail=d.n_avail,
        decomposition=d.decomposition,
    )


class DossierRecorder:
    """Bounded ring of :class:`DecisionDossier` records plus the per-tenant
    :class:`RegretLedger` the grading path feeds.  Thread-safe: sealing
    takes the recorder lock; drafts themselves are thread-local."""

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._ring: deque = deque(maxlen=self.capacity)
        self._by_job: Dict[str, DecisionDossier] = {}  # latest per job id
        self._lock = threading.Lock()
        self.regret = RegretLedger()
        self.n_dossiers = 0

    def _record(self, dossier: DecisionDossier) -> None:
        with self._lock:
            self._ring.append(dossier)
            self._by_job[dossier.job_id] = dossier
            self.n_dossiers += 1

    def note_grade(self, job_id: str, realized_bw: float,
                   oracle_bw: float = float("nan"),
                   tenant: str = "") -> None:
        """Back-fill the realized/oracle bandwidths of ``job_id``'s latest
        dossier and feed the regret ledger (called by the scheduler's
        grading path via the module-level :func:`note_grade`)."""
        with self._lock:
            d = self._by_job.get(job_id)
        if d is not None:
            d.realized_bw = float(realized_bw)
            d.oracle_bw = float(oracle_bw)
            if _isfinite(realized_bw) and _isfinite(oracle_bw):
                d.regret = float(oracle_bw) - float(realized_bw)
            tenant = tenant or d.tenant
        self.regret.note(tenant, realized_bw, oracle=oracle_bw)

    # -- inspection ----------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def dossiers(self, job_id: Optional[str] = None) -> List[DecisionDossier]:
        with self._lock:
            out = list(self._ring)
        if job_id is not None:
            out = [d for d in out if d.job_id == job_id]
        return out

    def by_seq(self, seq: int) -> Optional[DecisionDossier]:
        """The dossier whose commit wrote journal line ``seq``, if any."""
        with self._lock:
            for d in self._ring:
                if d.journal_seq == seq:
                    return d
        return None

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self._by_job.clear()

    def write_jsonl(self, path) -> int:
        """One dossier per line (``scripts/render_forensics.py`` input)."""
        ds = self.dossiers()
        with open(path, "w", encoding="utf-8") as fh:
            for d in ds:
                fh.write(json.dumps(d.to_dict(), sort_keys=True) + "\n")
        return len(ds)


# -- install / capture machinery (mirrors telemetry's tracer) ----------------

def install_forensics(
    recorder: Optional[DossierRecorder],
) -> Optional[DossierRecorder]:
    """Install ``recorder`` process-wide (None disables); returns the
    previous one.  Process-wide for the same reason as the tracer: the
    control plane's pool workers must seal into the same recorder as the
    submitting thread."""
    global _ACTIVE
    with _INSTALL_LOCK:
        prev, _ACTIVE = _ACTIVE, recorder
    return prev


def active_recorder() -> Optional[DossierRecorder]:
    return _ACTIVE


@contextlib.contextmanager
def capture(recorder: DossierRecorder):
    """``with forensics.capture(DossierRecorder()) as rec:`` — install for
    the block, restore the previous recorder after."""
    prev = install_forensics(recorder)
    try:
        yield recorder
    finally:
        install_forensics(prev)


def _stack() -> List[DecisionDraft]:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


def draft() -> Optional[DecisionDraft]:
    """The innermost open draft on the calling thread, or None.  THE hook
    entry point: one module-global read when capture is disabled, so
    instrumented hot paths stay within the ≤5% overhead budget."""
    if _ACTIVE is None:
        return None
    st = getattr(_TLS, "stack", None)
    return st[-1] if st else None


@contextlib.contextmanager
def decision(job_id: str, tenant: str = "", k: int = 0,
             policy: str = "", path: str = ""):
    """Open a decision draft for one admission attempt.  Yields None when
    capture is disabled.  The draft seals into the active recorder iff
    :meth:`DecisionDraft.commit` ran (parked/rejected/failed admissions
    leave no dossier)."""
    rec = _ACTIVE
    if rec is None:
        yield None
        return
    d = DecisionDraft(job_id, tenant, int(k), policy, path)
    st = _stack()
    st.append(d)
    try:
        yield d
    finally:
        # sealing happened inside DecisionDraft.commit (so the grading
        # path, which runs before this context exits, sees the dossier)
        if st and st[-1] is d:
            st.pop()
        elif d in st:
            st.remove(d)


def note_grade(job_id: str, realized_bw: float,
               oracle_bw: float = float("nan"), tenant: str = "") -> None:
    """Report an admission's graded bandwidths to the active recorder
    (no-op when capture is disabled — one global read)."""
    rec = _ACTIVE
    if rec is not None:
        rec.note_grade(job_id, realized_bw, oracle_bw=oracle_bw,
                       tenant=tenant)


# ---------------------------------------------------------------------------
# Bandwidth decomposition (Stage-1 intra vs inter rail, cap delta)
# ---------------------------------------------------------------------------

def bandwidth_decomposition(
    cluster, tables, ledger: JobLedger, subset: Sequence[int],
    base_predictor=None, predicted_bw: float = float("nan"),
    contention_mode: str = "analytic",
) -> Dict:
    """Attribute a placement's predicted bandwidth to its layers.

    * ``intra_bw``: per-host Stage-1 table bandwidth of each host's local
      share (exact, from :class:`~repro.core.intra_host.IntraHostTables`;
      None for single-GPU shares, which have no intra-host collective).
    * ``inter_cap``: the analytic fair-share rail cap against the ledger's
      live cross-host tenants (``inf`` when single-host or uncontended).
    * ``cap_delta``: isolated B-hat minus the final (contention-degraded)
      estimate — the bandwidth the contention branch charged, whether the
      analytic cap or the learned contended head produced it.

    Called *after* subset selection; the only model touch is one isolated
    predict of the already-chosen subset, which hits the dispatcher's
    isolated memo (every hybrid winner was already scored), so capture
    cannot perturb placements or blow the overhead budget.
    """
    subset = sorted(int(g) for g in subset)
    by_host = cluster.partition_by_host(subset)
    intra: Dict[int, Optional[float]] = {}
    for hid, gpus in sorted(by_host.items()):
        if len(gpus) > 1:
            intra[hid] = float(tables.lookup_global(gpus))
        else:
            intra[hid] = None
    cap = float("inf")
    if len(by_host) > 1:
        from repro.core.contention import contended_inter_cap

        cap = float(contended_inter_cap(cluster, ledger, subset))
    isolated = float("nan")
    if base_predictor is not None:
        isolated = float(np.asarray(base_predictor.predict([subset]))[0])
    cap_delta = float("nan")
    if _isfinite(isolated) and _isfinite(predicted_bw):
        cap_delta = isolated - float(predicted_bw)
    return {
        "intra_bw": intra,
        "n_hosts": len(by_host),
        "cross_host": len(by_host) > 1,
        "inter_cap": cap,
        "isolated_bw": isolated,
        "predicted_bw": float(predicted_bw),
        "cap_delta": cap_delta,
        "contention_mode": contention_mode,
    }


# ---------------------------------------------------------------------------
# Time-travel: reconstruct + deterministic re-search
# ---------------------------------------------------------------------------

def reconstruct(
    journal_path, cluster, seq: int
) -> Tuple[JobLedger, JournalEvent]:
    """The ledger state the event at journal ``seq`` was decided against
    (every durable event with a smaller seq applied, nothing else), plus
    the event itself.  Raises ValueError when ``seq`` is not in the
    journal's durable prefix — a truncated journal time-travels over its
    surviving prefix only."""
    events = read_journal(journal_path)
    target = None
    for ev in events:
        if ev.seq == seq:
            target = ev
            break
    if target is None:
        raise ValueError(
            f"no durable journal event with seq={seq} "
            f"(journal holds {len(events)} events)"
        )
    return replay_journal(journal_path, cluster, upto_seq=seq), target


_UNSET = object()


def _search_view(
    view: JobLedger, k: int, dispatcher, *,
    contention_mode: Optional[str] = None,
    frag_weight: Optional[float] = None,
    contended=_UNSET,
    policy: str = "hybrid",
) -> Tuple[List[int], float, str]:
    """Run the dispatcher's search recipe against an arbitrary ledger view
    — the same chain ``AdmissionControlPlane._search`` stages with
    (contention wrapper over the view, the dispatcher's shared isolated
    memo, optional fragmentation tie-break), with per-call overrides for
    the what-if knobs.  Returns ``(subset, predicted_bw, winner)``."""
    from repro.core import search as search_mod
    from repro.core.predict_cache import cached_contention_predictor

    d = dispatcher
    cluster = d.cluster
    mode = d.contention_mode if contention_mode is None else contention_mode
    cont = d.contended_predictor if contended is _UNSET else contended
    fw = d.frag_weight if frag_weight is None else float(frag_weight)
    if d.contention_aware and mode != "off":
        pred = cached_contention_predictor(
            cluster, d.base_predictor, view, mode=mode, contended=cont,
            use_cache=d.prediction_cache is not None,
        )
    else:
        pred = d.base_predictor
    penalty = None
    if fw > 0:
        from repro.core.defrag import make_frag_penalty

        penalty = make_frag_penalty(cluster, view, fw)
    avail = view.available()
    if policy == "eha":
        res = search_mod.eha_search(cluster, d.tables, pred, avail, k,
                                    frag_penalty=penalty)
        return list(res.subset), float(res.predicted_bw), "EHA"
    if policy == "pts":
        res = search_mod.pts_search(cluster, d.tables, pred, avail, k,
                                    frag_penalty=penalty)
        return list(res.subset), float(res.predicted_bw), "PTS"
    if policy != "hybrid":
        raise ValueError(f"unknown search policy {policy!r}")
    res = search_mod.hybrid_search(cluster, d.tables, pred, avail, k,
                                   frag_penalty=penalty)
    return list(res.subset), float(res.predicted_bw), res.winner


@dataclasses.dataclass(frozen=True)
class ReplayResult:
    """One time-travelled decision: journaled vs re-searched placement."""

    seq: int
    job_id: str
    tenant: str
    journaled: Tuple[int, ...]
    replayed: Tuple[int, ...]
    predicted_bw: float
    winner: str
    identical: bool
    ledger_version: int  # version of the reconstructed decision-time view


def replay_decision(journal_path, seq: int, dispatcher) -> ReplayResult:
    """Reconstruct the ledger at ``seq`` and deterministically re-run the
    dispatcher's search for that admission.  For every deterministic
    admission path the replayed subset equals the journaled one
    byte-for-byte (the hypothesis suite in ``tests/test_forensics.py``
    pins this across policies, contention modes, and truncated-journal
    prefixes)."""
    view, ev = reconstruct(journal_path, dispatcher.cluster, seq)
    if ev.op != "admit":
        raise ValueError(
            f"journal seq={seq} is a {ev.op!r} event; only admits carry a "
            f"search decision to replay"
        )
    subset, predicted, winner = _search_view(view, len(ev.gpus), dispatcher)
    return ReplayResult(
        seq=seq, job_id=ev.job_id, tenant=ev.tenant,
        journaled=tuple(ev.gpus), replayed=tuple(subset),
        predicted_bw=predicted, winner=winner,
        identical=tuple(subset) == tuple(ev.gpus),
        ledger_version=view.version,
    )


# ---------------------------------------------------------------------------
# Counterfactual what-if
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WhatIfReport:
    """Factual vs counterfactual outcome of one journaled admission.

    ``factual_bw`` and ``counter_bw`` are *true* (simulator) contended
    bandwidths against the decision-time view and the perturbed view
    respectively; ``delta_bw = counter_bw - factual_bw`` is the bandwidth
    the perturbation would have bought (negative: the perturbation
    hurts).  ``oracle_bw`` is the exact ledger-aware Oracle on the
    factual view when requested (NaN otherwise)."""

    seq: int
    job_id: str
    tenant: str
    k: int
    knobs: Dict
    dropped_jobs: Tuple[str, ...]
    factual_subset: Tuple[int, ...]
    factual_bw: float
    counter_subset: Tuple[int, ...]
    counter_predicted: float
    counter_bw: float
    counter_winner: str
    delta_bw: float
    oracle_bw: float = float("nan")

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        for key in ("factual_subset", "counter_subset", "dropped_jobs"):
            d[key] = list(d[key])
        return d


def whatif(
    journal_path, seq: int, dispatcher, sim, *,
    drop_tenant: Optional[str] = None,
    drop_jobs: Sequence[str] = (),
    frag_weight: Optional[float] = None,
    contention_mode: Optional[str] = None,
    policy: str = "hybrid",
    oracle: bool = False,
    regret_ledger: Optional["RegretLedger"] = None,
) -> WhatIfReport:
    """Counterfactually re-dispatch the admission at journal ``seq``.

    The ledger is reconstructed at decision time, perturbed
    (``drop_tenant``/``drop_jobs`` evict live co-tenants; the remaining
    knobs override the search recipe), and the dispatcher's search runs
    against the perturbed view.  Both placements are graded with the
    *true* contended simulator against their respective views, so the
    delta isolates the perturbation, not predictor error.  ``oracle=True``
    additionally runs the exact ledger-aware Oracle on the factual view
    (expensive: count-vector enumeration).  When a ``regret_ledger`` is
    given (or a recorder is installed), the counterfactual feeds its
    per-tenant best-counterfactual regret.
    """
    cluster = dispatcher.cluster
    view, ev = reconstruct(journal_path, cluster, seq)
    if ev.op != "admit":
        raise ValueError(
            f"journal seq={seq} is a {ev.op!r} event; what-if needs an admit"
        )
    k = len(ev.gpus)
    factual_bw = float(sim.true_bandwidth(list(ev.gpus), ledger=view))

    cview = view.clone()
    to_drop = set(drop_jobs)
    dropped: List[str] = []
    for a in list(cview.jobs()):
        if a.job_id in to_drop or (
            drop_tenant is not None and a.tenant == drop_tenant
        ):
            cview.release(a.job_id)
            dropped.append(a.job_id)
    subset, predicted, winner = _search_view(
        cview, k, dispatcher, contention_mode=contention_mode,
        frag_weight=frag_weight, policy=policy,
    )
    counter_bw = float(sim.true_bandwidth(subset, ledger=cview))

    oracle_bw = float("nan")
    if oracle:
        from repro.core.baselines import oracle_dispatch

        _, oracle_bw = oracle_dispatch(
            cluster, sim, dispatcher.tables, view.available(), k,
            ledger=view,
        )
        oracle_bw = float(oracle_bw)

    report = WhatIfReport(
        seq=seq, job_id=ev.job_id, tenant=ev.tenant, k=k,
        knobs={
            "drop_tenant": drop_tenant,
            "drop_jobs": list(drop_jobs),
            "frag_weight": frag_weight,
            "contention_mode": contention_mode,
            "policy": policy,
        },
        dropped_jobs=tuple(dropped),
        factual_subset=tuple(ev.gpus), factual_bw=factual_bw,
        counter_subset=tuple(subset), counter_predicted=predicted,
        counter_bw=counter_bw, counter_winner=winner,
        delta_bw=counter_bw - factual_bw, oracle_bw=oracle_bw,
    )
    reg = regret_ledger
    if reg is None and _ACTIVE is not None:
        reg = _ACTIVE.regret
    if reg is not None:
        reg.note(ev.tenant, factual_bw, oracle=oracle_bw,
                 counterfactual=counter_bw)
    return report


# ---------------------------------------------------------------------------
# The per-tenant regret ledger
# ---------------------------------------------------------------------------

class RegretLedger:
    """Per-tenant accounting of realized vs oracle vs best-counterfactual
    bandwidth.  ``regret = reference - realized`` in GB/s: positive means
    the reference placement (the exact Oracle, or the best counterfactual
    tried) would have bought that much more bandwidth.  Raw regret samples
    are kept (bounded per tenant) so :func:`absorb_regret` can export full
    distributions, not just means."""

    def __init__(self, max_samples_per_tenant: int = 1024):
        self._lock = threading.Lock()
        self._tenants: Dict[str, Dict] = {}
        self.max_samples = int(max_samples_per_tenant)

    def _entry(self, tenant: str) -> Dict:
        e = self._tenants.get(tenant)
        if e is None:
            e = self._tenants[tenant] = {
                "n": 0, "realized_sum": 0.0,
                "n_oracle": 0, "oracle_regret_sum": 0.0,
                "n_counterfactual": 0, "counterfactual_regret_sum": 0.0,
                "oracle_samples": deque(maxlen=self.max_samples),
                "counterfactual_samples": deque(maxlen=self.max_samples),
            }
        return e

    def note(self, tenant: str, realized: float,
             oracle: float = float("nan"),
             counterfactual: float = float("nan")) -> None:
        if not _isfinite(realized):
            return
        with self._lock:
            e = self._entry(tenant)
            e["n"] += 1
            e["realized_sum"] += float(realized)
            if _isfinite(oracle):
                r = float(oracle) - float(realized)
                e["n_oracle"] += 1
                e["oracle_regret_sum"] += r
                e["oracle_samples"].append(r)
            if _isfinite(counterfactual):
                r = float(counterfactual) - float(realized)
                e["n_counterfactual"] += 1
                e["counterfactual_regret_sum"] += r
                e["counterfactual_samples"].append(r)

    def tenants(self) -> List[str]:
        with self._lock:
            return sorted(self._tenants)

    def samples(self, tenant: str, kind: str = "oracle") -> List[float]:
        with self._lock:
            e = self._tenants.get(tenant)
            if e is None:
                return []
            return list(e[f"{kind}_samples"])

    def summary(self) -> Dict[str, Dict[str, float]]:
        """tenant -> {n, mean_realized, mean/total oracle + counterfactual
        regret} (NaN where a reference was never observed)."""
        with self._lock:
            items = sorted(self._tenants.items())
        out: Dict[str, Dict[str, float]] = {}
        for tenant, e in items:
            out[tenant] = {
                "n": float(e["n"]),
                "mean_realized": e["realized_sum"] / e["n"],
                "n_oracle": float(e["n_oracle"]),
                "mean_oracle_regret": (
                    e["oracle_regret_sum"] / e["n_oracle"]
                    if e["n_oracle"] else float("nan")
                ),
                "total_oracle_regret": e["oracle_regret_sum"],
                "n_counterfactual": float(e["n_counterfactual"]),
                "mean_counterfactual_regret": (
                    e["counterfactual_regret_sum"] / e["n_counterfactual"]
                    if e["n_counterfactual"] else float("nan")
                ),
            }
        return out


# regret distributions are signed GB/s deltas, nothing like the default
# latency buckets — the configurable-bucket registry path exists for this
REGRET_BUCKETS = (
    -100.0, -50.0, -20.0, -10.0, -5.0, -1.0, 0.0,
    1.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0,
)


def absorb_regret(reg, regret: RegretLedger, **labels) -> None:
    """Project a :class:`RegretLedger` into a
    :class:`~repro.core.telemetry.MetricsRegistry`.  Gauges and counters
    are set-idempotent; the regret *histograms* observe the ledger's
    (bounded) raw samples, so — like ``absorb_trace_summary`` — absorb a
    given ledger into a given registry once."""
    summ = regret.summary()
    names = tuple(sorted(labels)) + ("tenant",)
    count = reg.counter(
        "regret_admissions_total", "admissions graded into the regret ledger",
        names,
    )
    realized = reg.gauge(
        "regret_mean_realized_gbs", "mean realized bandwidth (GB/s)", names
    )
    mean_or = reg.gauge(
        "regret_mean_oracle_gbs",
        "mean oracle regret per admission (GB/s)", names,
    )
    mean_cf = reg.gauge(
        "regret_mean_counterfactual_gbs",
        "mean best-counterfactual regret per admission (GB/s)", names,
    )
    hist = reg.histogram(
        "regret_gbs", "per-admission regret vs reference (GB/s)",
        names + ("reference",), buckets=REGRET_BUCKETS,
    )
    for tenant, row in summ.items():
        count.set(row["n"], tenant=tenant, **labels)
        realized.set(row["mean_realized"], tenant=tenant, **labels)
        if row["n_oracle"]:
            mean_or.set(row["mean_oracle_regret"], tenant=tenant, **labels)
        if row["n_counterfactual"]:
            mean_cf.set(
                row["mean_counterfactual_regret"], tenant=tenant, **labels
            )
        for kind in ("oracle", "counterfactual"):
            for r in regret.samples(tenant, kind):
                hist.observe(r, tenant=tenant, reference=kind, **labels)
