"""Benchmark dispatchers (Appendix D) and the exact Oracle.

* Random — Algorithm 3: uniform k-subset of the available pool.
* Default — Algorithm 4: NUMA/CPU-affinity proximity heuristic.
* Topo — Algorithm 5: Slurm-style compactness over a static weighted
  topology graph.
* Oracle — arg max of the *ground truth* B(S); made exact (and fast) by
  enumerating per-host count vectors and exploiting that, for fixed counts,
  B is maximized by independently maximizing each host's intra-host
  bandwidth (B is monotone in every intra term; the inter term depends only
  on the counts).  Cross-checked against brute force in the tests.
"""

from __future__ import annotations

import itertools
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bandwidth_sim import BandwidthSimulator
from repro.core.cluster import Cluster
from repro.core.intra_host import IntraHostTables

Subset = List[int]


def random_dispatch(
    cluster: Cluster, avail: Sequence[int], k: int, rng: np.random.Generator
) -> Subset:
    """Algorithm 3."""
    sel = rng.choice(len(avail), size=k, replace=False)
    return sorted(avail[i] for i in sel)


def default_dispatch(cluster: Cluster, avail: Sequence[int], k: int) -> Subset:
    """Algorithm 4 — NUMA proximity: fill GPUs with adjacent local indices
    (same socket / CPU affinity), no interconnect awareness."""
    by_host = cluster.partition_by_host(avail)
    singles = {h: g for h, g in by_host.items() if len(g) >= k}
    if singles:
        hid = min(singles)  # "select any host": deterministic lowest id
        gpus = sorted(singles[hid], key=lambda g: cluster.gpu_local[g])
        return sorted(gpus[:k])
    # multi-host: pool the largest hosts, take the first k in local order
    hosts = sorted(by_host.items(), key=lambda kv: (-len(kv[1]), kv[0]))
    out: Subset = []
    for hid, gpus in hosts:
        gpus = sorted(gpus, key=lambda g: cluster.gpu_local[g])
        take = min(k - len(out), len(gpus))
        out.extend(gpus[:take])
        if len(out) == k:
            break
    return sorted(out)


def _topo_score(cluster: Cluster, subset: Sequence[int]) -> float:
    return sum(
        cluster.topo_weight(a, b) for a, b in itertools.combinations(subset, 2)
    )


def topo_dispatch(cluster: Cluster, avail: Sequence[int], k: int) -> Subset:
    """Algorithm 5 — compactness: maximize the sum of static link weights.

    Single-host: exact argmax over k-subsets of that host.  Multi-host: the
    canonical Slurm behaviour — greedily fill the hosts with the most
    available GPUs (maximum locality, e.g. 6+2 over 4+4), choosing within
    each host the subset with the best static score.
    """
    by_host = cluster.partition_by_host(avail)
    singles = {h: g for h, g in by_host.items() if len(g) >= k}
    if singles:
        best_sub, best_score = None, -1.0
        for hid, gpus in singles.items():
            for sub in itertools.combinations(sorted(gpus), k):
                s = _topo_score(cluster, sub)
                if s > best_score:
                    best_score, best_sub = s, list(sub)
        return sorted(best_sub)
    hosts = sorted(by_host.items(), key=lambda kv: (-len(kv[1]), kv[0]))
    out: Subset = []
    for hid, gpus in hosts:
        need = k - len(out)
        if need <= 0:
            break
        if len(gpus) <= need:
            out.extend(gpus)
        else:
            best_sub, best_score = None, -1.0
            for sub in itertools.combinations(sorted(gpus), need):
                s = _topo_score(cluster, sub)
                if s > best_score:
                    best_score, best_sub = s, list(sub)
            out.extend(best_sub)
    return sorted(out)


# ---------------------------------------------------------------------------
# Oracle
# ---------------------------------------------------------------------------

def _count_vectors(caps: Sequence[int], k: int) -> Iterable[Tuple[int, ...]]:
    """All vectors 0 <= n_i <= caps[i] with sum k (depth-first, pruned)."""
    n = len(caps)
    suffix = [0] * (n + 1)
    for i in range(n - 1, -1, -1):
        suffix[i] = suffix[i + 1] + caps[i]
    vec = [0] * n

    def rec(i: int, remaining: int):
        if i == n:
            if remaining == 0:
                yield tuple(vec)
            return
        if remaining > suffix[i]:
            return
        lo = max(0, remaining - suffix[i + 1])
        hi = min(caps[i], remaining)
        for c in range(lo, hi + 1):
            vec[i] = c
            yield from rec(i + 1, remaining - c)
        vec[i] = 0

    yield from rec(0, k)


def oracle_dispatch(
    cluster: Cluster,
    sim: BandwidthSimulator,
    tables: IntraHostTables,
    avail: Sequence[int],
    k: int,
    max_vectors: int = 200_000,
    ledger=None,
) -> Tuple[Subset, float]:
    """Exact arg max_S B(S).  Returns (subset, true_bandwidth).

    With a ``ledger`` of live jobs the argmax is taken over the
    *contention-degraded* B(S | ledger).  The per-host decomposition stays
    exact: rail contention depends only on which hosts S occupies (live
    allocations are disjoint from ``avail``), so for a fixed count vector the
    best subset still maximizes each host's intra-host bandwidth
    independently.
    """
    by_host = cluster.partition_by_host(avail)
    host_ids = sorted(by_host)
    caps = [len(by_host[h]) for h in host_ids]
    if ledger is not None:
        if not ledger.busy().isdisjoint(avail):
            raise ValueError(
                "oracle_dispatch: avail overlaps live allocations in the "
                "ledger; release (or exclude) those jobs first"
            )
        # candidates come from avail, hence GPU-disjoint from every live
        # job: freeze the per-host contender counts once instead of
        # recomputing them for each of up to max_vectors count vectors
        ledger = ledger.snapshot()
    best_bw, best_sub = -1.0, None
    n_vec = 0
    for counts in _count_vectors(caps, k):
        n_vec += 1
        if n_vec > max_vectors:
            raise RuntimeError(
                f"oracle: >{max_vectors} count vectors; cluster too large for "
                "exact search"
            )
        subset: Subset = []
        for hid, n_h in zip(host_ids, counts):
            if n_h == 0:
                continue
            locals_ = [cluster.gpu_local[g] for g in by_host[hid]]
            _, sub = tables.best_subset(hid, n_h, locals_)
            subset.extend(tables.to_globals(hid, sub))
        bw = sim.true_bandwidth(subset, ledger=ledger)
        if bw > best_bw:
            best_bw, best_sub = bw, sorted(subset)
    return best_sub, best_bw


def brute_force_oracle(
    cluster: Cluster,
    sim: BandwidthSimulator,
    avail: Sequence[int],
    k: int,
    ledger=None,
) -> Tuple[Subset, float]:
    """Reference oracle: literally enumerate C(|avail|, k).  Test-only."""
    if ledger is not None and not ledger.busy().isdisjoint(avail):
        raise ValueError(
            "brute_force_oracle: avail overlaps live allocations in the "
            "ledger; release (or exclude) those jobs first"
        )
    best_bw, best_sub = -1.0, None
    for sub in itertools.combinations(sorted(avail), k):
        bw = sim.true_bandwidth(sub, ledger=ledger)
        if bw > best_bw:
            best_bw, best_sub = bw, list(sub)
    return best_sub, best_bw
