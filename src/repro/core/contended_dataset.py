"""Contended training data for the learned-contention subsystem.

The Stage-2 surrogate of the paper learns isolated bandwidth from sparse
nccl-tests measurements; the ROADMAP's contention-aware-surrogate item asks
for the same trick under tenancy: train on **(subset, ledger, contended
bandwidth)** triples so the model absorbs the rail split the analytic
virtual-merge cap only approximates.  Two generators live here:

* **Synthetic sampling** (`build_contended_dataset` / `make_contended_split`):
  sample multi-host candidate allocations exactly like the isolated
  protocol, pair each with a randomly sampled co-tenant ledger
  (`sample_cotenant_ledger` — GPU-disjoint jobs biased toward the
  candidate's own hosts so rails actually contend), and measure
  ``BandwidthSimulator.true_bandwidth(S, ledger)`` (plus nccl-tests noise
  for training targets).

* **Telemetry harvesting** (`TelemetryHarvester` / `harvest_trace`): record
  the contention-degraded bandwidths live admissions actually observe —
  the :class:`~repro.core.scheduler.AdmissionScheduler` feeds every graded
  admission to an attached harvester, and a production
  ``DispatcherService`` forwards job-reported measurements through
  ``report_bandwidth``.  Harvested triples drive
  :func:`repro.core.training.online_finetune_contended` — the paper's
  Sec. 4.1.2 online-adaptation loop, now contended.

A sample stores its co-tenants as a tuple of GPU tuples (``cotenants``), not
a live :class:`~repro.core.tenancy.JobLedger`: samples are picklable,
hashable (dedupable) and independent of ledger mutation.
``materialize_ledger`` / ``to_triples`` rebuild ledgers for featurization.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bandwidth_sim import BandwidthSimulator
from repro.core.cluster import Cluster
from repro.core.tenancy import JobLedger

Cotenants = Tuple[Tuple[int, ...], ...]


@dataclasses.dataclass(frozen=True)
class ContendedSample:
    """One (subset, co-tenant ledger, contended bandwidth) observation."""

    subset: Tuple[int, ...]
    cotenants: Cotenants  # GPU tuples of live jobs disjoint from subset
    bw: float             # contended bandwidth (GB/s; possibly noisy)

    @property
    def key(self) -> Tuple:
        """Dedup/split key: the (subset, ledger) configuration."""
        return (self.subset, tuple(sorted(self.cotenants)))

    @property
    def contended(self) -> bool:
        return bool(self.cotenants)


def materialize_ledger(cluster: Cluster, cotenants: Cotenants) -> JobLedger:
    """Rebuild a live ledger from a sample's co-tenant GPU tuples."""
    ledger = JobLedger(cluster)
    for i, gpus in enumerate(cotenants):
        ledger.admit(f"ct-{i:03d}", gpus)
    return ledger


def to_triples(
    cluster: Cluster, samples: Sequence[ContendedSample]
) -> List[Tuple[List[int], Optional[JobLedger], float]]:
    """-> (subset, ledger-or-None, bw) triples for the training/eval APIs."""
    return [
        (
            list(s.subset),
            materialize_ledger(cluster, s.cotenants) if s.cotenants else None,
            s.bw,
        )
        for s in samples
    ]


# ---------------------------------------------------------------------------
# Synthetic co-tenant sampling
# ---------------------------------------------------------------------------

def sample_cotenant_ledger(
    cluster: Cluster,
    rng: np.random.Generator,
    exclude: Sequence[int] = (),
    max_cotenants: int = 3,
    focus_hosts: Sequence[int] = (),
    cross_bias: float = 0.75,
) -> List[Tuple[int, ...]]:
    """Sample up to ``max_cotenants`` pairwise GPU-disjoint co-tenant jobs,
    all disjoint from ``exclude`` (the candidate).

    ``cross_bias`` of the jobs span two hosts (the rail-contending kind),
    preferring hosts in ``focus_hosts`` so the sampled ledger usually
    contends with the candidate rather than idling on far hosts; the rest
    are single-host (they only move the occupancy channel).
    """
    busy = set(exclude)
    jobs: List[Tuple[int, ...]] = []
    n_jobs = int(rng.integers(0, max_cotenants + 1))
    focus = set(focus_hosts)
    for _ in range(n_jobs):
        by_host: Dict[int, List[int]] = {
            h.host_id: [g for g in h.gpu_ids if g not in busy]
            for h in cluster.hosts
        }
        nonempty = [h for h, gs in by_host.items() if gs]
        if not nonempty:
            break
        if len(nonempty) >= 2 and rng.random() < cross_bias:
            focused = [h for h in nonempty if h in focus]
            h1 = int(rng.choice(focused if focused else nonempty))
            others = [h for h in nonempty if h != h1]
            focused2 = [h for h in others if h in focus]
            h2 = int(rng.choice(focused2 if focused2 else others))
            gpus: List[int] = []
            for h in (h1, h2):
                n_h = int(rng.integers(1, min(4, len(by_host[h])) + 1))
                gpus.extend(
                    int(g) for g in rng.choice(by_host[h], n_h, replace=False)
                )
        else:
            h = int(rng.choice(nonempty))
            n_h = int(rng.integers(1, min(4, len(by_host[h])) + 1))
            gpus = [
                int(g) for g in rng.choice(by_host[h], n_h, replace=False)
            ]
        job = tuple(sorted(gpus))
        jobs.append(job)
        busy.update(job)
    return jobs


def build_contended_dataset(
    sim: BandwidthSimulator,
    n_samples: int,
    rng: np.random.Generator,
    isolated_frac: float = 0.25,
    noisy: bool = True,
    max_cotenants: int = 3,
    k_range: Optional[Tuple[int, int]] = None,
) -> List[ContendedSample]:
    """The curriculum: multi-host candidates, ``isolated_frac`` of them with
    an empty ledger (anchoring the zero-context behaviour), the rest paired
    with a sampled co-tenant ledger and measured against it."""
    cluster = sim.cluster
    subsets = sim.sample_allocations(n_samples, rng, k_range=k_range)
    out: List[ContendedSample] = []
    for s in subsets:
        if rng.random() < isolated_frac:
            cot: Cotenants = ()
        else:
            cot = tuple(sample_cotenant_ledger(
                cluster, rng, exclude=s, max_cotenants=max_cotenants,
                focus_hosts=sorted(cluster.partition_by_host(s)),
            ))
        ledger = materialize_ledger(cluster, cot) if cot else None
        bw = sim.measure(s, rng if noisy else None, ledger=ledger)
        out.append(ContendedSample(tuple(sorted(s)), cot, float(bw)))
    return out


def make_contended_split(
    sim: BandwidthSimulator,
    n_train: int,
    test_mult: int = 2,
    seed: int = 0,
    **kwargs,
) -> Tuple[List[ContendedSample], List[ContendedSample]]:
    """Train/held-out split over (subset, ledger) configurations.

    Mirrors the isolated protocol: noisy training targets, *noiseless* test
    targets, and the held-out set disjoint from training in the full
    (subset, co-tenant ledger) key."""
    rng = np.random.default_rng(seed)
    total = build_contended_dataset(
        sim, n_train * (test_mult + 1), rng, noisy=True, **kwargs
    )
    seen = set()
    unique = []
    for s in total:
        if s.key not in seen:
            seen.add(s.key)
            unique.append(s)
    train = unique[:n_train]
    test = [
        dataclasses.replace(
            s,
            bw=sim.true_bandwidth(
                list(s.subset),
                ledger=materialize_ledger(sim.cluster, s.cotenants)
                if s.cotenants else None,
            ),
        )
        for s in unique[n_train:]
    ]
    return train, test


# ---------------------------------------------------------------------------
# Telemetry harvesting (online adaptation under tenancy)
# ---------------------------------------------------------------------------

class TelemetryHarvester:
    """Collects contended-bandwidth observations from live admissions.

    Attach one to an :class:`~repro.core.scheduler.AdmissionScheduler`
    (``harvester=...``) to capture every graded admission, or to a
    ``DispatcherService`` (``service.harvester = h``) so job-reported
    measurements flow in via ``service.report_bandwidth(job_id, bw)``.
    Keeps at most ``max_samples`` (most recent — telemetry freshness is the
    point of the online loop).

    The harvester is also the drift tap: pass a
    :class:`~repro.core.telemetry.DriftMonitor` as ``drift=`` and every
    observation that carries a ``predicted`` B-hat (the scheduler's grading
    path) or a ``job_id`` with a previously-stamped prediction (the
    ``report_bandwidth`` path) is forwarded to the monitor — one
    observation pipeline, two consumers.
    """

    def __init__(
        self,
        cluster: Cluster,
        max_samples: int = 4096,
        drift: Optional["object"] = None,
    ):
        self.cluster = cluster
        self.max_samples = max_samples
        self.samples: List[ContendedSample] = []
        self.n_observed = 0  # lifetime count (before the ring-buffer trim)
        self.drift = drift   # optional repro.core.telemetry.DriftMonitor

    def __len__(self) -> int:
        return len(self.samples)

    def observe(
        self,
        ledger: JobLedger,
        subset: Sequence[int],
        bw: float,
        *,
        job_id: str = "",
        predicted: Optional[float] = None,
        tenant: str = "",
        t: float = 0.0,
        source: str = "grade",
    ) -> ContendedSample:
        """Record one observation: the co-tenant spec is every live job
        GPU-disjoint from ``subset`` (the job's own ledger entry, when it is
        already admitted, self-excludes by overlap — same predicate as the
        contended ground truth).

        Keyword-only extras feed the attached drift monitor: ``predicted``
        is the B-hat the admission committed on (grading path), or None to
        resolve through the monitor's pending map by ``job_id``
        (``report_bandwidth`` path)."""
        sset = set(subset)
        cot = tuple(
            a.gpus
            for a in sorted(ledger.jobs(), key=lambda a: a.job_id)
            if sset.isdisjoint(a.gpus)
        )
        sample = ContendedSample(tuple(sorted(subset)), cot, float(bw))
        self.samples.append(sample)
        self.n_observed += 1
        if len(self.samples) > self.max_samples:
            del self.samples[: len(self.samples) - self.max_samples]
        if self.drift is not None:
            from repro.core.telemetry import snapshot_digest

            self.drift.observe(
                float(bw), job_id=job_id, subset=tuple(sorted(subset)),
                predicted=predicted,
                digest=snapshot_digest(ledger, subset),
                tenant=tenant, t=t, source=source,
            )
        return sample

    def triples(self) -> List[Tuple[List[int], Optional[JobLedger], float]]:
        """Materialized (subset, ledger, bw) triples for (fine-)tuning."""
        return to_triples(self.cluster, self.samples)

    def clear(self) -> None:
        self.samples.clear()


def harvest_trace(
    cluster: Cluster,
    sim: BandwidthSimulator,
    tables,
    dispatcher,
    trace,
    rng: Optional[np.random.Generator] = None,
    config=None,
    harvester: Optional[TelemetryHarvester] = None,
):
    """Replay a trace with a harvester attached; -> (records, harvester).

    Convenience wrapper over the admission scheduler: the returned harvester
    holds one contended observation per admission, ready for
    :func:`repro.core.training.online_finetune_contended`."""
    from repro.core.scheduler import AdmissionScheduler

    if harvester is None:
        harvester = TelemetryHarvester(cluster)
    sched = AdmissionScheduler(
        cluster, sim, tables, dispatcher, config=config, rng=rng,
        harvester=harvester,
    )
    records = sched.run(trace)
    return records, harvester
