"""Fast hybrid search (Sec. 4.3): EHA + PTS, guided by the surrogate.

Both components consume a *predictor* object exposing
``predict(list_of_subsets) -> np.ndarray`` (the hierarchical surrogate, or
ground truth for the Ideal-BP upper bound) and return a (subset, predicted_bw)
pair.  ``hybrid_search`` runs both and keeps the argmax (Sec. 4.3.1).

Every search entry point accepts an optional ``frag_penalty(subset) ->
relative discount`` tie-break (built by :func:`repro.core.defrag.
make_frag_penalty`): candidate *selection* maximizes ``predicted_bw * (1 -
frag_penalty(S))``, steering otherwise-equal candidates away from breaking
up clean hosts, while the *reported* predicted bandwidth stays the raw
(undiscounted) estimate.  A relative discount is scale-free — the same
weight is a tie-break on a 500 GB/s H100 fabric and a 20 GB/s legacy one.
``frag_penalty=None`` (the default) is bit-identical to the historical
behaviour.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import forensics, telemetry
from repro.core.cluster import Cluster
from repro.core.intra_host import IntraHostTables
from repro.core.tenancy import JobLedger

Subset = List[int]
FragPenalty = Optional[Callable[[Sequence[int]], float]]


def _penalized(preds: np.ndarray, candidates, frag_penalty: FragPenalty):
    """Selection scores: predictions discounted by the relative tie-break."""
    if frag_penalty is None:
        return preds
    return preds * (1.0 - np.asarray([frag_penalty(c) for c in candidates]))


@dataclasses.dataclass
class SearchResult:
    subset: Subset
    predicted_bw: float
    seconds: float
    n_candidates: int


def _available_by_host(
    cluster: Cluster, avail: Sequence[int]
) -> Dict[int, List[int]]:
    return cluster.partition_by_host(avail)


# ---------------------------------------------------------------------------
# Single-host prioritization (shared by EHA and PTS pruning)
# ---------------------------------------------------------------------------

def best_single_host(
    cluster: Cluster,
    tables: IntraHostTables,
    avail_by_host: Dict[int, List[int]],
    k: int,
    frag_penalty: FragPenalty = None,
) -> Optional[Tuple[float, int, Subset]]:
    """Best k-GPU allocation on any single host with >=k available GPUs,
    using exact Stage-1 lookups.  Returns (bw, host_id, global_subset) with
    the raw bw; with ``frag_penalty`` the *choice* among hosts maximizes
    the penalized score (prefer topping up a dirty host over cracking open
    a clean one)."""
    best = None
    best_score = None
    for hid, gpus in avail_by_host.items():
        if len(gpus) < k:
            continue
        locals_ = [cluster.gpu_local[g] for g in gpus]
        bw, sub = tables.best_subset(hid, k, locals_)
        subset = tables.to_globals(hid, sub)
        score = bw * (1.0 - frag_penalty(subset)) if frag_penalty else bw
        if best_score is None or score > best_score:
            best = (bw, hid, subset)
            best_score = score
    return best


# ---------------------------------------------------------------------------
# EHA — Equilibrium-driven Heuristic Algorithm (Algorithm 1)
# ---------------------------------------------------------------------------

def _distinct_permutations(items: Sequence[int]):
    """Lazily yield the distinct permutations of a multiset in ascending
    lexicographic order (Narayana next-permutation with duplicate skipping).

    Replaces ``sorted(set(itertools.permutations(items)))``, which eagerly
    materializes all m! permutations before deduplication — an O(m!)
    landmine for m beyond ~10 hosts (k=64 over 2-GPU hosts makes m=32, which
    would never return) even though the caller only ever consumes the first
    few distinct entries.
    """
    arr = sorted(items)
    m = len(arr)
    while True:
        yield tuple(arr)
        i = m - 2
        while i >= 0 and arr[i] >= arr[i + 1]:
            i -= 1
        if i < 0:
            return
        j = m - 1
        while arr[j] <= arr[i]:
            j -= 1
        arr[i], arr[j] = arr[j], arr[i]
        arr[i + 1:] = arr[:i:-1]


def balanced_count_assignments(
    capacities: Sequence[int], k: int, max_assignments: int = 16
) -> List[Tuple[int, ...]]:
    """Distinct near-even distributions of k over hosts with capacities.

    E.g. k=8 over 3 hosts -> permutations of (3,3,2) that respect capacity.
    Capacity overflow is re-waterfilled onto the remaining hosts.  The
    permutation stream is lazy (:func:`_distinct_permutations`), so the
    ``max_assignments`` cap bounds the work even for many hosts.
    """
    m = len(capacities)
    base, rem = divmod(k, m)
    shape = [base + 1] * rem + [base] * (m - rem)
    out: List[Tuple[int, ...]] = []
    seen = set()
    for perm in _distinct_permutations(shape):
        counts = list(perm)
        # re-waterfill overflow (a host's share may exceed its availability)
        overflow = 0
        for i in range(m):
            if counts[i] > capacities[i]:
                overflow += counts[i] - capacities[i]
                counts[i] = capacities[i]
        while overflow > 0:
            # give to the host with the most remaining headroom
            heads = [(capacities[i] - counts[i], i) for i in range(m)]
            heads.sort(reverse=True)
            if heads[0][0] <= 0:
                break  # infeasible
            counts[heads[0][1]] += 1
            overflow -= 1
        if overflow > 0:
            continue
        # zero counts are fine (k < m): the host simply goes unused
        t = tuple(counts)
        if t not in seen:
            seen.add(t)
            out.append(t)
        if len(out) >= max_assignments:
            break
    return out


def eha_search(
    cluster: Cluster,
    tables: IntraHostTables,
    predictor,
    avail: Sequence[int],
    k: int,
    max_host_combos: int = 64,
    frag_penalty: FragPenalty = None,
) -> SearchResult:
    """Algorithm 1.  Fast constructive search around the equilibrium insight."""
    with telemetry.span("search.eha", k=k, n_avail=len(avail)) as sp:
        res = _eha_search(
            cluster, tables, predictor, avail, k, max_host_combos,
            frag_penalty,
        )
        if sp:
            sp["n_candidates"] = res.n_candidates
            sp["predicted_bw"] = res.predicted_bw
            sp["single_host_shortcut"] = res.n_candidates == 1
        return res


def _eha_search(
    cluster: Cluster,
    tables: IntraHostTables,
    predictor,
    avail: Sequence[int],
    k: int,
    max_host_combos: int = 64,
    frag_penalty: FragPenalty = None,
) -> SearchResult:
    t0 = time.time()
    by_host = _available_by_host(cluster, avail)
    n_cands = 0

    # Phase 1: single-host prioritization (exact via Stage-1 tables).
    # With a frag_penalty the shortcut is NOT taken blindly: consolidation
    # deliberately opens clean single-host blocks, and on heterogeneous
    # clusters a freed point-to-point host's full-host ring can be far
    # slower than a balanced cross-host placement — so the single-host
    # winner is scored against the phase-2 candidates below instead.
    single = best_single_host(cluster, tables, by_host, k, frag_penalty)
    if single is not None and frag_penalty is None:
        bw, _, subset = single
        return SearchResult(subset, bw, time.time() - t0, 1)

    # Phase 2: balanced multi-host construction over the minimum host count
    # (plus one more host when the single-host shortcut is being
    # re-examined, so genuine multi-host alternatives exist to compare).
    hosts = sorted(by_host.items(), key=lambda kv: -len(kv[1]))
    sizes = [len(g) for _, g in hosts]
    m = 0
    total = 0
    for s in sizes:
        m += 1
        total += s
        if total >= k:
            break
    if total < k:
        raise ValueError(f"request k={k} exceeds available pool {sum(sizes)}")

    # Host combinations of size m with enough capacity (largest-first bias).
    candidates: List[Subset] = []
    host_ids = [hid for hid, _ in hosts]
    m_sizes = [m]
    if single is not None and m + 1 <= len(host_ids):
        m_sizes.append(m + 1)
    for m_cur in m_sizes:
        combos = 0
        for combo in itertools.combinations(range(len(host_ids)), m_cur):
            caps = [sizes[i] for i in combo]
            if sum(caps) < k:
                continue
            combos += 1
            if combos > max_host_combos:
                break
            chosen_hids = [host_ids[i] for i in combo]
            for counts in balanced_count_assignments(caps, k):
                subset: Subset = []
                for hid, n_h in zip(chosen_hids, counts):
                    if n_h == 0:
                        continue
                    locals_ = [cluster.gpu_local[g] for g in by_host[hid]]
                    _, sub = tables.best_subset(hid, n_h, locals_)
                    subset.extend(tables.to_globals(hid, sub))
                candidates.append(sorted(subset))
    if single is not None:
        candidates.append(sorted(single[2]))

    if not candidates:  # degenerate fallback: greedy fill
        pool = [g for _, gs in hosts for g in gs]
        candidates = [sorted(pool[:k])]
    preds = predictor.predict(candidates)
    n_cands = len(candidates)
    best_idx = int(np.argmax(_penalized(preds, candidates, frag_penalty)))
    return SearchResult(
        candidates[best_idx], float(preds[best_idx]), time.time() - t0, n_cands
    )


# ---------------------------------------------------------------------------
# PTS — Pruned Tree Search (Algorithm 2)
# ---------------------------------------------------------------------------

def pts_search(
    cluster: Cluster,
    tables: IntraHostTables,
    predictor,
    avail: Sequence[int],
    k: int,
    frag_penalty: FragPenalty = None,
) -> SearchResult:
    """Algorithm 2.  Top-down iterative elimination of the bottleneck GPU."""
    with telemetry.span("search.pts", k=k, n_avail=len(avail)) as sp:
        res = _pts_search(cluster, tables, predictor, avail, k, frag_penalty)
        if sp:
            sp["n_candidates"] = res.n_candidates
            sp["predicted_bw"] = res.predicted_bw
        return res


def _pts_search(
    cluster: Cluster,
    tables: IntraHostTables,
    predictor,
    avail: Sequence[int],
    k: int,
    frag_penalty: FragPenalty = None,
) -> SearchResult:
    t0 = time.time()
    by_host = _available_by_host(cluster, avail)
    s_curr: Subset = sorted(avail)
    n_cands = 0
    df = forensics.draft()  # one global read when capture is off

    # Search pruning: node-insertion heuristic for small requests.  With a
    # frag_penalty the *host choice* is penalty-aware, but the prune itself
    # stays (full-pool elimination would cost O(|avail|^2) predictor calls
    # per dispatch); the single-vs-multi-host comparison that frag mode
    # needs happens in EHA's phase 2, which hybrid_search always runs.
    if k <= 8:
        single = best_single_host(cluster, tables, by_host, k, frag_penalty)
        if single is not None:
            _, hid, _ = single
            pruned = len(s_curr)
            s_curr = sorted(by_host[hid])
            if df is not None:
                df.note_pts_prune(hid, pruned - len(s_curr))

    # Fused on-device descent: the whole elimination |S| -> k as ONE device
    # call (``SurrogatePredictor.eliminate_to``; the contention wrapper
    # threads the analytic cap through as a lattice table).  The frag
    # penalty is host-side per-round arithmetic, so penalized searches stay
    # on the host loop; any configuration the scan declines (learned
    # contention, oversized parents, non-surrogate predictors, ...) falls
    # through to the loop below unchanged.
    if (
        frag_penalty is None
        and len(s_curr) > k
        and hasattr(predictor, "eliminate_to")
    ):
        res = predictor.eliminate_to(s_curr, k)
        if res is not None:
            n0 = len(s_curr)
            s_curr = list(res.subset)
            # the descent scored every remove-one child of every round
            n_cands += (n0 * (n0 + 1) - k * (k + 1)) // 2
            telemetry.event("search.pts.fused_scan", steps=n0 - len(s_curr))
            if df is not None:
                df.note_pts_fused(n0 - len(s_curr))

    # Iterative elimination |S| -> k, one GPU at a time.  Each round is ONE
    # fused featurize+predict call when the predictor has an incremental
    # child path (predict_children: the child batch is the parent's token
    # matrix with a patched row per child, deduplicated against the
    # prediction cache); the plain batched predict is the fallback.
    fused = hasattr(predictor, "predict_children")
    rounds = 0
    while len(s_curr) > k:
        children = [s_curr[:i] + s_curr[i + 1:] for i in range(len(s_curr))]
        if fused:
            preds = predictor.predict_children(s_curr)
        else:
            preds = predictor.predict(children)
        n_cands += len(children)
        rounds += 1
        best_i = int(np.argmax(_penalized(preds, children, frag_penalty)))
        if df is not None:  # child i omits s_curr[i]: that GPU bottlenecked
            df.note_pts_round(
                s_curr[best_i], float(preds[best_i]), len(children)
            )
        s_curr = children[best_i]
    if rounds:
        telemetry.event(
            "search.pts.host_rounds", rounds=rounds, fused_children=fused
        )

    final_bw = float(predictor.predict([s_curr])[0])
    return SearchResult(s_curr, final_bw, time.time() - t0, n_cands + 1)


# ---------------------------------------------------------------------------
# Hybrid (Sec. 4.3.1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HybridResult:
    subset: Subset
    predicted_bw: float
    eha: SearchResult
    pts: SearchResult
    winner: str

    @property
    def total_seconds(self) -> float:
        return self.eha.seconds + self.pts.seconds


def hybrid_search(
    cluster: Cluster,
    tables: IntraHostTables,
    predictor,
    avail: Sequence[int],
    k: int,
    frag_penalty: FragPenalty = None,
) -> HybridResult:
    df = forensics.draft()
    if df is not None:
        # resets per-search provenance: a make-room defrag pass (or a
        # control-plane conflict re-search) runs extra hybrid searches
        # inside one admission, and the committed subset comes from the
        # LAST one — which is the provenance the dossier should describe.
        df.note_search_begin(k, len(avail), frag_penalty is not None)
    eha = eha_search(cluster, tables, predictor, avail, k,
                     frag_penalty=frag_penalty)
    pts = pts_search(cluster, tables, predictor, avail, k,
                     frag_penalty=frag_penalty)
    eha_score, pts_score = eha.predicted_bw, pts.predicted_bw
    if frag_penalty is not None:
        eha_score *= 1.0 - frag_penalty(eha.subset)
        pts_score *= 1.0 - frag_penalty(pts.subset)
    winner = "EHA" if eha_score >= pts_score else "PTS"
    if df is not None:
        df.note_hybrid(eha, pts, eha_score, pts_score, winner)
    if winner == "EHA":
        return HybridResult(eha.subset, eha.predicted_bw, eha, pts, "EHA")
    return HybridResult(pts.subset, pts.predicted_bw, eha, pts, "PTS")


# ---------------------------------------------------------------------------
# Joint batched placement (admission scheduler, `batched` policy)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class JointPlacement:
    """One job's slot in a joint batch plan, in placement order."""

    job_id: str
    k: int
    subset: Subset
    predicted_bw: float  # contention-degraded, against ledger + ALL mates


@dataclasses.dataclass
class JointResult:
    placements: List[JointPlacement]  # in placement (commit) order
    order: str                        # winning candidate order
    total_predicted_bw: float         # sum of final per-job degraded estimates
    seconds: float


JOINT_ORDERS = ("largest-first", "arrival")


def _ordered_requests(
    requests: Sequence[Tuple[str, int]], order: str
) -> List[Tuple[str, int]]:
    if order == "arrival":
        return list(requests)
    if order == "largest-first":
        return sorted(requests, key=lambda r: -r[1])  # stable: arrival ties
    raise ValueError(f"unknown joint order {order!r}")


def joint_hybrid_search(
    cluster: Cluster,
    tables: IntraHostTables,
    predictor,
    ledger: JobLedger,
    requests: Sequence[Tuple[str, int]],
    orders: Sequence[str] = JOINT_ORDERS,
    contention_aware: bool = True,
    contention_mode: str = "analytic",
    contended=None,
    frag_weight: float = 0.0,
    use_cache: bool = True,
    vectorized: bool = True,
    stats_sink=None,
    batcher=None,
) -> JointResult:
    """Place a batch of ``(job_id, k)`` requests *jointly* against a ledger.

    For each candidate placement order, the live ledger is copied into a
    scratch ledger and each job runs the ordinary :func:`hybrid_search`
    against it — admitting every placement into the scratch as it is chosen,
    so later jobs see their earlier batch-mates as live co-tenants (and,
    with ``contention_aware``, the predictor degrades candidates next to
    them via the virtual-merge fair-share cap).  The plan is scored by the
    sum of each job's contention-degraded estimate against the *final*
    scratch ledger (a job placed early can be degraded by a mate placed
    later; scoring at the end charges for that), and the best order wins.

    The returned placements are valid to commit sequentially against the
    real ledger: they are pairwise GPU-disjoint and drawn from its current
    availability.  ``contention_aware=False`` keeps batch-mates as
    availability constraints only (the contention-oblivious ablation).
    ``contention_mode``/``contended`` select the analytic fair-share cap or
    the learned ContendedSurrogate for the degradation estimates, exactly as
    in :class:`~repro.core.contention.ContentionAwarePredictor`.
    ``frag_weight > 0`` applies the fragmentation tie-break
    (:func:`repro.core.defrag.make_frag_penalty`) against the *scratch*
    ledger, so later batch-mates are steered away from cracking open hosts
    their earlier mates left clean.

    ``use_cache`` (the default) wraps each order's contention-aware
    predictor in a scratch-ledger-versioned prediction cache
    (:mod:`repro.core.predict_cache`), so the final whole-plan re-scoring
    and the overlap between per-job EHA/PTS candidate sets are free; pass a
    cached *base* ``predictor`` (the dispatcher's ledger-independent
    isolated memo) to additionally share the expensive isolated inference
    across candidate orders.

    ``batcher`` (an :class:`~repro.core.predict_cache.InferenceBatcher`)
    runs the candidate orders on concurrent worker threads whose surrogate
    applies are padded and fused into shared device calls.  Each order's
    search is a pure function of the (immutable) real ledger, so the orders
    are independent; the winner is still reduced in the original ``orders``
    sequence with the same strict ``>`` comparison, and fusion itself is
    value-neutral (pad/row-independence is regression-pinned), so the
    chosen plan is byte-identical to the sequential path.
    """
    from repro.core.defrag import make_frag_penalty
    from repro.core.predict_cache import (
        PredictorStats,
        cached_contention_predictor,
    )

    if not requests:
        raise ValueError("joint_hybrid_search needs >=1 request")
    if not orders:
        raise ValueError("joint_hybrid_search needs >=1 candidate order")
    ids = [r[0] for r in requests]
    if len(set(ids)) != len(ids):
        raise ValueError(f"duplicate job ids in batch: {ids}")
    t0 = time.time()
    if len(requests) == 1:
        orders = orders[:1]
    uniq: List[str] = []
    tried = set()
    for order in orders:
        key = tuple(r[0] for r in _ordered_requests(requests, order))
        if key in tried:
            continue  # two orders coincide (e.g. batch already size-sorted)
        tried.add(key)
        uniq.append(order)

    def _run_order(order: str, sink) -> JointResult:
        seq = _ordered_requests(requests, order)
        scratch = JobLedger(cluster)
        for a in ledger.jobs():
            scratch.admit(a.job_id, a.gpus)
        pred = (
            cached_contention_predictor(
                cluster, predictor, scratch,
                mode=contention_mode, contended=contended,
                use_cache=use_cache, vectorized=vectorized,
                stats_sink=sink,
            )
            if contention_aware else predictor
        )
        # the penalty reads the scratch live, so it stays current as each
        # batch-mate admits below
        penalty = (
            make_frag_penalty(cluster, scratch, frag_weight)
            if frag_weight > 0 else None
        )
        placements: List[JointPlacement] = []
        for job_id, k in seq:
            avail = scratch.available()
            if k > len(avail):
                raise ValueError(
                    f"joint batch does not fit: {job_id!r} needs k={k}, "
                    f"{len(avail)} GPUs free"
                )
            res = hybrid_search(cluster, tables, pred, avail, k,
                                frag_penalty=penalty)
            scratch.admit(job_id, res.subset)
            placements.append(
                JointPlacement(job_id, k, res.subset, res.predicted_bw)
            )
        # Final scoring: every subset re-estimated against the complete
        # scratch (its own entry self-excludes via the contends predicate).
        finals = np.asarray(
            pred.predict([p.subset for p in placements]), dtype=np.float64
        )
        for p, bw in zip(placements, finals):
            p.predicted_bw = float(bw)
        return JointResult(placements, order, float(finals.sum()), 0.0)

    def _traced_order(order: str, sink) -> JointResult:
        # one span per candidate order — on the batcher path these run on
        # worker threads, so each is a root span on its own thread
        with telemetry.span(
            "search.joint_order", order=order, n_jobs=len(requests),
        ) as sp:
            res = _run_order(order, sink)
            if sp:
                sp["total_predicted_bw"] = res.total_predicted_bw
            return res

    if batcher is not None and len(uniq) > 1:
        # one worker thread per order; per-thread stats sinks (merged after
        # the join) keep the shared counters race-free
        sinks = [PredictorStats() for _ in uniq]
        results: List[Optional[JointResult]] = [None] * len(uniq)
        errs: List[Optional[BaseException]] = [None] * len(uniq)

        def _worker(i: int, order: str) -> None:
            try:
                with batcher.worker():
                    results[i] = _traced_order(order, sinks[i])
            except BaseException as e:
                errs[i] = e

        threads = [
            threading.Thread(
                target=_worker, args=(i, o), name=f"joint-order-{o}"
            )
            for i, o in enumerate(uniq)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        for e in errs:
            if e is not None:
                raise e
        if stats_sink is not None:
            merged = PredictorStats.merged(stats_sink, *sinks)
            for f in dataclasses.fields(PredictorStats):
                setattr(stats_sink, f.name, getattr(merged, f.name))
        candidates = results
    else:
        candidates = [_traced_order(o, stats_sink) for o in uniq]

    best: Optional[JointResult] = None
    for cand in candidates:
        if best is None or cand.total_predicted_bw > best.total_predicted_bw:
            best = cand
    assert best is not None
    best.seconds = time.time() - t0
    return best
