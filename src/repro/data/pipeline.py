"""Deterministic synthetic LM data pipeline.

Every batch is a pure function of (seed, step, host_shard), so restarts and
elastic rescaling reproduce the exact token stream with no data server:
after a failure the restored job re-derives batch ``step`` bit-identically,
and a host only materializes its own shard (host-local loading).

The "corpus" is a mixture of Zipf-distributed unigrams with short repeated
motifs — enough structure that a ~100M model visibly learns (loss drops
well below ln V) while remaining fully self-contained.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_len: int = 16
    n_motifs: int = 512


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed motif bank: repeated n-grams give the model learnable signal
        self.motifs = rng.integers(
            0, cfg.vocab_size, (cfg.n_motifs, cfg.motif_len), dtype=np.int32
        )
        # Zipf-ish unigram distribution
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.unigram = p / p.sum()

    def _sample_row(self, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        out = np.empty(cfg.seq_len + 1, np.int32)
        i = 0
        while i < cfg.seq_len + 1:
            if rng.random() < 0.7:  # motif
                m = self.motifs[rng.integers(cfg.n_motifs)]
                take = min(len(m), cfg.seq_len + 1 - i)
                out[i : i + take] = m[:take]
                i += take
            else:  # unigram noise
                take = min(int(rng.integers(4, 17)), cfg.seq_len + 1 - i)
                out[i : i + take] = rng.choice(
                    cfg.vocab_size, size=take, p=self.unigram
                )
                i += take
        return out

    def batch(
        self, step: int, host_id: int = 0, n_hosts: int = 1
    ) -> Dict[str, np.ndarray]:
        """Deterministic batch for ``step``; host-local shard if requested."""
        cfg = self.cfg
        assert cfg.global_batch % n_hosts == 0
        local = cfg.global_batch // n_hosts
        rows = np.empty((local, cfg.seq_len + 1), np.int32)
        for r in range(local):
            row_id = step * cfg.global_batch + host_id * local + r
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, row_id])
            )
            rows[r] = self._sample_row(rng)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    def batches(
        self, n_steps: int, start: int = 0, host_id: int = 0, n_hosts: int = 1
    ) -> Iterator[Dict[str, np.ndarray]]:
        for step in range(start, start + n_steps):
            yield self.batch(step, host_id, n_hosts)
