"""Batched serving engine: prefill + greedy/temperature decode.

Minimal-but-real continuous-batching-lite: requests are grouped into fixed
batch slots, prompts are left-padded to a common prefill length, and decode
proceeds lock-step with per-slot stop tracking.  Serves any zoo model
(decoder-only or enc-dec).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import Model


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    max_new_tokens: int = 64
    cache_dtype: jnp.dtype = jnp.float32
    temperature: float = 0.0  # 0 = greedy
    eos_id: Optional[int] = None


class ServeEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(
            lambda p, cache, toks: model.decode_step(p, cache, toks)
        )

    def generate(
        self, prompts: Sequence[Sequence[int]], rng_seed: int = 0
    ) -> List[List[int]]:
        """prompts: batch of token-id lists -> generated continuations."""
        cfg = self.cfg
        B = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = np.zeros((B, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p  # left-pad
        cache = self.model.init_cache(B, cfg.max_len, cfg.cache_dtype)
        logits, cache = self.model.prefill(
            self.params, {"tokens": jnp.asarray(toks)}, cache
        )
        rng = np.random.default_rng(rng_seed)
        out: List[List[int]] = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        cur = self._sample(logits, rng)
        for _ in range(cfg.max_new_tokens):
            for i in range(B):
                if not done[i]:
                    t = int(cur[i, 0])
                    out[i].append(t)
                    if cfg.eos_id is not None and t == cfg.eos_id:
                        done[i] = True
            if done.all():
                break
            logits, cache = self._decode(self.params, cache, jnp.asarray(cur))
            cur = self._sample(logits, rng)
        return out

    def _sample(self, logits, rng) -> np.ndarray:
        lg = np.asarray(logits[:, -1, :], np.float32)
        if self.cfg.temperature <= 0:
            return lg.argmax(-1)[:, None].astype(np.int32)
        p = jax.nn.softmax(jnp.asarray(lg / self.cfg.temperature), -1)
        p = np.asarray(p)
        choice = [rng.choice(p.shape[-1], p=row / row.sum()) for row in p]
        return np.asarray(choice, np.int32)[:, None]
