"""Pure-jnp oracle for the RG-LRU diagonal linear recurrence (Griffin).

The RG-LRU layer (arXiv:2402.19427) reduces to the diagonal recurrence

    h_t = a_t * h_{t-1} + b_t

with per-channel, data-dependent decay a_t in (0, 1] and gated input b_t.
The gates are computed in the model layer (repro/models/rglru.py); the
kernel/oracle implement only the scan, which is the sequential hot spot.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def linear_scan_reference(
    a: jnp.ndarray,  # [B, T, C] decay in (0, 1]
    b: jnp.ndarray,  # [B, T, C] input term
    h0: Optional[jnp.ndarray] = None,  # [B, C] initial state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (h [B, T, C], h_final [B, C]) via lax.scan (time-major)."""
    B, T, C = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, C), jnp.float32)
    h0 = h0.astype(jnp.float32)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t.astype(jnp.float32) * h + b_t.astype(jnp.float32)
        return h, h

    at = a.transpose(1, 0, 2)
    bt = b.transpose(1, 0, 2)
    h_final, hs = jax.lax.scan(step, h0, (at, bt))
    return hs.transpose(1, 0, 2).astype(a.dtype), h_final


def linear_scan_associative(
    a: jnp.ndarray, b: jnp.ndarray, h0: Optional[jnp.ndarray] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """O(log T) alternative via associative_scan (cross-check in tests)."""
    B, T, C = a.shape
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    if h0 is not None:
        b32 = b32.at[:, 0].add(a32[:, 0] * h0)

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    _, hs = jax.lax.associative_scan(combine, (a32, b32), axis=1)
    return hs.astype(a.dtype), hs[:, -1]
