"""Pallas TPU kernel for the RG-LRU diagonal linear recurrence.

TPU adaptation: the recurrence is inherently sequential in T but dense in
the channel dimension, so we tile channels across the grid (parallel) and
stream time blocks through VMEM with the carry ``h`` held in scratch across
sequential grid steps (T is the innermost grid axis).  Within a block the
time loop runs on the VPU over [block_c]-wide vectors — this matches how
production Griffin kernels behave: the op is HBM-bandwidth-bound, and the
pipeline keeps the next (a, b) tiles prefetching while the current block
scans.

Grid: (B, C // block_c, T // block_t), carry resets at t_block == 0.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 128
DEFAULT_BLOCK_C = 256


def largest_divisor_block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target`` (block-size helper)."""
    b = min(n, target)
    while n % b:
        b -= 1
    return b


def _rglru_kernel(
    a_ref,      # [1, block_t, block_c]
    b_ref,      # [1, block_t, block_c]
    h0_ref,     # [1, block_c]
    h_out_ref,  # [1, block_t, block_c]
    hn_ref,     # [1, block_c] final state output
    carry_ref,  # scratch [1, block_c] fp32
    *,
    block_t: int,
    n_t_blocks: int,
):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        carry_ref[...] = h0_ref[...].astype(jnp.float32)

    def body(t, h):
        a_t = a_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)
        h = a_t * h + b_t
        h_out_ref[0, t, :] = h.astype(h_out_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, body, carry_ref[0, :])
    carry_ref[0, :] = h

    @pl.when(ti == n_t_blocks - 1)
    def _final():
        hn_ref[...] = carry_ref[...].astype(hn_ref.dtype)


def rglru_scan(
    a: jnp.ndarray,  # [B, T, C]
    b: jnp.ndarray,  # [B, T, C]
    h0: Optional[jnp.ndarray] = None,  # [B, C]
    *,
    block_t: int = DEFAULT_BLOCK_T,
    block_c: int = DEFAULT_BLOCK_C,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pallas diagonal linear scan.  Returns (h [B,T,C], h_final [B,C])."""
    B, T, C = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, C), a.dtype)
    block_t = largest_divisor_block(T, block_t)
    block_c = largest_divisor_block(C, block_c)
    grid = (B, C // block_c, T // block_t)

    kernel = functools.partial(
        _rglru_kernel, block_t=block_t, n_t_blocks=T // block_t
    )
    h, hn = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_c), lambda bi, ci, ti: (bi, ti, ci)),
            pl.BlockSpec((1, block_t, block_c), lambda bi, ci, ti: (bi, ti, ci)),
            pl.BlockSpec((1, block_c), lambda bi, ci, ti: (bi, ci)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_c), lambda bi, ci, ti: (bi, ti, ci)),
            pl.BlockSpec((1, block_c), lambda bi, ci, ti: (bi, ci)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, C), a.dtype),
            jax.ShapeDtypeStruct((B, C), a.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_c), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return h, hn
