"""Public RG-LRU scan op with backend dispatch and custom VJP.

The VJP of the diagonal recurrence is itself a (reversed) diagonal
recurrence:  with  h_t = a_t h_{t-1} + b_t  and upstream dh_t:

    g_t   = dh_t + a_{t+1} g_{t+1}          (reverse scan)
    db_t  = g_t
    da_t  = g_t * h_{t-1}
    dh0   = a_1 g_1

so the backward pass reuses the same kernel with time-reversed inputs.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.rglru import ref
from repro.kernels.rglru.rglru import rglru_scan


def _default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def _scan_impl(a, b, h0, backend: str):
    if backend == "reference":
        return ref.linear_scan_reference(a, b, h0)
    return rglru_scan(a, b, h0, interpret=(backend == "interpret"))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _scan(a, b, h0, backend):
    return _scan_impl(a, b, h0, backend)


def _scan_fwd(a, b, h0, backend):
    h, hn = _scan_impl(a, b, h0, backend)
    return (h, hn), (a, h, h0)


def _scan_bwd(backend, res, grads):
    a, h, h0 = res
    dh, dhn = grads
    dh = dh.astype(jnp.float32)
    dh = dh.at[:, -1].add(dhn.astype(jnp.float32))
    # reverse scan: g_t = dh_t + a_{t+1} g_{t+1}
    a_rev = jnp.flip(a, axis=1)
    a_shift = jnp.concatenate(
        [jnp.ones_like(a_rev[:, :1]), a_rev[:, :-1]], axis=1
    )  # time-reversed a_{t+1}
    g_rev, _ = _scan_impl(a_shift, jnp.flip(dh, axis=1), None, backend)
    g = jnp.flip(g_rev, axis=1).astype(jnp.float32)
    h_prev = jnp.concatenate([h0[:, None], h[:, :-1]], axis=1).astype(jnp.float32)
    da = (g * h_prev).astype(a.dtype)
    db = g.astype(a.dtype)
    dh0 = (g[:, 0] * a[:, 0].astype(jnp.float32)).astype(h0.dtype)
    return da, db, dh0


_scan.defvjp(_scan_fwd, _scan_bwd)


def linear_scan(
    a: jnp.ndarray,
    b: jnp.ndarray,
    h0: Optional[jnp.ndarray] = None,
    *,
    backend: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Differentiable diagonal linear recurrence h_t = a_t h_{t-1} + b_t.

    Returns (h [B,T,C], h_final [B,C])."""
    if backend == "auto":
        backend = _default_backend()
    if h0 is None:
        h0 = jnp.zeros((a.shape[0], a.shape[2]), a.dtype)
    return _scan(a, b, h0, backend)
