"""Pure-jnp oracle for the RWKV-6 (Finch) WKV recurrence.

Per head with state S in R^{K x V} (arXiv:2404.05892):

    y_t = (S_{t-1} + (u ⊙ k_t) v_t^T)^T r_t
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

where r_t, k_t, w_t in R^K, v_t in R^V, u in R^K is the per-head bonus, and
w_t in (0, 1) is the data-dependent decay.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


CHUNK_T = 128


def wkv6_reference(
    r: jnp.ndarray,  # [B, T, H, K]
    k: jnp.ndarray,  # [B, T, H, K]
    v: jnp.ndarray,  # [B, T, H, V]
    w: jnp.ndarray,  # [B, T, H, K] decay in (0, 1)
    u: jnp.ndarray,  # [H, K] bonus
    s0: Optional[jnp.ndarray] = None,  # [B, H, K, V]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [B,T,H,V], s_final [B,H,K,V]).

    Time-chunked with rematerialization: autodiff through a plain
    T-step scan saves the [B,H,K,V] state at *every* timestep (a 215 GB/chip
    memory wall for train_4k in the dry-run); checkpointing each CHUNK_T-step
    chunk keeps only T/CHUNK_T boundary states and recomputes inside the
    chunk on the backward pass — the standard linear-attention trick, and
    bit-identical forward math (verified by the state-chaining test).
    """
    B, T, H, K = r.shape
    V = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((B, H, K, V), jnp.float32)

    def step(S, rkvw):
        r_t, k_t, v_t, w_t = rkvw  # [B,H,K], [B,H,K], [B,H,V], [B,H,K]
        kv = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32),
                        v_t.astype(jnp.float32))
        y = jnp.einsum(
            "bhk,bhkv->bhv", r_t.astype(jnp.float32),
            S + u[None, :, :, None] * kv
        )
        S = w_t.astype(jnp.float32)[..., None] * S + kv
        return S, y

    def chunk_scan(S, chunk):
        # chunk: tuple of [C, B, H, *] time-major slices
        return jax.lax.scan(step, S, chunk)

    ct = CHUNK_T
    while T % ct:
        ct -= 1
    n_chunks = T // ct

    def to_chunks(x):
        # [B, T, H, D] -> [n_chunks, C, B, H, D] (time-major within chunk)
        return x.transpose(1, 0, 2, 3).reshape(n_chunks, ct, B, H, x.shape[-1])

    xs = (to_chunks(r), to_chunks(k), to_chunks(v), to_chunks(w))
    body = jax.checkpoint(chunk_scan, prevent_cse=False)
    s_final, ys = jax.lax.scan(body, s0.astype(jnp.float32), xs)
    ys = ys.reshape(T, B, H, V).transpose(1, 0, 2, 3)
    return ys.astype(r.dtype), s_final
