"""Pallas TPU kernel for the RWKV-6 WKV recurrence.

TPU adaptation: the per-head state S [K, V] (64x64 fp32 = 16 KB) lives in
VMEM scratch across sequential time blocks; (r, k, v, w) tiles stream
through the BlockSpec pipeline.  Each timestep performs a rank-1 update and
a [K]x[K,V] contraction — small matmuls that map onto the MXU when K=V=64
(padded to the 128 lane width by Mosaic).  Heads and batch tile the parallel
grid axes.

Grid: (B, H, T // block_t); carry resets at t_block == 0.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 64


def _wkv6_kernel(
    r_ref,   # [1, block_t, 1, K]
    k_ref,   # [1, block_t, 1, K]
    v_ref,   # [1, block_t, 1, V]
    w_ref,   # [1, block_t, 1, K]
    u_ref,   # [1, K]
    s0_ref,  # [1, 1, K, V]
    y_ref,   # [1, block_t, 1, V]
    sn_ref,  # [1, 1, K, V]
    s_ref,   # scratch [K, V] fp32
    *,
    block_t: int,
    n_t_blocks: int,
):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0, :].astype(jnp.float32)  # [K]

    def body(t, _):
        r_t = r_ref[0, t, 0, :].astype(jnp.float32)  # [K]
        k_t = k_ref[0, t, 0, :].astype(jnp.float32)  # [K]
        v_t = v_ref[0, t, 0, :].astype(jnp.float32)  # [V]
        w_t = w_ref[0, t, 0, :].astype(jnp.float32)  # [K]
        S = s_ref[...]                               # [K, V]
        kv = k_t[:, None] * v_t[None, :]             # rank-1 [K, V]
        y = (r_t[:, None] * (S + u[:, None] * kv)).sum(axis=0)  # [V]
        y_ref[0, t, 0, :] = y.astype(y_ref.dtype)
        s_ref[...] = w_t[:, None] * S + kv
        return 0

    jax.lax.fori_loop(0, block_t, body, 0)

    @pl.when(ti == n_t_blocks - 1)
    def _final():
        sn_ref[0, 0] = s_ref[...].astype(sn_ref.dtype)


def wkv6(
    r: jnp.ndarray,  # [B, T, H, K]
    k: jnp.ndarray,  # [B, T, H, K]
    v: jnp.ndarray,  # [B, T, H, V]
    w: jnp.ndarray,  # [B, T, H, K]
    u: jnp.ndarray,  # [H, K]
    s0: Optional[jnp.ndarray] = None,  # [B, H, K, V]
    *,
    block_t: int = DEFAULT_BLOCK_T,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pallas WKV6.  Returns (y [B,T,H,V], s_final [B,H,K,V])."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((B, H, K, V), jnp.float32)
    from repro.kernels.rglru.rglru import largest_divisor_block

    block_t = largest_divisor_block(T, block_t)
    grid = (B, H, T // block_t)

    kernel = functools.partial(
        _wkv6_kernel, block_t=block_t, n_t_blocks=T // block_t
    )
    y, sn = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, 1, K), lambda bi, hi, ti: (bi, ti, hi, 0)),
            pl.BlockSpec((1, block_t, 1, K), lambda bi, hi, ti: (bi, ti, hi, 0)),
            pl.BlockSpec((1, block_t, 1, V), lambda bi, hi, ti: (bi, ti, hi, 0)),
            pl.BlockSpec((1, block_t, 1, K), lambda bi, hi, ti: (bi, ti, hi, 0)),
            pl.BlockSpec((1, K), lambda bi, hi, ti: (hi, 0)),
            pl.BlockSpec((1, 1, K, V), lambda bi, hi, ti: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, 1, V), lambda bi, hi, ti: (bi, ti, hi, 0)),
            pl.BlockSpec((1, 1, K, V), lambda bi, hi, ti: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, V), r.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, sn
