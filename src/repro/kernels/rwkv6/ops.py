"""Public WKV6 op with backend dispatch.

Gradients flow through the reference implementation (lax.scan autodiff) via
custom_vjp-free dispatch: the Pallas kernel is used for inference/forward
paths on TPU; training differentiates the scan reference (which XLA
optimizes well for this recurrence).  This mirrors how RWKV production
stacks treat the fused kernel (fwd-optimized) vs training (autodiff scan).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6 import ref
from repro.kernels.rwkv6.rwkv6 import wkv6 as wkv6_kernel


def _default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def wkv(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    s0: Optional[jnp.ndarray] = None,
    *,
    backend: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """RWKV-6 WKV.  Returns (y [B,T,H,V], s_final [B,H,K,V])."""
    if backend == "auto":
        backend = _default_backend()
    if backend == "reference":
        return ref.wkv6_reference(r, k, v, w, u, s0)
    return wkv6_kernel(r, k, v, w, u, s0, interpret=(backend == "interpret"))
