"""Pallas TPU kernels for the perf-critical compute hot spots.

Each kernel lives in its own subpackage with three files:
  <name>.py — the pl.pallas_call kernel with explicit BlockSpec VMEM tiling
  ops.py    — the public jit-able wrapper with backend dispatch + VJP
  ref.py    — the pure-jnp oracle the kernel is validated against

Kernels target TPU (MXU/VPU + VMEM pipelines) and are validated on CPU in
interpret mode; model code selects the `reference` backend when lowering on
non-TPU platforms (including the multi-pod dry-run).
"""

from repro.kernels.flash_attention.ops import attention
from repro.kernels.rglru.ops import linear_scan
from repro.kernels.rwkv6.ops import wkv

__all__ = ["attention", "linear_scan", "wkv"]
