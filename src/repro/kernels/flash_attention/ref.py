"""Pure-jnp oracle for flash attention (GQA / causal / sliding / softcap).

This is both the correctness reference for the Pallas kernel (tests compare
against it in interpret mode) and the XLA lowering path used by the models
when running on CPU or in the multi-pod dry-run (kernels target TPU).
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _bf16_wire() -> bool:
    """Perf knob (§Perf iteration): keep attention inputs in bf16 through
    any GSPMD-inserted collectives and let the MXU accumulate in fp32 via
    preferred_element_type, instead of casting to fp32 *before* the einsum
    (which puts 4-byte activations on the ICI for sequence-parallel
    gathers).  Numerics match the Pallas kernel's bf16-in/fp32-accumulate."""
    return os.environ.get("REPRO_ATTN_BF16_WIRE", "0") == "1"


def attention_mask(
    s_q: int,
    s_k: int,
    causal: bool,
    window: Optional[int],
    q_offset: int = 0,
) -> jnp.ndarray:
    """[s_q, s_k] boolean mask; True = attend.

    ``q_offset`` positions the query block inside the full sequence (used for
    decode where s_q=1 sits at position cache_len-1).
    """
    iq = jnp.arange(s_q)[:, None] + q_offset
    jk = jnp.arange(s_k)[None, :]
    mask = jnp.ones((s_q, s_k), bool)
    if causal:
        mask &= jk <= iq
    if window is not None:
        mask &= jk > iq - window
    return mask


def mha_reference(
    q: jnp.ndarray,  # [B, S_q, H_q, D]
    k: jnp.ndarray,  # [B, S_k, H_kv, D]
    v: jnp.ndarray,  # [B, S_k, H_kv, D]
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
    kv_len: Optional[jnp.ndarray] = None,  # [B] valid KV lengths (decode)
) -> jnp.ndarray:
    """Grouped-query attention, O(S^2) reference.  Returns [B, S_q, H_q, D]."""
    B, S_q, H_q, D = q.shape
    _, S_k, H_kv, _ = k.shape
    assert H_q % H_kv == 0, (H_q, H_kv)
    group = H_q // H_kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))

    # GQA via a grouped einsum — the KV tensors are never materialized at
    # q-head width (an 8x cache blow-up for 64q/8kv decode otherwise).
    if _bf16_wire():
        qf = (q * jnp.asarray(scale, q.dtype)).reshape(B, S_q, H_kv, group, D)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k,
                            preferred_element_type=jnp.float32)
    else:
        qf = (q.astype(jnp.float32) * scale).reshape(B, S_q, H_kv, group, D)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    mask = attention_mask(S_q, S_k, causal, window, q_offset)[None, None, None]
    if kv_len is not None:
        valid = jnp.arange(S_k)[None, :] < kv_len[:, None]  # [B, S_k]
        mask = mask & valid[:, None, None, None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    if _bf16_wire():
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(q.dtype), v,
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S_q, H_q, D).astype(q.dtype)


# Above this many score elements per (batch, head), the XLA path switches to
# a q-chunked scan so the S_q x S_k matrix is never fully materialized
# (flash-style memory behaviour for the reference backend; exact math).
CHUNK_THRESHOLD = 4096 * 4096
CHUNK_Q = 1024


def mha_chunked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
    chunk_q: int = CHUNK_Q,
) -> jnp.ndarray:
    """Exact attention via lax.map over query chunks (O(chunk*S_k) memory)."""
    B, S_q, H_q, D = q.shape
    cq = chunk_q
    while S_q % cq:
        cq -= 1
    n_chunks = S_q // cq
    qc = q.reshape(B, n_chunks, cq, H_q, D).transpose(1, 0, 2, 3, 4)

    def one(args):
        i, q_i = args
        return mha_reference(
            q_i, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset + i * cq,
        )

    out = jax.lax.map(one, (jnp.arange(n_chunks), qc))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S_q, H_q, D)
