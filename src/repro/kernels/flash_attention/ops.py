"""Public attention op: Pallas kernel on TPU, jnp oracle elsewhere.

``attention(...)`` takes [B, S, H, D]-layout tensors (the model-side layout),
handles the transpose to the kernel's heads-major layout, and provides a
``custom_vjp`` whose forward is the flash kernel and whose backward is the
(recompute-based) reference gradient — the O(S^2) score matrix is never
materialized in the forward pass.

Backend selection:
  * backend="pallas"     — TPU compiled kernel (the deployment target)
  * backend="interpret"  — Pallas interpret mode (CPU correctness runs/tests)
  * backend="reference"  — pure-jnp XLA path (CPU smoke tests + the multi-pod
                            dry-run, where CPU devices stand in for TPUs)
  * backend="auto"       — pallas on TPU, reference otherwise
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.flash_attention import flash_attention_fwd


def _default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "reference"


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def _flash_attn(q, k, v, causal, window, softcap, q_offset, interpret):
    # [B, S, H, D] -> [B, H, S, D] for the kernel
    out = flash_attention_fwd(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal,
        window=window,
        softcap=softcap,
        q_offset=q_offset,
        interpret=interpret,
    )
    return out.transpose(0, 2, 1, 3)


def _flash_attn_fwd(q, k, v, causal, window, softcap, q_offset, interpret):
    out = _flash_attn(q, k, v, causal, window, softcap, q_offset, interpret)
    return out, (q, k, v)


def _flash_attn_bwd(causal, window, softcap, q_offset, interpret, res, g):
    # Recompute-based backward via the reference implementation (XLA).
    # Correct for all kernel options; a dedicated Pallas backward is a
    # further optimization, not a correctness requirement.
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.mha_reference(
            q_, k_, v_, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset,
        ),
        q, k, v,
    )
    return vjp(g)


_flash_attn.defvjp(_flash_attn_fwd, _flash_attn_bwd)


def attention(
    q: jnp.ndarray,  # [B, S_q, H_q, D]
    k: jnp.ndarray,  # [B, S_k, H_kv, D]
    v: jnp.ndarray,  # [B, S_k, H_kv, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
    kv_len: Optional[jnp.ndarray] = None,
    backend: str = "auto",
) -> jnp.ndarray:
    """Grouped-query attention with optional sliding window / soft-capping."""
    if backend == "auto":
        backend = _default_backend()
    if backend == "reference" or kv_len is not None:
        # variable-length decode masking stays on the XLA path
        import os

        threshold = int(os.environ.get("REPRO_ATTN_CHUNK_THRESHOLD",
                                       ref.CHUNK_THRESHOLD))
        if kv_len is None and q.shape[1] * k.shape[1] > threshold:
            return ref.mha_chunked(
                q, k, v, causal=causal, window=window, softcap=softcap,
                q_offset=q_offset,
            )
        return ref.mha_reference(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, kv_len=kv_len,
        )
    interpret = backend == "interpret"
    return _flash_attn(q, k, v, causal, window, softcap, q_offset, interpret)
