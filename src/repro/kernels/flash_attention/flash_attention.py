"""Flash attention for TPU in Pallas (pl.pallas_call + explicit BlockSpecs).

TPU-native adaptation of FlashAttention: online-softmax tiling where the KV
axis is the innermost (sequential) grid dimension, so the running max / sum /
accumulator live in VMEM scratch across KV steps and q/k/v blocks stream
HBM -> VMEM via the BlockSpec pipeline.  MXU alignment: block_q and block_kv
are multiples of 128 and the contraction is over head_dim (128/256 for the
assigned archs).

Supports: GQA (kv-head indexed as q_head // group via the BlockSpec index
map — no materialized head broadcast), causal masking, sliding-window
attention (Mistral/Gemma2 local layers), and logit soft-capping (Gemma2).

Layouts: q [B, H_q, S_q, D], k/v [B, H_kv, S_k, D] — heads-major so that a
(S, D) tile is contiguous in the two minor dimensions.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128


def _attn_kernel(
    q_ref,    # [1, 1, block_q, D]
    k_ref,    # [1, 1, block_kv, D]
    v_ref,    # [1, 1, block_kv, D]
    o_ref,    # [1, 1, block_q, D]
    m_ref,    # scratch [block_q, 1] running max
    l_ref,    # scratch [block_q, 1] running sum
    acc_ref,  # scratch [block_q, D] fp32 accumulator
    *,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    scale: float,
    block_q: int,
    block_kv: int,
    n_kv_blocks: int,
    q_offset: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)               # [bkv, D]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                 # [bq, bkv]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    # positional mask (causal / sliding window)
    row = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + q_offset
    col = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= col <= row
    if window is not None:
        mask &= col > row - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                               # [bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows: exp(NEG_INF - NEG_INF) would be exp(0)=1
    p = jnp.exp(s - jnp.where(m_new <= NEG_INF / 2, 0.0, m_new))
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - jnp.where(m_new <= NEG_INF / 2, 0.0, m_new))
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)

    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)               # [bkv, D]
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = alpha * acc_ref[...] + pv
    m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0, :, :] = (
            acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        ).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,  # [B, H_q, S_q, D]
    k: jnp.ndarray,  # [B, H_kv, S_k, D]
    v: jnp.ndarray,  # [B, H_kv, S_k, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    q_offset: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pallas flash attention forward.  Returns [B, H_q, S_q, D]."""
    B, H_q, S_q, D = q.shape
    _, H_kv, S_k, _ = k.shape
    assert H_q % H_kv == 0
    group = H_q // H_kv
    from repro.kernels.rglru.rglru import largest_divisor_block

    block_q = largest_divisor_block(S_q, block_q)
    block_kv = largest_divisor_block(S_k, block_kv)
    n_q_blocks = S_q // block_q
    n_kv_blocks = S_k // block_kv
    grid = (B, H_q, n_q_blocks, n_kv_blocks)

    kernel = functools.partial(
        _attn_kernel,
        causal=causal,
        window=window,
        softcap=softcap,
        scale=1.0 / (D**0.5),
        block_q=block_q,
        block_kv=block_kv,
        n_kv_blocks=n_kv_blocks,
        q_offset=q_offset,
    )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_kv, D), lambda b, h, qi, ki: (b, h // group, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_kv, D), lambda b, h, qi, ki: (b, h // group, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H_q, S_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
