"""recurrentgemma-9b [hybrid]: Griffin — RG-LRU + local attention, 2:1.

38 blocks, pattern (rglru, rglru, attn_local); d_model=4096 16H (kv=1,
head_dim=256) d_ff=12288 GeGLU, vocab=256000, window=2048, lru_width=4096.
[arXiv:2402.19427; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    mixer_pattern=("rglru", "rglru", "attn_local"),
    window=2048,
    mlp_type="geglu",
    rnn_width=4096,
    conv_width=4,
    tie_embeddings=True,
    embed_scale=True,
    max_seq_len=8192,
    source="arXiv:2402.19427",
)
