"""whisper-medium [audio]: enc-dec, conv frontend stubbed to frame embeds.

24L (per stack) d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    mlp_type="gelu",
    norm_type="layernorm",
    use_rope=False,
    qkv_bias=True,
    tie_embeddings=True,
    is_encoder_decoder=True,
    n_encoder_layers=24,
    frontend="audio",
    frontend_seq_len=1500,   # 30s of audio at 50 Hz after conv stride-2
    max_seq_len=448,
    source="arXiv:2212.04356",
)
