"""gemma2-9b [dense]: 42L d_model=3584 16H (kv=8) head_dim=256 d_ff=14336
GeGLU, vocab=256000, alternating local(4096)/global attention, logit
softcaps (attn 50, final 30).
[arXiv:2408.00118; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    mixer_pattern=("attn_local", "attn"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    mlp_type="geglu",
    tie_embeddings=True,
    embed_scale=True,
    max_seq_len=8192,
    source="arXiv:2408.00118",
)
