"""rwkv6-7b [ssm]: Finch — attention-free, data-dependent decay.

32L d_model=4096 (64 heads x 64 dim) channel-mix d_ff=14336, vocab=65536.
[arXiv:2404.05892; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    mixer_pattern=("rwkv",),
    rwkv_head_dim=64,
    norm_type="layernorm",
    max_seq_len=1048576,     # state-based: context bounded by memory, not cache
    source="arXiv:2404.05892",
)
