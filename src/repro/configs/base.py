"""Model configuration schema for the architecture zoo.

One frozen dataclass describes every assigned architecture: dense / MoE /
hybrid (RG-LRU + local attention) / SSM (RWKV6) / encoder-decoder / VLM- and
audio-frontend LMs.  ``reduced()`` derives the CPU-smoke-test variant of any
config (same family and block pattern, tiny dims).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm | audio

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # block pattern: mixer type per position, cycled over layers.
    #   "attn" (global), "attn_local" (sliding window), "rglru", "rwkv"
    mixer_pattern: Tuple[str, ...] = ("attn",)

    # attention details
    window: Optional[int] = None            # sliding-window size
    attn_softcap: Optional[float] = None    # gemma2 attention-logit cap
    qkv_bias: bool = False
    qk_norm: bool = False                   # qwen3 per-head q/k RMSNorm
    rope_theta: float = 10000.0
    use_rope: bool = True                   # False: learned absolute (whisper)

    # output head
    final_softcap: Optional[float] = None   # gemma2 final-logit cap
    tie_embeddings: bool = False

    # MLP
    mlp_type: str = "swiglu"                # swiglu | geglu | gelu

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # recurrent (RG-LRU / RWKV)
    rnn_width: int = 0
    conv_width: int = 4                     # griffin temporal conv
    rwkv_head_dim: int = 64

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # modality frontend stub: None | "audio" | "vision"
    frontend: Optional[str] = None
    frontend_seq_len: int = 0               # frames/patches per sample

    # numerics
    norm_type: str = "rmsnorm"              # rmsnorm | layernorm
    embed_scale: bool = False               # gemma sqrt(d) embedding scale
    max_seq_len: int = 8192

    # citation provenance for the config values
    source: str = ""

    # ------------------------------------------------------------------

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def n_groups_and_tail(self) -> Tuple[int, int]:
        """Layers are organized as scan(n_groups x pattern) + unrolled tail."""
        p = len(self.mixer_pattern)
        return self.n_layers // p, self.n_layers % p

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        n_embed = V * d * (1 if self.tie_embeddings else 2)
        per_layer = {}
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        gates = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        if self.is_moe:
            mlp = self.n_experts * gates * d * ff + d * self.n_experts
        else:
            mlp = gates * d * ff
        rnn = 0
        if "rglru" in self.mixer_pattern:
            w = self.rnn_width or d
            rnn = 2 * d * w + w * d + self.conv_width * w + 3 * w
        rwkv = 0
        if "rwkv" in self.mixer_pattern:
            rwkv = 6 * d * d + 2 * d * ff  # r/k/v/w/g/o + channel-mix
        total = n_embed
        pattern = self.mixer_pattern
        n_layers = self.n_layers + (
            self.n_encoder_layers if self.is_encoder_decoder else 0
        )
        for i in range(self.n_layers):
            m = pattern[i % len(pattern)]
            if m == "rwkv":
                total += rwkv + 2 * d
            elif m == "rglru":
                total += rnn + mlp + 2 * d
            else:
                total += attn + mlp + 2 * d
        if self.is_encoder_decoder:
            total += self.n_encoder_layers * (attn + mlp + 2 * d)
            total += self.n_layers * (attn + d)  # cross-attention
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        gates = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        dense_moe = self.n_experts * gates * d * ff
        active_moe = self.experts_per_token * gates * d * ff
        return self.param_count() - self.n_layers * (dense_moe - active_moe)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        p = len(self.mixer_pattern)
        _, tail = self.n_groups_and_tail()
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2 * p + tail,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            n_experts=min(self.n_experts, 8),
            experts_per_token=min(self.experts_per_token, 2),
            rnn_width=64 if self.rnn_width else 0,
            rwkv_head_dim=16,
            window=32 if self.window else None,
            n_encoder_layers=2 if self.is_encoder_decoder else 0,
            frontend_seq_len=16 if self.frontend else 0,
            max_seq_len=128,
        )
