"""Assigned architecture configs (+ the paper's cluster configs).

Every config cites its public source; values follow the assignment sheet.
``get_config(name)`` resolves by arch id; ``ARCHS`` lists all ten.
"""

from repro.configs.base import ModelConfig
from repro.configs.whisper_medium import CONFIG as whisper_medium
from repro.configs.recurrentgemma_9b import CONFIG as recurrentgemma_9b
from repro.configs.qwen3_moe_235b import CONFIG as qwen3_moe_235b
from repro.configs.phi35_moe_42b import CONFIG as phi35_moe_42b
from repro.configs.qwen15_110b import CONFIG as qwen15_110b
from repro.configs.mistral_nemo_12b import CONFIG as mistral_nemo_12b
from repro.configs.gemma_7b import CONFIG as gemma_7b
from repro.configs.gemma2_9b import CONFIG as gemma2_9b
from repro.configs.internvl2_76b import CONFIG as internvl2_76b
from repro.configs.rwkv6_7b import CONFIG as rwkv6_7b

ARCHS = {
    "whisper-medium": whisper_medium,
    "recurrentgemma-9b": recurrentgemma_9b,
    "qwen3-moe-235b-a22b": qwen3_moe_235b,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b,
    "qwen1.5-110b": qwen15_110b,
    "mistral-nemo-12b": mistral_nemo_12b,
    "gemma-7b": gemma_7b,
    "gemma2-9b": gemma2_9b,
    "internvl2-76b": internvl2_76b,
    "rwkv6-7b": rwkv6_7b,
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ModelConfig", "ARCHS", "get_config"]
