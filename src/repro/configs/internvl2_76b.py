"""internvl2-76b [vlm]: InternViT frontend (stubbed to patch embeddings) +
Llama-3-70B-class backbone: 80L d_model=8192 64H (kv=8) d_ff=28672,
vocab=128256.
[arXiv:2404.16821; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=5e5,
    frontend="vision",
    frontend_seq_len=256,    # 256 visual tokens per image tile
    max_seq_len=8192,
    source="arXiv:2404.16821",
)
