"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (kv=4) expert d_ff=1536,
vocab=151936, 128 experts top-8, QK-norm.
[hf:Qwen/Qwen3-30B-A3B (family); hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    n_experts=128,
    experts_per_token=8,
    qk_norm=True,
    rope_theta=1e6,
    max_seq_len=32768,
    source="hf:Qwen/Qwen3-235B-A22B",
)
