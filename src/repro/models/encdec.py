"""Whisper-style encoder-decoder backbone.

The audio frontend (mel + conv downsampling) is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings [B, T_frames, d].
The encoder adds learned positions and runs bidirectional attention blocks;
the decoder runs causal self-attention + cross-attention + MLP with tied
embeddings, exactly the Whisper block layout (pre-LN LayerNorm, GELU MLP).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, mlp
from repro.parallel.sharding import shard_activation

PyTree = Any


def _maybe_remat(fn, policy):
    from repro.models.transformer import _maybe_remat as mr
    return mr(fn, policy)


def _init_enc_block(key, cfg: ModelConfig, dtype) -> PyTree:
    kg = common.KeyGen(key)
    return {
        "norm1": common.norm_init(cfg.norm_type, cfg.d_model, dtype),
        "attn": attention.init_attention(kg, cfg, dtype),
        "norm2": common.norm_init(cfg.norm_type, cfg.d_model, dtype),
        "mlp": mlp.init_mlp(kg, cfg, dtype),
    }


def _init_dec_block(key, cfg: ModelConfig, dtype) -> PyTree:
    kg = common.KeyGen(key)
    return {
        "norm1": common.norm_init(cfg.norm_type, cfg.d_model, dtype),
        "attn": attention.init_attention(kg, cfg, dtype),
        "norm_x": common.norm_init(cfg.norm_type, cfg.d_model, dtype),
        "xattn": attention.init_attention(kg, cfg, dtype, cross=True),
        "norm2": common.norm_init(cfg.norm_type, cfg.d_model, dtype),
        "mlp": mlp.init_mlp(kg, cfg, dtype),
    }


def init_encdec_params(cfg: ModelConfig, key, dtype=jnp.float32) -> PyTree:
    kg = common.KeyGen(key)
    d = cfg.d_model
    enc_keys = jax.random.split(kg(), cfg.n_encoder_layers)
    dec_keys = jax.random.split(kg(), cfg.n_layers)
    return {
        "embed": common.embed_init(kg(), (cfg.vocab_size, d), dtype),
        "enc_pos": common.embed_init(kg(), (cfg.frontend_seq_len or 1500, d), dtype),
        "dec_pos": common.embed_init(kg(), (cfg.max_seq_len, d), dtype),
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(enc_keys),
        "enc_norm": common.norm_init(cfg.norm_type, d, dtype),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(dec_keys),
        "dec_norm": common.norm_init(cfg.norm_type, d, dtype),
    }


# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames: jnp.ndarray, *,
           backend: str = "auto", scan_unroll: int = 1,
           remat_policy=None) -> jnp.ndarray:
    """frames [B, T_f, d] (stub frontend output) -> memory [B, T_f, d]."""
    T = frames.shape[1]
    # tile positions past the table length (dry-run shapes can exceed the
    # audio backbone's native 1500-frame context; documented in DESIGN.md)
    pos = params["enc_pos"][jnp.arange(T) % params["enc_pos"].shape[0]]
    x = frames + pos[None]
    x = shard_activation(x, "batch", "seq", "act_embed")
    positions = jnp.arange(T)

    def block(x, p):
        h = common.apply_norm(cfg.norm_type, p["norm1"], x)
        h = attention.attention_block(
            p["attn"], cfg, h, positions, causal=False, backend=backend
        )
        x = x + h
        h = common.apply_norm(cfg.norm_type, p["norm2"], x)
        x = x + mlp.mlp_block(p["mlp"], cfg, h)
        return shard_activation(x, "batch", "seq", "act_embed"), None

    body = _maybe_remat(block, remat_policy)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"], unroll=scan_unroll)
    return common.apply_norm(cfg.norm_type, params["enc_norm"], x)


def _dec_block(p, cfg, x, positions, memory, backend):
    h = common.apply_norm(cfg.norm_type, p["norm1"], x)
    h = attention.attention_block(p["attn"], cfg, h, positions, backend=backend)
    x = x + h
    h = common.apply_norm(cfg.norm_type, p["norm_x"], x)
    h = attention.attention_block(
        p["xattn"], cfg, h, positions, memory=memory, backend=backend
    )
    x = x + h
    h = common.apply_norm(cfg.norm_type, p["norm2"], x)
    x = x + mlp.mlp_block(p["mlp"], cfg, h)
    return shard_activation(x, "batch", "seq", "act_embed")


def decode_train(params, cfg: ModelConfig, tokens, memory, *,
                 backend: str = "auto", scan_unroll: int = 1,
                 remat_policy=None) -> jnp.ndarray:
    """Teacher-forced decoder forward -> logits [B, S, V]."""
    S = tokens.shape[1]
    pos_emb = params["dec_pos"][jnp.arange(S) % params["dec_pos"].shape[0]]
    x = params["embed"][tokens] + pos_emb[None]
    positions = jnp.arange(S)

    def block(x, p):
        return _dec_block(p, cfg, x, positions, memory, backend), None

    body = _maybe_remat(block, remat_policy)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"], unroll=scan_unroll)
    x = common.apply_norm(cfg.norm_type, params["dec_norm"], x)
    return jnp.einsum("bsd,vd->bsv", x, params["embed"])  # tied


def encdec_loss(
    params: PyTree,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    *,
    backend: str = "auto",
    remat_policy: Optional[str] = None,
    compute_dtype=None,
    scan_unroll: int = 1,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """batch: frames [B,T_f,d], tokens [B,S], labels [B,S], optional mask."""
    if compute_dtype is not None:
        params = jax.tree_util.tree_map(
            lambda p: p.astype(compute_dtype)
            if p.dtype in (jnp.float32, jnp.bfloat16) else p, params,
        )
    memory = encode(params, cfg, batch["frames"], backend=backend,
                    scan_unroll=scan_unroll, remat_policy=remat_policy)
    logits = decode_train(params, cfg, batch["tokens"], memory, backend=backend,
                          scan_unroll=scan_unroll, remat_policy=remat_policy)
    xent = common.softmax_xent(logits, batch["labels"], batch.get("mask"))
    return xent, {"xent": xent, "moe_aux": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# Serving: encoder runs once, decoder steps with a KV cache
# ---------------------------------------------------------------------------

def init_encdec_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    one = attention.init_kv_cache(cfg, batch, max_len, dtype)
    L = cfg.n_layers
    return {
        "self": jax.tree_util.tree_map(
            lambda c: jnp.broadcast_to(c, (L,) + c.shape), one
        ),
        "pos": jnp.zeros((), jnp.int32),
    }


def encdec_decode_step(
    params: PyTree,
    cfg: ModelConfig,
    cache,
    tokens: jnp.ndarray,   # [B, 1]
    memory: jnp.ndarray,   # [B, T_f, d]
    *,
    backend: str = "auto",
    scan_unroll: int = 1,
):
    pos = cache["pos"]
    pos_emb = params["dec_pos"][pos % params["dec_pos"].shape[0]][None, None]
    x = params["embed"][tokens] + pos_emb

    def block(x, xs):
        p, c = xs
        h = common.apply_norm(cfg.norm_type, p["norm1"], x)
        h, c = attention.decode_attention_block(
            p["attn"], cfg, h, pos, c, backend=backend
        )
        x = x + h
        h = common.apply_norm(cfg.norm_type, p["norm_x"], x)
        h, _ = attention.decode_attention_block(
            p["xattn"], cfg, h, pos, c, memory=memory, backend=backend
        )
        x = x + h
        h = common.apply_norm(cfg.norm_type, p["norm2"], x)
        x = x + mlp.mlp_block(p["mlp"], cfg, h)
        return x, c

    x, new_self = jax.lax.scan(
        block, x, (params["dec_blocks"], cache["self"]), unroll=scan_unroll,
    )
    x = common.apply_norm(cfg.norm_type, params["dec_norm"], x)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return logits, {"self": new_self, "pos": pos + 1}
