"""Decoder-only LM assembly: scan-over-layer-groups, mixed mixer patterns.

Layers are organized as ``n_groups`` repetitions of ``cfg.mixer_pattern``
(+ an unrolled tail when the depth isn't a multiple of the pattern).  Each
pattern position's parameters are stacked along a leading "layers" axis and
consumed by ``lax.scan`` — HLO size is depth-independent, which is what
makes 94-layer MoE dry-runs compile in seconds.

Three entry points share the block code:
  * ``lm_loss``      — training forward + softmax xent (remat-able groups)
  * ``lm_prefill``   — forward that also materializes the decode caches
  * ``lm_decode_step`` — single-token step against the caches

Activation sharding constraints route through repro.parallel.sharding and
are no-ops outside a ``use_sharding`` context.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention, common, mlp, moe, rglru, rwkv6
from repro.parallel.sharding import shard_activation

PyTree = Any


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, mixer: str, dtype) -> PyTree:
    kg = common.KeyGen(key)
    d = cfg.d_model
    p: Dict[str, PyTree] = {"norm1": common.norm_init(cfg.norm_type, d, dtype)}
    if mixer in ("attn", "attn_local"):
        p["attn"] = attention.init_attention(kg, cfg, dtype)
    elif mixer == "rglru":
        p["rglru"] = rglru.init_rglru(kg, cfg, dtype)
    elif mixer == "rwkv":
        p["tm"] = rwkv6.init_rwkv_time_mix(kg, cfg, dtype)
    else:
        raise ValueError(mixer)
    p["norm2"] = common.norm_init(cfg.norm_type, d, dtype)
    if mixer == "rwkv":
        p["cm"] = rwkv6.init_rwkv_channel_mix(kg, cfg, dtype)
    elif cfg.is_moe:
        p["moe"] = moe.init_moe(kg, cfg, dtype)
    else:
        p["mlp"] = mlp.init_mlp(kg, cfg, dtype)
    return p


def _sp_gather(h):
    """[REFUTED perf experiment, kept as an ablation knob] Megatron-style
    explicit gather of the seq-sharded residual stream before block matmuls.
    Hypothesis was that GSPMD resolves the SP x TP conflict by gathering
    weights; measured: forcing the activation gather made the collective
    term 3.6x WORSE (38.8 -> 141 s on qwen1.5 train_4k) — GSPMD's implicit
    resolution was already better.  Default OFF."""
    import os

    if os.environ.get("REPRO_SP_GATHER", "0") == "1":
        return shard_activation(h, "batch", None, "act_embed")
    return h


def apply_block(
    p: PyTree,
    cfg: ModelConfig,
    mixer: str,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    backend: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence block.  Returns (x, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = _sp_gather(common.apply_norm(cfg.norm_type, p["norm1"], x))
    if mixer in ("attn", "attn_local"):
        h = attention.attention_block(
            p["attn"], cfg, h, positions, local=(mixer == "attn_local"),
            backend=backend,
        )
    elif mixer == "rglru":
        h = rglru.rglru_block(p["rglru"], cfg, h, backend=backend)
    else:  # rwkv
        h, _, _ = rwkv6.time_mix(p["tm"], cfg, h, backend=backend)
    x = shard_activation(x + h, "batch", "seq", "act_embed")
    h = _sp_gather(common.apply_norm(cfg.norm_type, p["norm2"], x))
    if mixer == "rwkv":
        h, _ = rwkv6.channel_mix(p["cm"], cfg, h)
    elif cfg.is_moe:
        h, aux = moe.moe_block(p["moe"], cfg, h)
    else:
        h = mlp.mlp_block(p["mlp"], cfg, h)
    x = shard_activation(x + h, "batch", "seq", "act_embed")
    return x, aux


# -- cache-carrying variants -------------------------------------------------

def init_block_cache(
    cfg: ModelConfig, mixer: str, batch: int, max_len: int, dtype
) -> PyTree:
    if mixer in ("attn", "attn_local"):
        return attention.init_kv_cache(
            cfg, batch, max_len, dtype, local=(mixer == "attn_local")
        )
    if mixer == "rglru":
        return rglru.init_rglru_state(cfg, batch, dtype)
    return rwkv6.init_rwkv_state(cfg, batch, dtype)


def prefill_block(
    p: PyTree, cfg: ModelConfig, mixer: str, x, positions, cache, *,
    backend: str = "auto",
) -> Tuple[jnp.ndarray, PyTree, jnp.ndarray]:
    """Full-sequence block that also fills the decode cache."""
    aux = jnp.zeros((), jnp.float32)
    S = x.shape[1]
    h = common.apply_norm(cfg.norm_type, p["norm1"], x)
    if mixer in ("attn", "attn_local"):
        q, k, v = attention._project_qkv(p["attn"], cfg, h, h)
        if cfg.use_rope:
            sin, cos = common.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
            q = common.apply_rope(q, sin, cos)
            k = common.apply_rope(k, sin, cos)
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.attention(
            q, k, v, causal=True,
            window=cfg.window if mixer == "attn_local" else None,
            softcap=cfg.attn_softcap, backend=backend,
        )
        h = jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])
        L = cache["k"].shape[1]
        if L >= S:
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1),
                "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1),
            }
        else:
            # ring cache shorter than the prefill: keep the tail, placed at
            # its ring slots (position p lives at slot p % L) so subsequent
            # decode writes overwrite the oldest entry.
            shift = S % L
            new_cache = {
                "k": jnp.roll(k[:, S - L:], shift, axis=1),
                "v": jnp.roll(v[:, S - L:], shift, axis=1),
            }
    elif mixer == "rglru":
        gate = jax.nn.gelu(h @ p["rglru"]["w_in_gate"], approximate=True)
        u = h @ p["rglru"]["w_in_rec"]
        u, conv_state = rglru._causal_conv(p["rglru"], u)
        a, b = rglru._rglru_gates(p["rglru"], u)
        hs, h_final = rglru.linear_scan_dispatch(a, b, backend)
        h = (hs * gate) @ p["rglru"]["w_out"]
        new_cache = {"h": h_final.astype(jnp.float32), "conv": conv_state}
    else:  # rwkv
        h, tm_shift, wkv_state = rwkv6.time_mix(
            p["tm"], cfg, h, None, None, backend=backend
        )
        new_cache = {"tm_shift": tm_shift, "wkv": wkv_state}
    x = x + h
    h = common.apply_norm(cfg.norm_type, p["norm2"], x)
    if mixer == "rwkv":
        h, cm_shift = rwkv6.channel_mix(p["cm"], cfg, h)
        new_cache["cm_shift"] = cm_shift
    elif cfg.is_moe:
        h, aux = moe.moe_block(p["moe"], cfg, h)
    else:
        h = mlp.mlp_block(p["mlp"], cfg, h)
    return x + h, new_cache, aux


def decode_block(
    p: PyTree, cfg: ModelConfig, mixer: str, x, pos, cache, *,
    backend: str = "auto",
) -> Tuple[jnp.ndarray, PyTree]:
    h = common.apply_norm(cfg.norm_type, p["norm1"], x)
    if mixer in ("attn", "attn_local"):
        h, cache = attention.decode_attention_block(
            p["attn"], cfg, h, pos, cache, local=(mixer == "attn_local"),
            backend=backend,
        )
    elif mixer == "rglru":
        h, cache = rglru.decode_rglru_block(p["rglru"], cfg, h, cache)
    else:
        h, tm_shift, wkv_state = rwkv6.time_mix(
            p["tm"], cfg, h, cache["tm_shift"], cache["wkv"], backend=backend
        )
        cache = dict(cache, tm_shift=tm_shift, wkv=wkv_state)
    x = x + h
    h = common.apply_norm(cfg.norm_type, p["norm2"], x)
    if mixer == "rwkv":
        h, cm_shift = rwkv6.channel_mix(p["cm"], cfg, h, cache["cm_shift"])
        cache = dict(cache, cm_shift=cm_shift)
    elif cfg.is_moe:
        h, _ = moe.moe_block(p["moe"], cfg, h)
    else:
        h = mlp.mlp_block(p["mlp"], cfg, h)
    return x + h, cache


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def init_lm_params(cfg: ModelConfig, key, dtype=jnp.float32) -> PyTree:
    kg = common.KeyGen(key)
    n_groups, n_tail = cfg.n_groups_and_tail()
    pattern = cfg.mixer_pattern

    params: Dict[str, PyTree] = {
        "embed": common.embed_init(kg(), (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": common.norm_init(cfg.norm_type, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = common.dense_init(
            kg(), (cfg.d_model, cfg.vocab_size), dtype
        )

    def stacked_init(mixer: str, n: int):
        keys = jax.random.split(kg(), n)
        return jax.vmap(lambda k: init_block(k, cfg, mixer, dtype))(keys)

    params["blocks"] = [stacked_init(m, n_groups) for m in pattern]
    params["tail"] = [
        init_block(kg(), cfg, pattern[i % len(pattern)], dtype)
        for i in range(n_tail)
    ]
    return params


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _embed_tokens(params, cfg: ModelConfig, tokens, prefix_embeds=None):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return shard_activation(x, "batch", "seq", "act_embed")


def _logits(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = x @ params["unembed"]
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return shard_activation(logits, "batch", "seq", "act_vocab")


def lm_forward(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jnp.ndarray,           # [B, S]
    prefix_embeds: Optional[jnp.ndarray] = None,
    *,
    backend: str = "auto",
    remat_policy: Optional[str] = "nothing",
    scan_unroll: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training/scoring forward.  Returns (logits [B,S',V], moe_aux)."""
    pattern = cfg.mixer_pattern
    x = _embed_tokens(params, cfg, tokens, prefix_embeds)
    S = x.shape[1]
    positions = jnp.arange(S)

    def group_fn(x, group_params):
        aux = jnp.zeros((), jnp.float32)
        for i, mixer in enumerate(pattern):
            x, a = apply_block(
                group_params[i], cfg, mixer, x, positions, backend=backend
            )
            aux += a
        return x, aux

    body = _maybe_remat(group_fn, remat_policy)
    x, auxs = jax.lax.scan(
        lambda c, xs: body(c, xs), x, tuple(params["blocks"]),
        unroll=scan_unroll,
    )
    aux = jnp.sum(auxs)
    for i, p in enumerate(params["tail"]):
        x, a = apply_block(
            p, cfg, pattern[i % len(pattern)], x, positions, backend=backend
        )
        aux += a
    x = common.apply_norm(cfg.norm_type, params["final_norm"], x)
    return _logits(params, cfg, x), aux


def _maybe_remat(fn, policy: Optional[str]):
    if policy is None or policy == "none":
        return fn
    policies = {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_saveable,
        "dots_with_no_batch_dims": (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        ),
    }
    return jax.checkpoint(fn, policy=policies[policy], prevent_cse=False)


MOE_AUX_WEIGHT = 0.01


def lm_loss(
    params: PyTree,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    *,
    backend: str = "auto",
    remat_policy: Optional[str] = "nothing",
    compute_dtype=None,
    scan_unroll: int = 1,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """batch: tokens [B,S], labels [B,S], optional mask, prefix_embeds."""
    if compute_dtype is not None:
        params = jax.tree_util.tree_map(
            lambda p: p.astype(compute_dtype)
            if p.dtype in (jnp.float32, jnp.bfloat16) else p,
            params,
        )
        import os

        if os.environ.get("REPRO_CAST_BARRIER", "0") == "1":
            # Pin the fp32->bf16 master-weight cast *before* any FSDP
            # all-gather: without the barrier XLA may reorder to
            # gather-then-convert, doubling weight bytes on the ICI.
            params = jax.lax.optimization_barrier(params)
    prefix = batch.get("prefix_embeds")
    logits, aux = lm_forward(
        params, cfg, batch["tokens"], prefix,
        backend=backend, remat_policy=remat_policy, scan_unroll=scan_unroll,
    )
    if prefix is not None:  # loss only over the token positions
        logits = logits[:, prefix.shape[1]:]
    xent = common.softmax_xent(logits, batch["labels"], batch.get("mask"))
    loss = xent + MOE_AUX_WEIGHT * aux
    return loss, {"xent": xent, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Prefill + decode
# ---------------------------------------------------------------------------

def init_decode_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype
) -> Dict[str, PyTree]:
    n_groups, n_tail = cfg.n_groups_and_tail()
    pattern = cfg.mixer_pattern

    def stacked_cache(mixer):
        one = init_block_cache(cfg, mixer, batch, max_len, dtype)
        return jax.tree_util.tree_map(
            lambda c: jnp.broadcast_to(c, (n_groups,) + c.shape), one
        )

    return {
        "blocks": [stacked_cache(m) for m in pattern],
        "tail": [
            init_block_cache(cfg, pattern[i % len(pattern)], batch, max_len, dtype)
            for i in range(n_tail)
        ],
        "pos": jnp.zeros((), jnp.int32),
    }


def lm_prefill(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    cache: Dict[str, PyTree],
    prefix_embeds: Optional[jnp.ndarray] = None,
    *,
    backend: str = "auto",
    scan_unroll: int = 1,
) -> Tuple[jnp.ndarray, Dict[str, PyTree]]:
    """Process the prompt, fill caches.  Returns (last-token logits, cache)."""
    pattern = cfg.mixer_pattern
    x = _embed_tokens(params, cfg, tokens, prefix_embeds)
    S = x.shape[1]
    positions = jnp.arange(S)

    def group_fn(x, group_params):
        caches = []
        for i, mixer in enumerate(pattern):
            x, c, _ = prefill_block(
                group_params[i], cfg, mixer, x, positions,
                _zero_block_cache_like(cfg, pattern[i], x.shape[0], cache, i),
                backend=backend,
            )
            caches.append(c)
        return x, tuple(caches)

    # scan writes one cache slice per group
    x, caches = jax.lax.scan(group_fn, x, tuple(params["blocks"]),
                             unroll=scan_unroll)
    new_cache = {"blocks": list(caches), "tail": [], "pos": jnp.asarray(S, jnp.int32)}
    for i, p in enumerate(params["tail"]):
        x, c, _ = prefill_block(
            p, cfg, pattern[i % len(pattern)], x, positions,
            jax.tree_util.tree_map(jnp.zeros_like, cache["tail"][i]),
            backend=backend,
        )
        new_cache["tail"].append(c)
    x = common.apply_norm(cfg.norm_type, params["final_norm"], x)
    logits = _logits(params, cfg, x[:, -1:, :])
    # pad prefill caches up to the allocated cache length
    new_cache = _merge_prefill_cache(cache, new_cache)
    return logits, new_cache


def _zero_block_cache_like(cfg, mixer, batch, cache, pos_idx):
    """An all-zero single-layer cache with the allocated shapes."""
    tpl = jax.tree_util.tree_map(lambda c: c[0], cache["blocks"][pos_idx])
    return jax.tree_util.tree_map(jnp.zeros_like, tpl)


def _merge_prefill_cache(alloc: PyTree, fresh: PyTree) -> PyTree:
    """Pad prefill-produced KV tensors into the allocated max_len buffers."""

    def merge(a, f):
        if a.shape == f.shape:
            return f
        pad = [(0, sa - sf) for sa, sf in zip(a.shape, f.shape)]
        return jnp.pad(f, pad)

    out = {"pos": fresh["pos"], "blocks": [], "tail": []}
    for a, f in zip(alloc["blocks"], fresh["blocks"]):
        out["blocks"].append(jax.tree_util.tree_map(merge, a, f))
    for a, f in zip(alloc["tail"], fresh["tail"]):
        out["tail"].append(jax.tree_util.tree_map(merge, a, f))
    return out


def lm_decode_step(
    params: PyTree,
    cfg: ModelConfig,
    cache: Dict[str, PyTree],
    tokens: jnp.ndarray,  # [B, 1]
    *,
    backend: str = "auto",
    scan_unroll: int = 1,
) -> Tuple[jnp.ndarray, Dict[str, PyTree]]:
    """One decode step.  Returns (logits [B,1,V], updated cache)."""
    pattern = cfg.mixer_pattern
    pos = cache["pos"]
    x = _embed_tokens(params, cfg, tokens)

    def group_fn(x, xs):
        group_params, group_cache = xs
        new_caches = []
        for i, mixer in enumerate(pattern):
            x, c = decode_block(
                group_params[i], cfg, mixer, x, pos, group_cache[i],
                backend=backend,
            )
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_block_caches = jax.lax.scan(
        group_fn, x, (tuple(params["blocks"]), tuple(cache["blocks"])),
        unroll=scan_unroll,
    )
    new_cache = {
        "blocks": list(new_block_caches),
        "tail": [],
        "pos": pos + 1,
    }
    for i, p in enumerate(params["tail"]):
        x, c = decode_block(
            p, cfg, pattern[i % len(pattern)], x, pos, cache["tail"][i],
            backend=backend,
        )
        new_cache["tail"].append(c)
    x = common.apply_norm(cfg.norm_type, params["final_norm"], x)
    return _logits(params, cfg, x), new_cache
