"""Shared model building blocks: norms, RoPE, initializers, dtype policy.

All models are functional: parameters are plain nested dicts of jnp arrays
(stacked along a leading "group" axis for scan-over-layers), so the same
pytree paths drive initialization, sharding rules, checkpointing and the
optimizer.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    param_dtype: jnp.dtype = jnp.float32   # master weights
    compute_dtype: jnp.dtype = jnp.bfloat16

    def cast(self, x):
        return x.astype(self.compute_dtype)


FP32 = DTypePolicy(jnp.float32, jnp.float32)
MIXED = DTypePolicy(jnp.float32, jnp.bfloat16)
SERVE_BF16 = DTypePolicy(jnp.bfloat16, jnp.bfloat16)


# ---------------------------------------------------------------------------
# Initializers (operate on PRNG key streams; shapes may be stacked)
# ---------------------------------------------------------------------------

def dense_init(key, shape: Tuple[int, ...], dtype, in_axis: int = -2) -> jnp.ndarray:
    """Truncated-normal fan-in init (MaxText-style 1/sqrt(fan_in))."""
    fan_in = shape[in_axis]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def embed_init(key, shape, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype) -> jnp.ndarray:
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype) -> jnp.ndarray:
    return jnp.ones(shape, dtype)


class KeyGen:
    """Sequential PRNG key dispenser for nested init code."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    # gemma-style (1 + scale) parameterization: zero-init'd scale is identity
    return (x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(p: Dict[str, jnp.ndarray], x: jnp.ndarray, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(dt)


def norm_init(norm_type: str, d: int, dtype) -> PyTree:
    if norm_type == "rmsnorm":
        return jnp.zeros((d,), dtype)  # (1 + scale) parameterization
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def apply_norm(norm_type: str, p: PyTree, x: jnp.ndarray) -> jnp.ndarray:
    if norm_type == "rmsnorm":
        return rms_norm(p, x)
    return layer_norm(p, x)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions [*, S] -> (sin, cos) [*, S, head_dim/2]."""
    freq = 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x [B, S, H, D]; sin/cos [B, S, D/2] or [S, D/2]."""
    if sin.ndim == 2:
        sin = sin[None]
        cos = cos[None]
    sin = sin[:, :, None, :]  # [B, S, 1, D/2]
    cos = cos[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_xent(
    logits: jnp.ndarray,       # [B, S, V]
    labels: jnp.ndarray,       # [B, S] int32
    mask: Optional[jnp.ndarray] = None,  # [B, S]
) -> jnp.ndarray:
    """Sharding-friendly cross-entropy.

    The gold-logit gather is computed as a one-hot contraction rather than
    take_along_axis: a gather over the (vocab-sharded) class dim forces
    GSPMD to all-gather the full [B,S,V] logits (measured: ~16 GB of
    collectives + ~80 GB of fp32 HBM traffic per step on 150k-vocab
    models), whereas the one-hot einsum keeps every term vocab-local and
    reduces a [B,S] partial across shards.  XLA fuses the one-hot (an iota
    compare) into the contraction — nothing V-sized materializes.
    """
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    gold = jnp.einsum("bsv,bsv->bs", logits32, onehot)
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
