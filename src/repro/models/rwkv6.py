"""RWKV-6 (Finch) block: time-mix (WKV recurrence) + channel-mix.

Follows arXiv:2404.05892 with static token-shift interpolation weights for
r/k/v/g and the data-dependent decay w produced by a low-rank (LoRA-style)
projection — the signature Finch feature.  The WKV recurrence runs through
the Pallas kernel on TPU.  Attention-free: decode state is O(1) per layer
(two shift vectors + the per-head K x V state), which is what qualifies this
family for the 500k-token long-context cell.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.rwkv6 import ops as wkv_ops
from repro.models import common

PyTree = Any

DECAY_LORA = 64


def init_rwkv_time_mix(keygen, cfg: ModelConfig, dtype) -> PyTree:
    d = cfg.d_model
    K = cfg.rwkv_head_dim
    H = d // K
    lora = min(DECAY_LORA, d // 2)
    return {
        # token-shift interpolation weights (static mu per channel)
        "mu_r": jnp.full((d,), 0.5, dtype),
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype),
        "mu_w": jnp.full((d,), 0.5, dtype),
        "mu_g": jnp.full((d,), 0.5, dtype),
        "w_r": common.dense_init(keygen(), (d, d), dtype),
        "w_k": common.dense_init(keygen(), (d, d), dtype),
        "w_v": common.dense_init(keygen(), (d, d), dtype),
        "w_g": common.dense_init(keygen(), (d, d), dtype),
        "w_o": common.dense_init(keygen(), (d, d), dtype),
        # data-dependent decay: w_t = exp(-exp(w0 + tanh(x W_a) W_b))
        "decay_base": jnp.asarray(jnp.linspace(-6.0, -0.5, d), dtype),
        "decay_a": common.dense_init(keygen(), (d, lora), dtype),
        "decay_b": (common.dense_init(keygen(), (lora, d), dtype) * 0.1),
        "bonus": common.dense_init(keygen(), (H, K), dtype),
        # per-head group norm on the WKV output
        "out_norm": jnp.zeros((d,), dtype),
    }


def init_rwkv_channel_mix(keygen, cfg: ModelConfig, dtype) -> PyTree:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "w_k": common.dense_init(keygen(), (d, ff), dtype),
        "w_v": common.dense_init(keygen(), (ff, d), dtype),
        "w_r": common.dense_init(keygen(), (d, d), dtype),
    }


def _shift(x: jnp.ndarray, prev: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Token shift: x_{t-1} (zeros / carried state at t=0).  x [B,S,d]."""
    if x.shape[1] == 1 and prev is not None:
        return prev[:, None, :]
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev)
    return shifted


def _mix(x, x_prev, mu):
    return x + (x_prev - x) * mu


def _decay(p, xw):
    lo = jnp.tanh(xw @ p["decay_a"]) @ p["decay_b"]
    log_w = -jnp.exp(
        jnp.clip(p["decay_base"].astype(jnp.float32) + lo.astype(jnp.float32),
                 -10.0, 2.0)
    )
    return jnp.exp(log_w)  # in (0, 1)


def _group_norm(scale, y, H):
    """Per-head normalization of the WKV output.  y [B,S,d]."""
    B, S, d = y.shape
    yh = y.reshape(B, S, H, d // H).astype(jnp.float32)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 1e-5)
    return (yh.reshape(B, S, d) * (1.0 + scale.astype(jnp.float32))).astype(y.dtype)


def time_mix(
    p: PyTree,
    cfg: ModelConfig,
    x: jnp.ndarray,  # [B, S, d]
    shift_state: Optional[jnp.ndarray] = None,  # [B, d]
    wkv_state: Optional[jnp.ndarray] = None,    # [B, H, K, V]
    *,
    backend: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (out, new_shift_state, new_wkv_state)."""
    B, S, d = x.shape
    K = cfg.rwkv_head_dim
    H = d // K
    x_prev = _shift(x, shift_state)
    r = _mix(x, x_prev, p["mu_r"]) @ p["w_r"]
    k = _mix(x, x_prev, p["mu_k"]) @ p["w_k"]
    v = _mix(x, x_prev, p["mu_v"]) @ p["w_v"]
    g = jax.nn.silu(_mix(x, x_prev, p["mu_g"]) @ p["w_g"])
    w = _decay(p, _mix(x, x_prev, p["mu_w"])).astype(x.dtype)

    rh = r.reshape(B, S, H, K)
    kh = k.reshape(B, S, H, K)
    vh = v.reshape(B, S, H, K)
    wh = w.reshape(B, S, H, K)
    y, new_state = wkv_ops.wkv(rh, kh, vh, wh, p["bonus"], wkv_state,
                               backend=backend)
    y = _group_norm(p["out_norm"], y.reshape(B, S, d), H)
    out = (y * g) @ p["w_o"]
    return out, x[:, -1, :], new_state


def channel_mix(
    p: PyTree,
    cfg: ModelConfig,
    x: jnp.ndarray,
    shift_state: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x_prev = _shift(x, shift_state)
    k = _mix(x, x_prev, p["mu_k"]) @ p["w_k"]
    v = jnp.square(jax.nn.relu(k)) @ p["w_v"]
    r = jax.nn.sigmoid(_mix(x, x_prev, p["mu_r"]) @ p["w_r"])
    return r * v, x[:, -1, :]


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    K = cfg.rwkv_head_dim
    H = d // K
    return {
        "tm_shift": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, H, K, K), jnp.float32),
        "cm_shift": jnp.zeros((batch, d), dtype),
    }
