"""Feed-forward blocks: SwiGLU / GeGLU / vanilla GELU."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common

PyTree = Any


def init_mlp(keygen, cfg: ModelConfig, dtype) -> PyTree:
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "w_gate": common.dense_init(keygen(), (d, ff), dtype),
            "w_up": common.dense_init(keygen(), (d, ff), dtype),
            "w_down": common.dense_init(keygen(), (ff, d), dtype),
        }
    return {
        "w_up": common.dense_init(keygen(), (d, ff), dtype),
        "w_down": common.dense_init(keygen(), (ff, d), dtype),
    }


def mlp_block(p: PyTree, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.mlp_type == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"], approximate=True)
    return h @ p["w_down"]
