"""Griffin/RecurrentGemma recurrent block (RG-LRU + temporal conv).

Block structure (arXiv:2402.19427):
    x -> [linear -> GeLU]                        (gate branch)
      -> [linear -> causal conv1d(w=4) -> RG-LRU] (recurrent branch)
    merge: recurrent * gate -> linear -> out

RG-LRU gates use block-diagonal linears (n_blocks = n_heads) as in the
reference implementation; the diagonal recurrence itself runs through the
Pallas scan kernel (repro/kernels/rglru) on TPU.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.rglru import ops as lru_ops
from repro.models import common

PyTree = Any

RGLRU_C = 8.0  # Griffin's fixed recurrence-sharpness constant


def init_rglru(keygen, cfg: ModelConfig, dtype) -> PyTree:
    d = cfg.d_model
    w = cfg.rnn_width or d
    nb = cfg.n_heads
    bw = w // nb
    return {
        "w_in_rec": common.dense_init(keygen(), (d, w), dtype),
        "w_in_gate": common.dense_init(keygen(), (d, w), dtype),
        "conv_w": common.dense_init(keygen(), (cfg.conv_width, w), dtype, in_axis=0),
        "conv_b": jnp.zeros((w,), dtype),
        # block-diagonal gate projections [nb, bw, bw]
        "gate_a": common.dense_init(keygen(), (nb, bw, bw), dtype, in_axis=1),
        "gate_a_b": jnp.zeros((nb, bw), dtype),
        "gate_x": common.dense_init(keygen(), (nb, bw, bw), dtype, in_axis=1),
        "gate_x_b": jnp.zeros((nb, bw), dtype),
        # Lambda parameterized so a = exp(-c*softplus(lam)*r) starts ~0.9..0.999
        "lam": jnp.asarray(
            jnp.linspace(-2.0, 1.0, w), dtype
        ),
        "w_out": common.dense_init(keygen(), (w, d), dtype),
    }


def _block_diag(p_w, p_b, u):
    """u [..., w] -> block-diagonal linear with blocks [nb, bw, bw]."""
    nb, bw, _ = p_w.shape
    shape = u.shape
    ub = u.reshape(*shape[:-1], nb, bw)
    out = jnp.einsum("...nb,nbc->...nc", ub, p_w) + p_b
    return out.reshape(shape)


def _rglru_gates(p, u):
    """-> (a, gated_input) for the diagonal recurrence."""
    r = jax.nn.sigmoid(_block_diag(p["gate_a"], p["gate_a_b"], u))
    i = jax.nn.sigmoid(_block_diag(p["gate_x"], p["gate_x_b"], u))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * (
        r.astype(jnp.float32)
    )
    a = jnp.exp(log_a)
    # sqrt(1-a^2) input normalization keeps the state scale-invariant
    b = jnp.sqrt(jnp.clip(1.0 - a**2, 1e-9)) * (
        i.astype(jnp.float32) * u.astype(jnp.float32)
    )
    return a.astype(u.dtype), b.astype(u.dtype)


def _causal_conv(p, u, conv_state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv, width W.  u [B,S,w].

    conv_state [B, W-1, w] carries the trailing inputs for decode."""
    W = p["conv_w"].shape[0]
    if conv_state is not None:
        u_pad = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
    else:
        u_pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        u_pad[:, i : i + u.shape[1], :] * p["conv_w"][W - 1 - i]
        for i in range(W)
    )
    return out + p["conv_b"], u_pad[:, -(W - 1):, :]


def linear_scan_dispatch(a, b, backend: str = "auto"):
    """Expose the scan with (h, h_final) for prefill cache capture."""
    return lru_ops.linear_scan(a, b, backend=backend)


def rglru_block(
    p: PyTree,
    cfg: ModelConfig,
    x: jnp.ndarray,  # [B, S, d]
    *,
    backend: str = "auto",
) -> jnp.ndarray:
    """Full-sequence recurrent block (train / prefill)."""
    gate = jax.nn.gelu(x @ p["w_in_gate"], approximate=True)
    u = x @ p["w_in_rec"]
    u, _ = _causal_conv(p, u)
    a, b = _rglru_gates(p, u)
    h, _ = lru_ops.linear_scan(a, b, backend=backend)
    return (h * gate) @ p["w_out"]


def init_rglru_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    w = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def decode_rglru_block(
    p: PyTree,
    cfg: ModelConfig,
    x: jnp.ndarray,  # [B, 1, d]
    state: Dict[str, jnp.ndarray],
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    gate = jax.nn.gelu(x @ p["w_in_gate"], approximate=True)
    u = x @ p["w_in_rec"]
    u, conv_state = _causal_conv(p, u, state["conv"])
    a, b = _rglru_gates(p, u)
    h = a[:, 0].astype(jnp.float32) * state["h"] + b[:, 0].astype(jnp.float32)
    out = (h[:, None].astype(x.dtype) * gate) @ p["w_out"]
    return out, {"h": h, "conv": conv_state}
