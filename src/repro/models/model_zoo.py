"""Uniform model API over decoder-only and encoder-decoder families.

``build_model(cfg)`` returns a :class:`Model` with:
  init(key)                      -> params
  loss(params, batch, **kw)      -> (loss, metrics)         [train_step]
  prefill(params, batch, cache)  -> (logits, cache)         [serve prefill]
  decode_step(params, cache, tokens, memory=None) -> (logits, cache)
  init_cache(batch, max_len, dtype)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer

PyTree = Any


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable[..., PyTree]
    loss: Callable[..., Any]
    init_cache: Callable[..., PyTree]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]


def build_model(cfg: ModelConfig) -> Model:
    if cfg.is_encoder_decoder:
        return _build_encdec(cfg)
    return _build_lm(cfg)


def _build_lm(cfg: ModelConfig) -> Model:
    def init(key, dtype=jnp.float32):
        return transformer.init_lm_params(cfg, key, dtype)

    def loss(params, batch, **kw):
        return transformer.lm_loss(params, cfg, batch, **kw)

    def init_cache(batch, max_len, dtype=jnp.bfloat16):
        return transformer.init_decode_cache(cfg, batch, max_len, dtype)

    def prefill(params, batch, cache, **kw):
        return transformer.lm_prefill(
            params, cfg, batch["tokens"], cache,
            batch.get("prefix_embeds"), **kw,
        )

    def decode_step(params, cache, tokens, memory=None, **kw):
        return transformer.lm_decode_step(params, cfg, cache, tokens, **kw)

    return Model(cfg, init, loss, init_cache, prefill, decode_step)


def _build_encdec(cfg: ModelConfig) -> Model:
    def init(key, dtype=jnp.float32):
        return encdec.init_encdec_params(cfg, key, dtype)

    def loss(params, batch, **kw):
        return encdec.encdec_loss(params, cfg, batch, **kw)

    def init_cache(batch, max_len, dtype=jnp.bfloat16):
        return encdec.init_encdec_cache(cfg, batch, max_len, dtype)

    def prefill(params, batch, cache, **kw):
        # encoder pass = the "prefill" for enc-dec serving
        memory = encdec.encode(params, cfg, batch["frames"], **kw)
        return memory, cache

    def decode_step(params, cache, tokens, memory=None, **kw):
        return encdec.encdec_decode_step(
            params, cfg, cache, tokens, memory, **kw
        )

    return Model(cfg, init, loss, init_cache, prefill, decode_step)
