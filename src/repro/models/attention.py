"""Attention layer: GQA/MQA with RoPE, sliding window, softcap, QK-norm.

Supports three execution modes driven by the same parameters:
  * train/prefill: full-sequence self-attention (flash kernel on TPU),
  * decode: single-token query against a KV cache (full or ring-buffer
    sliding window; the ring exploits softmax permutation-invariance so no
    unrotation is needed),
  * cross-attention (encoder-decoder): keys/values from encoder memory.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention import ops as fa_ops
from repro.models import common

PyTree = Any


def init_attention(keygen, cfg: ModelConfig, dtype, cross: bool = False) -> PyTree:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cross:
        hkv = hq  # whisper cross-attention is full MHA
    p = {
        "wq": common.dense_init(keygen(), (d, hq, hd), dtype, in_axis=0),
        "wk": common.dense_init(keygen(), (d, hkv, hd), dtype, in_axis=0),
        "wv": common.dense_init(keygen(), (d, hkv, hd), dtype, in_axis=0),
        "wo": common.dense_init(keygen(), (hq, hd, d), dtype, in_axis=0),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), dtype)
        p["bk"] = jnp.zeros((hkv, hd), dtype)
        p["bv"] = jnp.zeros((hkv, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(p: PyTree, cfg: ModelConfig, x, kv_x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if "q_norm" in p:
        q = common.rms_norm(p["q_norm"], q)
        k = common.rms_norm(p["k_norm"], k)
    return q, k, v


def attention_block(
    p: PyTree,
    cfg: ModelConfig,
    x: jnp.ndarray,              # [B, S, d]
    positions: jnp.ndarray,      # [S] or [B, S]
    *,
    local: bool = False,
    causal: bool = True,
    memory: Optional[jnp.ndarray] = None,  # cross-attn memory [B, S_m, d]
    backend: str = "auto",
) -> jnp.ndarray:
    """Full-sequence attention (train / prefill)."""
    kv_x = memory if memory is not None else x
    q, k, v = _project_qkv(p, cfg, x, kv_x)
    if memory is None:  # RoPE only for self-attention
        if cfg.use_rope:
            sin, cos = common.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
            q = common.apply_rope(q, sin, cos)
            k = common.apply_rope(k, sin, cos)
        window = cfg.window if local else None
    else:
        causal, window = False, None
    out = fa_ops.attention(
        q, k, v,
        causal=causal,
        window=window,
        softcap=cfg.attn_softcap,
        backend=backend,
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# Decode path (single token + cache)
# ---------------------------------------------------------------------------

def init_kv_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype, local: bool = False
) -> Dict[str, jnp.ndarray]:
    length = min(cfg.window, max_len) if (local and cfg.window) else max_len
    return {
        "k": jnp.zeros((batch, length, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, length, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


def decode_attention_block(
    p: PyTree,
    cfg: ModelConfig,
    x: jnp.ndarray,          # [B, 1, d]
    pos: jnp.ndarray,        # scalar int32 — current position
    cache: Dict[str, jnp.ndarray],
    *,
    local: bool = False,
    memory: Optional[jnp.ndarray] = None,
    backend: str = "auto",
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decode step.  Returns (out [B,1,d], updated cache)."""
    if memory is not None:
        # cross-attention: no cache mutation (memory is fixed)
        q, k, v = _project_qkv(p, cfg, x, memory)
        out = fa_ops.attention(q, k, v, causal=False, backend="reference")
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache

    q, k_new, v_new = _project_qkv(p, cfg, x, x)
    if cfg.use_rope:
        sin, cos = common.rope_angles(pos[None].astype(jnp.int32), cfg.head_dim,
                                      cfg.rope_theta)
        q = common.apply_rope(q, sin, cos)
        k_new = common.apply_rope(k_new, sin, cos)

    length = cache["k"].shape[1]
    slot = jnp.where(
        jnp.logical_and(local, cfg.window is not None), pos % length, pos
    ) if local else pos
    slot = slot % length  # ring semantics also guard the full cache
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)

    # number of valid cache entries
    kv_len = jnp.minimum(pos + 1, length)
    # ring buffers hold an unordered window; softmax is permutation-invariant
    # so a validity mask is all we need (RoPE was applied before caching).
    out = fa_ops.attention(
        q, k_cache, v_cache,
        causal=False,
        kv_len=kv_len[None] if kv_len.ndim == 0 else kv_len,
        softcap=cfg.attn_softcap,
        backend="reference",
    )
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, {"k": k_cache, "v": v_cache}
