"""Distributed-optimization collectives: int8-compressed gradient sync.

For the slow inter-pod DCN axis, fp32 gradient all-reduce dominates step
time at multi-pod scale.  ``compressed_psum_int8`` implements the standard
1-byte compression scheme with per-row scales and *error feedback* support:
quantize -> all_gather(int8 + scales) -> dequantize-sum locally.  Wire bytes
drop ~4x vs an fp32 ring all-reduce of the same tensor; the quantization
residual can be carried to the next step by the caller (error feedback
keeps SGD convergence unbiased — Karimireddy et al., arXiv:1901.09847).

These run under ``shard_map`` along the named axis; correctness vs plain
psum is asserted in tests within the quantization tolerance.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row symmetric int8 quantization.  x [R, C] -> (q int8, scale [R])."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0]


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale[..., None]


def compressed_psum_int8(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """psum along ``axis_name`` with int8 on the wire.

    Must be called inside shard_map/pmap with ``axis_name`` bound.  The
    result equals psum(x) up to per-row quantization error (<= absmax/127
    per element per participant).
    """
    orig_shape = x.shape
    flat = x.reshape(-1)
    # pad to a multiple of 256 and view as rows for per-row scales
    row = 256
    pad = (-flat.shape[0]) % row
    flat = jnp.pad(flat, (0, pad))
    rows = flat.reshape(-1, row)
    q, scale = quantize_int8(rows)
    # all_gather the compressed payload (int8 + fp32 scales per row)
    q_all = jax.lax.all_gather(q, axis_name)          # [P, R, row] int8
    s_all = jax.lax.all_gather(scale, axis_name)      # [P, R]
    total = jnp.sum(dequantize_int8(q_all, s_all), axis=0)
    out = total.reshape(-1)[: int(np.prod(orig_shape))].reshape(orig_shape)
    return out


def compressed_grad_sync(grads, axis_name: str, residual=None):
    """Tree-wide compressed psum with error feedback.

    Returns (synced_grads, new_residual): callers carry ``residual`` into
    the next step and add it to the local grads before syncing.
    """
    if residual is not None:
        grads = jax.tree_util.tree_map(jnp.add, grads, residual)

    def sync_one(g):
        approx = compressed_psum_int8(g, axis_name)
        exact_local_contrib = g  # local part of the true sum
        return approx, exact_local_contrib

    synced = jax.tree_util.tree_map(
        lambda g: compressed_psum_int8(g, axis_name), grads
    )
    # residual: what compression lost of *this* worker's contribution
    def res_one(g):
        q, s = quantize_int8(
            jnp.pad(g.reshape(-1), (0, (-g.size) % 256)).reshape(-1, 256)
        )
        deq = dequantize_int8(q, s).reshape(-1)[: g.size].reshape(g.shape)
        return g - deq

    new_residual = jax.tree_util.tree_map(res_one, grads)
    return synced, new_residual


def wire_bytes_fp32_allreduce(n_elements: int, participants: int) -> int:
    """Ring all-reduce: 2 (P-1)/P N * 4 bytes per device."""
    return int(2 * (participants - 1) / participants * n_elements * 4)


def wire_bytes_int8_allgather(n_elements: int, participants: int) -> int:
    """all_gather of int8 payload + fp32 per-256 scales."""
    payload = n_elements + 4 * (n_elements // 256 + 1)
    return int((participants - 1) / participants * payload * participants)
