"""GPipe-style pipeline parallelism over a mesh axis (the DCN "pod" axis).

``pipeline_apply`` runs a layer-stage pipeline under ``shard_map``: each
device along ``axis`` owns one stage's parameters; microbatches stream
through stages via ``lax.ppermute`` (neighbor shifts over DCN).  The
schedule is the classic GPipe fill-drain loop expressed as a single
``lax.scan`` of length (n_micro + n_stages - 1): at tick t, stage s
processes microbatch (t - s) — a bubble fraction of
(n_stages-1)/(n_micro+n_stages-1).

This complements FSDP×TP within a pod: inter-pod traffic becomes one
activation hand-off per microbatch per tick (point-to-point, DCN-friendly)
instead of gradient all-reduce over the full model.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any


def pipeline_apply(
    stage_fn: Callable[[PyTree, jnp.ndarray], jnp.ndarray],
    stage_params: PyTree,      # leaves stacked [n_stages, ...]
    x: jnp.ndarray,            # [n_micro, micro_batch, ...]
    mesh: Mesh,
    axis: str = "pod",
) -> jnp.ndarray:
    """Run x through n_stages sequential stages, pipelined along ``axis``.

    Returns [n_micro, micro_batch, ...] — the output of the final stage.
    Semantics match ``fold_left(stage_fn, stages)`` applied per microbatch.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    n_ticks = n_micro + n_stages - 1

    def per_stage(params_local, x_local):
        # params_local: this stage's params ([1, ...] leaves); x_local:
        # microbatches only valid on stage 0 ([n_micro, mb, ...]).
        params_local = jax.tree_util.tree_map(
            lambda p: p[0], params_local
        )
        stage_id = jax.lax.axis_index(axis)
        mb_shape = x_local.shape[1:]

        def tick(carry, t):
            buf, outputs = carry
            # stage 0 injects microbatch t (or zeros past the end)
            inject = jnp.where(
                t < n_micro,
                x_local[jnp.minimum(t, n_micro - 1)],
                jnp.zeros(mb_shape, x_local.dtype),
            )
            state_in = jnp.where(stage_id == 0, inject, buf)
            state_out = stage_fn(params_local, state_in)
            # last stage emits microbatch (t - n_stages + 1)
            out_idx = t - (n_stages - 1)
            outputs = jax.lax.cond(
                out_idx >= 0,
                lambda o: o.at[jnp.maximum(out_idx, 0)].set(state_out),
                lambda o: o,
                outputs,
            )
            # shift activations to the next stage (ring permute; the wrap
            # edge s-1 -> 0 carries junk that stage 0 overwrites next tick)
            buf = jax.lax.ppermute(
                state_out, axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)],
            )
            return (buf, outputs), None

        buf0 = jnp.zeros(mb_shape, x_local.dtype)
        outs0 = jnp.zeros((n_micro,) + mb_shape, x_local.dtype)
        (buf, outputs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(n_ticks)
        )
        # outputs are only valid on the LAST stage; broadcast them back so
        # every shard returns the same (replicated) result (masked psum —
        # ppermute cannot express one-to-all).
        if n_stages > 1:
            mask = (stage_id == n_stages - 1).astype(outputs.dtype)
            outputs = jax.lax.psum(outputs * mask, axis)
        return outputs

    other_axes = [a for a in mesh.axis_names if a != axis]
    param_spec = jax.tree_util.tree_map(
        lambda _: P(axis), stage_params
    )
    return shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(P(axis), P()),      # params sharded by stage; x replicated
        out_specs=P(),                 # replicated final outputs
        check_rep=False,
    )(stage_params, x)


def pipeline_reference(
    stage_fn: Callable[[PyTree, jnp.ndarray], jnp.ndarray],
    stage_params: PyTree,
    x: jnp.ndarray,
) -> jnp.ndarray:
    """Sequential oracle: fold each microbatch through all stages."""
    n_stages = jax.tree_util.tree_leaves(stage_params)[0].shape[0]

    def one_micro(mb):
        h = mb
        for s in range(n_stages):
            params_s = jax.tree_util.tree_map(lambda p: p[s], stage_params)
            h = stage_fn(params_s, h)
        return h

    return jax.vmap(one_micro)(x)


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
