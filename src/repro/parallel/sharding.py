"""Logical-axis sharding rules -> NamedSharding / PartitionSpec.

MaxText-style indirection: every parameter leaf and activation carries
*logical* axis names; a rule table maps logical names to mesh axes; a
divisibility-aware resolver turns them into PartitionSpecs against the
active mesh (axes that do not divide evenly fall back to replication, which
is what keeps one rule table valid across all 10 architectures — e.g. MQA's
single KV head simply cannot shard 16-way and silently replicates).

The default strategy is FSDP("data") x TP("model") with the multi-pod
"pod" axis doing data parallelism; the rule table is a plain dict so the
perf-iteration loop can swap strategies without touching model code.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any
MeshAxes = Union[None, str, Tuple[str, ...]]


# ---------------------------------------------------------------------------
# Rule tables (logical axis -> mesh axes).  These are *strategies*: the
# dry-run/perf loop selects one by name; custom dicts may override entries.
# ---------------------------------------------------------------------------

def _rules_fsdp_tp() -> Dict[str, MeshAxes]:
    """Default: FSDP(data) x TP(model), pod = DP, Megatron-style sequence
    sharding of the residual stream (saved activations live seq-sharded on
    the model axis — the memory lever that makes 80-layer train shapes fit
    v5e HBM)."""
    return {
        # activations
        "batch": ("pod", "data"),
        "seq": "model",           # residual-stream sequence sharding (SP)
        "act_embed": None,
        "act_heads": "model",
        "act_ff": "model",
        "act_vocab": "model",
        # decode caches
        "seq_cache": "model",
        # weights
        "embed": "data",          # FSDP axis for the d_model dim of weights
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ff": "model",
        "experts": "model",
        "rnn": "model",
        "conv": None,
        "blocks": None,           # per-head block-diagonal gates (rglru)
        "lora": None,
        "layers": None,           # stacked scan axis
    }


def _rules_fsdp_tp_noseq() -> Dict[str, MeshAxes]:
    # ablation: no sequence sharding of the residual stream
    r = _rules_fsdp_tp()
    r["seq"] = None
    return r


def _rules_tp_only() -> Dict[str, MeshAxes]:
    r = _rules_fsdp_tp()
    r["embed"] = None
    return r


def _rules_fsdp_tp_pod_fsdp() -> Dict[str, MeshAxes]:
    # beyond-paper variant: extend the FSDP axis across pods (DCN) too
    r = _rules_fsdp_tp()
    r["embed"] = ("pod", "data")
    return r


def _rules_serve_2d() -> Dict[str, MeshAxes]:
    """Decode-optimized: weight-stationary 2D TP.

    FSDP is an anti-pattern for single-token decode — the per-step weight
    all-gather moves the entire (bf16) model over ICI for one token.  Here
    weights stay sharded over BOTH axes (embed dim on "data", heads/ff/vocab
    on "model") and never move; the per-layer collectives become tiny
    activation all-reduces.  The batch is kept OFF the "data" axis so it
    cannot conflict with the weights' embed dim (the conflict is what forced
    GSPMD into weight gathering); the KV cache spreads its sequence axis
    over ("data","model") = 256-way so 32k-token caches fit per chip.
    """
    return {
        "batch": "pod",
        "seq": None,
        "act_embed": None,
        "act_heads": "model",
        "act_ff": "model",
        "act_vocab": "model",
        "seq_cache": ("data", "model"),
        "embed": "data",
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ff": "model",
        "experts": "model",
        "rnn": ("data", "model"),
        "conv": None,
        "blocks": None,
        "lora": None,
        "layers": None,
    }


STRATEGIES = {
    "fsdp_tp": _rules_fsdp_tp,
    "fsdp_tp_noseq": _rules_fsdp_tp_noseq,
    "tp_only": _rules_tp_only,
    "fsdp_tp_pod_fsdp": _rules_fsdp_tp_pod_fsdp,
    "serve_2d": _rules_serve_2d,
}


# ---------------------------------------------------------------------------
# Active sharding context (mesh + rules), used by model code for activation
# constraints without threading mesh handles through every function.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardingContext:
    mesh: Mesh
    rules: Dict[str, MeshAxes]


_CTX = threading.local()


def set_context(ctx: Optional[ShardingContext]) -> None:
    _CTX.value = ctx


def get_context() -> Optional[ShardingContext]:
    return getattr(_CTX, "value", None)


class use_sharding:
    """``with use_sharding(mesh, rules): ...`` — enables activation
    constraints inside model code."""

    def __init__(self, mesh: Mesh, rules: Optional[Dict[str, MeshAxes]] = None,
                 strategy: str = "fsdp_tp"):
        if rules is None:
            rules = STRATEGIES[strategy]()
        self.ctx = ShardingContext(mesh, rules)

    def __enter__(self):
        set_context(self.ctx)
        return self.ctx

    def __exit__(self, *exc):
        set_context(None)
        return False


# ---------------------------------------------------------------------------
# Spec resolution with divisibility fallback
# ---------------------------------------------------------------------------

def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def resolve_spec(
    mesh: Mesh,
    rules: Dict[str, MeshAxes],
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
) -> P:
    """logical axis names + concrete shape -> PartitionSpec.

    Drops mesh axes that don't exist in the mesh or don't divide the dim.
    """
    spec = []
    used: set = set()
    for name, dim in zip(logical, shape):
        axes = rules.get(name) if name else None
        if axes is None:
            spec.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        # keep only axes present in the mesh and not already used
        axes = tuple(a for a in axes if a in mesh.shape and a not in used)
        while axes and dim % _axis_size(mesh, axes) != 0:
            axes = axes[:-1]  # drop trailing axes until it divides
        if not axes:
            spec.append(None)
        else:
            used.update(axes)
            spec.append(axes if len(axes) > 1 else axes[0])
    return P(*spec)


def shard_activation(x: jnp.ndarray, *logical: Optional[str]) -> jnp.ndarray:
    """Annotate an activation with its logical axes (no-op without context)."""
    ctx = get_context()
    if ctx is None:
        return x
    spec = resolve_spec(ctx.mesh, ctx.rules, logical, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec)
    )


# ---------------------------------------------------------------------------
# Parameter sharding: leaf-name -> logical axes
# ---------------------------------------------------------------------------

# Maps the *leaf key name* in the params pytree to logical axes of its
# non-stacked shape.  Stacked variants (scan-over-layers) are detected by
# ndim and get a leading "layers" axis.
PARAM_LOGICAL: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings
    "embed": ("vocab", "embed"),
    "unembed": ("embed", "vocab"),
    "pos_embed": ("seq", "embed"),
    # attention
    "wq": ("embed", "heads", "head_dim"),
    "wk": ("embed", "kv_heads", "head_dim"),
    "wv": ("embed", "kv_heads", "head_dim"),
    "wo": ("heads", "head_dim", "embed"),
    "bq": ("heads", "head_dim"),
    "bk": ("kv_heads", "head_dim"),
    "bv": ("kv_heads", "head_dim"),
    "q_norm": (None,),
    "k_norm": (None,),
    # dense mlp
    "w_gate": ("embed", "ff"),
    "w_up": ("embed", "ff"),
    "w_down": ("ff", "embed"),
    # moe (expert-stacked, detected by ndim)
    "router": ("embed", None),
    # rglru
    "w_in_rec": ("embed", "rnn"),
    "w_in_gate": ("embed", "rnn"),
    "w_out": ("rnn", "embed"),
    "conv_w": ("conv", "rnn"),
    "conv_b": ("rnn",),
    "gate_a": ("blocks", None, None),
    "gate_a_b": ("blocks", None),
    "gate_x": ("blocks", None, None),
    "gate_x_b": ("blocks", None),
    "lam": ("rnn",),
    # rwkv
    "mu_r": (None,), "mu_k": (None,), "mu_v": (None,), "mu_w": (None,),
    "mu_g": (None,),
    "w_r": ("embed", "ff"),
    "w_k": ("embed", "ff"),
    "w_v": ("ff", "embed"),
    "w_g": ("embed", "ff"),
    "decay_base": (None,),
    "decay_a": ("embed", "lora"),
    "decay_b": ("lora", "embed"),
    "bonus": (None, None),
    "out_norm": (None,),
}

# MoE expert weights share leaf names with dense MLP; their base logical
# shapes get an "experts" prefix when a leading expert dim is present.
_MOE_LEAVES = {"w_gate": ("experts", "embed", "ff"),
               "w_up": ("experts", "embed", "ff"),
               "w_down": ("experts", "ff", "embed")}


def logical_for_leaf(name: str, ndim: int) -> Tuple[Optional[str], ...]:
    base = PARAM_LOGICAL.get(name)
    if base is None:
        return (None,) * ndim  # norms, scalars: replicate
    if name in _MOE_LEAVES and ndim >= 3:
        base = _MOE_LEAVES[name]
    if ndim == len(base) + 1:
        return ("layers",) + base
    if ndim == len(base) + 2:  # stacked MoE inside scanned blocks
        return ("layers",) + _MOE_LEAVES.get(name, base)
    if ndim != len(base):
        return (None,) * ndim
    return base


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
    return ""


def param_specs(mesh: Mesh, rules: Dict[str, MeshAxes], params: PyTree) -> PyTree:
    """PartitionSpec pytree for a params (or shapes) pytree."""

    def spec_for(path, leaf):
        name = _leaf_name(path)
        logical = logical_for_leaf(name, len(leaf.shape))
        return resolve_spec(mesh, rules, logical, leaf.shape)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def param_shardings(mesh: Mesh, rules: Dict[str, MeshAxes], params: PyTree):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        param_specs(mesh, rules, params),
        is_leaf=lambda x: isinstance(x, P),
    )


# Decode-cache leaves (see repro/models/*: init_kv_cache / init_*_state).
CACHE_LOGICAL: Dict[str, Tuple[Optional[str], ...]] = {
    "k": ("batch", "seq_cache", "kv_heads", "head_dim"),
    "v": ("batch", "seq_cache", "kv_heads", "head_dim"),
    "h": ("batch", "rnn"),
    "conv": ("batch", None, "rnn"),
    "tm_shift": ("batch", "rnn"),
    "wkv": ("batch", "heads", None, None),
    "cm_shift": ("batch", "rnn"),
    "pos": (),
}


def cache_shardings(mesh: Mesh, rules: Dict[str, MeshAxes], cache: PyTree):
    """NamedShardings for a decode-cache pytree (stacked leading layer dim
    auto-detected)."""

    def spec_for(path, leaf):
        name = _leaf_name(path)
        base = CACHE_LOGICAL.get(name, (None,) * len(leaf.shape))
        if len(leaf.shape) == len(base) + 1:
            base = ("layers",) + base
        spec = resolve_spec(mesh, rules, base[: len(leaf.shape)], leaf.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def batch_specs(mesh: Mesh, rules: Dict[str, MeshAxes], batch: PyTree) -> PyTree:
    """Input batch: [B, S] / [B, S, d] arrays shard batch (+seq if SP)."""

    def spec_for(leaf):
        logical = ("batch", "seq") + (None,) * (len(leaf.shape) - 2)
        return resolve_spec(mesh, rules, logical[: len(leaf.shape)], leaf.shape)

    return jax.tree_util.tree_map(spec_for, batch)
