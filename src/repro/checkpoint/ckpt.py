"""Checkpointing: atomic pytree save/restore with latest-k retention.

Format: one .npz with flattened path-keyed arrays + a JSON sidecar holding
the step and tree structure.  Writes go to a temp dir that is atomically
renamed, so a crash mid-save can never corrupt the latest checkpoint —
restart-from-latest is always safe (the fault-tolerance contract).
An optional background thread makes saves non-blocking (async checkpointing
overlaps the next training steps).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_SEP = "/"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree: PyTree, metadata: Optional[dict] = None):
        # materialize on host *before* handing to the writer thread
        flat = _flatten(tree)
        if self.async_save:
            self.wait()  # one in-flight save at a time
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, metadata or {})
            )
            self._thread.start()
        else:
            self._write(step, flat, metadata or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: Dict[str, np.ndarray], metadata: dict):
        tmp = os.path.join(self.directory, f".tmp-{step}-{os.getpid()}")
        final = os.path.join(self.directory, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "time": time.time(), **metadata}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, template: PyTree, step: Optional[int] = None
    ) -> Tuple[int, PyTree]:
        """Restore into the structure of ``template`` (shapes must match)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        paths_leaves = jax.tree_util.tree_leaves_with_path(template)
        leaves = []
        for p, leaf in paths_leaves:
            key = jax.tree_util.keystr(p)
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch at {key}: ckpt {arr.shape} vs "
                    f"template {leaf.shape}"
                )
            leaves.append(arr.astype(leaf.dtype))
        treedef = jax.tree_util.tree_structure(template)
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
