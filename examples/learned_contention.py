"""Learned contention: train the ContendedSurrogate, harvest, fine-tune.

Walks the learned-contention subsystem end to end on the H100 testbed with
the *saturating* contention ground truth (demand-weighted rail shares +
non-linear NIC multiplexing — the system-level effects the analytic
even-split cap cannot see).  Deliberately tiny training budgets so the demo
stays fast; `benchmarks/bench_learned_contention.py` runs the full
protocol.

  1. train the isolated surrogate, then the ContendedSurrogate on a small
     (subset, ledger, contended-bandwidth) curriculum;
  2. compare held-out contended MAPE: learned vs the analytic fair-share
     cap;
  3. replay a Poisson trace with a TelemetryHarvester attached and
     fine-tune the contended model online on the harvested observations
     (the Sec. 4.1.2 adaptation loop, now contended).

  PYTHONPATH=src python examples/learned_contention.py
"""

import numpy as np

import repro.core as core


def main():
    cluster = core.h100_cluster()
    sat = core.BandwidthSimulator(cluster, contention="saturating")
    tables = core.IntraHostTables(cluster, sat)
    print(cluster.describe())

    # -- 1. isolated surrogate, then the contended curriculum ---------------
    train_iso, _ = core.make_train_test_split(sat, 150, test_mult=1, seed=0)
    params, _ = core.train_surrogate(
        cluster, tables, train_iso, core.TrainConfig(steps=600)
    )
    iso_pred = core.SurrogatePredictor(cluster, tables, params)

    train, test = core.make_contended_split(sat, 300, test_mult=1, seed=3)
    n_cont = sum(1 for s in train if s.contended)
    print(f"\ncurriculum: {len(train)} samples ({n_cont} contended, "
          f"{len(train) - n_cont} isolated)")
    cparams, info = core.train_contended_surrogate(
        cluster, tables, core.to_triples(cluster, train),
        core.TrainConfig(steps=600), base_params=params,
    )
    cpred = core.ContendedSurrogatePredictor(cluster, tables, cparams)
    print(f"trained ContendedSurrogate in {info['train_seconds']:.0f}s "
          f"({info['param_bytes'] / 1024:.0f} KB)")

    # -- 2. held-out accuracy: learned vs analytic cap ----------------------
    triples = core.to_triples(cluster, [s for s in test if s.contended])
    learned = core.evaluate_contended_predictor(cpred, triples)
    _, analytic = core.evaluate_analytic_cap(cluster, iso_pred, triples)
    print(f"\nheld-out contended MAPE ({learned['n']} samples): "
          f"learned {learned['mape']:.1f}% vs analytic cap "
          f"{analytic['mape']:.1f}%")

    # -- 3. harvest live admissions, fine-tune online -----------------------
    disp = core.BandPilotDispatcher(
        cluster, tables, iso_pred, name="BP-learned",
        contention_mode="learned", contended_predictor=cpred,
    )
    trace = core.poisson_trace(
        cluster, 30, np.random.default_rng(5),
        mean_interarrival=1.0, mean_duration=8.0,
        k_choices=range(4, cluster.n_gpus // 2 + 1),
    )
    recs, harvester = core.harvest_trace(
        cluster, sat, tables, disp, trace
    )
    s = core.summarize_trace(recs)["BP-learned"]
    print(f"\nreplayed {len(recs)} jobs with mode='learned': "
          f"mean contended GBE {100 * s['mean_gbe']:.2f}%, "
          f"harvested {len(harvester)} telemetry samples")

    before = core.evaluate_contended_predictor(cpred, harvester.triples())
    cparams2 = core.online_finetune_contended(
        cluster, tables, cparams, harvester.triples(), steps=150
    )
    cpred2 = core.ContendedSurrogatePredictor(cluster, tables, cparams2)
    after = core.evaluate_contended_predictor(cpred2, harvester.triples())
    print(f"online fine-tune on harvested telemetry: MAPE "
          f"{before['mape']:.1f}% -> {after['mape']:.1f}% "
          "(on the harvested distribution)")


if __name__ == "__main__":
    main()
