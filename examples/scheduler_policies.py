"""Admission-scheduler queue policies, end to end on the paper clusters.

Replays one seeded 60-job Poisson trace through Ideal-BP (ground-truth
predictor — no surrogate training, so this stays snappy) on H100 and
Het-4Mix under:

  * ``fifo``             — legacy head-of-line admission;
  * ``backfill``         — EASY-style overtaking with an aging bound;
  * ``batched``          — co-arrival batches placed jointly
                           (``joint_hybrid_search`` threads a scratch ledger
                           so each placement sees its batch-mates);
  * ``fifo+redispatch``  — release-time elastic re-dispatch of the most
                           contention-degraded live job, charged with the
                           migration-cost term.

  PYTHONPATH=src python examples/scheduler_policies.py
"""

import numpy as np

import repro.core as core


def main():
    for cname in ("H100", "Het-4Mix"):
        cluster = core.PAPER_CLUSTERS[cname]()
        sim = core.BandwidthSimulator(cluster)
        tables = core.IntraHostTables(cluster, sim)
        print(f"\n{cluster.describe()}")

        trace = core.poisson_trace(
            cluster, 60, np.random.default_rng(0),
            mean_interarrival=1.0, mean_duration=8.0,
            k_choices=range(4, cluster.n_gpus // 2 + 1),
        )
        configs = {
            "fifo": core.SchedulerConfig(policy="fifo"),
            "backfill": core.SchedulerConfig(policy="backfill"),
            "batched": core.SchedulerConfig(
                policy="batched", batch_window=2.0
            ),
            "fifo+redispatch": core.SchedulerConfig(
                policy="fifo", redispatch=True
            ),
        }
        schedulers = core.compare_policies(
            cluster, sim, tables,
            lambda: core.BandPilotDispatcher(
                cluster, tables, core.GroundTruthPredictor(sim),
                name="Ideal-BP",
            ),
            trace, configs=configs, seed=0,
        )
        print(f"{'policy':<16} {'mean wait':>9} {'mean GBE':>9} "
              f"{'batch':>6} {'overtakes':>9} {'migrations':>10}")
        for pol, sched in schedulers.items():
            s = next(iter(core.summarize_trace(sched.records).values()))
            print(f"{pol:<16} {s['mean_wait']:>9.2f} "
                  f"{100 * s['mean_gbe']:>8.2f}% {s['mean_batch_size']:>6.2f} "
                  f"{s['total_overtakes']:>9d} {len(sched.migrations):>10d}")
        for m in schedulers["fifo+redispatch"].migrations[:3]:
            print(f"  migrated {m.job_id} at t={m.t:.1f}: "
                  f"{m.old_bw:.1f} -> {m.new_bw:.1f} GB/s "
                  f"(cost {m.cost:.1f})")


if __name__ == "__main__":
    main()
