"""Defragmentation subsystem, end to end.

Three demos on the H100 cluster:

1. **Metrics** — fragment the ledger by hand and read the stranding score,
   clean-host count, and largest placeable block
   (``ledger.fragmentation()``, also carried by ``ledger.snapshot()``).
2. **Planner** — build and apply a consolidation plan
   (``plan_defrag`` / ``apply_plan``) with the best-fit proposer, then
   show it is idempotent (re-planning on the defragmented ledger yields
   no moves).
3. **Scheduler triggers** — replay one bimodal Poisson trace with
   ``SchedulerConfig(defrag=True)`` vs off and compare the large
   arrivals' contended bandwidth, the stranding, and the committed moves.

  PYTHONPATH=src python examples/defrag.py
"""

import numpy as np

import repro.core as core


def main():
    cluster = core.h100_cluster()
    sim = core.BandwidthSimulator(cluster)
    tables = core.IntraHostTables(cluster, sim)
    print(cluster.describe())

    # -- 1. metrics ---------------------------------------------------------
    ledger = core.JobLedger(cluster)
    ledger.admit("small-a", [0, 1])
    ledger.admit("small-b", [8, 9])
    ledger.admit("small-c", [16, 17])
    ledger.admit("straggler", [4, 12, 24, 25])  # cross-host: holds 3 rails
    frag = ledger.fragmentation()
    print(f"\nfragmented ledger: {frag.describe()}")
    print(f"  forced cross-host for k=8? "
          f"{core.forced_rail_contended(cluster, ledger, 8)}")
    aware = core.ContentionAwarePredictor(
        cluster, core.GroundTruthPredictor(sim), ledger
    )
    for job_id, bw in aware.tenant_bandwidths().items():
        print(f"  tenant {job_id}: contended estimate {bw:.0f} GB/s")

    # -- 2. planner ---------------------------------------------------------
    cfg = core.DefragConfig(max_moves_per_pass=4)
    proposer = core.consolidation_proposer(
        cluster, tables, core.GroundTruthPredictor(sim),
        frag_weight=cfg.frag_weight,
    )
    plan = core.plan_defrag(cluster, sim, ledger, cfg, proposer, target_k=8)
    for mv in plan.moves:
        print(f"  move {mv.job_id}: {list(mv.old_gpus)} -> "
              f"{list(mv.new_gpus)}  (bw {mv.old_bw:.0f} -> {mv.new_bw:.0f} "
              f"GB/s, cost {mv.cost:.0f}, clean hosts "
              f"{mv.clean_hosts_delta:+d})")
    core.apply_plan(ledger, plan)
    print(f"after plan:        {ledger.fragmentation().describe()}")
    replan = core.plan_defrag(cluster, sim, ledger, cfg, proposer, target_k=8)
    print(f"re-plan moves (idempotence): {replan.n_moves}")

    # -- 3. scheduler triggers ---------------------------------------------
    trace = core.poisson_trace(
        cluster, 60, np.random.default_rng(1),
        mean_interarrival=1.0, mean_duration=8.0,
        k_choices=[2, 2, 3, 4, 4, 6, 8, 12, 16],
    )
    print(f"\n60-job bimodal trace, defrag off vs on "
          f"({'policy=fifo'}, Ideal-BP):")
    print(f"{'variant':<6} {'GBE':>8} {'bw k>=8':>9} {'stranding':>9} "
          f"{'moves':>6}")
    for tag, defrag_on in (("off", False), ("on", True)):
        disp = core.BandPilotDispatcher(
            cluster, tables, core.GroundTruthPredictor(sim),
            name="Ideal-BP", frag_weight=0.02 if defrag_on else 0.0,
        )
        sched = core.AdmissionScheduler(
            cluster, sim, tables, disp,
            core.SchedulerConfig(policy="fifo", defrag=defrag_on),
        )
        recs = sched.run(trace)
        s = next(iter(core.summarize_trace(recs).values()))
        bw_big = np.mean([r.bw for r in recs if r.k >= 8])
        print(f"{tag:<6} {100 * s['mean_gbe']:>7.2f}% {bw_big:>8.1f}G "
              f"{s['mean_stranding']:>9.3f} {len(sched.migrations):>6d}")
        for mv in sched.migrations[:3]:
            print(f"       [{mv.kind}] t={mv.t:.1f} {mv.job_id} "
                  f"bw {mv.old_bw:.0f} -> {mv.new_bw:.0f} GB/s")


if __name__ == "__main__":
    main()
