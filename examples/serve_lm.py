"""Serving example: batched prefill+decode with a KV cache.

Trains a tiny LM briefly on the motif corpus, then serves a batch of
requests — demonstrating that generation continues motifs it learned
(prefill/decode path is the exact same code the 32k dry-run cells lower).

  PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model_zoo import build_model
from repro.serve.engine import ServeConfig, ServeEngine
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import TrainRunConfig, train_loop


def main():
    cfg = get_config("gemma2-9b").reduced()  # local+global attn, softcaps
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # brief training on a small motif bank so generation is non-trivial
    data = SyntheticLM(DataConfig(cfg.vocab_size, 64, 16, seed=0, n_motifs=16))
    steps = 250
    run = TrainRunConfig(
        optimizer=AdamWConfig(lr=5e-3, weight_decay=0.01),
        total_steps=steps, warmup_steps=20, compute_dtype=jnp.float32,
    )
    batches = ({k: jnp.asarray(v) for k, v in b.items()}
               for b in data.batches(steps))
    params, _, hist = train_loop(model, params, batches, run, log_every=100)

    # serve a batch: prompts drawn from the corpus' motif bank
    prompts = [data.motifs[i][:8].tolist() for i in (0, 1, 2, 3)]
    eng = ServeEngine(model, params, ServeConfig(
        max_len=96, max_new_tokens=12
    ))
    outs = eng.generate(prompts)
    print("\nbatched generation:")
    hits = 0
    for i, (p, o) in enumerate(zip(prompts, outs)):
        target = data.motifs[i][8:8 + len(o)].tolist()
        match = sum(int(a == b) for a, b in zip(o, target))
        hits += match
        print(f"  req{i}: prompt={p} -> {o} "
              f"(motif continuation match {match}/{len(o)})")
    print(f"\nmotif-continuation accuracy: "
          f"{hits}/{sum(len(o) for o in outs)} tokens")


if __name__ == "__main__":
    main()
