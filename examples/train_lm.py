"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the mistral-nemo block architecture scaled to ~100M params, the
deterministic synthetic pipeline, AdamW + cosine schedule, checkpointing,
and (if >1 device) BandPilot-dispatched mesh construction.  Loss drops well
below ln(V) within a few hundred steps.

  PYTHONPATH=src python examples/train_lm.py            # ~100M, 300 steps
  PYTHONPATH=src python examples/train_lm.py --quick    # smoke-sized
"""

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model_zoo import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import TrainRunConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args()

    base = get_config("mistral-nemo-12b")
    if args.quick:
        cfg = base.reduced()
        steps = args.steps or 60
        batch, seq = 8, 64
    else:
        # ~100M-param dense LM with the mistral-nemo block layout
        cfg = dataclasses.replace(
            base, name="nemo-100m", n_layers=8, d_model=512, n_heads=8,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768,
            max_seq_len=512,
        )
        steps = args.steps or 300
        batch, seq = 16, 256

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"{cfg.name}: {n_params / 1e6:.1f}M params, {steps} steps, "
          f"batch {batch} x seq {seq}")

    data = SyntheticLM(DataConfig(cfg.vocab_size, seq, batch, seed=0))
    run = TrainRunConfig(
        optimizer=AdamWConfig(lr=3e-3, weight_decay=0.01),
        total_steps=steps, warmup_steps=max(10, steps // 10),
        compute_dtype=jnp.float32,
    )
    ck = Checkpointer(args.ckpt_dir, keep=2, async_save=True)
    batches = ({k: jnp.asarray(v) for k, v in b.items()}
               for b in data.batches(steps))
    t0 = time.time()
    params, opt_state, hist = train_loop(
        model, params, batches, run, log_every=max(10, steps // 15),
        checkpointer=ck, checkpoint_every=max(50, steps // 4),
    )
    ck.wait()
    lnv = float(np.log(cfg.vocab_size))
    final = hist[-1]["loss"] if hist else float("nan")
    print(f"\ndone in {time.time() - t0:.0f}s; final loss {final:.3f} "
          f"vs ln(V)={lnv:.2f} ({'LEARNED' if final < 0.75 * lnv else 'check'})")
    print(f"checkpoints: {ck.all_steps()} in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
