"""Quickstart: BandPilot end-to-end on a simulated H100 cluster.

Builds the paper's physical testbed (4 hosts x 8 H100), trains the
hierarchical Transformer surrogate on 250 sparse measurements, and compares
dispatchers on the Fig. 1 scenario + randomized requests.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import repro.core as core


def main():
    # 1. the cluster + its (black-box) bandwidth landscape
    cluster = core.h100_cluster()
    sim = core.BandwidthSimulator(cluster)
    print(cluster.describe())

    # 2. Stage-1: exhaustive intra-host measurement (one-time, offline)
    tables = core.IntraHostTables(cluster, sim)
    print(f"intra-host tables: {tables.n_measurements} measurements, "
          f"{tables.storage_bytes() / 1024:.0f} KB")

    # 3. Stage-2: train the surrogate on 250 sparse inter-host samples
    train_set, test_set = core.make_train_test_split(sim, 250, seed=0)
    params, info = core.train_surrogate(
        cluster, tables, train_set, core.TrainConfig(steps=2000)
    )
    predictor = core.SurrogatePredictor(cluster, tables, params)
    acc = core.evaluate_surrogate(predictor, test_set)
    print(f"surrogate: R2={acc['r2']:.4f} MAPE={acc['mape']:.2f}% "
          f"({info['param_bytes'] / 1024:.0f} KB model)")

    # 4. the Fig. 1 scenario: two hosts with 6 idle GPUs each, k=8
    avail = list(range(0, 6)) + list(range(8, 14))
    bp = core.BandPilotDispatcher(cluster, tables, predictor)
    topo = core.BaselineDispatcher(cluster, "topo")
    s_bp = bp.dispatch(avail, 8)
    s_topo = topo.dispatch(avail, 8)
    print(f"\nFig.1 scenario (k=8, 6+6 idle):")
    print(f"  Topo      -> {s_topo}  B={sim.true_bandwidth(s_topo):.1f} GB/s")
    print(f"  BandPilot -> {s_bp}  B={sim.true_bandwidth(s_bp):.1f} GB/s")

    # 5. randomized availability protocol (Sec. 5.3, abbreviated)
    ds = [bp, topo, core.BaselineDispatcher(cluster, "default"),
          core.BaselineDispatcher(cluster, "random")]
    recs = core.evaluate_dispatchers(
        cluster, sim, tables, ds, request_sizes=[4, 8, 12, 16, 20],
        n_scenarios=10, seed=1,
    )
    print("\nmean GBE over randomized scenarios:")
    for name, s in sorted(core.summarize(recs).items(),
                          key=lambda kv: -kv[1]["mean_gbe"]):
        print(f"  {name:10s} {100 * s['mean_gbe']:5.1f}%  "
              f"(bw loss {s['mean_bw_loss']:.1f} GB/s)")


if __name__ == "__main__":
    main()
