"""Multi-tenant dispatching: the admit/release lifecycle + contention.

Walks the contention subsystem end to end on the H100 testbed (no surrogate
training — the ground-truth predictor keeps this snappy):

  1. admit a cross-host tenant, watch a candidate's bandwidth degrade under
     the fair-share rail model, release and watch it restore *exactly*;
  2. replay the same Poisson job trace through contention-aware BandPilot,
     the contention-oblivious variant, and the Topo/Default/Random
     baselines, grading every admission with contention-degraded GBE
     against the ledger-aware exact Oracle.

  PYTHONPATH=src python examples/multi_tenant.py
"""

import numpy as np

import repro.core as core


def main():
    cluster = core.h100_cluster()
    sim = core.BandwidthSimulator(cluster)
    tables = core.IntraHostTables(cluster, sim)
    print(cluster.describe())

    # -- 1. lifecycle: degrade under contention, restore on release --------
    bp = core.BandPilotDispatcher(
        cluster, tables, core.GroundTruthPredictor(sim)
    )
    candidate = list(range(0, 4)) + list(range(8, 12))  # 4+4 on hosts 0,1
    iso = sim.true_bandwidth(candidate)
    print(f"\ncandidate 4+4 on hosts (0,1): isolated B(S) = {iso:.1f} GB/s")

    tenant = bp.ledger.admit("tenant-a", list(range(4, 8)) + list(range(12, 16)))
    print(f"admitted {tenant.job_id}: k={tenant.k} on hosts {tenant.host_ids}")
    print(bp.ledger.describe())
    deg = sim.true_bandwidth(candidate, ledger=bp.ledger)
    view = core.virtual_merge(cluster, bp.ledger, candidate)
    print(f"virtual merge: rail shares {view.rail_shares} "
          f"({len(view.merged_gpus)} GPUs in merged collective)")
    print(f"contended B(S | ledger) = {deg:.1f} GB/s "
          f"({100 * (1 - deg / iso):.0f}% degradation)")

    # the aware search routes around the tenant; the oblivious one cannot tell
    s_aware = bp.dispatch(bp.ledger.available(), 8)
    hosts = sorted(cluster.partition_by_host(s_aware))
    print(f"aware dispatch(k=8) lands on hosts {hosts}: "
          f"B = {sim.true_bandwidth(s_aware, ledger=bp.ledger):.1f} GB/s")

    bp.release("tenant-a")
    restored = sim.true_bandwidth(candidate, ledger=bp.ledger)
    assert restored == iso
    print(f"released tenant-a: B(S | ledger) = {restored:.1f} GB/s "
          "(exactly isolated again)")

    # -- 2. trace replay: aware vs oblivious vs baselines -------------------
    seed = 3
    trace = core.poisson_trace(
        cluster, 40, np.random.default_rng(seed),
        mean_interarrival=1.0, mean_duration=8.0,
        k_choices=range(4, cluster.n_gpus // 2 + 1),
    )
    print(f"\nreplaying {len(trace)} Poisson jobs "
          f"(k in [4, {cluster.n_gpus // 2}], mean duration 8.0) ...")
    results = core.compare_contention_awareness(
        cluster, sim, tables,
        lambda: core.GroundTruthPredictor(sim), trace, seed=seed,
    )
    summaries = {
        name: core.summarize_trace(recs)[name]
        for name, recs in results.items()
    }
    print(f"{'dispatcher':<22} {'mean GBE':>9} {'degraded':>9} "
          f"{'contended':>10} {'mean wait':>10}")
    for name, s in sorted(
        summaries.items(), key=lambda kv: -kv[1]["mean_gbe"]
    ):
        print(f"{name:<22} {100 * s['mean_gbe']:>8.2f}% "
              f"{100 * s['mean_degradation']:>8.1f}% "
              f"{100 * s['frac_contended']:>9.0f}% {s['mean_wait']:>10.2f}")


if __name__ == "__main__":
    main()
