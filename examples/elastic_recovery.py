"""Fault-tolerance demo: host failure -> BandPilot re-dispatch -> restore.

A 4-host simulated cluster trains a tiny LM; at step 40 a host "dies".
The coordinator marks its GPUs unavailable, re-dispatches the surviving
pool through BandPilot (maximizing post-failure collective bandwidth),
restores the latest checkpoint, and training resumes on the new allocation
with the deterministic data stream continuing exactly where it left off.

  PYTHONPATH=src python examples/elastic_recovery.py
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from repro.checkpoint.ckpt import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.ft.elastic import ElasticCoordinator, FailureEvent, run_elastic_training
from repro.models.model_zoo import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import TrainRunConfig, train_loop

TOTAL_STEPS = 80
FAIL_AT = 40
CKPT_EVERY = 10


def main():
    # cluster + BandPilot (ground-truth-guided for a deterministic demo)
    cluster = core.h100_cluster()
    sim = core.BandwidthSimulator(cluster)
    tables = core.IntraHostTables(cluster, sim)
    bp = core.BandPilotDispatcher(
        cluster, tables, core.GroundTruthPredictor(sim)
    )
    coord = ElasticCoordinator(cluster, bp, request_size=16)

    # model + deterministic data + checkpointing
    cfg = get_config("gemma-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(cfg.vocab_size, 48, 8, seed=0))
    ckdir = tempfile.mkdtemp(prefix="elastic_")
    ck = Checkpointer(ckdir, keep=2)
    run = TrainRunConfig(
        optimizer=AdamWConfig(lr=2e-3), total_steps=TOTAL_STEPS,
        compute_dtype=jnp.float32,
    )

    state = {"params": params, "opt": None, "step": 0}

    def build_and_train(allocation, start_step):
        """Train on the dispatched allocation until the next event."""
        # restore from the latest checkpoint after a failure
        if start_step > 0 and ck.all_steps():
            tpl = {"params": state["params"], "opt": state["opt"]}
            ck_step, restored = ck.restore(tpl)
            state.update(params=restored["params"], opt=restored["opt"])
            start_step = ck_step
            print(f"  restored checkpoint @ step {ck_step}")
        until = min(
            (f.step for f in failures if f.step > start_step),
            default=TOTAL_STEPS,
        )
        n = until - start_step
        batches = ({k: jnp.asarray(v) for k, v in b.items()}
                   for b in data.batches(n, start=start_step))
        p, o, hist = train_loop(
            model, state["params"], batches, run, log_every=20,
            checkpointer=ck, checkpoint_every=CKPT_EVERY,
            start_step=start_step, opt_state=state["opt"],
        )
        state.update(params=p, opt=o, step=until)
        loss = hist[-1]["loss"] if hist else float("nan")
        return until, loss

    failures = [FailureEvent(step=FAIL_AT, failed_gpus=list(range(8, 16)))]
    log = run_elastic_training(coord, build_and_train, failures, TOTAL_STEPS)

    print("\nevent log:")
    for e in log:
        if e["event"] == "dispatch":
            print(f"  dispatch: {len(e['alloc'])} GPUs, "
                  f"predicted B={e['bw']:.0f} GB/s")
        elif e["event"] == "redispatch":
            print(f"  {e['kind']}: lost {e['failed']}; re-dispatched "
                  f"{len(e['alloc'])} GPUs (B={e['bw']:.0f} GB/s), "
                  f"none on the dead host: "
                  f"{not set(e['alloc']) & set(e['failed'])}")
        else:
            print(f"  trained to step {e['until']} (loss {e['loss']:.3f})")
    assert state["step"] == TOTAL_STEPS
    print("\nrecovered and completed all steps.")


if __name__ == "__main__":
    main()
