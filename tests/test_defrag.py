"""Defragmentation subsystem (ISSUE 4): metrics, planner invariants, triggers.

Covers the ISSUE 4 satellites:
  * property-based plan invariants (hypothesis + seeded fallback, matching
    ``tests/test_tenancy_properties.py``) — plans conserve occupancy, never
    violate the per-tenant no-harm check, and are idempotent on an
    already-defragmented ledger;
  * the shared migration economics (``migration_cost`` re-export,
    ``net_migration_gain``, ``evaluate_placement`` exact-restore);
  * golden equivalence — ``defrag=off`` scheduler runs are bit-identical
    to the plain fifo path (and hence to the PR-1 golden records already
    pinned in ``tests/test_scheduler.py``);
  * triggers — budget bound, MigrationEvent kinds, drained ledger;
  * the fragmentation-aware placement tie-break and the small-k
    oversampling knob (``sample_allocations(small_k_weight=...)``).
"""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip, module still collects
    from _hypothesis_fallback import given, settings, st

import repro.core as core
from repro.core import defrag
from repro.core.scheduler import AdmissionScheduler, SchedulerConfig
from repro.core.tenancy import JobLedger


@pytest.fixture(scope="module")
def h100():
    cl = core.h100_cluster()
    sim = core.BandwidthSimulator(cl)
    tables = core.IntraHostTables(cl, sim)
    return cl, sim, tables


@pytest.fixture(scope="module")
def mix():
    cl = core.het_4mix_cluster()
    sim = core.BandwidthSimulator(cl)
    tables = core.IntraHostTables(cl, sim)
    return cl, sim, tables


def _bp(cl, tables, sim, **kw):
    return core.BandPilotDispatcher(
        cl, tables, core.GroundTruthPredictor(sim), **kw
    )


# ---------------------------------------------------------------------------
# Layer 1: fragmentation metrics
# ---------------------------------------------------------------------------

def test_metrics_on_empty_and_fragmented_ledger(h100):
    cl, _, _ = h100
    ledger = core.JobLedger(cl)
    frag = ledger.fragmentation()
    assert frag.total_free == cl.n_gpus
    assert frag.clean_hosts == cl.n_hosts
    assert frag.fragmented_hosts == 0
    assert frag.largest_free_block == 8
    assert frag.largest_quality_block == 8  # H100 hosts are switch-fabric
    assert frag.premium_free == cl.n_gpus
    assert frag.stranding == 0.0
    # dirty every host a little: all free GPUs become stranded
    for i, h in enumerate(cl.hosts):
        ledger.admit(f"j{i}", [h.gpu_ids[0], h.gpu_ids[1]])
    frag = ledger.fragmentation()
    assert frag.clean_hosts == 0
    assert frag.fragmented_hosts == cl.n_hosts
    assert frag.largest_free_block == 6
    assert frag.stranding == 1.0
    # a fully-busy host is neither clean nor fragmented
    ledger.release("j0")
    ledger.admit("full", list(cl.hosts[0].gpu_ids))
    frag = ledger.fragmentation()
    assert frag.clean_hosts == 0
    assert frag.fragmented_hosts == cl.n_hosts - 1
    assert frag.stranding == 1.0


def test_metrics_quality_block_on_heterogeneous(mix):
    cl, _, _ = mix
    ledger = core.JobLedger(cl)
    frag = ledger.fragmentation()
    # Het-4Mix: only the A800 host is switch-fabric
    assert frag.largest_quality_block == 8
    assert frag.premium_free == 8
    a800 = next(h for h in cl.hosts if h.host_type.nvswitch)
    ledger.admit("a", list(a800.gpu_ids[:6]))
    frag = ledger.fragmentation()
    assert frag.largest_quality_block == 2
    assert frag.premium_free == 2
    assert frag.largest_free_block == 8  # point-to-point hosts still clean


def test_snapshot_carries_fragmentation(h100):
    cl, _, _ = h100
    ledger = core.JobLedger(cl)
    ledger.admit("a", [0, 1, 8, 9])
    snap = ledger.snapshot()
    assert snap.frag == ledger.fragmentation()
    assert sum(ledger.free_by_host().values()) == ledger.n_free()


def test_tenant_bandwidths_grades_every_live_job(h100):
    """The predictor-side per-tenant view: each live job's own entry
    self-excludes, so with the ground-truth predictor the estimates equal
    the contended ground truth exactly."""
    cl, sim, _ = h100
    ledger = core.JobLedger(cl)
    ledger.admit("solo", [0, 1, 2, 3])
    ledger.admit("crossy", [4, 12, 24, 25])
    aware = core.ContentionAwarePredictor(
        cl, core.GroundTruthPredictor(sim), ledger
    )
    out = aware.tenant_bandwidths()
    assert set(out) == {"solo", "crossy"}
    for job_id, bw in out.items():
        alloc = ledger.allocation(job_id)
        assert bw == pytest.approx(
            sim.true_bandwidth(alloc.gpus, ledger=ledger)
        )


def test_forced_rail_contended(h100):
    cl, _, _ = h100
    ledger = core.JobLedger(cl)
    # empty cluster: a clean block always fits k <= 8
    assert not core.forced_rail_contended(cl, ledger, 8)
    # k larger than any host: cross-host is inherent, never "forced"
    assert not core.forced_rail_contended(cl, ledger, 9)
    # fragment every host AND add rail traffic
    ledger.admit("a", [0, 1])
    ledger.admit("b", [8, 9])
    ledger.admit("c", [16, 17])
    ledger.admit("x", [4, 12, 24, 25])  # cross-host tenant on 3 rails
    assert core.forced_rail_contended(cl, ledger, 8)
    # not admittable at all -> queueing problem, not fragmentation
    assert not core.forced_rail_contended(cl, ledger, 30)


def test_room_makeable_quality_gate():
    h100 = core.h100_cluster()
    assert core.room_makeable(h100, 8)
    assert not core.room_makeable(h100, 9)
    het_va = core.het_va_cluster()  # no switch-fabric hosts at all
    assert not core.room_makeable(het_va, 4, quality_only=True)
    assert core.room_makeable(het_va, 4, quality_only=False)


# ---------------------------------------------------------------------------
# Shared migration economics
# ---------------------------------------------------------------------------

def test_migration_cost_shared_single_definition():
    from repro.core import scheduler
    assert scheduler.migration_cost is defrag.migration_cost
    assert core.migration_cost is defrag.migration_cost
    assert defrag.net_migration_gain([0, 1], [2, 3], 10.0, 15.0, 2.0) == \
        pytest.approx(15.0 - 10.0 - 4.0)
    # identical placement: zero cost, zero gain
    assert defrag.net_migration_gain([0, 1], [1, 0], 10.0, 10.0, 2.0) == 0.0


def test_evaluate_placement_restores_ledger_exactly(h100):
    cl, sim, _ = h100
    ledger = core.JobLedger(cl)
    alloc = ledger.admit("a", [0, 1, 8, 9])
    ledger.admit("b", [16, 17])
    before_owner = dict(ledger._owner)
    # identical subset -> None, untouched
    assert core.evaluate_placement(sim, ledger, alloc, [9, 8, 1, 0], 2.0) \
        is None
    ev = core.evaluate_placement(sim, ledger, alloc, [2, 3, 4, 5], 2.0)
    assert ledger._owner == before_owner  # exact restore either way
    assert ev is not None
    assert ev.new_gpus == (2, 3, 4, 5)
    assert ev.cost == pytest.approx(2.0 * 4)
    assert ev.self_gain == pytest.approx(ev.new_bw - ev.old_bw - ev.cost)
    # the moved job went cross-host -> single-host: a consolidating move
    assert core.is_consolidating(cl, ev)


def test_is_consolidating_rejects_premium_squat(h100):
    cl, sim, _ = h100
    ledger = core.JobLedger(cl)
    ledger.admit("other", [2, 3])          # keeps host 0 dirty
    alloc = ledger.admit("squat", [0, 1])  # single-host pair on host 0
    # host 1 is clean: relocating the pair there frees nothing, dirties a
    # clean host, keeps span at 1 -> NOT a defrag move
    ev = core.evaluate_placement(
        sim, ledger, alloc, [8, 9], 2.0, require_no_harm=False,
    )
    assert ev is not None
    assert not core.is_consolidating(cl, ev)


def test_evaluate_move_matches_redispatch_semantics(h100):
    """The scheduler's release-time re-dispatch refactored onto the shared
    helper: a move that pays must have the same gain the legacy inline code
    computed (new - old - cost), and declined trials restore the ledger."""
    cl, sim, tables = h100
    disp = _bp(cl, tables, sim)
    ledger = disp.ledger
    ledger.admit("t1", [0, 1, 2, 3])
    bad = ledger.admit("bad", [4, 12, 20, 28])  # 1+1+1+1: rail-bound
    busy_before = set(ledger.busy())
    ev = core.evaluate_move(
        sim, ledger, bad,
        lambda led, avail, k: disp.dispatch(avail, k),
        cost_per_gpu=2.0,
    )
    assert set(ledger.busy()) == busy_before
    assert ev is not None and ev.self_gain > 0
    assert ev.self_gain == pytest.approx(
        ev.new_bw - ev.old_bw
        - core.migration_cost(ev.old_gpus, ev.new_gpus, 2.0)
    )


# ---------------------------------------------------------------------------
# Planner properties (hypothesis + seeded fallback)
# ---------------------------------------------------------------------------

def _random_fragmented_ledger(cl, seeds):
    """Deterministically admit small jobs from an integer stream."""
    ledger = JobLedger(cl)
    n = 0
    for s in seeds:
        avail = ledger.available()
        k = 2 + s % 4
        if k + 4 > len(avail):  # keep some headroom so moves exist
            break
        picks = sorted({avail[(s * 7 + i * 13) % len(avail)]
                        for i in range(k)})
        ledger.admit(f"p{n}", picks)
        n += 1
    return ledger


def check_plan_invariants(cl, sim, tables, ledger, target_k=None):
    cfg = core.DefragConfig(max_moves_per_pass=6, max_total_moves=6)
    proposer = core.consolidation_proposer(
        cl, tables, core.GroundTruthPredictor(sim),
        frag_weight=cfg.frag_weight,
    )
    before_alloc = {a.job_id: a.gpus for a in ledger.jobs()}
    before_bw = {
        a.job_id: sim.true_bandwidth(a.gpus, ledger=ledger)
        for a in ledger.jobs()
    }
    plan = core.plan_defrag(cl, sim, ledger, cfg, proposer, target_k=target_k)
    # planning never touches the live ledger
    assert {a.job_id: a.gpus for a in ledger.jobs()} == before_alloc
    core.apply_plan(ledger, plan)
    after = {a.job_id: a for a in ledger.jobs()}
    # occupancy conserved: same jobs, same sizes, still disjoint (the
    # ledger enforces disjointness on admit; sizes checked here)
    assert set(after) == set(before_alloc)
    for job_id, gpus in before_alloc.items():
        assert after[job_id].k == len(gpus)
    seen = set()
    for a in after.values():
        assert not (set(a.gpus) & seen)
        seen |= set(a.gpus)
    # per-tenant no-harm composes across the plan's moves
    for job_id in before_bw:
        now = sim.true_bandwidth(after[job_id].gpus, ledger=ledger)
        assert now >= before_bw[job_id] - 1e-6, job_id
    # every committed move was consolidating and cleared the bar
    for mv in plan.moves:
        assert core.is_consolidating(cl, mv)
    # idempotence: the defragmented ledger plans no further moves
    replan = core.plan_defrag(cl, sim, ledger, cfg, proposer,
                              target_k=target_k)
    assert replan.n_moves == 0, [m.job_id for m in replan.moves]
    return plan


@settings(max_examples=10, deadline=None)
@given(seeds=st.lists(st.integers(0, 10_000), min_size=2, max_size=8))
def test_plan_invariants_random_ledgers(seeds):
    cl = core.h100_cluster()
    sim = core.BandwidthSimulator(cl)
    tables = core.IntraHostTables(cl, sim)
    ledger = _random_fragmented_ledger(cl, seeds)
    if len(ledger) == 0:
        return
    check_plan_invariants(cl, sim, tables, ledger)


def test_plan_invariants_seeded(h100):
    """Same property, driven by seeded randomness: runs even without
    hypothesis installed."""
    cl, sim, tables = h100
    rng = np.random.default_rng(0)
    for trial in range(6):
        seeds = rng.integers(0, 10_000, size=int(rng.integers(2, 9)))
        ledger = _random_fragmented_ledger(cl, seeds.tolist())
        if len(ledger) == 0:
            continue
        check_plan_invariants(cl, sim, tables, ledger,
                              target_k=8 if trial % 2 else None)


def test_plan_invariants_seeded_heterogeneous(mix):
    cl, sim, tables = mix
    rng = np.random.default_rng(3)
    for _ in range(4):
        seeds = rng.integers(0, 10_000, size=int(rng.integers(2, 8)))
        ledger = _random_fragmented_ledger(cl, seeds.tolist())
        if len(ledger) == 0:
            continue
        check_plan_invariants(cl, sim, tables, ledger)


def test_make_room_plan_opens_target_block(h100):
    cl, sim, tables = h100
    ledger = core.JobLedger(cl)
    ledger.admit("a", [0, 1])
    ledger.admit("b", [8, 9])
    ledger.admit("c", [16, 17])
    ledger.admit("x", [4, 12, 24, 25])
    assert ledger.fragmentation().largest_free_block < 8
    cfg = core.DefragConfig(max_moves_per_pass=4)
    proposer = core.consolidation_proposer(
        cl, tables, core.GroundTruthPredictor(sim),
        frag_weight=cfg.frag_weight,
    )
    plan = core.plan_defrag(cl, sim, ledger, cfg, proposer, target_k=8)
    assert plan.n_moves >= 1
    assert plan.after.largest_free_block >= 8
    core.apply_plan(ledger, plan)
    assert ledger.fragmentation().largest_free_block >= 8


def test_plan_respects_budget(h100):
    cl, sim, tables = h100
    ledger = core.JobLedger(cl)
    ledger.admit("a", [0, 1])
    ledger.admit("b", [8, 9])
    ledger.admit("c", [16, 17])
    ledger.admit("x", [4, 12, 24, 25])
    cfg = core.DefragConfig(max_moves_per_pass=5)
    proposer = core.consolidation_proposer(
        cl, tables, core.GroundTruthPredictor(sim),
    )
    plan = core.plan_defrag(cl, sim, ledger, cfg, proposer, budget=1)
    assert plan.n_moves <= 1


def test_defrag_config_validation():
    with pytest.raises(ValueError):
        core.DefragConfig(max_moves_per_pass=0)
    with pytest.raises(ValueError):
        core.DefragConfig(max_total_moves=-1)
    with pytest.raises(ValueError):
        core.DefragConfig(interval=-1.0)


def test_apply_plan_raises_on_stale_state(h100):
    cl, sim, tables = h100
    ledger = core.JobLedger(cl)
    ledger.admit("a", [0, 1])
    ledger.admit("b", [8, 9])
    ledger.admit("c", [16, 17])
    ledger.admit("x", [4, 12, 24, 25])
    cfg = core.DefragConfig()
    proposer = core.consolidation_proposer(
        cl, tables, core.GroundTruthPredictor(sim),
    )
    plan = core.plan_defrag(cl, sim, ledger, cfg, proposer, target_k=8)
    assert plan.n_moves >= 1
    # occupy a GPU the plan wants: the apply must raise, not corrupt
    ledger.admit("intruder", [plan.moves[0].new_gpus[0]])
    with pytest.raises(ValueError):
        core.apply_plan(ledger, plan)


# ---------------------------------------------------------------------------
# Placement tie-break
# ---------------------------------------------------------------------------

def test_frag_penalty_prefers_topping_up_dirty_hosts(h100):
    cl, sim, tables = h100
    ledger = core.JobLedger(cl)
    ledger.admit("tenant", [0, 1, 2, 3])  # host 0: 4 busy, 4 free
    penalty = core.make_frag_penalty(cl, ledger, weight=0.02)
    assert penalty([4, 5, 6, 7]) == 0.0     # tops up the dirty host
    assert penalty([8, 9, 10, 11]) == pytest.approx(0.02)  # cracks a clean one
    assert penalty(list(range(8, 16))) == 0.0  # consumes it fully: no strand
    gt = core.GroundTruthPredictor(sim)
    res = core.hybrid_search(cl, tables, gt, ledger.available(), 4,
                             frag_penalty=penalty)
    # NVSwitch hosts are uniform up to jitter (<2%): the tie-break must pick
    # the dirty host's remaining GPUs over cracking open a clean host
    assert set(res.subset) == {4, 5, 6, 7}


def test_frag_penalty_none_is_bit_identical(h100):
    cl, sim, tables = h100
    gt = core.GroundTruthPredictor(sim)
    rng = np.random.default_rng(5)
    for _ in range(5):
        avail = core.cluster.availability_scenario(cl, rng)
        k = int(rng.integers(2, max(3, len(avail) // 2)))
        if k > len(avail):
            continue
        a = core.hybrid_search(cl, tables, gt, avail, k)
        b = core.hybrid_search(cl, tables, gt, avail, k, frag_penalty=None)
        assert a.subset == b.subset
        assert a.predicted_bw == b.predicted_bw


def test_joint_search_accepts_frag_weight(h100):
    cl, sim, tables = h100
    ledger = core.JobLedger(cl)
    ledger.admit("tenant", [0, 1, 2, 3])
    gt = core.GroundTruthPredictor(sim)
    plan = core.joint_hybrid_search(
        cl, tables, gt, ledger, [("a", 4), ("b", 4)], frag_weight=0.02,
    )
    subs = [set(p.subset) for p in plan.placements]
    assert not (subs[0] & subs[1])
    assert all(len(s) == 4 for s in subs)
    assert not (subs[0] | subs[1]) & ledger.busy()


# ---------------------------------------------------------------------------
# Scheduler triggers
# ---------------------------------------------------------------------------

def _trace(cl, n=20, seed=7, k_choices=None):
    return core.poisson_trace(
        cl, n, np.random.default_rng(seed),
        mean_interarrival=1.0, mean_duration=8.0,
        k_choices=k_choices or [2, 3, 4, 6, 8, 12, 16],
    )


def test_defrag_off_is_bit_identical_to_plain_fifo(h100):
    """The golden-pinned acceptance: defrag=off replays are the PR 3 fifo
    path, record for record (the goldens themselves are pinned in
    tests/test_scheduler.py; this guards the off-path wiring)."""
    cl, sim, tables = h100
    trace = _trace(cl)
    legacy = core.replay_trace(cl, sim, tables, _bp(cl, tables, sim), trace)
    sched = AdmissionScheduler(
        cl, sim, tables, _bp(cl, tables, sim),
        SchedulerConfig(policy="fifo", defrag=False),
    )
    off = sched.run(trace)
    assert [(r.job_id, r.t_admit, r.gbe, r.bw) for r in off] == \
        [(r.job_id, r.t_admit, r.gbe, r.bw) for r in legacy]
    assert sched.migrations == []


def test_defrag_triggers_fire_and_respect_budget(h100):
    cl, sim, tables = h100
    trace = _trace(cl, n=30, seed=0)
    budget = 3
    disp = _bp(cl, tables, sim, frag_weight=0.02)
    sched = AdmissionScheduler(
        cl, sim, tables, disp,
        SchedulerConfig(
            policy="fifo", defrag=True,
            defrag_config=core.DefragConfig(
                max_total_moves=budget, interval=1.0,
            ),
        ),
    )
    recs = sched.run(trace)
    assert len(recs) == len(trace)
    assert len(disp.ledger) == 0  # drained
    assert 1 <= len(sched.migrations) <= budget
    assert all(m.kind in ("defrag", "make-room") for m in sched.migrations)
    assert sum(r.migrations for r in recs) == len(sched.migrations)
    # fragmentation state is recorded and summarized
    assert all(0.0 <= r.stranding <= 1.0 for r in recs)
    s = core.summarize_trace(recs)[disp.name]
    assert "mean_stranding" in s and "mean_clean_hosts" in s


def test_defrag_moves_never_lower_live_bandwidth(h100, monkeypatch):
    cl, sim, tables = h100
    checked = {"passes": 0}
    orig = AdmissionScheduler._run_defrag_pass

    def verified(self, t, kind, target_k=None):
        ledger = self.dispatcher.ledger
        before = {
            a.job_id: self.sim.true_bandwidth(a.gpus, ledger=ledger)
            for a in ledger.jobs()
        }
        n = len(self.migrations)
        orig(self, t, kind, target_k=target_k)
        if len(self.migrations) > n:
            checked["passes"] += 1
            for a in ledger.jobs():
                if a.job_id in before:
                    after = self.sim.true_bandwidth(a.gpus, ledger=ledger)
                    assert after >= before[a.job_id] - 1e-6, a.job_id

    monkeypatch.setattr(AdmissionScheduler, "_run_defrag_pass", verified)
    disp = _bp(cl, tables, sim, frag_weight=0.02)
    sched = AdmissionScheduler(
        cl, sim, tables, disp,
        SchedulerConfig(policy="fifo", defrag=True,
                        defrag_config=core.DefragConfig(interval=1.0)),
    )
    sched.run(_trace(cl, n=30, seed=0))
    assert checked["passes"] >= 1  # the hook actually consolidated


def test_defrag_composes_with_redispatch_and_batched(h100):
    cl, sim, tables = h100
    trace = _trace(cl, n=20, seed=3)
    for cfg in (
        SchedulerConfig(policy="fifo", defrag=True, redispatch=True),
        SchedulerConfig(policy="batched", batch_window=2.0, defrag=True),
        SchedulerConfig(policy="backfill", defrag=True),
    ):
        disp = _bp(cl, tables, sim, frag_weight=0.02)
        sched = AdmissionScheduler(cl, sim, tables, disp, cfg)
        recs = sched.run(trace)
        assert len(recs) == len(trace), cfg.policy
        assert len(disp.ledger) == 0
        spent = sum(1 for m in sched.migrations
                    if m.kind in ("defrag", "make-room"))
        assert spent <= cfg.defrag_config.max_total_moves


@pytest.mark.slow
def test_defrag_improves_large_arrivals_on_h100_trace(h100):
    """The ISSUE 4 acceptance bar at test scale: on a 60-job bimodal H100
    trace, defrag=on improves the large (k>=8) arrivals' mean contended
    bandwidth without losing GBE, within the migration budget."""
    cl, sim, tables = h100
    trace = _trace(cl, n=60, seed=1, k_choices=[2, 2, 3, 4, 4, 6, 8, 12, 16])

    def replay(cfg, fw):
        disp = _bp(cl, tables, sim, frag_weight=fw)
        sched = AdmissionScheduler(cl, sim, tables, disp, cfg)
        return sched.run(trace), sched

    off, _ = replay(SchedulerConfig(policy="fifo"), 0.0)
    on, sched = replay(
        SchedulerConfig(policy="fifo", defrag=True,
                        defrag_config=core.DefragConfig(
                            max_total_moves=16, interval=2.0)),
        0.02,
    )
    bw_off = np.mean([r.bw for r in off if r.k >= 8])
    bw_on = np.mean([r.bw for r in on if r.k >= 8])
    assert bw_on > bw_off + 10.0  # double-digit GB/s gain on this trace
    gbe_off = np.mean([r.gbe for r in off])
    gbe_on = np.mean([r.gbe for r in on])
    assert gbe_on > gbe_off - 0.01
    assert 1 <= len(sched.migrations) <= 16


# ---------------------------------------------------------------------------
# Satellite: small-k oversampling
# ---------------------------------------------------------------------------

def test_sample_allocations_small_k_weight(mix):
    cl, sim, _ = mix
    # default: explicit 0.0 is bit-identical to the legacy call
    a = sim.sample_allocations(30, np.random.default_rng(0))
    b = sim.sample_allocations(30, np.random.default_rng(0),
                               small_k_weight=0.0)
    assert a == b
    # oversampling skews the k distribution toward the crossover range
    heavy = sim.sample_allocations(60, np.random.default_rng(0),
                                   small_k_weight=0.9)
    frac_small = np.mean([len(s) <= 5 for s in heavy])
    frac_small_base = np.mean(
        [len(s) <= 5 for s in sim.sample_allocations(
            60, np.random.default_rng(0))]
    )
    assert frac_small > frac_small_base + 0.2
    assert all(len(self_) >= 2 for self_ in heavy)
    with pytest.raises(ValueError):
        sim.sample_allocations(5, np.random.default_rng(0),
                               small_k_weight=1.5)
