"""Sharding-rule unit tests: spec resolution, divisibility fallbacks,
parameter/caches logical mapping.  Uses a fake mesh built over 1 device
repeated via jax.sharding.Mesh abstract construction — resolve_spec only
consults mesh.shape, so a small real mesh suffices."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel import sharding as shd


def _mesh(shape, axes):
    # resolve_spec only needs mesh.shape; an abstract mesh is enough.
    # jax >= 0.5 takes (axis_sizes, axis_names); 0.4.x takes one tuple of
    # (name, size) pairs.
    try:
        return jax.sharding.AbstractMesh(shape, axes)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


M = _mesh((2, 4, 4), ("pod", "data", "model"))
RULES = shd.STRATEGIES["fsdp_tp"]()


def test_resolve_simple():
    spec = shd.resolve_spec(M, RULES, ("embed", "ff"), (64, 128))
    assert spec == P("data", "model")


def test_resolve_divisibility_fallback():
    # kv_heads=1 (MQA) cannot shard 4 ways -> replicated
    spec = shd.resolve_spec(M, RULES, ("embed", "kv_heads", "head_dim"),
                            (64, 1, 128))
    assert spec == P("data", None, None)


def test_resolve_multi_axis_batch():
    spec = shd.resolve_spec(M, RULES, ("batch", "seq"), (16, 128))
    assert spec == P(("pod", "data"), "model")
    # batch=2 can only take the pod axis
    spec2 = shd.resolve_spec(M, RULES, ("batch", "seq"), (2, 128))
    assert spec2 == P("pod", "model")


def test_resolve_no_axis_reuse():
    # two dims mapping to "model": only the first gets it
    spec = shd.resolve_spec(M, RULES, ("heads", "ff"), (8, 128))
    assert spec == P("model", None)


def test_param_logical_stacked_detection():
    # stacked scan leaf gets a leading "layers"=None axis
    log = shd.logical_for_leaf("wq", 4)
    assert log == ("layers", "embed", "heads", "head_dim")
    log2 = shd.logical_for_leaf("wq", 3)
    assert log2 == ("embed", "heads", "head_dim")


def test_moe_leaf_logical():
    assert shd.logical_for_leaf("w_up", 3) == ("experts", "embed", "ff")
    assert shd.logical_for_leaf("w_up", 4) == ("layers", "experts", "embed", "ff")
    assert shd.logical_for_leaf("w_up", 2) == ("embed", "ff")


def test_unknown_leaf_replicates():
    assert shd.logical_for_leaf("mystery", 3) == (None, None, None)


def test_param_specs_tree():
    params = {
        "embed": jax.ShapeDtypeStruct((1024, 64), jnp.float32),
        "blocks": [{
            "wq": jax.ShapeDtypeStruct((6, 64, 8, 16), jnp.float32),
            "norm1": jax.ShapeDtypeStruct((64,), jnp.float32),
        }],
    }
    specs = shd.param_specs(M, RULES, params)
    assert specs["embed"] == P("model", "data")
    assert specs["blocks"][0]["wq"] == P(None, "data", "model", None)
    assert specs["blocks"][0]["norm1"] == P(None)


def test_serve_2d_rules_keep_batch_off_data():
    rules = shd.STRATEGIES["serve_2d"]()
    spec = shd.resolve_spec(M, rules, ("batch", None), (128, 1))
    assert spec == P("pod", None)
    cache_spec = shd.resolve_spec(
        M, rules, ("batch", "seq_cache", "kv_heads", "head_dim"),
        (128, 32768, 8, 128),
    )
    assert cache_spec == P("pod", ("data", "model"), None, None)


def test_activation_constraint_noop_without_context():
    x = jnp.ones((4, 8))
    y = shd.shard_activation(x, "batch", "seq")
    assert y is x
