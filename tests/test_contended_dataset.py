"""Learned-contention data pipeline: featurization, sampling, harvesting.

Invariants under test (ISSUE 3 satellites):
  * empty-ledger featurization is bit-identical to the isolated path (zero
    context channels, no contender tokens, same mask);
  * sampled co-tenant ledgers are pairwise GPU-disjoint and disjoint from
    the candidate (property-based, hypothesis with seeded fallback);
  * encode_bw/decode_bw round-trips at contended magnitudes;
  * the saturating contention model keeps the PR-1 invariants (empty ledger
    exact, monotone degradation, never above isolated);
  * the telemetry harvester records one observation per admission with the
    correct co-tenant context, from both the scheduler and the
    DispatcherService telemetry entry point.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

import repro.core as core
from repro.core import features as feat
from repro.core import surrogate as surr
from repro.core.contended_dataset import (
    ContendedSample,
    TelemetryHarvester,
    materialize_ledger,
    sample_cotenant_ledger,
)
from repro.core.tenancy import JobLedger


@pytest.fixture(scope="module")
def h100():
    cl = core.h100_cluster()
    sim = core.BandwidthSimulator(cl, contention="saturating")
    tables = core.IntraHostTables(cl, sim)
    return cl, sim, tables


CAND = [0, 1, 2, 3, 8, 9, 10, 11]      # 4+4 on hosts 0,1
TENANT_A = [4, 5, 6, 7, 12, 13, 14, 15]  # 4+4 on hosts 0,1 (contends)
SINGLE = [16, 17, 18, 19]               # host 2 only


# ---------------------------------------------------------------------------
# Featurization
# ---------------------------------------------------------------------------

def test_empty_ledger_featurization_bit_identical(h100):
    cl, sim, tables = h100
    subs = sim.sample_allocations(15, np.random.default_rng(0))
    f_iso, m_iso = feat.featurize_batch(cl, tables, subs)
    for ledger in (None, JobLedger(cl)):
        f_c, m_c = feat.featurize_contended_batch(
            cl, tables, [(s, ledger) for s in subs], max_tokens=cl.n_hosts
        )
        assert np.array_equal(f_iso, f_c[:, :, : feat.N_FEATURES])
        assert np.array_equal(m_iso, m_c)
        assert np.all(f_c[:, :, feat.N_FEATURES:] == 0.0)


def test_ledger_channels_and_contender_tokens(h100):
    cl, _, tables = h100
    led = JobLedger(cl)
    led.admit("a", TENANT_A)                 # cross-host: contends on 0,1
    led.admit("s", [20, 21])                 # single-host: occupancy only
    f, m = feat.featurize_contended_one(
        cl, tables, CAND, led, max_tokens=feat.default_max_tokens(cl)
    )
    # two candidate host tokens + one contender token per shared host
    assert m.sum() == 4
    seg = f[:, feat.N_FEATURES]
    assert list(seg[:4]) == [0.0, 0.0, 1.0, 1.0]
    # c_h = 1 contender on both hosts, demand = 4 GPUs, occupancy = 4/8
    assert np.allclose(f[:2, feat.N_FEATURES + 1], 1.0 / 4.0)
    assert np.allclose(f[:2, feat.N_FEATURES + 2], 4.0 / 8.0)
    assert np.allclose(f[:2, feat.N_FEATURES + 3], 4.0 / 8.0)
    # contender token base features describe the contender's own slice
    assert np.isclose(f[2, 1], 4.0 / 8.0)    # 4 GPUs on host 0
    # without contender tokens only the candidate hosts remain
    f2, m2 = feat.featurize_contended_one(
        cl, tables, CAND, led, max_tokens=cl.n_hosts,
        include_contenders=False,
    )
    assert m2.sum() == 2
    assert np.array_equal(f[:2], f2[:2])


def test_single_host_candidates_ignore_ledger(h100):
    cl, _, tables = h100
    led = JobLedger(cl)
    led.admit("a", TENANT_A)
    params = surr.init_contended_params(
        surr.init_hierarchical_params(__import__("jax").random.PRNGKey(0))
    )
    cpred = core.ContendedSurrogatePredictor(cl, tables, params)
    out = cpred.predict([SINGLE], led)
    assert out[0] == tables.lookup_global(SINGLE)  # Stage-1 exact, no NIC


def test_occupancy_excludes_candidate_gpus(h100):
    """A harvested sample's candidate is itself in the ledger: its own GPUs
    must not count toward the occupancy channel (self-exclusion)."""
    cl, _, tables = h100
    led = JobLedger(cl)
    led.admit("a", TENANT_A)
    led.admit("cand", CAND)
    f_in, _ = feat.featurize_contended_one(
        cl, tables, CAND, led, max_tokens=cl.n_hosts * 3
    )
    led.release("cand")
    f_out, _ = feat.featurize_contended_one(
        cl, tables, CAND, led, max_tokens=cl.n_hosts * 3
    )
    assert np.array_equal(f_in, f_out)


# ---------------------------------------------------------------------------
# encode/decode round-trip at contended magnitudes
# ---------------------------------------------------------------------------

def test_encode_decode_roundtrip_contended_magnitudes():
    # contention pushes bandwidths an order of magnitude below isolated:
    # cover the full degraded range down to fractions of a GB/s
    bws = np.asarray(
        [0.05, 0.4, 1.0, 3.9, 17.0, 38.9, 62.7, 135.5, 322.0, 500.0],
        np.float32,
    )
    round_tripped = np.asarray(surr.decode_bw(surr.encode_bw(bws)))
    np.testing.assert_allclose(round_tripped, bws, rtol=1e-4)


# ---------------------------------------------------------------------------
# Co-tenant ledger sampling
# ---------------------------------------------------------------------------

def _assert_ledger_invariants(cl, cand, jobs):
    seen = set(cand)
    for gpus in jobs:
        assert len(gpus) == len(set(gpus))
        assert seen.isdisjoint(gpus), "co-tenant overlaps candidate/earlier job"
        assert all(0 <= g < cl.n_gpus for g in gpus)
        seen.update(gpus)


def test_sampled_cotenants_disjoint(h100):
    cl, sim, _ = h100
    rng = np.random.default_rng(7)
    for cand in sim.sample_allocations(25, rng):
        jobs = sample_cotenant_ledger(
            cl, rng, exclude=cand, max_cotenants=4,
            focus_hosts=sorted(cl.partition_by_host(cand)),
        )
        _assert_ledger_invariants(cl, cand, jobs)
        # materialization must admit cleanly (JobLedger re-checks all of it)
        materialize_ledger(cl, tuple(jobs))


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**32 - 1),
    max_cotenants=st.integers(min_value=0, max_value=6),
)
def test_property_cotenant_sampling(seed, max_cotenants):
    cl = core.h100_cluster()
    rng = np.random.default_rng(seed)
    k = int(rng.integers(2, 17))
    cand = sorted(int(g) for g in rng.choice(cl.n_gpus, k, replace=False))
    jobs = sample_cotenant_ledger(
        cl, rng, exclude=cand, max_cotenants=max_cotenants,
        focus_hosts=sorted(cl.partition_by_host(cand)),
    )
    assert len(jobs) <= max_cotenants
    _assert_ledger_invariants(cl, cand, jobs)


def test_build_dataset_mixes_isolated_and_contended(h100):
    cl, sim, _ = h100
    ds = core.build_contended_dataset(
        sim, 60, np.random.default_rng(3), isolated_frac=0.25
    )
    n_cont = sum(1 for s in ds if s.contended)
    assert 0 < n_cont < len(ds)
    for s in ds:
        _assert_ledger_invariants(cl, s.subset, s.cotenants)
        assert s.bw > 0


def test_contended_split_heldout_and_noiseless(h100):
    cl, sim, _ = h100
    train, test = core.make_contended_split(sim, 40, test_mult=1, seed=5)
    train_keys = {s.key for s in train}
    assert not any(s.key in train_keys for s in test)
    for s in test[:10]:
        led = materialize_ledger(cl, s.cotenants) if s.cotenants else None
        assert s.bw == sim.true_bandwidth(list(s.subset), ledger=led)


# ---------------------------------------------------------------------------
# Saturating contention model
# ---------------------------------------------------------------------------

def test_saturating_empty_ledger_exact(h100):
    cl, sat, _ = h100
    fair = core.BandwidthSimulator(cl)
    led = JobLedger(cl)
    for s in sat.sample_allocations(20, np.random.default_rng(1)):
        assert sat.true_bandwidth(s, ledger=led) == fair.true_bandwidth(s)


def test_saturating_monotone_and_below_isolated(h100):
    cl, sat, _ = h100
    led = JobLedger(cl)
    cand = [0, 1, 8, 9]
    iso = sat.true_bandwidth(cand)
    led.admit("a", [2, 3, 10, 11])
    one = sat.true_bandwidth(cand, ledger=led)
    led.admit("b", [4, 5, 12, 13])
    two = sat.true_bandwidth(cand, ledger=led)
    assert two < one < iso
    # saturating is strictly harsher than the even fair split here (equal
    # demands -> same share, times the multiplexing loss)
    fair = core.BandwidthSimulator(cl)
    assert two < fair.true_bandwidth(cand, ledger=led)


def test_saturating_demand_weighting(h100):
    """A small co-tenant degrades the candidate less than a big one."""
    cl, sat, _ = h100
    cand = [0, 1, 2, 8, 9, 10]
    small = JobLedger(cl)
    small.admit("a", [3, 11])             # 1+1 GPUs on hosts 0,1
    big = JobLedger(cl)
    big.admit("a", [4, 5, 6, 12, 13, 14])  # 3+3 GPUs on hosts 0,1
    assert (sat.true_bandwidth(cand, ledger=small)
            > sat.true_bandwidth(cand, ledger=big))


def test_unknown_contention_model_rejected(h100):
    cl, _, _ = h100
    with pytest.raises(ValueError):
        core.BandwidthSimulator(cl, contention="psychic")


# ---------------------------------------------------------------------------
# Telemetry harvesting
# ---------------------------------------------------------------------------

def test_harvester_records_every_admission(h100):
    cl, sat, tables = h100
    disp = core.BandPilotDispatcher(cl, tables, core.GroundTruthPredictor(sat))
    trace = core.poisson_trace(
        cl, 20, np.random.default_rng(2), mean_duration=6.0
    )
    recs, h = core.harvest_trace(cl, sat, tables, disp, trace)
    assert len(h) == len(recs) == len(trace)
    for sample, rec in zip(h.samples, recs):
        assert len(sample.subset) == rec.k
        assert sample.bw == rec.bw  # the contended-degraded grading value
        _assert_ledger_invariants(cl, sample.subset, sample.cotenants)
    assert h.n_observed == len(trace)


def test_harvester_ring_buffer(h100):
    cl, sat, _ = h100
    h = TelemetryHarvester(cl, max_samples=5)
    led = JobLedger(cl)
    for i in range(9):
        h.observe(led, [i], 100.0 + i)
    assert len(h) == 5 and h.n_observed == 9
    assert h.samples[0].bw == 104.0  # oldest trimmed, most recent kept


def test_dispatcher_report_bandwidth_feeds_harvester(h100):
    cl, sat, tables = h100
    disp = core.BandPilotDispatcher(cl, tables, core.GroundTruthPredictor(sat))
    disp.harvester = TelemetryHarvester(cl)
    disp.admit("a", 8)
    disp.admit("b", 8)
    alloc = disp.report_bandwidth("a", 123.4)
    assert alloc.job_id == "a"
    assert len(disp.harvester) == 1
    s = disp.harvester.samples[0]
    assert s.subset == alloc.gpus and s.bw == 123.4
    # the reporting job's own entry self-excludes from its co-tenant spec
    assert alloc.gpus not in s.cotenants
    assert disp.ledger.allocation("b").gpus in s.cotenants
    # a stale report (job already released) is dropped, not an error
    disp.release("a")
    assert disp.report_bandwidth("a", 99.0) is None
    assert len(disp.harvester) == 1


def test_evaluate_analytic_cap_per_sample_ledgers(h100):
    """The analytic baseline must score every triple against its OWN ledger
    (a single wrapped-ledger predictor cannot and is rejected)."""
    cl, sat, tables = h100
    led = JobLedger(cl)
    led.admit("a", TENANT_A)
    gt = core.GroundTruthPredictor(sat)
    triples = [
        (CAND, led, sat.true_bandwidth(CAND, ledger=led)),
        (CAND, None, sat.true_bandwidth(CAND)),
        (SINGLE, led, sat.true_bandwidth(SINGLE, ledger=led)),
    ]
    preds, acc = core.evaluate_analytic_cap(cl, gt, triples)
    iso = sat.true_bandwidth(CAND)
    assert preds[0] < iso        # capped under its own ledger
    assert preds[1] == iso       # isolated sample untouched
    assert preds[2] == sat.true_bandwidth(SINGLE)  # single-host untouched
    assert acc["n"] == 3
    with pytest.raises(TypeError):
        core.evaluate_contended_predictor(gt, triples)


def test_harvested_triples_trainable_shapes(h100):
    cl, sat, tables = h100
    h = TelemetryHarvester(cl)
    led = JobLedger(cl)
    led.admit("a", TENANT_A)
    h.observe(led, CAND, 42.0)
    triples = h.triples()
    assert len(triples) == 1
    subset, ledger, bw = triples[0]
    assert bw == 42.0 and sorted(subset) == sorted(CAND)
    assert len(ledger) == 1  # the co-tenant was rematerialized
