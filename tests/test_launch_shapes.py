"""Shape-cell accounting + input-spec construction (no compiles)."""

import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.launch import shapes as shp


def test_forty_cells_accounted():
    cells = shp.all_cells()
    assert len(cells) == 40  # 10 archs x 4 shapes
    runnable = shp.runnable_cells()
    skipped = [c for c in cells if c not in runnable]
    # long_500k runs only for the sub-quadratic families
    assert len(runnable) == 32
    assert all(s == "long_500k" for _, s in skipped)
    assert {a for a, _ in skipped} == set(ARCHS) - set(shp.LONG_CONTEXT_ARCHS)


def test_skip_reasons_are_explicit():
    assert shp.cell_skip_reason("gemma-7b", "long_500k")
    assert shp.cell_skip_reason("rwkv6-7b", "long_500k") is None
    assert shp.cell_skip_reason("recurrentgemma-9b", "long_500k") is None
    assert shp.cell_skip_reason("gemma-7b", "train_4k") is None


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_input_specs_match_assignment(arch):
    cfg = get_config(arch)
    cell = shp.SHAPES["train_4k"]
    batch = shp.train_input_specs(cfg, cell)
    if cfg.is_encoder_decoder:
        assert batch["frames"].shape == (256, 4096, cfg.d_model)
        assert batch["tokens"].shape[0] == 256
    else:
        assert batch["tokens"].shape == (256, 4096)
        assert batch["labels"].shape == (256, 4096)
        if cfg.frontend:
            assert batch["prefix_embeds"].shape == (
                256, cfg.frontend_seq_len, cfg.d_model
            )


def test_cache_specs_shapes_no_allocation():
    cfg = get_config("gemma2-9b")
    cell = shp.SHAPES["decode_32k"]
    cache, toks = shp.decode_input_specs(cfg, cell)
    assert toks.shape == (128, 1)
    # alternating local/global: position 0 cache is window-capped
    k_local = cache["blocks"][0]["k"]
    k_global = cache["blocks"][1]["k"]
    assert k_local.shape[2] == cfg.window       # ring buffer
    assert k_global.shape[2] == cell.seq_len    # full cache
    assert isinstance(k_local, jax.ShapeDtypeStruct if False else type(k_local))


def test_state_cache_for_ssm():
    cfg = get_config("rwkv6-7b")
    cell = shp.SHAPES["long_500k"]
    cache, toks = shp.decode_input_specs(cfg, cell)
    # attention-free: O(1) state regardless of the 500k context
    wkv = cache["blocks"][0]["wkv"]
    H = cfg.d_model // cfg.rwkv_head_dim
    assert wkv.shape == (cfg.n_layers, 1, H, cfg.rwkv_head_dim,
                         cfg.rwkv_head_dim)
    total_bytes = sum(
        int(jnp.asarray([], l.dtype).dtype.itemsize) *
        int(__import__("numpy").prod(l.shape))
        for l in jax.tree_util.tree_leaves(cache)
    )
    assert total_bytes < 2**30  # the whole 500k "cache" is under 1 GiB


import jax  # noqa: E402  (used by test above)
