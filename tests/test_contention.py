"""Multi-tenant contention subsystem: ledger, virtual merge, trace harness.

Invariants under test (ISSUE 1 acceptance):
  * contention-degraded bandwidth <= isolated bandwidth, monotone in the
    number of co-located cross-host tenants;
  * an empty ledger is a no-op: B(S | ledger) == B(S) exactly;
  * releasing every job restores availability and *exact* isolated
    bandwidth;
  * the trace harness runs end-to-end and contention-aware BandPilot
    strictly beats the contention-oblivious variant on the same seed.
"""

import numpy as np
import pytest

import repro.core as core
from repro.core import baselines
from repro.core.bandwidth_sim import BandwidthSimulator
from repro.core.contention import contended_inter_cap, virtual_merge
from repro.core.tenancy import JobLedger


@pytest.fixture(scope="module")
def h100():
    cl = core.h100_cluster()
    sim = BandwidthSimulator(cl)
    tables = core.IntraHostTables(cl, sim)
    return cl, sim, tables


@pytest.fixture(scope="module")
def mix():
    cl = core.het_4mix_cluster()
    sim = BandwidthSimulator(cl)
    tables = core.IntraHostTables(cl, sim)
    return cl, sim, tables


CAND = list(range(0, 4)) + list(range(8, 12))        # 4+4 on hosts 0,1
TENANT_A = list(range(4, 8)) + list(range(12, 16))   # 4+4 on hosts 0,1
TENANT_B = list(range(16, 20)) + list(range(24, 28))  # 4+4 on hosts 2,3


# ---------------------------------------------------------------------------
# Ledger bookkeeping
# ---------------------------------------------------------------------------

def test_ledger_availability_roundtrip(h100):
    cl, _, _ = h100
    led = JobLedger(cl)
    assert led.available() == cl.all_gpus()
    alloc = led.admit("j", TENANT_A)
    assert alloc.k == 8 and alloc.host_ids == (0, 1) and alloc.cross_host
    assert set(led.available()) == set(cl.all_gpus()) - set(TENANT_A)
    assert led.occupancy(0) == 4 and led.occupancy(2) == 0
    led.release("j")
    assert led.available() == cl.all_gpus()
    assert len(led) == 0


def test_ledger_rejects_conflicts(h100):
    cl, _, _ = h100
    led = JobLedger(cl)
    led.admit("j", TENANT_A)
    with pytest.raises(ValueError):
        led.admit("j", TENANT_B)  # duplicate job id
    with pytest.raises(ValueError):
        led.admit("j2", [TENANT_A[0]])  # busy GPU
    with pytest.raises(ValueError):
        led.admit("j3", [0, 0])  # duplicate ids
    with pytest.raises(ValueError):
        led.admit("j4", [])  # empty
    with pytest.raises(KeyError):
        led.release("nope")  # unknown job


def test_single_host_jobs_never_contend(h100):
    cl, sim, _ = h100
    led = JobLedger(cl)
    led.admit("intra", list(range(4, 8)))  # single-host job on host 0
    assert led.rail_contenders(0, against=CAND) == 0
    assert sim.true_bandwidth(CAND, ledger=led) == sim.true_bandwidth(CAND)


# ---------------------------------------------------------------------------
# Contended ground truth
# ---------------------------------------------------------------------------

def test_empty_ledger_is_noop(h100):
    cl, sim, _ = h100
    led = JobLedger(cl)
    rng = np.random.default_rng(0)
    for s in sim.sample_allocations(20, rng):
        assert sim.true_bandwidth(s, ledger=led) == sim.true_bandwidth(s)


def test_degraded_leq_isolated(h100):
    cl, sim, _ = h100
    led = JobLedger(cl)
    iso = sim.true_bandwidth(CAND)
    led.admit("b", TENANT_B)  # different hosts: no effect
    assert sim.true_bandwidth(CAND, ledger=led) == iso
    led.admit("a", TENANT_A)  # shares hosts 0,1
    one = sim.true_bandwidth(CAND, ledger=led)
    assert one < iso
    led.admit("c", [20, 21, 28, 29])  # hosts 2,3: still no effect on CAND
    assert sim.true_bandwidth(CAND, ledger=led) == one


def test_more_contenders_degrade_more(h100):
    cl, sim, _ = h100
    led = JobLedger(cl)
    cand = [0, 1, 8, 9]  # 2+2 on hosts 0,1: rail-bound on H100
    iso = sim.true_bandwidth(cand)
    led.admit("a", [2, 3, 10, 11])
    one = sim.true_bandwidth(cand, ledger=led)
    led.admit("b", [4, 5, 12, 13])
    two = sim.true_bandwidth(cand, ledger=led)
    assert two < one < iso


def test_contention_never_increases_bandwidth(mix):
    """On intra-bound candidates extra contenders may be a no-op, but the
    degraded value must never exceed isolated."""
    cl, sim, _ = mix
    led = JobLedger(cl)
    cand = [0, 1, 8, 9]
    iso = sim.true_bandwidth(cand)
    led.admit("a", [2, 3, 10, 11])
    one = sim.true_bandwidth(cand, ledger=led)
    led.admit("b", [4, 5, 12, 13])
    two = sim.true_bandwidth(cand, ledger=led)
    assert two <= one <= iso


def test_release_restores_exact_isolated(h100):
    cl, sim, _ = h100
    led = JobLedger(cl)
    iso = sim.true_bandwidth(CAND)
    led.admit("a", TENANT_A)
    led.admit("b", TENANT_B)
    assert sim.true_bandwidth(CAND, ledger=led) < iso
    led.release("a")
    led.release("b")
    assert sim.true_bandwidth(CAND, ledger=led) == iso
    assert led.available() == cl.all_gpus()


def test_self_is_never_a_contender(h100):
    """Grading an *admitted* job must see the same contention as grading the
    candidate pre-admit: the job's own ledger entry is GPU-overlapping and
    therefore excluded."""
    cl, sim, _ = h100
    led = JobLedger(cl)
    led.admit("a", TENANT_A)
    pre = sim.true_bandwidth(CAND, ledger=led)
    led.admit("cand", CAND)
    post = sim.true_bandwidth(CAND, ledger=led)
    assert post == pre


# ---------------------------------------------------------------------------
# Virtual merge + predictor wrapper
# ---------------------------------------------------------------------------

def test_virtual_merge_structure(h100):
    cl, sim, _ = h100
    led = JobLedger(cl)
    led.admit("a", TENANT_A)
    led.admit("b", TENANT_B)
    view = virtual_merge(cl, led, CAND)
    assert view.contended
    assert [a.job_id for a in view.contenders] == ["a"]  # b shares no host
    assert set(view.merged_gpus) == set(CAND) | set(TENANT_A)
    assert view.rail_shares == {0: 2, 1: 2}
    # single-host subsets merge with nothing
    assert not virtual_merge(cl, led, [16, 17, 18]).contended


def test_wrapper_caps_multi_host_only(h100):
    cl, sim, tables = h100
    led = JobLedger(cl)
    gt = core.GroundTruthPredictor(sim)
    wrapped = core.ContentionAwarePredictor(cl, gt, led)
    single = [16, 17, 18, 19]
    subs = [CAND, single]
    np.testing.assert_allclose(wrapped.predict(subs), gt.predict(subs))
    led.admit("a", TENANT_A)
    iso_c, iso_s = gt.predict(subs)
    deg_c, deg_s = wrapped.predict(subs)
    assert deg_c < iso_c
    assert deg_s == iso_s  # single-host candidates never degraded
    assert np.isinf(contended_inter_cap(cl, led, single))
    # wrapper tracks the live ledger: release -> no-op again
    led.release("a")
    np.testing.assert_allclose(wrapped.predict(subs), gt.predict(subs))


def test_wrapped_ground_truth_matches_contended_truth(h100):
    """min(isolated GT, jittered fair-share cap) == contended ground truth
    whenever the intra terms don't dominate — and never exceeds it."""
    cl, sim, tables = h100
    led = JobLedger(cl)
    led.admit("a", TENANT_A)
    gt = core.GroundTruthPredictor(sim)
    wrapped = core.ContentionAwarePredictor(cl, gt, led)
    rng = np.random.default_rng(1)
    subs = [s for s in sim.sample_allocations(30, rng)
            if set(s).isdisjoint(TENANT_A)]
    est = wrapped.predict(subs)
    truth = np.asarray([sim.true_bandwidth(s, ledger=led) for s in subs])
    np.testing.assert_allclose(est, truth, rtol=1e-9)


def test_oracle_with_ledger_dominates(h100):
    cl, sim, tables = h100
    led = JobLedger(cl)
    led.admit("a", TENANT_A)
    avail = led.available()
    sub, opt = baselines.oracle_dispatch(cl, sim, tables, avail, 8, ledger=led)
    assert sim.true_bandwidth(sub, ledger=led) == opt
    # dominates the compactness baseline under the same contended metric
    topo = baselines.topo_dispatch(cl, avail, 8)
    assert opt >= sim.true_bandwidth(topo, ledger=led) - 1e-9
    # and matches brute force on a small pool
    pool = avail[:10]
    bsub, bopt = baselines.brute_force_oracle(cl, sim, pool, 4, ledger=led)
    osub, oopt = baselines.oracle_dispatch(cl, sim, tables, pool, 4, ledger=led)
    assert abs(oopt - bopt) < 1e-9


# ---------------------------------------------------------------------------
# Trace harness
# ---------------------------------------------------------------------------

def test_trace_replay_end_to_end(h100):
    cl, sim, tables = h100
    rng = np.random.default_rng(5)
    trace = core.poisson_trace(cl, 25, rng, mean_duration=6.0)
    disp = core.BandPilotDispatcher(
        cl, tables, core.GroundTruthPredictor(sim)
    )
    recs = core.replay_trace(cl, sim, tables, disp, trace)
    assert len(recs) == len(trace)  # every job eventually admitted
    assert len(disp.ledger) == 0    # ledger drained
    for r in recs:
        assert 0.0 < r.gbe <= 1.0 + 1e-9
        assert r.bw <= r.isolated_bw + 1e-9
        assert r.wait >= 0.0
    # FIFO: admissions never reorder arrivals
    order = {j.job_id: i for i, j in enumerate(trace)}
    admitted = sorted(recs, key=lambda r: (r.t_admit, order[r.job_id]))
    assert [order[r.job_id] for r in admitted] == sorted(order.values())


def test_contention_aware_beats_oblivious(h100):
    """The headline acceptance criterion, on the exact benchmark protocol:
    same seed, >=2 concurrent cross-host jobs sharing hosts, strictly higher
    mean contention-degraded GBE for the aware variant."""
    cl, sim, tables = h100
    seed = 0
    trace = core.poisson_trace(
        cl, 40, np.random.default_rng(seed),
        mean_interarrival=1.0, mean_duration=8.0,
        k_choices=range(4, cl.n_gpus // 2 + 1),
    )
    results = core.compare_contention_awareness(
        cl, sim, tables, lambda: core.GroundTruthPredictor(sim), trace,
        seed=seed, include_baselines=False,
    )
    summ = {n: core.summarize_trace(r)[n] for n, r in results.items()}
    # the trace actually exercises contention
    assert summ["BandPilot"]["frac_contended"] > 0.2
    assert max(r.n_live for r in results["BandPilot"]) >= 2
    assert (summ["BandPilot"]["mean_gbe"]
            > summ["BandPilot-oblivious"]["mean_gbe"])


def test_trace_with_het_cluster(mix):
    cl, sim, tables = mix
    seed = 1
    trace = core.poisson_trace(
        cl, 30, np.random.default_rng(seed),
        mean_interarrival=1.0, mean_duration=8.0,
        k_choices=range(4, 13),
    )
    results = core.compare_contention_awareness(
        cl, sim, tables, lambda: core.GroundTruthPredictor(sim), trace,
        seed=seed, include_baselines=False,
    )
    summ = {n: core.summarize_trace(r)[n] for n, r in results.items()}
    assert (summ["BandPilot"]["mean_gbe"]
            > summ["BandPilot-oblivious"]["mean_gbe"])
