"""Fallback shims for ``hypothesis`` so its absence degrades to skips.

The property-based tests use a small slice of the hypothesis API
(``@settings``/``@given`` decorators and ``st.*`` strategy constructors).
On images without hypothesis installed, importing these stand-ins lets the
test modules collect normally and marks each property test as skipped
instead of erroring the whole module at import time.

Usage (top of a test module):

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hypothesis_fallback import given, settings, st
"""

import pytest


def given(*_args, **_kwargs):
    """Replace the test with a zero-arg skipper (strategies are ignored)."""

    def deco(fn):
        def _skipped():
            pytest.skip("hypothesis is not installed")

        _skipped.__name__ = fn.__name__
        _skipped.__doc__ = fn.__doc__
        return _skipped

    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn

    return deco


class _StrategyStub:
    """``st.<anything>(...)`` -> None; only ever consumed by the fake given."""

    def __getattr__(self, name):
        def _strategy(*args, **kwargs):
            return None

        return _strategy


st = _StrategyStub()
