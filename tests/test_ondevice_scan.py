"""On-device elimination scan (ISSUE 6): audit, goldens, batcher, LRU.

The load-bearing guarantees:

* every round of a fused ``lax.scan`` descent scores children at exactly
  ``np.float32(host-path float64 score)`` and eliminates the same slot the
  host loop would (audited round by round, bare-isolated AND through the
  analytic contention cap table);
* pinned scheduler-trace replays select **byte-identical subsets** with the
  scan enabled (the new default) vs disabled (``use_scan=False``), in both
  analytic and learned contention modes;
* the cross-search inference batcher is value-neutral: whichever requests
  happen to fuse into one padded apply, every caller receives bit-identical
  outputs to a solo apply (property-based, concurrent threads included);
* the LRU-capped lifetime memo can only forget values, never change them;
* ``PredictorStats`` accounts the scan path in its own bucket — no
  double-counting through the ``collect_stats`` chain merge.
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

    HAVE_HYPOTHESIS = False

import repro.core as core
from repro.core import defrag as defrag_mod
from repro.core import features as feat
from repro.core import search
from repro.core import surrogate as surr
from repro.core.predict_cache import (
    InferenceBatcher,
    LruDict,
    PredictionCache,
    PredictorStats,
)
from repro.core.tenancy import JobLedger


@pytest.fixture(scope="module", params=["H100", "Het-4Mix"])
def stack(request):
    cl = core.PAPER_CLUSTERS[request.param]()
    sim = core.BandwidthSimulator(cl)
    tables = core.IntraHostTables(cl, sim)
    params = surr.init_hierarchical_params(jax.random.PRNGKey(0))
    return cl, sim, tables, params


def _tenanted_ledger(cl):
    led = JobLedger(cl)
    led.admit("a", [0, 1, cl.hosts[1].gpu_ids[0]])
    led.admit("b", [cl.hosts[1].gpu_ids[1], cl.hosts[-1].gpu_ids[0]])
    led.admit("s", [cl.hosts[0].gpu_ids[5]])  # single-host: occupancy only
    return led


def _multi_host_parent(cl, rng, n0, exclude=()):
    """A sorted n0-GPU parent spanning >= 2 hosts, avoiding ``exclude``."""
    pool = [g for g in range(cl.n_gpus) if g not in set(exclude)]
    while True:
        parent = sorted(rng.choice(pool, size=n0, replace=False).tolist())
        if len(cl.partition_by_host(parent)) > 1:
            return parent


def _audit_descent(cl, predictor, res, parent, k):
    """Replay the host elimination loop round by round against a
    ScanResult: f32 score identity at every live slot, same elimination."""
    parent = sorted(parent)
    s = list(parent)
    assert res.n_rounds == len(parent) - k
    for r in range(res.n_rounds):
        live = np.nonzero(res.sels[r])[0]
        assert [parent[i] for i in live] == s
        children = [s[:i] + s[i + 1:] for i in range(len(s))]
        host = predictor.predict(children)          # float64 host path
        host32 = np.float32(host)
        np.testing.assert_array_equal(res.scores[r][live], host32)
        # same argmax over the f32 scores (first-wins tie break both sides)
        j = int(np.argmax(host32))
        assert res.elims[r] == live[j]
        s.pop(j)
    assert res.subset == s and len(s) == k


# ---------------------------------------------------------------------------
# Round-by-round audit vs the host loop
# ---------------------------------------------------------------------------

def test_scan_descent_audit_isolated(stack):
    cl, sim, tables, params = stack
    pred = core.SurrogatePredictor(cl, tables, params)
    rng = np.random.default_rng(10)
    for n0, k in ((12, 6), (20, 10), (9, 2)):
        parent = _multi_host_parent(cl, rng, n0)
        res = pred.eliminate_to(parent, k)
        assert res is not None
        assert res.n_capped == 0  # no caps table: isolated scoring
        _audit_descent(cl, pred, res, parent, k)


def test_scan_descent_audit_contended(stack):
    """Through the analytic contention wrapper: device scores gather the
    per-ledger cap table and still match np.float32(host min(iso, cap))."""
    cl, sim, tables, params = stack
    led = _tenanted_ledger(cl)
    pred = core.SurrogatePredictor(cl, tables, params)
    wrapped = core.ContentionAwarePredictor(cl, pred, led)
    rng = np.random.default_rng(11)
    free = sorted(set(range(cl.n_gpus)) - led.busy())
    for n0, k in ((14, 7), (10, 4)):
        parent = _multi_host_parent(cl, rng, n0, exclude=led.busy())
        assert set(parent) <= set(free)
        before = wrapped.stats.n_capped
        res = wrapped.eliminate_to(parent, k)
        assert res is not None
        assert wrapped.stats.n_capped == before + res.n_capped
        _audit_descent(cl, wrapped, res, parent, k)  # host predicts also
        #                           bump n_capped, so assert before auditing


def test_scan_declines_out_of_envelope(stack):
    cl, sim, tables, params = stack
    pred = core.SurrogatePredictor(cl, tables, params)
    h0 = list(cl.hosts[0].gpu_ids[:6])
    assert pred.eliminate_to(h0, 3) is None          # single-host parent
    assert pred.eliminate_to([0, cl.hosts[1].gpu_ids[0]], 2) is None  # n0<=k
    off = core.SurrogatePredictor(cl, tables, params, use_scan=False)
    parent = _multi_host_parent(cl, np.random.default_rng(0), 12)
    assert off.eliminate_to(parent, 6) is None       # scan disabled
    slow = core.SurrogatePredictor(cl, tables, params, vectorized=False)
    assert slow.eliminate_to(parent, 6) is None      # loop featurizer
    # parents overlapping live jobs decline at the wrapper
    led = _tenanted_ledger(cl)
    wrapped = core.ContentionAwarePredictor(cl, pred, led)
    overlap = sorted(set(parent) | {0})  # GPU 0 is held by job "a"
    assert wrapped.eliminate_to(overlap, 6) is None


# ---------------------------------------------------------------------------
# Search- and trace-level goldens: scan on vs off, byte-identical
# ---------------------------------------------------------------------------

def test_pts_search_scan_vs_host(stack):
    cl, sim, tables, params = stack
    on = core.SurrogatePredictor(cl, tables, params)
    off = core.SurrogatePredictor(cl, tables, params, use_scan=False)
    rng = np.random.default_rng(12)
    for k in (4, 9, 12):
        avail = sorted(
            rng.choice(cl.n_gpus, size=min(cl.n_gpus, 22),
                       replace=False).tolist()
        )
        a = search.pts_search(cl, tables, on, avail, k)
        b = search.pts_search(cl, tables, off, avail, k)
        assert a.subset == b.subset
        assert a.predicted_bw == b.predicted_bw
        assert a.n_candidates == b.n_candidates  # same descent accounting


def test_hybrid_search_scan_vs_host_contended(stack):
    cl, sim, tables, params = stack
    led = _tenanted_ledger(cl)
    free = sorted(set(range(cl.n_gpus)) - led.busy())
    rng = np.random.default_rng(13)
    avail = sorted(rng.choice(free, size=min(len(free), 18),
                              replace=False).tolist())
    results = {}
    for use_scan in (True, False):
        pred = core.SurrogatePredictor(cl, tables, params,
                                       use_scan=use_scan)
        wrapped = core.cached_contention_predictor(cl, pred, led)
        results[use_scan] = core.hybrid_search(cl, tables, wrapped, avail, 9)
    assert results[True].subset == results[False].subset
    assert results[True].predicted_bw == results[False].predicted_bw


def _scan_dispatcher(cl, tables, params, use_scan, **kw):
    pred = core.SurrogatePredictor(cl, tables, params, use_scan=use_scan)
    return core.BandPilotDispatcher(cl, tables, pred, aot_warm=use_scan,
                                    **kw)


def _logged_replay(disp, cl, sim, tables, trace):
    log = []
    orig = core.BandPilotDispatcher.dispatch

    def wrapped(self, avail, k, rng=None, _log=log):
        s = orig(self, avail, k, rng=rng)
        _log.append(tuple(s))
        return s

    disp.dispatch = wrapped.__get__(disp)
    recs = core.AdmissionScheduler(cl, sim, tables, disp).run(trace)
    return log, recs


def test_trace_replay_golden_scan_on_off(stack):
    """THE acceptance golden: a pinned fifo scheduler trace selects
    byte-identical subsets with the on-device scan enabled (the new
    default) vs disabled (the host-loop configuration)."""
    cl, sim, tables, params = stack
    trace = core.poisson_trace(
        cl, 14, np.random.default_rng(14),
        mean_interarrival=1.0, mean_duration=6.0,
        k_choices=range(4, cl.n_gpus // 2 + 1),
    )
    logs, recs = {}, {}
    for use_scan in (True, False):
        disp = _scan_dispatcher(cl, tables, params, use_scan)
        logs[use_scan], recs[use_scan] = _logged_replay(
            disp, cl, sim, tables, trace
        )
    assert logs[True] == logs[False]
    for a, b in zip(recs[True], recs[False]):
        assert (a.job_id, a.t_admit, a.bw, a.gbe) == \
            (b.job_id, b.t_admit, b.bw, b.gbe)


@pytest.mark.slow
def test_trace_replay_golden_scan_learned_mode(stack):
    """Scan on/off byte identity in the learned-contention configuration:
    contended ledgers decline to the host loop, empty-ledger admissions
    still ride the scan — placements must not move either way."""
    cl, sim, tables, params = stack
    cparams = surr.init_contended_params(params)
    trace = core.poisson_trace(
        cl, 10, np.random.default_rng(15), mean_duration=6.0,
        k_choices=range(4, cl.n_gpus // 2 + 1),
    )
    logs = {}
    for use_scan in (True, False):
        cpred = core.ContendedSurrogatePredictor(cl, tables, cparams)
        disp = _scan_dispatcher(
            cl, tables, params, use_scan,
            contention_mode="learned", contended_predictor=cpred,
        )
        logs[use_scan], _ = _logged_replay(disp, cl, sim, tables, trace)
    assert logs[True] == logs[False]


# ---------------------------------------------------------------------------
# AOT warm-up
# ---------------------------------------------------------------------------

def test_warm_scan_idempotent(stack):
    cl, sim, tables, params = stack
    pred = core.SurrogatePredictor(cl, tables, params)
    pred.warm_scan()  # may or may not compile (executables are process-wide)
    dt = feat.device_tables(cl, tables)
    caps_l = dt.caps_inf().shape[0]
    b = surr.SCAN_MIN_SLOTS
    while b <= min(max(cl.n_gpus, surr.SCAN_MIN_SLOTS), surr.SCAN_MAX_SLOTS):
        assert (b, cl.n_hosts, dt.mask_size, caps_l) in surr._SCAN_COMPILED
        b *= 2
    assert pred.warm_scan() == 0.0  # everything already compiled
    off = core.SurrogatePredictor(cl, tables, params, use_scan=False)
    assert off.warm_scan() == 0.0  # outside the envelope: no-op
    # a warmed dispatcher records the spend; aot_warm=False records zero
    disp = core.BandPilotDispatcher(cl, tables, pred)
    assert disp.aot_warm_seconds == 0.0  # warmed above: nothing left to do
    cold = core.BandPilotDispatcher(cl, tables, pred, aot_warm=False)
    assert cold.aot_warm_seconds == 0.0


# ---------------------------------------------------------------------------
# Cross-search inference batcher: value neutrality (property-based)
# ---------------------------------------------------------------------------

_STACK_CACHE = {}


def _h100_stack():
    if "H100" not in _STACK_CACHE:
        cl = core.PAPER_CLUSTERS["H100"]()
        sim = core.BandwidthSimulator(cl)
        tables = core.IntraHostTables(cl, sim)
        params = surr.init_hierarchical_params(jax.random.PRNGKey(0))
        _STACK_CACHE["H100"] = (cl, sim, tables, params)
    return _STACK_CACHE["H100"]


def _solo_apply(params, feats, mask):
    """The un-batched apply path: pad B to a power of two with sentinel
    rows, one jitted call, slice the real rows back."""
    B = feats.shape[0]
    Bp = 1
    while Bp < B:
        Bp *= 2
    f = np.zeros((Bp,) + feats.shape[1:], feats.dtype)
    m = np.zeros((Bp, feats.shape[1]), mask.dtype)
    m[B:, 0] = 1.0
    f[:B] = feats
    m[:B] = mask
    out = np.asarray(
        surr._apply_hierarchical_bw(params, jnp.asarray(f), jnp.asarray(m))
    )
    return out[:B]


def _check_batcher_neutral(seed: int) -> None:
    cl, sim, tables, params = _h100_stack()
    rng = np.random.default_rng(seed)
    n_workers = int(rng.integers(1, 4))
    requests = []
    for _ in range(n_workers):
        B = int(rng.integers(1, 5))
        subs = [
            sorted(rng.choice(cl.n_gpus, size=int(rng.integers(2, 13)),
                              replace=False).tolist())
            for _ in range(B)
        ]
        requests.append(feat.featurize_batch(cl, tables, subs))
    want = [_solo_apply(params, f, m) for f, m in requests]
    batcher = InferenceBatcher()
    got = [None] * n_workers
    errs = []
    barrier = threading.Barrier(n_workers)

    def run(i):
        try:
            with batcher.worker():
                barrier.wait()
                f, m = requests[i]
                got[i] = batcher.apply(
                    surr._apply_hierarchical_bw, params, f, m
                )
        except BaseException as e:  # noqa: BLE001 - re-raised below
            errs.append(e)

    threads = [threading.Thread(target=run, args=(i,))
               for i in range(n_workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errs:
        raise errs[0]
    assert batcher.n_requests == n_workers
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_property_batcher_value_neutral(seed):
    _check_batcher_neutral(seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS, reason="hypothesis drives this instead")
def test_seeded_batcher_value_neutral():
    for seed in (0, 1, 7, 1234):
        _check_batcher_neutral(seed)


def test_batcher_through_predictor(stack):
    """The surrogate's apply path routes through a thread-registered
    batcher and returns exactly what the direct path returns."""
    cl, sim, tables, params = stack
    pred = core.SurrogatePredictor(cl, tables, params)
    rng = np.random.default_rng(16)
    subs = [sorted(rng.choice(cl.n_gpus, size=10, replace=False).tolist())
            for _ in range(5)]
    want = pred.predict(subs)
    batcher = InferenceBatcher()
    with batcher.worker():
        got = pred.predict(subs)
    np.testing.assert_array_equal(want, got)
    assert batcher.n_requests > 0


def test_joint_search_batched_identical(stack):
    """joint_hybrid_search with the batcher (threaded orders) picks the
    same plan as the sequential path."""
    cl, sim, tables, params = stack
    pred = core.SurrogatePredictor(cl, tables, params)
    led = JobLedger(cl)
    led.admit("t", [0, 1])
    reqs = [("j1", 12), ("j2", 4), ("j3", 8)]
    seq = search.joint_hybrid_search(cl, tables, pred, led, reqs)
    bat = search.joint_hybrid_search(cl, tables, pred, led, reqs,
                                     batcher=InferenceBatcher())
    assert seq.order == bat.order
    assert [p.subset for p in seq.placements] == \
        [p.subset for p in bat.placements]
    assert seq.total_predicted_bw == bat.total_predicted_bw


def test_defrag_proposer_batcher_neutral(stack):
    cl, sim, tables, params = stack
    pred = core.SurrogatePredictor(cl, tables, params)
    led = _tenanted_ledger(cl)
    free = sorted(set(range(cl.n_gpus)) - led.busy())
    plain = defrag_mod.consolidation_proposer(cl, tables, pred)
    batched = defrag_mod.consolidation_proposer(
        cl, tables, pred, batcher=InferenceBatcher()
    )
    assert plain(led, free, 4) == batched(led, free, 4)


# ---------------------------------------------------------------------------
# LRU-capped lifetime memo
# ---------------------------------------------------------------------------

def _check_lru(seed: int) -> None:
    rng = np.random.default_rng(seed)
    cap = int(rng.integers(1, 9))
    lru = LruDict(cap)
    ref = {}
    for step in range(60):
        key = int(rng.integers(0, 12))
        if rng.random() < 0.5:
            ref[key] = (key, step) if rng.random() < 0.2 else key * 2
            lru[key] = ref[key]
        else:
            got = lru.get(key)
            # eviction may forget, but a served value is never wrong
            assert got is None or got == ref[key]
        assert len(lru) <= cap
    # recency: touch the oldest entry, insert a fresh key -> the touched
    # entry survives and the next-oldest is the one evicted
    lru = LruDict(2)
    lru["a"] = 1
    lru["b"] = 2
    assert lru["a"] == 1
    lru["c"] = 3
    assert "a" in lru and "b" not in lru and "c" in lru


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_property_lru_dict(seed):
    _check_lru(seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS, reason="hypothesis drives this instead")
def test_seeded_lru_dict():
    for seed in (0, 1, 7, 1234):
        _check_lru(seed)


def test_prediction_cache_lru_capped(stack):
    """A tightly-capped lifetime memo stays within its bound and keeps
    serving correct values (recompute-on-evict, never a wrong hit)."""
    cl, sim, tables, params = stack
    pred = core.SurrogatePredictor(cl, tables, params)
    cache = PredictionCache(max_entries=8)
    cached = cache.wrap(pred, mode="isolated", versioned=False)
    fresh = core.SurrogatePredictor(cl, tables, params)
    rng = np.random.default_rng(17)
    subs = [sorted(rng.choice(cl.n_gpus, size=6, replace=False).tolist())
            for _ in range(30)]
    for s in subs + subs[:10]:
        np.testing.assert_array_equal(
            cached.predict([s]), fresh.predict([s])
        )
        assert len(cache._static) <= 8


# ---------------------------------------------------------------------------
# Stats: the scan path gets its own bucket, merges cleanly
# ---------------------------------------------------------------------------

def test_scan_stats_accounting(stack):
    cl, sim, tables, params = stack
    pred = core.SurrogatePredictor(cl, tables, params)
    parent = _multi_host_parent(cl, np.random.default_rng(18), 16)
    res = pred.eliminate_to(parent, 8)
    assert res is not None
    assert pred.stats.n_scan_steps == 8
    assert pred.stats.scan_seconds > 0.0
    # the fused descent bumps ONLY the scan bucket: no phantom model calls
    assert pred.stats.n_model_calls == 0
    assert pred.stats.infer_seconds == 0.0
    merged = PredictorStats.merged(pred.stats, pred.stats)
    assert merged.n_scan_steps == 2 * pred.stats.n_scan_steps
    # reset() clears the new fields with everything else
    pred.stats.reset()
    assert pred.stats.n_scan_steps == 0 and pred.stats.scan_seconds == 0.0


def test_dispatcher_stats_include_scan(stack):
    cl, sim, tables, params = stack
    pred = core.SurrogatePredictor(cl, tables, params)
    disp = core.BandPilotDispatcher(cl, tables, pred)
    disp.admit("a", 12)
    disp.admit("b", 10)
    st_ = disp.predictor_stats()
    assert st_.n_scan_steps == pred.stats.n_scan_steps > 0
    assert st_.scan_seconds == pred.stats.scan_seconds > 0.0
    # the host-loop fields still behave (final re-score runs on the host)
    assert st_.n_model_calls > 0 and st_.infer_seconds > 0.0


# ---------------------------------------------------------------------------
# Scheduler: batch_applies on/off golden
# ---------------------------------------------------------------------------

def test_scheduler_batch_applies_golden(stack, monkeypatch):
    """A batched-policy burst placed as one joint plan is byte-identical
    with the cross-search batcher on vs off."""
    cl, sim, tables, params = stack
    trace = (
        [core.TraceJob("filler", 0.0, 5.0, cl.n_gpus)]
        + [core.TraceJob(f"b{i}", 1.0 + 0.1 * i, 50.0, [4, 8, 12][i % 3])
           for i in range(3)]
    )
    plans = {}
    orig = search.joint_hybrid_search

    def run(batch_applies):
        log = []

        def spy(*a, **kw):
            plan = orig(*a, **kw)
            log.append([tuple(p.subset) for p in plan.placements])
            return plan

        monkeypatch.setattr(search, "joint_hybrid_search", spy)
        pred = core.SurrogatePredictor(cl, tables, params)
        disp = core.BandPilotDispatcher(cl, tables, pred)
        cfg = core.SchedulerConfig(
            policy="batched", batch_window=1.0, batch_applies=batch_applies
        )
        sch = core.AdmissionScheduler(cl, sim, tables, disp, config=cfg)
        recs = sch.run(trace)
        plans[batch_applies] = log
        return [(r.job_id, r.t_admit, r.batch_size, r.bw, r.gbe)
                for r in recs], sch

    recs_off, _ = run(False)
    recs_on, sch_on = run(True)
    assert recs_off == recs_on
    assert plans[True] == plans[False]
    assert any(len(p) > 1 for p in plans[True])  # a real joint batch ran
    assert sch_on._batcher is not None
    assert sch_on._batcher.n_requests > 0  # applies actually fused
