"""Edge cases of the hybrid search machinery (ISSUE 1 satellite).

Covers the paths the seed tests never exercised: capacity-overflow
re-waterfilling and infeasibility in ``balanced_count_assignments``, EHA's
degenerate greedy fallback, and PTS at the k extremes.
"""

import numpy as np
import pytest

import repro.core as core
from repro.core import search
from repro.core.bandwidth_sim import BandwidthSimulator
from repro.core.search import balanced_count_assignments


@pytest.fixture(scope="module")
def h100():
    cl = core.h100_cluster()
    sim = BandwidthSimulator(cl)
    tables = core.IntraHostTables(cl, sim)
    gt = core.GroundTruthPredictor(sim)
    return cl, sim, tables, gt


# ---------------------------------------------------------------------------
# balanced_count_assignments
# ---------------------------------------------------------------------------

def test_balanced_counts_even_split():
    out = balanced_count_assignments([8, 8], 8)
    assert (4, 4) in out
    assert all(sum(c) == 8 for c in out)


def test_balanced_counts_overflow_rewaterfill():
    """A host's near-even share can exceed its availability; the overflow
    must be re-waterfilled onto hosts with headroom."""
    out = balanced_count_assignments([8, 1], 8)
    assert out, "feasible split must be found"
    for counts in out:
        assert sum(counts) == 8
        assert counts[0] <= 8 and counts[1] <= 1
    assert (7, 1) in out


def test_balanced_counts_overflow_three_hosts():
    out = balanced_count_assignments([8, 2, 2], 10)
    assert out
    for counts in out:
        assert sum(counts) == 10
        assert all(c <= cap for c, cap in zip(counts, [8, 2, 2]))


def test_balanced_counts_infeasible_returns_empty():
    assert balanced_count_assignments([2, 2], 5) == []


def test_balanced_counts_k_below_host_count():
    # k < m: some hosts legitimately get zero
    out = balanced_count_assignments([8, 8, 8], 2)
    assert out
    for counts in out:
        assert sum(counts) == 2


# ---------------------------------------------------------------------------
# EHA degenerate fallback
# ---------------------------------------------------------------------------

def test_eha_greedy_fallback(h100):
    """With the host-combination budget zeroed out, EHA must still return a
    valid allocation via its greedy fill."""
    cl, sim, tables, gt = h100
    avail = list(range(4)) + list(range(8, 12)) + list(range(16, 20))
    res = search.eha_search(cl, tables, gt, avail, 9, max_host_combos=0)
    assert len(res.subset) == 9
    assert set(res.subset) <= set(avail)
    assert res.predicted_bw > 0


def test_eha_k_exceeds_pool_raises(h100):
    cl, sim, tables, gt = h100
    with pytest.raises(ValueError):
        search.eha_search(cl, tables, gt, list(range(4)), 5)


# ---------------------------------------------------------------------------
# PTS extremes
# ---------------------------------------------------------------------------

def test_pts_k_equals_pool(h100):
    """k == len(avail): nothing to eliminate; the answer is the pool."""
    cl, sim, tables, gt = h100
    avail = sorted([0, 1, 2, 9, 10, 17, 18, 19, 25, 26])
    res = search.pts_search(cl, tables, gt, avail, len(avail))
    assert res.subset == avail
    assert res.predicted_bw == pytest.approx(sim.true_bandwidth(avail))


def test_pts_k_one(h100):
    cl, sim, tables, gt = h100
    avail = [3, 11, 19, 27]
    res = search.pts_search(cl, tables, gt, avail, 1)
    assert len(res.subset) == 1
    assert set(res.subset) <= set(avail)


def test_pts_single_gpu_full_cluster(h100):
    cl, sim, tables, gt = h100
    res = search.pts_search(cl, tables, gt, cl.all_gpus(), 1)
    assert len(res.subset) == 1


def test_hybrid_at_extremes(h100):
    cl, sim, tables, gt = h100
    rng = np.random.default_rng(0)
    avail = sorted(rng.choice(cl.n_gpus, size=12, replace=False).tolist())
    for k in (1, len(avail)):
        hyb = search.hybrid_search(cl, tables, gt, avail, k)
        assert len(hyb.subset) == k
        assert set(hyb.subset) <= set(avail)
