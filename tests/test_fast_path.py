"""Dispatch fast path (ISSUE 5): bit-identity, cache invalidation, perms.

The load-bearing guarantees:

* the vectorized featurizers and batched analytic caps are **bit-identical**
  (exact array equality) to the legacy loop implementations;
* with the prediction cache and every vectorized path enabled (the new
  defaults), searches and pinned scheduler-trace replays select
  **byte-identical subsets** vs the all-off pre-PR configuration;
* the ledger version counter bumps on every admit/release and versioned
  cache entries invalidate by construction (property-based, hypothesis with
  seeded fallback);
* the lazy distinct-multiset-permutation generator equals the old
  ``sorted(set(itertools.permutations(...)))`` on small inputs and respects
  ``max_assignments`` without enumeration on large ones.
"""

import itertools
import time

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

    HAVE_HYPOTHESIS = False

import repro.core as core
from repro.core import contention as ct
from repro.core import features as feat
from repro.core import search
from repro.core import surrogate as surr
from repro.core.predict_cache import (
    GradingCache,
    PredictionCache,
    PredictorStats,
)
from repro.core.search import _distinct_permutations, balanced_count_assignments
from repro.core.tenancy import JobLedger


@pytest.fixture(scope="module", params=["H100", "Het-4Mix"])
def stack(request):
    cl = core.PAPER_CLUSTERS[request.param]()
    sim = core.BandwidthSimulator(cl)
    tables = core.IntraHostTables(cl, sim)
    params = surr.init_hierarchical_params(jax.random.PRNGKey(0))
    return cl, sim, tables, params


def _tenanted_ledger(cl):
    led = JobLedger(cl)
    led.admit("a", [0, 1, cl.hosts[1].gpu_ids[0]])
    led.admit("b", [cl.hosts[1].gpu_ids[1], cl.hosts[-1].gpu_ids[0]])
    led.admit("s", [cl.hosts[0].gpu_ids[5]])  # single-host: occupancy only
    return led


# ---------------------------------------------------------------------------
# Vectorized featurization == loop featurization, bit for bit
# ---------------------------------------------------------------------------

def test_vectorized_featurizers_bit_identical(stack):
    cl, sim, tables, _ = stack
    subs = sim.sample_allocations(30, np.random.default_rng(0),
                                  multi_host_only=False)
    subs += [[0], [0, 1], list(range(cl.n_gpus))]
    for hn in (True, False):
        f1, m1 = feat.featurize_batch_loop(cl, tables, subs, host_norm=hn)
        f2, m2 = feat.featurize_batch(cl, tables, subs, host_norm=hn)
        assert np.array_equal(f1, f2) and np.array_equal(m1, m2)
    led = _tenanted_ledger(cl)
    busy = led.busy()
    pairs = [(s, led) for s in subs if busy.isdisjoint(s)]
    pairs += [(s, None) for s in subs[:5]]
    pairs += [(s, JobLedger(cl)) for s in subs[:5]]       # empty ledger
    pairs += [(list(led.allocation("a").gpus), led)]       # self-overlap
    for inc in (True, False):
        f1, m1 = feat.featurize_contended_batch_loop(
            cl, tables, pairs, include_contenders=inc
        )
        f2, m2 = feat.featurize_contended_batch(
            cl, tables, pairs, include_contenders=inc
        )
        assert np.array_equal(f1, f2) and np.array_equal(m1, m2)
    # truncation parity under a tight token budget
    f1, m1 = feat.featurize_contended_batch_loop(
        cl, tables, pairs, max_tokens=cl.n_hosts
    )
    f2, m2 = feat.featurize_contended_batch(
        cl, tables, pairs, max_tokens=cl.n_hosts
    )
    assert np.array_equal(f1, f2) and np.array_equal(m1, m2)


def test_featurize_children_bit_identical(stack):
    cl, sim, tables, _ = stack
    rng = np.random.default_rng(1)
    for _ in range(5):
        k = int(rng.integers(2, cl.n_gpus + 1))
        parent = sorted(rng.choice(cl.n_gpus, size=k, replace=False).tolist())
        kids = [parent[:i] + parent[i + 1:] for i in range(len(parent))]
        f1, m1 = feat.featurize_batch_loop(cl, tables, kids)
        f2, m2 = feat.featurize_children(cl, tables, parent)
        assert np.array_equal(f1, f2) and np.array_equal(m1, m2)


def test_featurize_one_bounds_check(stack):
    """A subset spanning more hosts than max_hosts raises the descriptive
    ValueError (used to die with a bare IndexError)."""
    cl, sim, tables, _ = stack
    spread = [h.gpu_ids[0] for h in cl.hosts]  # one GPU per host
    with pytest.raises(ValueError, match="spans"):
        feat.featurize_one(cl, tables, spread, max_hosts=cl.n_hosts - 1)
    with pytest.raises(ValueError, match="spans"):
        feat.featurize_batch(cl, tables, [spread], max_hosts=cl.n_hosts - 1)
    with pytest.raises(ValueError, match="spans"):
        feat.featurize_contended_one(
            cl, tables, spread, None, max_tokens=cl.n_hosts - 1
        )


# ---------------------------------------------------------------------------
# Batched analytic caps == scalar caps, bit for bit
# ---------------------------------------------------------------------------

def test_batched_caps_bit_identical(stack):
    cl, sim, tables, _ = stack
    led = _tenanted_ledger(cl)
    rng = np.random.default_rng(2)
    free = sorted(set(range(cl.n_gpus)) - led.busy())
    subs = [[free[0]]]
    for _ in range(40):
        k = int(rng.integers(1, min(12, len(free)) + 1))
        subs.append(sorted(rng.choice(free, size=k, replace=False).tolist()))
    subs.append(list(led.allocation("a").gpus))  # re-grading a live job
    cross = led.cross_jobs_by_host()
    loop = np.asarray([ct._cap_from_snapshot(cl, cross, s) for s in subs])
    vec = ct._caps_from_snapshot_batched(cl, cross, subs)
    assert np.array_equal(loop, vec)


def test_contention_wrapper_vectorized_equals_loop(stack):
    cl, sim, tables, _ = stack
    led = _tenanted_ledger(cl)
    gt = core.GroundTruthPredictor(sim)
    free = sorted(set(range(cl.n_gpus)) - led.busy())
    rng = np.random.default_rng(3)
    subs = [sorted(rng.choice(free, size=6, replace=False).tolist())
            for _ in range(20)]
    fast = core.ContentionAwarePredictor(cl, gt, led)
    slow = core.ContentionAwarePredictor(cl, gt, led, vectorized=False)
    np.testing.assert_array_equal(fast.predict(subs), slow.predict(subs))
    assert fast.n_capped == slow.n_capped


# ---------------------------------------------------------------------------
# Ledger version counter + cache invalidation (property-based)
# ---------------------------------------------------------------------------

def _check_version_and_invalidation(seed: int) -> None:
    cl = core.h100_cluster()
    sim = core.BandwidthSimulator(cl)
    led = JobLedger(cl)
    gt = core.GroundTruthPredictor(sim)
    wrapped = core.ContentionAwarePredictor(cl, gt, led)
    cache = PredictionCache(led)
    cached = cache.wrap(wrapped, mode="analytic")
    fresh = core.ContentionAwarePredictor(
        cl, core.GroundTruthPredictor(sim), led
    )
    rng = np.random.default_rng(seed)
    live = []
    cand = [0, 1, 8, 9, 16, 17]
    last_version = led.version
    for step in range(12):
        if live and (len(live) > 3 or rng.random() < 0.4):
            led.release(live.pop(int(rng.integers(len(live)))))
        else:
            free = sorted(set(range(cl.n_gpus)) - led.busy() - set(cand))
            k = int(rng.integers(1, 5))
            gpus = sorted(rng.choice(free, size=min(k, len(free)),
                                     replace=False).tolist())
            jid = f"j{step}"
            led.admit(jid, gpus)
            live.append(jid)
        # ANY admit/release bumps the version...
        assert led.version > last_version
        last_version = led.version
        # ...and the versioned cache serves the current-occupancy value
        # (twice: the second call must be a hit with the same answer)
        v1 = cached.predict([cand])
        v2 = cached.predict([cand])
        want = fresh.predict([cand])
        np.testing.assert_array_equal(v1, want)
        np.testing.assert_array_equal(v2, want)
    assert cache.stats.cache_hits > 0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_property_cache_invalidation(seed):
    _check_version_and_invalidation(seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS, reason="hypothesis drives this instead")
def test_seeded_cache_invalidation():
    for seed in (0, 1, 7, 1234):
        _check_version_and_invalidation(seed)


def test_release_restores_state_but_not_version():
    cl = core.h100_cluster()
    led = JobLedger(cl)
    v0 = led.version
    led.admit("j", [0, 1])
    led.release("j")
    assert led.available() == cl.all_gpus()
    assert led.version == v0 + 2  # monotonic: restores never rewind it


def test_grading_cache_matches_sim():
    cl = core.h100_cluster()
    sim = core.BandwidthSimulator(cl)
    led = JobLedger(cl)
    led.admit("a", [4, 5, 12, 13])
    gc = GradingCache(sim)
    for s in ([0, 1, 8, 9], [0, 1, 2, 3], [16, 17, 24, 25]):
        assert gc.true_bandwidth(s, ledger=led) == \
            sim.true_bandwidth(s, ledger=led)
        assert gc.true_bandwidth(s) == sim.true_bandwidth(s)
    before = gc.true_bandwidth([0, 1, 8, 9], ledger=led)
    led.admit("b", [2, 3, 10, 11])  # version bump: entry must not be served
    after = gc.true_bandwidth([0, 1, 8, 9], ledger=led)
    assert after == sim.true_bandwidth([0, 1, 8, 9], ledger=led)
    assert after < before
    assert gc.stats.cache_hits > 0


# ---------------------------------------------------------------------------
# Golden bit-identity: cache on/off, batched vs sequential PTS, trace replay
# ---------------------------------------------------------------------------

class _PredictOnly:
    """Strips the fused-children protocol off a predictor: pts_search then
    takes the sequential per-round batch path (the pre-PR shape)."""

    def __init__(self, base):
        self.base = base

    def predict(self, subsets):
        return self.base.predict(subsets)


def test_batched_pts_round_identical(stack):
    cl, sim, tables, params = stack
    pred = core.SurrogatePredictor(cl, tables, params)
    rng = np.random.default_rng(4)
    for k in (3, 6, 10):
        avail = sorted(
            rng.choice(cl.n_gpus, size=min(cl.n_gpus, 14), replace=False)
            .tolist()
        )
        fused = search.pts_search(cl, tables, pred, avail, k)
        seq = search.pts_search(cl, tables, _PredictOnly(pred), avail, k)
        assert fused.subset == seq.subset
        assert fused.predicted_bw == seq.predicted_bw


def test_predict_children_matches_predict(stack):
    cl, sim, tables, params = stack
    pred = core.SurrogatePredictor(cl, tables, params)
    rng = np.random.default_rng(5)
    parent = sorted(rng.choice(cl.n_gpus, size=12, replace=False).tolist())
    kids = [parent[:i] + parent[i + 1:] for i in range(len(parent))]
    np.testing.assert_array_equal(
        pred.predict_children(parent), pred.predict(kids)
    )
    # through the contention wrapper, against a live ledger
    led = JobLedger(cl)
    led.admit("t", [g for g in range(cl.n_gpus) if g not in parent][:4])
    wrapped = core.ContentionAwarePredictor(cl, pred, led)
    np.testing.assert_array_equal(
        wrapped.predict_children(parent), wrapped.predict(kids)
    )


def test_cache_on_off_identical_hybrid_search(stack):
    cl, sim, tables, params = stack
    rng = np.random.default_rng(6)
    for factory in (
        lambda: core.SurrogatePredictor(cl, tables, params),
        lambda: core.GroundTruthPredictor(sim),
    ):
        for k in (4, 9):
            avail = sorted(
                rng.choice(cl.n_gpus, size=min(cl.n_gpus, 20),
                           replace=False).tolist()
            )
            led = JobLedger(cl)
            led.admit("t", [g for g in range(cl.n_gpus)
                            if g not in avail][:3])
            plain = core.cached_contention_predictor(
                cl, factory(), led, use_cache=False
            )
            cached = core.cached_contention_predictor(cl, factory(), led)
            r1 = core.hybrid_search(cl, tables, plain, avail, k)
            r2 = core.hybrid_search(cl, tables, cached, avail, k)
            assert r1.subset == r2.subset
            assert r1.predicted_bw == r2.predicted_bw


def _fast_dispatcher(cl, tables, sim, params, fast):
    pred = core.SurrogatePredictor(
        cl, tables, params, vectorized=fast, bucket_shapes=fast
    )
    disp = core.BandPilotDispatcher(cl, tables, pred, cache=fast)
    if not fast:
        disp.contention_predictor.vectorized = False
    return disp


def test_trace_replay_golden_fast_vs_slow(stack):
    """THE acceptance golden: a pinned fifo scheduler trace selects
    byte-identical subsets with the fast path enabled (the new defaults)
    vs fully disabled (the pre-PR configuration)."""
    cl, sim, tables, params = stack
    trace = core.poisson_trace(
        cl, 14, np.random.default_rng(7),
        mean_interarrival=1.0, mean_duration=6.0,
        k_choices=range(4, cl.n_gpus // 2 + 1),
    )
    logs = {}
    recs = {}
    for fast in (True, False):
        disp = _fast_dispatcher(cl, tables, sim, params, fast)
        log = []
        orig = core.BandPilotDispatcher.dispatch

        def wrapped(self, avail, k, rng=None, _log=log):
            s = orig(self, avail, k, rng=rng)
            _log.append(tuple(s))
            return s

        disp.dispatch = wrapped.__get__(disp)
        sched = core.AdmissionScheduler(cl, sim, tables, disp)
        recs[fast] = sched.run(trace)
        logs[fast] = log
    assert logs[True] == logs[False]
    for a, b in zip(recs[True], recs[False]):
        assert (a.job_id, a.t_admit, a.bw, a.gbe) == \
            (b.job_id, b.t_admit, b.bw, b.gbe)


@pytest.mark.slow
def test_trace_replay_golden_learned_mode(stack):
    """Fast-vs-slow byte identity for the learned-contention configuration
    (contended featurizer + learned degradation on the hot path)."""
    cl, sim, tables, params = stack
    cparams = surr.init_contended_params(params)
    trace = core.poisson_trace(
        cl, 10, np.random.default_rng(9), mean_duration=6.0,
        k_choices=range(4, cl.n_gpus // 2 + 1),
    )
    logs = {}
    for fast in (True, False):
        pred = core.SurrogatePredictor(
            cl, tables, params, vectorized=fast, bucket_shapes=fast
        )
        cpred = core.ContendedSurrogatePredictor(
            cl, tables, cparams, vectorized=fast, bucket_shapes=fast
        )
        disp = core.BandPilotDispatcher(
            cl, tables, pred, cache=fast,
            contention_mode="learned", contended_predictor=cpred,
        )
        if not fast:
            disp.contention_predictor.vectorized = False
        log = []
        orig = core.BandPilotDispatcher.dispatch

        def wrapped(self, avail, k, rng=None, _log=log):
            s = orig(self, avail, k, rng=rng)
            _log.append(tuple(s))
            return s

        disp.dispatch = wrapped.__get__(disp)
        core.AdmissionScheduler(cl, sim, tables, disp).run(trace)
        logs[fast] = log
    assert logs[True] == logs[False]


# ---------------------------------------------------------------------------
# Lazy distinct-multiset-permutation generator
# ---------------------------------------------------------------------------

def _check_perms(items):
    want = sorted(set(itertools.permutations(items)))
    got = list(_distinct_permutations(items))
    assert got == want


def test_distinct_permutations_small_cases():
    for items in ([1], [1, 1], [1, 2], [2, 1, 1], [3, 2, 2, 1],
                  [0, 0, 1, 1], [1, 2, 3]):
        _check_perms(items)


@settings(max_examples=40, deadline=None)
@given(items=st.lists(st.integers(min_value=0, max_value=3),
                      min_size=1, max_size=7))
def test_property_distinct_permutations(items):
    _check_perms(items)


@pytest.mark.skipif(HAVE_HYPOTHESIS, reason="hypothesis drives this instead")
def test_seeded_distinct_permutations():
    rng = np.random.default_rng(0)
    for _ in range(30):
        m = int(rng.integers(1, 8))
        _check_perms(rng.integers(0, 4, size=m).tolist())


def test_balanced_counts_large_m_respects_cap_lazily():
    """k=64 over 32 2-GPU hosts (m=32): the old implementation materialized
    32! permutations and never returned; the lazy generator must honour the
    cap quickly."""
    t0 = time.time()
    out = balanced_count_assignments([2] * 32, 48, max_assignments=16)
    assert time.time() - t0 < 5.0
    assert 0 < len(out) <= 16
    for counts in out:
        assert sum(counts) == 48
        assert all(c <= 2 for c in counts)
    # and the exact-fit case: one distinct permutation, returned instantly
    out = balanced_count_assignments([2] * 32, 64)
    assert out == [tuple([2] * 32)]


def test_balanced_counts_matches_old_implementation():
    """Bit-identity of the output stream vs the eager reference on sizes
    the old code could handle."""
    def old(capacities, k, max_assignments=16):
        m = len(capacities)
        base, rem = divmod(k, m)
        shape = [base + 1] * rem + [base] * (m - rem)
        out, seen = [], set()
        for perm in sorted(set(itertools.permutations(shape))):
            counts = list(perm)
            overflow = 0
            for i in range(m):
                if counts[i] > capacities[i]:
                    overflow += counts[i] - capacities[i]
                    counts[i] = capacities[i]
            while overflow > 0:
                heads = [(capacities[i] - counts[i], i) for i in range(m)]
                heads.sort(reverse=True)
                if heads[0][0] <= 0:
                    break
                counts[heads[0][1]] += 1
                overflow -= 1
            if overflow > 0:
                continue
            t = tuple(counts)
            if t not in seen:
                seen.add(t)
                out.append(t)
            if len(out) >= max_assignments:
                break
        return out

    rng = np.random.default_rng(3)
    for _ in range(40):
        m = int(rng.integers(1, 7))
        caps = rng.integers(1, 9, size=m).tolist()
        k = int(rng.integers(1, sum(caps) + 1))
        assert balanced_count_assignments(caps, k) == old(caps, k)


# ---------------------------------------------------------------------------
# Unified instrumentation
# ---------------------------------------------------------------------------

def test_predictor_stats_unified(stack):
    cl, sim, tables, params = stack
    pred = core.SurrogatePredictor(cl, tables, params)
    disp = core.BandPilotDispatcher(cl, tables, pred)
    disp.admit("a", 12)  # k > 8: past the single-host shortcut, so the
    disp.admit("b", 10)  # Stage-2 model actually runs

    st_ = disp.predictor_stats()
    assert st_.n_model_calls > 0
    assert st_.predict_seconds > 0.0
    assert st_.featurize_seconds >= 0.0
    assert st_.infer_seconds > 0.0
    assert st_.cache_hits + st_.cache_misses > 0
    # legacy attribute names stay readable AND writable (benchmarks reset)
    pred.predict_seconds = 0.0
    assert pred.stats.predict_seconds == 0.0
    pred.n_model_calls = 0
    assert pred.stats.n_model_calls == 0
    wrapper = disp.contention_predictor
    wrapper.predict_seconds = 0.0
    assert wrapper.stats.wrapper_seconds == 0.0
    assert PredictorStats.merged(st_, st_).n_model_calls == \
        2 * st_.n_model_calls
    assert 0.0 <= st_.hit_rate <= 1.0
