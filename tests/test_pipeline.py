"""Pipeline-parallelism tests: GPipe schedule == sequential oracle.

The multi-device run executes in a subprocess with 4 forced host devices
(the main pytest process keeps its single-device backend).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.pipeline import bubble_fraction, pipeline_reference


def test_pipeline_reference_matches_manual_fold():
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((3, 8, 8)), jnp.float32)}
    x = jnp.asarray(rng.standard_normal((5, 2, 8)), jnp.float32)

    def stage(p, h):
        return jnp.tanh(h @ p["w"])

    out = pipeline_reference(stage, params, x)
    h = x[0]
    for s in range(3):
        h = jnp.tanh(h @ params["w"][s])
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(h), atol=1e-6)


def test_bubble_fraction():
    assert bubble_fraction(8, 4) == 3 / 11
    assert bubble_fraction(1, 1) == 0.0


_PIPE_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline import pipeline_apply, pipeline_reference

mesh = jax.make_mesh((4,), ("pod",))
rng = np.random.default_rng(0)
N_STAGES, N_MICRO, MB, D = 4, 6, 2, 16
params = {
    "w": jnp.asarray(rng.standard_normal((N_STAGES, D, D)) * 0.5, jnp.float32),
    "b": jnp.asarray(rng.standard_normal((N_STAGES, D)) * 0.1, jnp.float32),
}
x = jnp.asarray(rng.standard_normal((N_MICRO, MB, D)), jnp.float32)

def stage(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])

got = jax.jit(
    lambda pp, xx: pipeline_apply(stage, pp, xx, mesh, axis="pod")
)(params, x)
want = pipeline_reference(stage, params, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
print("PIPE_OK")
"""


def test_pipeline_apply_matches_reference_4stages():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _PIPE_SCRIPT], capture_output=True, text=True,
        env=env, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PIPE_OK" in out.stdout
