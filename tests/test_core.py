"""BandPilot core tests: simulator, tables, oracle, search, dispatchers."""

import itertools

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip, module still collects
    from _hypothesis_fallback import given, settings, st

import repro.core as core
from repro.core import baselines, search
from repro.core.bandwidth_sim import BandwidthSimulator, intra_aggregate_bw
from repro.core.cluster import HOST_TYPES, Cluster, availability_scenario


@pytest.fixture(scope="module")
def h100():
    cl = core.h100_cluster()
    sim = BandwidthSimulator(cl)
    tables = core.IntraHostTables(cl, sim)
    return cl, sim, tables


@pytest.fixture(scope="module")
def mix():
    cl = core.het_4mix_cluster()
    sim = BandwidthSimulator(cl)
    tables = core.IntraHostTables(cl, sim)
    return cl, sim, tables


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------

def test_fig1_reproduction(h100):
    """The paper's headline measurements: balance beats compactness."""
    _, sim, _ = h100
    b44 = sim.true_bandwidth(list(range(0, 4)) + list(range(8, 12)))
    b62 = sim.true_bandwidth(list(range(0, 6)) + list(range(8, 10)))
    b55 = sim.true_bandwidth(list(range(0, 5)) + list(range(8, 13)))
    b82 = sim.true_bandwidth(list(range(0, 8)) + list(range(8, 10)))
    # orderings from Fig. 1
    assert b44 > 2.2 * b62 * 0.8  # 337 vs 153 => ~2.2x (within jitter)
    assert b55 > 2.0 * b82
    # absolute calibration within ~10% of the paper's numbers
    for got, paper in [(b44, 337.17), (b62, 153.44), (b55, 412.49),
                       (b82, 157.30)]:
        assert abs(got - paper) / paper < 0.10, (got, paper)


def test_anti_locality_4090():
    """Fig. 2: on 4090 hosts remote (SYS) pairs beat proximal (PXB) pairs."""
    ht = HOST_TYPES["RTX4090"]
    assert intra_aggregate_bw(ht, (0, 7)) > intra_aggregate_bw(ht, (0, 1))


def test_bandwidth_deterministic(h100):
    _, sim, _ = h100
    s = [0, 1, 8, 9, 16]
    assert sim.true_bandwidth(s) == sim.true_bandwidth(list(reversed(s)))


def test_measurement_noise(h100):
    _, sim, _ = h100
    rng = np.random.default_rng(0)
    vals = {sim.measure([0, 1, 8, 9], rng) for _ in range(5)}
    assert len(vals) > 1  # noisy
    base = sim.true_bandwidth([0, 1, 8, 9])
    assert all(abs(v - base) / base < 0.1 for v in vals)


def test_single_host_beats_cross_host_on_h100(h100):
    _, sim, _ = h100
    single = sim.true_bandwidth(list(range(8)))
    cross = sim.true_bandwidth(list(range(4)) + list(range(8, 12)))
    assert single > cross


# ---------------------------------------------------------------------------
# Stage-1 tables + oracle
# ---------------------------------------------------------------------------

def test_tables_cover_all_combos(h100):
    cl, _, tables = h100
    assert all(len(t) == 255 for t in tables.tables)
    assert tables.storage_bytes() < 100 * 1024  # ~12KB/host claim


def test_oracle_matches_brute_force(mix):
    """Exact count-vector oracle == literal brute force on small pools."""
    cl, sim, tables = mix
    rng = np.random.default_rng(3)
    for trial in range(4):
        avail = sorted(rng.choice(cl.n_gpus, size=12, replace=False).tolist())
        for k in (3, 5):
            s1, bw1 = baselines.oracle_dispatch(cl, sim, tables, avail, k)
            s2, bw2 = baselines.brute_force_oracle(cl, sim, avail, k)
            assert abs(bw1 - bw2) < 1e-9, (trial, k, bw1, bw2)


def test_dispatchers_return_valid_allocations(h100):
    cl, sim, tables = h100
    rng = np.random.default_rng(1)
    avail = availability_scenario(cl, rng, frac_busy=0.3)
    k = min(6, len(avail))
    for fn in [
        lambda: baselines.random_dispatch(cl, avail, k, rng),
        lambda: baselines.default_dispatch(cl, avail, k),
        lambda: baselines.topo_dispatch(cl, avail, k),
    ]:
        sub = fn()
        assert len(sub) == k and len(set(sub)) == k
        assert set(sub) <= set(avail)


def test_topo_prefers_compact_unbalanced(h100):
    """The paper's criticism: Topo picks 6+2 over 4+4 (Fig. 1 scenario)."""
    cl, sim, tables = h100
    avail = list(range(0, 6)) + list(range(8, 14))  # two hosts, 6 idle each
    sub = baselines.topo_dispatch(cl, avail, 8)
    by_host = cl.partition_by_host(sub)
    counts = sorted(len(v) for v in by_host.values())
    assert counts == [2, 6]  # compact-but-unbalanced


def test_eha_finds_balanced_allocation(h100):
    """BandPilot's EHA picks 4+4 in the same scenario and wins on bandwidth."""
    cl, sim, tables = h100
    gt = core.GroundTruthPredictor(sim)
    avail = list(range(0, 6)) + list(range(8, 14))
    res = search.eha_search(cl, tables, gt, avail, 8)
    counts = sorted(
        len(v) for v in cl.partition_by_host(res.subset).values()
    )
    assert counts == [4, 4]
    topo = baselines.topo_dispatch(cl, avail, 8)
    assert sim.true_bandwidth(res.subset) > 1.5 * sim.true_bandwidth(topo)


def test_pts_single_host_pruning(h100):
    cl, sim, tables = h100
    gt = core.GroundTruthPredictor(sim)
    res = search.pts_search(cl, tables, gt, cl.all_gpus(), 4)
    # k<=8 with full hosts available: must land inside one host
    assert len(cl.partition_by_host(res.subset)) == 1


def test_hybrid_beats_or_ties_components(mix):
    cl, sim, tables = mix
    gt = core.GroundTruthPredictor(sim)
    rng = np.random.default_rng(7)
    for _ in range(3):
        avail = availability_scenario(cl, rng, frac_busy=0.25)
        k = min(10, len(avail))
        hyb = search.hybrid_search(cl, tables, gt, avail, k)
        assert hyb.predicted_bw >= max(
            hyb.eha.predicted_bw, hyb.pts.predicted_bw
        ) - 1e-9


@settings(max_examples=15, deadline=None)
@given(k=st.integers(1, 12), seed=st.integers(0, 100))
def test_search_validity_property(k, seed):
    """Property: every search result is a valid k-subset of the pool."""
    cl = core.h100_cluster()
    sim = BandwidthSimulator(cl)
    tables = core.IntraHostTables(cl, sim)
    gt = core.GroundTruthPredictor(sim)
    rng = np.random.default_rng(seed)
    avail = availability_scenario(cl, rng, frac_busy=0.3)
    if len(avail) < k:
        avail = cl.all_gpus()
    res = search.hybrid_search(cl, tables, gt, avail, k)
    assert len(res.subset) == k
    assert len(set(res.subset)) == k
    assert set(res.subset) <= set(avail)


# ---------------------------------------------------------------------------
# End-to-end GBE sanity (Ideal-BP; surrogate-driven numbers live in benches)
# ---------------------------------------------------------------------------

def test_ideal_bp_near_oracle_h100(h100):
    cl, sim, tables = h100
    gt = core.GroundTruthPredictor(sim)
    bp = core.BandPilotDispatcher(cl, tables, gt, name="Ideal-BP")
    ds = [bp, core.BaselineDispatcher(cl, "topo")]
    recs = core.evaluate_dispatchers(
        cl, sim, tables, ds, request_sizes=[6, 10, 14], n_scenarios=6, seed=5
    )
    summ = core.summarize(recs)
    assert summ["Ideal-BP"]["mean_gbe"] > 0.97
    assert summ["Ideal-BP"]["mean_gbe"] > summ["Topo"]["mean_gbe"]
