"""End-to-end system tests: the full BandPilot pipeline and the launchers.

These exercise the integrated flows the examples demonstrate: measure ->
train surrogate -> dispatch -> (train | serve) on dispatched devices, and
the multi-device launcher in a subprocess (so the forced device count never
leaks into this process' jax backend).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import repro.core as core

pytestmark = pytest.mark.slow  # surrogate training + subprocess launchers


def _repo_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return env


def test_full_bandpilot_pipeline_small():
    """measure -> train -> dispatch beats Topo on the Fig.1 scenario."""
    cluster = core.h100_cluster()
    sim = core.BandwidthSimulator(cluster)
    tables = core.IntraHostTables(cluster, sim)
    train, test = core.make_train_test_split(sim, 120, test_mult=2, seed=0)
    params, _ = core.train_surrogate(
        cluster, tables, train, core.TrainConfig(steps=800)
    )
    pred = core.SurrogatePredictor(cluster, tables, params)
    acc = core.evaluate_surrogate(pred, test)
    assert acc["r2"] > 0.9, acc

    bp = core.BandPilotDispatcher(cluster, tables, pred)
    avail = list(range(0, 6)) + list(range(8, 14))
    s_bp = bp.dispatch(avail, 8)
    s_topo = core.BaselineDispatcher(cluster, "topo").dispatch(avail, 8)
    assert sim.true_bandwidth(s_bp) > 1.5 * sim.true_bandwidth(s_topo)


def test_train_launcher_multidevice_subprocess():
    """The real launcher: 8 simulated devices, BandPilot-dispatched mesh,
    a few pjit training steps on a reduced arch."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "gemma-7b", "--reduced", "--steps", "6",
         "--devices", "8", "--mesh", "4x2", "--log-every", "3",
         "--global-batch", "8", "--seq-len", "64"],
        capture_output=True, text=True, env=_repo_env(), timeout=560,
        cwd=os.path.dirname(_repo_env()["PYTHONPATH"]),
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "training complete" in out.stdout
    assert "dispatched devices" in out.stdout
    # loss is finite
    assert "loss=nan" not in out.stdout


def test_serve_launcher_subprocess():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--arch", "rwkv6-7b", "--reduced", "--batch", "2",
         "--max-new", "4", "--max-len", "48"],
        capture_output=True, text=True, env=_repo_env(), timeout=560,
        cwd=os.path.dirname(_repo_env()["PYTHONPATH"]),
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "generated" in out.stdout


def test_dryrun_single_cell_subprocess():
    """The minimum multi-pod contract: one cell lowers + compiles on the
    512-device production meshes (both), in a dedicated process."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "gemma2-9b", "--shape", "decode_32k",
         "--multi-pod", "both"],
        capture_output=True, text=True, env=_repo_env(), timeout=560,
        cwd=os.path.dirname(_repo_env()["PYTHONPATH"]),
    )
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    assert "16x16" in out.stdout and "2x16x16" in out.stdout
    assert "FAILED" not in out.stdout
